//! Offline stub of the `xla` (xla-rs) PJRT surface used by
//! `mergecomp::runtime`.
//!
//! The build image does not ship the PJRT C API or the xla-rs bindings, so
//! this crate provides the exact type/method surface `runtime/step.rs`
//! compiles against. Every entry point fails at `PjRtClient::cpu()` with a
//! clear message; nothing downstream can be reached. The e2e tests skip
//! when `artifacts/` is absent, so the default `cargo test` never hits this
//! path. Swap the `xla` path dependency in `rust/Cargo.toml` for the real
//! bindings to enable the PJRT execution plane — no call sites change.

#![allow(dead_code)]

use std::fmt;

/// Error type mirroring xla-rs's.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT unavailable: this build uses the vendored xla stub (no PJRT C \
         API in the image). Point the `xla` dependency at the real xla-rs \
         bindings to execute AOT artifacts."
            .to_string(),
    ))
}

/// Stub PJRT client; `cpu()` always fails, making all other methods
/// unreachable in practice.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct Literal(());

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    /// Single-element tuple accessor (xla-rs convenience).
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn get_first_element<T: Default>(&self) -> Result<T> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
