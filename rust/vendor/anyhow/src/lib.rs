//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build image has no crates.io access, so the repository vendors the
//! small slice of anyhow it actually uses: [`Error`], [`Result`], and the
//! `anyhow!` / `bail!` / `ensure!` macros. Swap this path dependency for
//! the real crate when building online — no call sites change.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with a human-readable message chain.
///
/// Like the real anyhow::Error, this intentionally does NOT implement
/// `std::error::Error` itself, which is what makes the blanket `From`
/// conversion below coherent.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            inner: Box::new(MessageError(message.to_string())),
        }
    }

    /// Construct from a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error {
            inner: Box::new(error),
        }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error::msg(format!("{context}: {}", self.inner))
    }

    /// The root error as a `std::error::Error` trait object.
    pub fn as_std(&self) -> &(dyn StdError + Send + Sync + 'static) {
        self.inner.as_ref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Match anyhow's unwrap-friendly output: message, then the chain.
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// String-backed error used by `Error::msg` and the macros.
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// Create an [`Error`] from a format string (or a single displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {}", flag);
        Ok(7)
    }

    #[test]
    fn macros_and_conversions() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");

        let io: Result<()> = (|| {
            let _ = std::fs::read("/definitely/not/a/path")?;
            Ok(())
        })();
        assert!(io.is_err());

        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
    }

    #[test]
    fn bail_returns_early() {
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }
}
