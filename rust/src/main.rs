//! `mergecomp` — leader binary for the MergeComp reproduction.
//!
//! Subcommands:
//!   train     run data-parallel training with a compression schedule
//!   simulate  scaling factors on the simulated V100 testbed (Figs. 2/4–6)
//!   search    run Algorithm 2 and print the chosen partition
//!   overhead  per-codec encode/decode cost sweep (Fig. 3)
//!   info      artifact + environment report

use mergecomp::compression::CodecKind;
use mergecomp::config::{ScheduleSpec, TrainConfig};
use mergecomp::netsim::Fabric;
use mergecomp::profiles;
use mergecomp::scheduler::objective::SimObjective;
use mergecomp::scheduler::{mergecomp_search, Partition, SearchParams};
use mergecomp::simulator::{scaling_factor, simulate, OverheadModel, SimSetup};
use mergecomp::util::cli::Args;
use mergecomp::util::{fmt_bytes, fmt_secs};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("search") => cmd_search(&args),
        Some("overhead") => cmd_overhead(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "mergecomp — compression scheduler for distributed training\n\
         \n\
         USAGE: mergecomp <subcommand> [flags]\n\
         \n\
         SUBCOMMANDS\n\
           train     --workers N --codec C --schedule S [--steps K] [--config f.json]\n\
                     [--sched-mode online|warmup|fixed] [--resched-interval K]\n\
                     [--resched-ewma W] [--resched-eps E]\n\
           simulate  --model M --codec C --fabric F --workers a,b,c --schedule S\n\
           search    --model M --codec C --fabric F --workers N [--ymax Y] [--alpha A]\n\
           overhead  --codec C [--sizes 64,1024,...]\n\
           info\n\
         \n\
         CODECS   fp32 fp16 qsgd topk randk dgc signsgd efsignsgd onebit signum terngrad\n\
         MODELS   resnet50-cifar10 resnet50-imagenet resnet101-imagenet maskrcnn transformer\n\
         SCHEDULES layerwise | fullmerge | naive:<y> | mergecomp[:Y[,alpha=a]]\n\
         \n\
         The schedule is resolved online by default: per-group timings feed a\n\
         rolling cost model and Algorithm 2 re-runs every --resched-interval\n\
         steps, repartitioning (EF state preserved bit-exactly) when the\n\
         predicted gain beats --resched-eps. `--schedule online|warmup|fixed`\n\
         is accepted as a shorthand for --sched-mode."
    );
}

fn profile_for(name: &str) -> anyhow::Result<mergecomp::profiles::ModelProfile> {
    Ok(match name {
        "resnet50-cifar10" | "resnet50" => profiles::resnet50_cifar10(),
        "resnet50-imagenet" => profiles::resnet50_imagenet(),
        "resnet101-imagenet" | "resnet101" => profiles::resnet101_imagenet(),
        "maskrcnn" | "maskrcnn-coco" => profiles::maskrcnn_coco(),
        "transformer" => profiles::transformer::transformer_e2e(),
        "transformer-100m" => profiles::transformer::transformer_100m(),
        other => anyhow::bail!("unknown model profile '{other}'"),
    })
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let base = match args.str("config") {
        Some(path) => TrainConfig::from_json(&mergecomp::config::load_json(path)?)?,
        None => TrainConfig::default(),
    };
    let cfg = base.apply_cli(args)?;
    println!(
        "training: {} workers, codec {}, schedule {}, {} steps",
        cfg.workers,
        cfg.codec.name(),
        cfg.schedule.name(),
        cfg.steps
    );
    let result = mergecomp::training::train(&cfg)?;
    println!(
        "partition: {} groups, bounds {:?} ({} search evals, {} online reschedules, epoch {})",
        result.partition.num_groups(),
        result.partition.bounds(),
        result.search_evals,
        result.reschedules,
        result.schedule_epoch
    );
    for r in &result.records {
        println!(
            "  step {:>5}  loss {:.4}  t={:.1}s  exch={}",
            r.step,
            r.loss,
            r.elapsed,
            fmt_secs(r.exchange.total_secs())
        );
    }
    println!(
        "final train loss {:.4}, eval loss {:.4}, mean step {} (+{} exchange), {} sent",
        result.final_train_loss,
        result.eval_loss,
        fmt_secs(result.mean_step_secs),
        fmt_secs(result.mean_exchange.total_secs()),
        fmt_bytes(result.total_bytes_sent as usize)
    );
    if let Some(out) = &cfg.out {
        let mut w = mergecomp::metrics::JsonlWriter::create(out)?;
        w.write(&result.to_json(&cfg))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let profile = profile_for(args.str_or("model", "resnet50-cifar10"))?;
    let kind = CodecKind::from_name(args.str_or("codec", "fp32"))?;
    let fabric = Fabric::from_name(args.str_or("fabric", "pcie"))?;
    let schedule = ScheduleSpec::parse(args.str_or("schedule", "mergecomp"))?;
    let worlds = args.usize_list_or("workers", &[2, 4, 8]);
    let n = profile.num_tensors();

    println!(
        "model {} ({} tensors, {} params), codec {}, fabric {}, schedule {}",
        profile.name,
        n,
        profile.total_params(),
        kind.name(),
        fabric.name,
        schedule.name()
    );
    for world in worlds {
        let setup = SimSetup {
            profile: &profile,
            kind,
            fabric,
            world,
        };
        let mut obj = SimObjective::new(setup);
        let p = schedule.resolve(n, &mut obj);
        let b = simulate(&setup, &p);
        println!(
            "  {world} workers: scaling {:.3}  iter {}  (compute {}, enc {}, dec {}, comm total {}, exposed {}) groups={}",
            scaling_factor(&setup, &p),
            fmt_secs(b.iter_time),
            fmt_secs(b.compute),
            fmt_secs(b.encode_path),
            fmt_secs(b.decode_path),
            fmt_secs(b.comm_total),
            fmt_secs(b.comm_exposed),
            p.num_groups(),
        );
    }
    Ok(())
}

fn cmd_search(args: &Args) -> anyhow::Result<()> {
    let profile = profile_for(args.str_or("model", "resnet101-imagenet"))?;
    let kind = CodecKind::from_name(args.str_or("codec", "efsignsgd"))?;
    let fabric = Fabric::from_name(args.str_or("fabric", "pcie"))?;
    let world = args.usize_or("workers", 8);
    let params = SearchParams {
        y_max: args.usize_or("ymax", 2),
        alpha: args.f64_or("alpha", 0.02),
    };
    let setup = SimSetup {
        profile: &profile,
        kind,
        fabric,
        world,
    };
    let mut obj = SimObjective::new(setup);
    let out = mergecomp_search(&mut obj, profile.num_tensors(), params);
    println!(
        "Algorithm 2 on {} / {} / {} workers / {}:",
        profile.name,
        kind.name(),
        world,
        fabric.name
    );
    for (y, f) in &out.per_y {
        println!("  y={y}: F = {}", fmt_secs(*f));
    }
    println!(
        "chosen: {} groups, bounds {:?}, F = {} ({} evals)",
        out.partition.num_groups(),
        out.partition.bounds(),
        fmt_secs(out.f_min),
        out.evals
    );
    let base = simulate(&setup, &Partition::layer_wise(profile.num_tensors()));
    println!(
        "layer-wise for comparison: {} ({:.2}x slower)",
        fmt_secs(base.iter_time),
        base.iter_time / out.f_min
    );
    Ok(())
}

fn cmd_overhead(args: &Args) -> anyhow::Result<()> {
    let kinds: Vec<CodecKind> = match args.str_list("codec") {
        Some(names) => names
            .iter()
            .map(|n| CodecKind::from_name(n))
            .collect::<anyhow::Result<_>>()?,
        None => CodecKind::paper_set(),
    };
    let sizes = args.usize_list_or(
        "sizes",
        &[1 << 6, 1 << 10, 1 << 14, 1 << 18, 1 << 20, 1 << 24],
    );
    println!(
        "{:<12} {:>12} {:>14} {:>14}",
        "codec", "elems", "encode(model)", "decode(model)"
    );
    for kind in kinds {
        let m = OverheadModel::for_codec(kind);
        for &n in &sizes {
            println!(
                "{:<12} {:>12} {:>14} {:>14}",
                kind.name(),
                n,
                fmt_secs(m.encode.time(n)),
                fmt_secs(m.decode.time(n))
            );
        }
    }
    Ok(())
}

fn cmd_info(_args: &Args) -> anyhow::Result<()> {
    println!(
        "mergecomp {} — MergeComp reproduction",
        env!("CARGO_PKG_VERSION")
    );
    for art in [
        "artifacts/train_step.hlo.txt",
        "artifacts/train_step_pallas.hlo.txt",
        "artifacts/sign_compress.hlo.txt",
        "artifacts/meta.json",
    ] {
        let status = match std::fs::metadata(art) {
            Ok(m) => fmt_bytes(m.len() as usize),
            Err(_) => "MISSING (run `make artifacts`)".to_string(),
        };
        println!("  {art}: {status}");
    }
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "  PJRT: platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    Ok(())
}
