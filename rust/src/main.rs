//! `mergecomp` — leader binary for the MergeComp reproduction.
//!
//! Subcommands:
//!   train     run data-parallel training with a compression schedule
//!             (--transport tcp turns this process into ONE rank of a
//!             multi-process group — the worker mode)
//!   launch    spawn W local `train --transport tcp` worker processes over
//!             loopback and assert their results agree (CI's smoke path)
//!   simulate  scaling factors on the simulated V100 testbed (Figs. 2/4–6)
//!   search    run Algorithm 2 and print the chosen partition
//!   overhead  per-codec encode/decode cost sweep (Fig. 3)
//!   info      artifact + environment report

use mergecomp::compression::CodecKind;
use mergecomp::config::{ScheduleSpec, TrainConfig};
use mergecomp::netsim::Fabric;
use mergecomp::profiles;
use mergecomp::scheduler::objective::SimObjective;
use mergecomp::scheduler::{mergecomp_search, Partition, SearchParams};
use mergecomp::simulator::{scaling_factor, simulate, OverheadModel, SimSetup};
use mergecomp::util::cli::Args;
use mergecomp::util::{fmt_bytes, fmt_secs};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("launch") => cmd_launch(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("search") => cmd_search(&args),
        Some("overhead") => cmd_overhead(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "mergecomp — compression scheduler for distributed training\n\
         \n\
         USAGE: mergecomp <subcommand> [flags]\n\
         \n\
         SUBCOMMANDS\n\
           train     --workers N --codec C --schedule S [--steps K] [--config f.json]\n\
                     [--sched-mode online|warmup|fixed] [--resched-interval K]\n\
                     [--resched-ewma W] [--resched-eps E]\n\
                     [--topology flat|nodes=G|nodes=a+b+...[;racks=...]]\n\
                     [--route auto|flat|hierarchical]  (auto: Algorithm 2 picks\n\
                      flat vs hierarchical per tensor group from the live fits)\n\
                     [--codec auto] [--codec-mode auto|fixed] [--codec-switch-cost S]\n\
                      (auto: Algorithm 2 also picks each group's codec from a\n\
                      pool — fp32 always included — using microcalibrated fits;\n\
                      online scheduling only)\n\
                     [--exchange-mode full|sharded]  (sharded: reduce-scatter +\n\
                      parameter allgather; each rank keeps 1/world of the\n\
                      optimizer state, bit-identical results — DESIGN.md)\n\
                     [--accum-steps N]  (average N micro-batch gradients\n\
                      locally before each exchange+update)\n\
                     [--transport inproc|tcp --rank N --world W\n\
                      --rendezvous HOST:PORT [--advertise HOST]\n\
                      [--bootstrap-timeout-secs S]]\n\
                     [--synthetic [PROFILE]]   (no PJRT needed; CI smoke path)\n\
                     [--policy f.json|'{{...}}']  (typed run policy: elastic,\n\
                      checkpointing, fault injection — see DESIGN.md)\n\
                     [--elastic] [--checkpoint-dir D] [--checkpoint-interval K]\n\
                     [--resume] [--faults SPEC] [--die-at-step K --die-rank R]\n\
                      (shorthands over --policy; SPEC grammar e.g.\n\
                      rank=2,delay=2ms,jitter=1ms,rate=65536/100ms,drop-after=40)\n\
                     [--join] [--rejoin-wait-secs S]  (hot re-join: --join marks\n\
                      this process a replacement for a dead rank; survivors wait\n\
                      S seconds at the re-rendezvous before shrinking instead —\n\
                      DESIGN.md \"Online join\")\n\
           launch    --workers N [--rendezvous HOST:PORT] [--out-dir D]\n\
                     [--timeout-secs S] [--expect-dead R1,R2] [--rejoin R1,R2]\n\
                     + any train flags\n\
                     (forwarded to all ranks; --topology nodes=G maps the local\n\
                     processes onto G synthetic nodes; --expect-dead excludes\n\
                     chaos-killed ranks from the aggregate verdict; --rejoin\n\
                     respawns a dead rank once with --join so it streams back\n\
                     into the live group)\n\
           simulate  --model M --codec C --fabric F --workers a,b,c --schedule S\n\
           search    --model M --codec C --fabric F --workers N [--ymax Y] [--alpha A]\n\
           overhead  --codec C [--sizes 64,1024,...]\n\
           info\n\
         \n\
         CODECS   fp32 fp16 qsgd topk randk dgc signsgd efsignsgd onebit signum terngrad\n\
         MODELS   resnet50-cifar10 resnet50-imagenet resnet101-imagenet maskrcnn transformer\n\
         SCHEDULES layerwise | fullmerge | naive:<y> | mergecomp[:Y[,alpha=a]]\n\
         \n\
         The schedule is resolved online by default: per-group timings feed a\n\
         rolling cost model and Algorithm 2 re-runs every --resched-interval\n\
         steps, repartitioning (EF state preserved bit-exactly) when the\n\
         predicted gain beats --resched-eps. `--schedule online|warmup|fixed`\n\
         is accepted as a shorthand for --sched-mode."
    );
}

fn profile_for(name: &str) -> anyhow::Result<mergecomp::profiles::ModelProfile> {
    profiles::by_name(name)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let base = match args.str("config") {
        Some(path) => TrainConfig::from_json(&mergecomp::config::load_json(path)?)?,
        None => TrainConfig::default(),
    };
    let cfg = base.apply_cli(args)?;
    println!(
        "training: {} workers ({} transport{}, topology {}), codec {}, schedule {}, {} steps{}",
        cfg.workers,
        cfg.transport.name(),
        if cfg.transport == mergecomp::collectives::TransportKind::Tcp {
            format!(", this process is rank {}", cfg.rank)
        } else {
            String::new()
        },
        cfg.topology.name(),
        if cfg.codec_mode == mergecomp::scheduler::CodecMode::Auto {
            format!("auto (base {})", cfg.codec.name())
        } else {
            cfg.codec.name().to_string()
        },
        cfg.schedule.name(),
        cfg.steps,
        cfg.synthetic
            .as_deref()
            .map(|p| format!(", synthetic source '{p}'"))
            .unwrap_or_default()
    );
    let result = mergecomp::training::train(&cfg)?;
    // The digest line is the cross-process agreement contract: `launch`
    // (and the CI smoke job) compare it across ranks.
    println!("rank {} param digest {:016x}", result.rank, result.param_digest);
    if let Some(s) = result.resumed_from_step {
        println!("rank {} resumed from a checkpoint at step {s}", result.rank);
    }
    if result.recoveries > 0 {
        println!(
            "rank {} survived {} elastic recover{}; finished at world size {}",
            result.rank,
            result.recoveries,
            if result.recoveries == 1 { "y" } else { "ies" },
            result.world_at_end
        );
    }
    if result.joins > 0 {
        println!(
            "rank {} took part in {} hot re-join{}; finished at world size {}",
            result.rank,
            result.joins,
            if result.joins == 1 { "" } else { "s" },
            result.world_at_end
        );
    }
    if result.rank == 0 {
        println!(
            "partition: {} groups, bounds {:?} ({} search evals, {} online reschedules, epoch {})",
            result.partition.num_groups(),
            result.partition.bounds(),
            result.search_evals,
            result.reschedules,
            result.schedule_epoch
        );
        if !result.final_routes.is_empty() {
            let routes: Vec<&str> = result.final_routes.iter().map(|r| r.name()).collect();
            println!("routes: [{}]", routes.join(", "));
        }
        if result.final_codecs.iter().any(|&k| k != cfg.codec) {
            let codecs: Vec<&str> = result.final_codecs.iter().map(|k| k.name()).collect();
            println!("codecs: [{}]", codecs.join(", "));
        }
        if let Some(tl) = result.two_level_fit {
            println!(
                "per-level comm fits: intra b={:.3e} g={:.3e}, inter b={:.3e} g={:.3e} \
                 (inter dominates at 1M elems: {})",
                tl.intra.b,
                tl.intra.g,
                tl.inter.b,
                tl.inter.g,
                tl.inter_dominates(1 << 20)
            );
        }
        for r in &result.records {
            println!(
                "  step {:>5}  loss {:.4}  t={:.1}s  exch={}",
                r.step,
                r.loss,
                r.elapsed,
                fmt_secs(r.exchange.total_secs())
            );
        }
        println!(
            "final train loss {:.4}, eval loss {:.4}, mean step {} (+{} exchange), {} sent",
            result.final_train_loss,
            result.eval_loss,
            fmt_secs(result.mean_step_secs),
            fmt_secs(result.mean_exchange.total_secs()),
            fmt_bytes(result.total_bytes_sent as usize)
        );
    }
    if let Some(out) = &cfg.out {
        let mut w = mergecomp::metrics::JsonlWriter::create(out)?;
        w.write(&result.to_json(&cfg))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Spawn W local `train --transport tcp` processes over loopback, wait for
/// them, and fail unless every rank exited 0 with the same param digest.
fn cmd_launch(args: &Args) -> anyhow::Result<()> {
    let world = args.usize_or("workers", args.usize_or("world", 4));
    let out_dir = args.str_or("out-dir", "results/launch");
    // Flags owned by the launcher itself; everything else is forwarded to
    // the worker `train` invocations verbatim.
    const LAUNCHER_FLAGS: &[&str] = &[
        "workers",
        "world",
        "out-dir",
        "timeout-secs",
        "rendezvous",
        "transport",
        "rank",
        "out",
        "expect-dead",
        "rejoin",
    ];
    // Chaos runs: ranks listed here are expected to die mid-run (pair with
    // the forwarded --elastic/--die-at-step/--die-rank train flags); the
    // aggregate verdict is computed over the survivors.
    let parse_ranks = |flag: &str| -> anyhow::Result<Vec<usize>> {
        match args.str(flag) {
            Some(list) => list
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|e| anyhow::anyhow!("--{flag} '{s}': {e}"))
                })
                .collect::<anyhow::Result<_>>(),
            None => Ok(Vec::new()),
        }
    };
    let expect_dead = parse_ranks("expect-dead")?;
    // Hot re-join: ranks listed here are respawned once with --join when
    // they die; the replacement's result stands in for the rank.
    let rejoin = parse_ranks("rejoin")?;
    let mut train_flags = Vec::new();
    for (k, v) in &args.flags {
        if LAUNCHER_FLAGS.contains(&k.as_str()) {
            continue;
        }
        train_flags.push(format!("--{k}"));
        train_flags.push(v.clone());
    }
    let opts = mergecomp::training::LaunchOptions {
        binary: std::env::current_exe()
            .map_err(|e| anyhow::anyhow!("locating own binary: {e}"))?,
        world,
        rendezvous: args.str("rendezvous").map(String::from),
        out_dir: out_dir.into(),
        train_flags,
        timeout: std::time::Duration::from_secs(args.u64_or("timeout-secs", 600)),
        expect_dead,
        rejoin,
    };
    if let Some(t) = args.str("topology") {
        // Forwarded verbatim to every worker: the launcher maps the local
        // process group onto the synthetic nodes the spec describes.
        println!("topology: {t} (each worker derives its node from its rank)");
    }
    println!("launching {world} local TCP workers (results in {out_dir}/)");
    let report = mergecomp::training::launch_local(&opts)?;
    println!("rendezvous: {}", report.rendezvous);
    for r in &report.ranks {
        println!(
            "  rank {}: exit {:?}  digest {}  ({})",
            r.rank,
            r.exit_code,
            r.param_digest.as_deref().unwrap_or("-"),
            r.log_path.display()
        );
    }
    anyhow::ensure!(
        report.all_exited_zero,
        "not every surviving rank exited 0 — see the per-rank logs in {out_dir}/"
    );
    anyhow::ensure!(
        report.digests_match,
        "param digests diverged across surviving ranks — transport bug, see {out_dir}/"
    );
    println!("all surviving ranks ({world} launched) exited 0 with identical param digests");
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let profile = profile_for(args.str_or("model", "resnet50-cifar10"))?;
    let kind = CodecKind::from_name(args.str_or("codec", "fp32"))?;
    let fabric = Fabric::from_name(args.str_or("fabric", "pcie"))?;
    let schedule = ScheduleSpec::parse(args.str_or("schedule", "mergecomp"))?;
    let worlds = args.usize_list_or("workers", &[2, 4, 8]);
    let n = profile.num_tensors();

    println!(
        "model {} ({} tensors, {} params), codec {}, fabric {}, schedule {}",
        profile.name,
        n,
        profile.total_params(),
        kind.name(),
        fabric.name,
        schedule.name()
    );
    for world in worlds {
        let setup = SimSetup {
            profile: &profile,
            kind,
            fabric,
            world,
        };
        let mut obj = SimObjective::new(setup);
        let p = schedule.resolve(n, &mut obj);
        let b = simulate(&setup, &p);
        println!(
            "  {world} workers: scaling {:.3}  iter {}  (compute {}, enc {}, dec {}, comm total {}, exposed {}) groups={}",
            scaling_factor(&setup, &p),
            fmt_secs(b.iter_time),
            fmt_secs(b.compute),
            fmt_secs(b.encode_path),
            fmt_secs(b.decode_path),
            fmt_secs(b.comm_total),
            fmt_secs(b.comm_exposed),
            p.num_groups(),
        );
    }
    Ok(())
}

fn cmd_search(args: &Args) -> anyhow::Result<()> {
    let profile = profile_for(args.str_or("model", "resnet101-imagenet"))?;
    let kind = CodecKind::from_name(args.str_or("codec", "efsignsgd"))?;
    let fabric = Fabric::from_name(args.str_or("fabric", "pcie"))?;
    let world = args.usize_or("workers", 8);
    let params = SearchParams {
        y_max: args.usize_or("ymax", 2),
        alpha: args.f64_or("alpha", 0.02),
    };
    let setup = SimSetup {
        profile: &profile,
        kind,
        fabric,
        world,
    };
    let mut obj = SimObjective::new(setup);
    let out = mergecomp_search(&mut obj, profile.num_tensors(), params);
    println!(
        "Algorithm 2 on {} / {} / {} workers / {}:",
        profile.name,
        kind.name(),
        world,
        fabric.name
    );
    for (y, f) in &out.per_y {
        println!("  y={y}: F = {}", fmt_secs(*f));
    }
    println!(
        "chosen: {} groups, bounds {:?}, F = {} ({} evals)",
        out.partition.num_groups(),
        out.partition.bounds(),
        fmt_secs(out.f_min),
        out.evals
    );
    let base = simulate(&setup, &Partition::layer_wise(profile.num_tensors()));
    println!(
        "layer-wise for comparison: {} ({:.2}x slower)",
        fmt_secs(base.iter_time),
        base.iter_time / out.f_min
    );
    Ok(())
}

fn cmd_overhead(args: &Args) -> anyhow::Result<()> {
    let kinds: Vec<CodecKind> = match args.str_list("codec") {
        Some(names) => names
            .iter()
            .map(|n| CodecKind::from_name(n))
            .collect::<anyhow::Result<_>>()?,
        None => CodecKind::paper_set(),
    };
    let sizes = args.usize_list_or(
        "sizes",
        &[1 << 6, 1 << 10, 1 << 14, 1 << 18, 1 << 20, 1 << 24],
    );
    println!(
        "{:<12} {:>12} {:>14} {:>14}",
        "codec", "elems", "encode(model)", "decode(model)"
    );
    for kind in kinds {
        let m = OverheadModel::for_codec(kind);
        for &n in &sizes {
            println!(
                "{:<12} {:>12} {:>14} {:>14}",
                kind.name(),
                n,
                fmt_secs(m.encode.time(n)),
                fmt_secs(m.decode.time(n))
            );
        }
    }
    Ok(())
}

fn cmd_info(_args: &Args) -> anyhow::Result<()> {
    println!(
        "mergecomp {} — MergeComp reproduction",
        env!("CARGO_PKG_VERSION")
    );
    for art in [
        "artifacts/train_step.hlo.txt",
        "artifacts/train_step_pallas.hlo.txt",
        "artifacts/sign_compress.hlo.txt",
        "artifacts/meta.json",
    ] {
        let status = match std::fs::metadata(art) {
            Ok(m) => fmt_bytes(m.len() as usize),
            Err(_) => "MISSING (run `make artifacts`)".to_string(),
        };
        println!("  {art}: {status}");
    }
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "  PJRT: platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    Ok(())
}
