//! Training-run configuration for the real execution plane.

use super::{RunPolicy, ScheduleSpec, SchedulingMode};
use crate::collectives::{TopologySpec, TransportKind};
use crate::compression::CodecKind;
use crate::coordinator::{ExchangeMode, PipelineMode};
use crate::scheduler::{CodecMode, RouteMode};
use crate::util::cli::Args;
use crate::util::json::Value;

/// Configuration of one data-parallel training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Number of data-parallel workers. With `--transport inproc` they are
    /// threads in this process; with `--transport tcp` this is the world
    /// size and each worker is a separate OS process (`--rank N` selects
    /// which rank this process is). `--world` is accepted as an alias.
    pub workers: usize,
    /// Which transport the collectives run over.
    pub transport: TransportKind,
    /// Cluster topology (`--topology flat|nodes=G|nodes=a+b+…`). Non-flat
    /// topologies route the gradient collectives through the two-level
    /// (intra-node / inter-node) exchange; every rank must be launched
    /// with the same value (the TCP bootstrap cross-checks node labels).
    pub topology: TopologySpec,
    /// Collective-route policy on a non-flat topology
    /// (`--route auto|flat|hierarchical`). `Auto` lets Algorithm 2 pick
    /// flat vs hierarchical per tensor group from the fitted per-level
    /// costs (online scheduling only); the forced modes pin every group.
    /// Ignored under `--topology flat`.
    pub route: RouteMode,
    /// This process's rank (TCP transport only; inproc spawns all ranks).
    pub rank: usize,
    /// Rendezvous address: rank 0 listens, every other rank dials.
    pub rendezvous: String,
    /// Host this rank binds/advertises its data listener on — must be
    /// routable from the other ranks (loopback for single-machine runs).
    pub advertise_host: String,
    /// Budget for the TCP rendezvous + mesh formation (seconds) — raise it
    /// when ranks are started by hand on different machines.
    pub bootstrap_timeout_secs: u64,
    /// Synthetic step source: run the trainer against deterministic
    /// profile-shaped gradients instead of the PJRT artifact (no XLA
    /// needed — what CI's multi-process smoke run uses). The value names
    /// the model profile ("tiny", "resnet50-cifar10", …).
    pub synthetic: Option<String>,
    /// Optimization steps to run.
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub codec: CodecKind,
    /// Codec-selection policy (`--codec auto` or `--codec-mode auto|fixed`).
    /// `Auto` puts the codec on Algorithm 2's search axes: the online
    /// scheduler prices every group under each pool codec (FP32 always
    /// included) and the schedule broadcast carries one codec per group.
    /// `Fixed` (default) pins every group to `codec`. Online MergeComp
    /// scheduling only; other modes ignore it.
    pub codec_mode: CodecMode,
    /// Predicted-seconds penalty the objective charges a candidate group
    /// whose codec differs from any spanned tensor's current codec —
    /// dampens codec thrash on top of the relative hysteresis ε.
    pub codec_switch_cost: f64,
    pub schedule: ScheduleSpec,
    /// When the schedule is resolved: continuously (`Online`, via the
    /// scheduler driver), once from warmup (`Warmup`), or never measured
    /// (`Fixed`, static specs only). `--schedule online|warmup|fixed` is
    /// accepted as a shorthand for `--sched-mode`.
    pub sched_mode: SchedulingMode,
    /// Steps between online reschedule attempts.
    pub resched_interval: usize,
    /// Weight of each new timing sample in the rolling cost fits, (0, 1].
    pub resched_ewma: f64,
    /// Hysteresis ε: repartition only when the predicted relative gain
    /// exceeds this fraction.
    pub resched_eps: f64,
    /// Exchange-engine scheduling: `Pipelined` overlaps each group's
    /// collective with neighbouring groups' encode/decode (bit-identical
    /// results; see `coordinator/`).
    pub pipeline: PipelineMode,
    /// Gradient-distribution mode (`--exchange-mode full|sharded`). `Full`
    /// leaves every rank with the full averaged gradient and full optimizer
    /// state; `Sharded` runs reduce-scatter + parameter allgather so each
    /// rank holds only its 1/world shard of optimizer state (DESIGN.md
    /// "Sharded exchange"). Bit-identical final parameters either way.
    pub exchange_mode: ExchangeMode,
    /// Gradient accumulation: average `accum_steps` micro-batch gradients
    /// locally before each exchange+update (`--accum-steps N`). 1 (the
    /// default) is exactly the legacy single-micro-step behavior.
    pub accum_steps: usize,
    pub seed: u64,
    /// Per-worker batch size (must match the AOT-compiled step artifact).
    pub batch_per_worker: usize,
    pub seq_len: usize,
    /// Path to the AOT-lowered train-step HLO text.
    pub artifact: String,
    /// Emit a loss record every `log_every` steps.
    pub log_every: usize,
    /// Warm-up steps used by the measured-objective schedule search.
    pub search_steps: usize,
    /// Optional JSONL output path for per-step records.
    pub out: Option<String>,
    /// Recovery/fault policy: checkpointing, elastic degraded-world
    /// continuation, restore, and fault injection. Set wholesale with
    /// `--policy <json|path>` or field-by-field with the shorthand flags
    /// (`--elastic`, `--checkpoint-dir`, `--checkpoint-interval`,
    /// `--resume`, `--faults`, `--die-at-step`, `--die-rank`).
    pub policy: RunPolicy,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            transport: TransportKind::InProc,
            topology: TopologySpec::Flat,
            route: RouteMode::Auto,
            rank: 0,
            rendezvous: "127.0.0.1:29500".to_string(),
            advertise_host: "127.0.0.1".to_string(),
            bootstrap_timeout_secs: 60,
            synthetic: None,
            steps: 200,
            lr: 0.05,
            momentum: 0.9,
            codec: CodecKind::Fp32,
            codec_mode: CodecMode::Fixed,
            codec_switch_cost: 0.0,
            schedule: ScheduleSpec::MergeComp { y_max: 2, alpha: 0.02 },
            sched_mode: SchedulingMode::Online,
            resched_interval: 25,
            resched_ewma: 0.1,
            resched_eps: 0.05,
            pipeline: PipelineMode::Pipelined,
            exchange_mode: ExchangeMode::Full,
            accum_steps: 1,
            seed: 42,
            batch_per_worker: 8,
            seq_len: 128,
            artifact: "artifacts/train_step.hlo.txt".to_string(),
            log_every: 10,
            search_steps: 3,
            out: None,
            policy: RunPolicy::default(),
        }
    }
}

impl TrainConfig {
    /// Load from a JSON object (missing keys keep defaults).
    pub fn from_json(v: &Value) -> anyhow::Result<TrainConfig> {
        let d = TrainConfig::default();
        // `"codec": "auto"` is sugar for codec_mode=auto with the default
        // base codec (an explicit `codec_mode` key still wins below).
        let codec_raw = v.str_or("codec", "fp32");
        let codec_is_auto = codec_raw.eq_ignore_ascii_case("auto");
        let codec = if codec_is_auto { d.codec } else { CodecKind::from_name(codec_raw)? };
        let codec_mode = CodecMode::from_name(v.str_or(
            "codec_mode",
            if codec_is_auto { "auto" } else { d.codec_mode.name() },
        ))?;
        Ok(TrainConfig {
            workers: v.usize_or("workers", d.workers),
            transport: TransportKind::from_name(v.str_or("transport", d.transport.name()))?,
            topology: TopologySpec::parse(v.str_or("topology", &d.topology.name()))?,
            route: RouteMode::from_name(v.str_or("route", d.route.name()))?,
            rank: v.usize_or("rank", d.rank),
            rendezvous: v.str_or("rendezvous", &d.rendezvous).to_string(),
            advertise_host: v.str_or("advertise_host", &d.advertise_host).to_string(),
            bootstrap_timeout_secs: v.usize_or(
                "bootstrap_timeout_secs",
                d.bootstrap_timeout_secs as usize,
            ) as u64,
            synthetic: v.get("synthetic").and_then(Value::as_str).map(String::from),
            steps: v.usize_or("steps", d.steps),
            lr: v.f64_or("lr", d.lr as f64) as f32,
            momentum: v.f64_or("momentum", d.momentum as f64) as f32,
            codec,
            codec_mode,
            codec_switch_cost: v.f64_or("codec_switch_cost", d.codec_switch_cost),
            schedule: ScheduleSpec::parse(v.str_or("schedule", "mergecomp"))?,
            sched_mode: SchedulingMode::from_name(v.str_or("sched_mode", d.sched_mode.name()))?,
            resched_interval: v.usize_or("resched_interval", d.resched_interval),
            resched_ewma: v.f64_or("resched_ewma", d.resched_ewma),
            resched_eps: v.f64_or("resched_eps", d.resched_eps),
            pipeline: PipelineMode::from_name(v.str_or("pipeline", d.pipeline.name()))?,
            exchange_mode: ExchangeMode::from_name(
                v.str_or("exchange_mode", d.exchange_mode.name()),
            )?,
            accum_steps: v.usize_or("accum_steps", d.accum_steps),
            seed: v.f64_or("seed", d.seed as f64) as u64,
            batch_per_worker: v.usize_or("batch_per_worker", d.batch_per_worker),
            seq_len: v.usize_or("seq_len", d.seq_len),
            artifact: v.str_or("artifact", &d.artifact).to_string(),
            log_every: v.usize_or("log_every", d.log_every),
            search_steps: v.usize_or("search_steps", d.search_steps),
            out: v.get("out").and_then(Value::as_str).map(String::from),
            policy: match v.get("policy") {
                Some(p) => RunPolicy::from_json(p)?,
                None => d.policy,
            },
        })
    }

    /// Apply CLI overrides (`--workers 4 --codec dgc --schedule layerwise …`).
    pub fn apply_cli(mut self, args: &Args) -> anyhow::Result<TrainConfig> {
        // `--world` is the launcher-facing alias; `--workers` wins if both
        // are given.
        if let Some(w) = args.usize("world") {
            self.workers = w;
        }
        self.workers = args.usize_or("workers", self.workers);
        if let Some(t) = args.str("transport") {
            self.transport = TransportKind::from_name(t)?;
        }
        if let Some(t) = args.str("topology") {
            self.topology = TopologySpec::parse(t)?;
        }
        if let Some(r) = args.str("route") {
            self.route = RouteMode::from_name(r)?;
        }
        self.rank = args.usize_or("rank", self.rank);
        if let Some(r) = args.str("rendezvous") {
            self.rendezvous = r.to_string();
        }
        if let Some(a) = args.str("advertise") {
            self.advertise_host = a.to_string();
        }
        self.bootstrap_timeout_secs =
            args.u64_or("bootstrap-timeout-secs", self.bootstrap_timeout_secs);
        if let Some(s) = args.str("synthetic") {
            // Bare `--synthetic` selects the tiny profile.
            self.synthetic = Some(if s == "true" { "tiny".to_string() } else { s.to_string() });
        }
        self.steps = args.usize_or("steps", self.steps);
        self.lr = args.f64_or("lr", self.lr as f64) as f32;
        self.momentum = args.f64_or("momentum", self.momentum as f64) as f32;
        if let Some(c) = args.str("codec") {
            // `--codec auto` flips the selection policy and keeps the
            // configured base codec; any other value pins a codec.
            if c.eq_ignore_ascii_case("auto") {
                self.codec_mode = CodecMode::Auto;
            } else {
                self.codec = CodecKind::from_name(c)?;
            }
        }
        if let Some(m) = args.str("codec-mode") {
            self.codec_mode = CodecMode::from_name(m)?;
        }
        self.codec_switch_cost = args.f64_or("codec-switch-cost", self.codec_switch_cost);
        if let Some(s) = args.str("schedule") {
            // `--schedule online|warmup|fixed` selects the scheduling mode
            // (the ISSUE-facing shorthand); anything else is a partition
            // strategy spec.
            match SchedulingMode::from_name(s) {
                Ok(mode) => self.sched_mode = mode,
                Err(_) => self.schedule = ScheduleSpec::parse(s)?,
            }
        }
        if let Some(m) = args.str("sched-mode") {
            self.sched_mode = SchedulingMode::from_name(m)?;
        }
        self.resched_interval = args.usize_or("resched-interval", self.resched_interval);
        self.resched_ewma = args.f64_or("resched-ewma", self.resched_ewma);
        self.resched_eps = args.f64_or("resched-eps", self.resched_eps);
        if let Some(p) = args.str("pipeline") {
            self.pipeline = PipelineMode::from_name(p)?;
        }
        if let Some(m) = args.str("exchange-mode") {
            self.exchange_mode = ExchangeMode::from_name(m)?;
        }
        self.accum_steps = args.usize_or("accum-steps", self.accum_steps);
        anyhow::ensure!(self.accum_steps >= 1, "--accum-steps must be >= 1");
        self.seed = args.u64_or("seed", self.seed);
        self.log_every = args.usize_or("log-every", self.log_every);
        self.search_steps = args.usize_or("search-steps", self.search_steps);
        if let Some(a) = args.str("artifact") {
            self.artifact = a.to_string();
        }
        if let Some(o) = args.str("out") {
            self.out = Some(o.to_string());
        }
        self.policy = self.policy.apply_cli(args)?;
        Ok(self)
    }

    pub fn to_json(&self) -> Value {
        Value::from_pairs(vec![
            ("workers", Value::from(self.workers)),
            ("transport", Value::from(self.transport.name())),
            ("topology", Value::from(self.topology.name())),
            ("route", Value::from(self.route.name())),
            ("rank", Value::from(self.rank)),
            ("rendezvous", Value::from(self.rendezvous.clone())),
            ("advertise_host", Value::from(self.advertise_host.clone())),
            ("bootstrap_timeout_secs", Value::from(self.bootstrap_timeout_secs)),
            (
                "synthetic",
                self.synthetic.clone().map(Value::from).unwrap_or(Value::Null),
            ),
            ("steps", Value::from(self.steps)),
            ("lr", Value::from(self.lr as f64)),
            ("momentum", Value::from(self.momentum as f64)),
            ("codec", Value::from(self.codec.name())),
            ("codec_mode", Value::from(self.codec_mode.name())),
            ("codec_switch_cost", Value::from(self.codec_switch_cost)),
            ("schedule", Value::from(self.schedule.name())),
            ("sched_mode", Value::from(self.sched_mode.name())),
            ("resched_interval", Value::from(self.resched_interval)),
            ("resched_ewma", Value::from(self.resched_ewma)),
            ("resched_eps", Value::from(self.resched_eps)),
            ("pipeline", Value::from(self.pipeline.name())),
            ("exchange_mode", Value::from(self.exchange_mode.name())),
            ("accum_steps", Value::from(self.accum_steps)),
            ("seed", Value::from(self.seed)),
            ("batch_per_worker", Value::from(self.batch_per_worker)),
            ("seq_len", Value::from(self.seq_len)),
            ("artifact", Value::from(self.artifact.clone())),
            ("log_every", Value::from(self.log_every)),
            ("search_steps", Value::from(self.search_steps)),
            ("policy", self.policy.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip_json() {
        let c = TrainConfig::default();
        let j = c.to_json();
        let c2 = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c2.workers, c.workers);
        assert_eq!(c2.codec, c.codec);
        assert_eq!(c2.schedule, c.schedule);
        assert_eq!(c2.pipeline, c.pipeline);
        assert_eq!(c2.lr, c.lr);
    }

    #[test]
    fn json_partial_override() {
        let v = Value::parse(r#"{"workers": 8, "codec": "dgc"}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.workers, 8);
        assert_eq!(c.codec.name(), "dgc");
        assert_eq!(c.steps, TrainConfig::default().steps);
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            ["x", "--workers", "4", "--schedule", "naive:3", "--lr", "0.5"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = TrainConfig::default().apply_cli(&args).unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.schedule, ScheduleSpec::NaiveEven { y: 3 });
        assert_eq!(c.lr, 0.5);
    }

    #[test]
    fn pipeline_mode_overrides() {
        assert_eq!(TrainConfig::default().pipeline, PipelineMode::Pipelined);
        let v = Value::parse(r#"{"pipeline": "serial"}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.pipeline, PipelineMode::Serial);
        let args = Args::parse(
            ["x", "--pipeline", "pipelined"].iter().map(|s| s.to_string()),
        );
        let c = c.apply_cli(&args).unwrap();
        assert_eq!(c.pipeline, PipelineMode::Pipelined);
        let v = Value::parse(r#"{"pipeline": "bogus"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
    }

    #[test]
    fn exchange_mode_and_accum_overrides() {
        let d = TrainConfig::default();
        assert_eq!(d.exchange_mode, ExchangeMode::Full);
        assert_eq!(d.accum_steps, 1);
        let c = TrainConfig::from_json(&d.to_json()).unwrap();
        assert_eq!(c.exchange_mode, ExchangeMode::Full);
        assert_eq!(c.accum_steps, 1);

        let v = Value::parse(r#"{"exchange_mode": "sharded", "accum_steps": 4}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.exchange_mode, ExchangeMode::Sharded);
        assert_eq!(c.accum_steps, 4);
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.exchange_mode, ExchangeMode::Sharded);
        assert_eq!(c2.accum_steps, 4);

        let args = Args::parse(
            ["x", "--exchange-mode", "full", "--accum-steps", "2"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = c.apply_cli(&args).unwrap();
        assert_eq!(c.exchange_mode, ExchangeMode::Full);
        assert_eq!(c.accum_steps, 2);

        let args = Args::parse(
            ["x", "--exchange-mode", "mirrored"].iter().map(|s| s.to_string()),
        );
        assert!(TrainConfig::default().apply_cli(&args).is_err());
        let args = Args::parse(["x", "--accum-steps", "0"].iter().map(|s| s.to_string()));
        assert!(TrainConfig::default().apply_cli(&args).is_err());
        let v = Value::parse(r#"{"exchange_mode": "bogus"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
    }

    #[test]
    fn transport_fields_roundtrip_and_cli_override() {
        let d = TrainConfig::default();
        assert_eq!(d.transport, TransportKind::InProc);
        assert_eq!(d.rank, 0);
        assert!(d.synthetic.is_none());
        let j = d.to_json();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.transport, d.transport);
        assert_eq!(c.rendezvous, d.rendezvous);
        assert!(c.synthetic.is_none());

        let args = Args::parse(
            [
                "x",
                "--transport",
                "tcp",
                "--rank",
                "2",
                "--world",
                "4",
                "--rendezvous",
                "127.0.0.1:4242",
                "--synthetic",
                "tiny",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let c = TrainConfig::default().apply_cli(&args).unwrap();
        assert_eq!(c.transport, TransportKind::Tcp);
        assert_eq!(c.rank, 2);
        assert_eq!(c.workers, 4);
        assert_eq!(c.rendezvous, "127.0.0.1:4242");
        assert_eq!(c.synthetic.as_deref(), Some("tiny"));

        // Bare `--synthetic` (boolean form) selects the tiny profile.
        let args = Args::parse(["x", "--synthetic"].iter().map(|s| s.to_string()));
        let c = TrainConfig::default().apply_cli(&args).unwrap();
        assert_eq!(c.synthetic.as_deref(), Some("tiny"));

        let args = Args::parse(
            ["x", "--transport", "smoke-signals"].iter().map(|s| s.to_string()),
        );
        assert!(TrainConfig::default().apply_cli(&args).is_err());
    }

    #[test]
    fn topology_roundtrips_json_and_cli() {
        let d = TrainConfig::default();
        assert_eq!(d.topology, TopologySpec::Flat);
        let j = d.to_json();
        assert_eq!(TrainConfig::from_json(&j).unwrap().topology, TopologySpec::Flat);

        let v = Value::parse(r#"{"topology": "nodes=4+2"}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.topology, TopologySpec::Sized(vec![4, 2]));
        let j = c.to_json();
        assert_eq!(
            TrainConfig::from_json(&j).unwrap().topology,
            TopologySpec::Sized(vec![4, 2])
        );

        let args =
            Args::parse(["x", "--topology", "nodes=2"].iter().map(|s| s.to_string()));
        let c = TrainConfig::default().apply_cli(&args).unwrap();
        assert_eq!(c.topology, TopologySpec::Nodes(2));

        let args =
            Args::parse(["x", "--topology", "mesh"].iter().map(|s| s.to_string()));
        assert!(TrainConfig::default().apply_cli(&args).is_err());
        let v = Value::parse(r#"{"topology": "nodes=0"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
    }

    #[test]
    fn route_mode_roundtrips_json_and_cli() {
        let d = TrainConfig::default();
        assert_eq!(d.route, RouteMode::Auto);
        let j = d.to_json();
        assert_eq!(TrainConfig::from_json(&j).unwrap().route, RouteMode::Auto);

        let v = Value::parse(r#"{"route": "hierarchical"}"#).unwrap();
        assert_eq!(TrainConfig::from_json(&v).unwrap().route, RouteMode::Hierarchical);

        let args = Args::parse(["x", "--route", "flat"].iter().map(|s| s.to_string()));
        let c = TrainConfig::default().apply_cli(&args).unwrap();
        assert_eq!(c.route, RouteMode::Flat);

        let args = Args::parse(["x", "--route", "scenic"].iter().map(|s| s.to_string()));
        assert!(TrainConfig::default().apply_cli(&args).is_err());
        let v = Value::parse(r#"{"route": "scenic"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
    }

    #[test]
    fn codec_auto_selects_mode_not_codec() {
        let d = TrainConfig::default();
        assert_eq!(d.codec_mode, CodecMode::Fixed);
        assert_eq!(d.codec_switch_cost, 0.0);

        // CLI: `--codec auto` flips the mode, leaves the base codec alone.
        let args = Args::parse(
            ["x", "--codec", "auto", "--codec-switch-cost", "0.01"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = TrainConfig { codec: CodecKind::EfSignSgd, ..TrainConfig::default() }
            .apply_cli(&args)
            .unwrap();
        assert_eq!(c.codec_mode, CodecMode::Auto);
        assert_eq!(c.codec, CodecKind::EfSignSgd);
        assert_eq!(c.codec_switch_cost, 0.01);

        // JSON sugar + roundtrip through to_json.
        let v = Value::parse(r#"{"codec": "auto"}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.codec_mode, CodecMode::Auto);
        assert_eq!(c.codec, CodecKind::Fp32);
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.codec_mode, CodecMode::Auto);
        assert_eq!(c2.codec, CodecKind::Fp32);

        // Explicit codec-mode knob, and a pinned codec alongside auto mode.
        let args = Args::parse(
            ["x", "--codec", "efsignsgd", "--codec-mode", "auto"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = TrainConfig::default().apply_cli(&args).unwrap();
        assert_eq!(c.codec, CodecKind::EfSignSgd);
        assert_eq!(c.codec_mode, CodecMode::Auto);

        let v = Value::parse(r#"{"codec_mode": "sometimes"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
    }

    #[test]
    fn bad_codec_rejected() {
        let v = Value::parse(r#"{"codec": "zip"}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
    }

    #[test]
    fn online_knobs_roundtrip_and_default() {
        let d = TrainConfig::default();
        assert_eq!(d.sched_mode, SchedulingMode::Online);
        let j = d.to_json();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.sched_mode, d.sched_mode);
        assert_eq!(c.resched_interval, d.resched_interval);
        assert_eq!(c.resched_ewma, d.resched_ewma);
        assert_eq!(c.resched_eps, d.resched_eps);

        let v = Value::parse(
            r#"{"sched_mode": "warmup", "resched_interval": 7, "resched_eps": 0.2}"#,
        )
        .unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.sched_mode, SchedulingMode::Warmup);
        assert_eq!(c.resched_interval, 7);
        assert_eq!(c.resched_eps, 0.2);
    }

    #[test]
    fn schedule_flag_doubles_as_mode_shorthand() {
        // `--schedule online` flips the mode, leaving the spec untouched.
        let args = Args::parse(["x", "--schedule", "online"].iter().map(|s| s.to_string()));
        let c = TrainConfig {
            sched_mode: SchedulingMode::Fixed,
            ..TrainConfig::default()
        };
        let c = c.apply_cli(&args).unwrap();
        assert_eq!(c.sched_mode, SchedulingMode::Online);
        assert_eq!(c.schedule, TrainConfig::default().schedule);

        // A strategy spec still parses as before.
        let args = Args::parse(["x", "--schedule", "naive:4"].iter().map(|s| s.to_string()));
        let c = TrainConfig::default().apply_cli(&args).unwrap();
        assert_eq!(c.schedule, ScheduleSpec::NaiveEven { y: 4 });

        // Dedicated knobs.
        let args = Args::parse(
            [
                "x",
                "--sched-mode",
                "fixed",
                "--resched-interval",
                "11",
                "--resched-ewma",
                "0.5",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let c = TrainConfig::default().apply_cli(&args).unwrap();
        assert_eq!(c.sched_mode, SchedulingMode::Fixed);
        assert_eq!(c.resched_interval, 11);
        assert_eq!(c.resched_ewma, 0.5);
    }

    #[test]
    fn policy_roundtrips_and_takes_cli() {
        // Default policy is inert and survives the JSON round trip.
        let d = TrainConfig::default();
        assert_eq!(d.policy, RunPolicy::default());
        let c = TrainConfig::from_json(&d.to_json()).unwrap();
        assert_eq!(c.policy, RunPolicy::default());

        // A nested policy object loads and round-trips through to_json.
        let v = Value::parse(
            r#"{"policy": {"elastic": true, "checkpoint_dir": "ck", "checkpoint_interval": 9}}"#,
        )
        .unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert!(c.policy.elastic);
        assert_eq!(c.policy.checkpoint_dir.as_deref(), Some("ck"));
        assert_eq!(c.policy.checkpoint_interval, 9);
        let c2 = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.policy, c.policy);

        // Shorthand flags reach the nested policy through apply_cli.
        let args = Args::parse(
            ["x", "--elastic", "--checkpoint-dir", "out/ck", "--die-at-step", "30"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = TrainConfig::default().apply_cli(&args).unwrap();
        assert!(c.policy.elastic);
        assert_eq!(c.policy.checkpoint_dir.as_deref(), Some("out/ck"));
        assert_eq!(c.policy.die_at_step, Some(30));

        // Invalid nested policy fails the config load.
        let v = Value::parse(r#"{"policy": {"resume": true}}"#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
    }
}
