//! Typed configuration for experiments, training runs and the simulator —
//! loaded from JSON files (util::json; serde is unavailable offline) with
//! CLI-flag overrides applied on top.

mod policy;
mod schedule;
mod train;

pub use policy::{RunPolicy, RunPolicyBuilder};
pub use schedule::{ScheduleSpec, SchedulingMode};
pub use train::TrainConfig;

use crate::util::json::Value;
use std::path::Path;

/// Read and parse a JSON config file.
pub fn load_json(path: impl AsRef<Path>) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
    Value::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.as_ref().display()))
}
