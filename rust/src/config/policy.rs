//! [`RunPolicy`] — the typed recovery/fault policy of a training run.
//!
//! Everything elastic about a run lives here, separated from the model/
//! schedule knobs of [`TrainConfig`](super::TrainConfig): checkpointing
//! (where, how often), elastic degraded-world continuation, restore, fault
//! injection, and the deterministic kill switch the chaos tests use. One
//! `--policy <json|path>` flag sets the whole policy; the individual flags
//! (`--elastic`, `--checkpoint-dir`, `--checkpoint-interval`, `--resume`,
//! `--faults`, `--die-at-step`, `--die-rank`) remain shorthands layered on
//! top of it.

use crate::collectives::FaultPlan;
use crate::util::cli::Args;
use crate::util::json::Value;

/// Recovery/fault policy of one training run. Build with
/// [`RunPolicy::builder`] (library callers) or `--policy` / the shorthand
/// flags (CLI); `default()` is the fully-inert policy — no checkpoints, no
/// elasticity, no faults.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunPolicy {
    /// Continue at world−1 when a peer dies mid-run (degraded-world
    /// continuation) instead of failing the step. Off: any peer failure is
    /// fatal, as before.
    pub elastic: bool,
    /// Directory for per-rank snapshots (`ckpt-rank<N>.json`). `None`
    /// disables checkpointing entirely.
    pub checkpoint_dir: Option<String>,
    /// Steps between periodic snapshots; 0 writes only the emergency
    /// snapshot taken when a peer failure is detected. Requires
    /// `checkpoint_dir`.
    pub checkpoint_interval: usize,
    /// Restore from `checkpoint_dir` at startup and continue from the
    /// snapshotted step (synthetic step source only — resume needs the
    /// deterministic gradient stream).
    pub resume: bool,
    /// On-wire fault plan spec (see [`FaultPlan::parse`] for the grammar),
    /// injected below this rank's transport. Validated at build time.
    pub faults: Option<String>,
    /// Deterministic kill switch: the rank selected by `die_rank` calls
    /// `std::process::abort()` at the start of this step — a hard kill with
    /// no cleanup, as close to SIGKILL as a process can do to itself. The
    /// chaos tests use it to stage mid-run rank loss reproducibly.
    /// Ignored when `join` is set, so a respawned replacement for the dead
    /// rank does not immediately re-die at the same step.
    pub die_at_step: Option<usize>,
    /// Which rank `die_at_step` kills (default 0).
    pub die_rank: usize,
    /// This process is a *replacement* for a dead rank: instead of running
    /// the normal bootstrap-and-train path it re-HELLOs into the live
    /// group's re-rendezvous, receives the replicated state streamed by
    /// rank 0, merges it with its own last interval checkpoint (EF/codec
    /// planes, sharded velocity), and resumes mid-run (DESIGN.md "Online
    /// join"). Requires `checkpoint_dir` and the TCP transport; mutually
    /// exclusive with `resume`.
    pub join: bool,
    /// How long (seconds) survivors of a peer loss wait at the
    /// re-rendezvous for a replacement rank before giving up and falling
    /// back to the elastic shrink path. 0 (default) disables hot re-join
    /// entirely — peer loss always shrinks the world.
    pub rejoin_wait_secs: u64,
}

impl RunPolicy {
    pub fn builder() -> RunPolicyBuilder {
        RunPolicyBuilder { policy: RunPolicy::default() }
    }

    /// The parsed fault plan, if any (the spec was validated at build /
    /// parse time, so this only fails on a hand-constructed policy).
    pub fn fault_plan(&self) -> anyhow::Result<Option<FaultPlan>> {
        match &self.faults {
            Some(s) if !s.trim().is_empty() => Ok(Some(FaultPlan::parse(s)?)),
            _ => Ok(None),
        }
    }

    /// Cross-field validation (what [`RunPolicyBuilder::build`] and the
    /// config loaders enforce).
    pub fn validate(&self) -> anyhow::Result<()> {
        if let Some(s) = &self.faults {
            FaultPlan::parse(s)?;
        }
        anyhow::ensure!(
            self.checkpoint_interval == 0 || self.checkpoint_dir.is_some(),
            "checkpoint_interval {} needs a checkpoint_dir",
            self.checkpoint_interval
        );
        anyhow::ensure!(
            !self.resume || self.checkpoint_dir.is_some(),
            "resume needs a checkpoint_dir to restore from"
        );
        anyhow::ensure!(
            !self.join || self.checkpoint_dir.is_some(),
            "join needs a checkpoint_dir: the joiner restores its rank-local \
             EF/codec (and sharded velocity) planes from its own interval checkpoint"
        );
        anyhow::ensure!(
            !(self.join && self.resume),
            "join and resume are mutually exclusive: a joiner's restore point \
             comes from the live group's snapshot stream, not from disk alone"
        );
        Ok(())
    }

    /// Load from a JSON object (missing keys keep the inert defaults);
    /// validates cross-field constraints.
    pub fn from_json(v: &Value) -> anyhow::Result<RunPolicy> {
        let d = RunPolicy::default();
        let policy = RunPolicy {
            elastic: v.bool_or("elastic", d.elastic),
            checkpoint_dir: v.get("checkpoint_dir").and_then(Value::as_str).map(String::from),
            checkpoint_interval: v.usize_or("checkpoint_interval", d.checkpoint_interval),
            resume: v.bool_or("resume", d.resume),
            faults: v.get("faults").and_then(Value::as_str).map(String::from),
            die_at_step: v.get("die_at_step").and_then(Value::as_usize),
            die_rank: v.usize_or("die_rank", d.die_rank),
            join: v.bool_or("join", d.join),
            rejoin_wait_secs: v.usize_or("rejoin_wait_secs", d.rejoin_wait_secs as usize) as u64,
        };
        policy.validate()?;
        Ok(policy)
    }

    pub fn to_json(&self) -> Value {
        Value::from_pairs(vec![
            ("elastic", Value::from(self.elastic)),
            (
                "checkpoint_dir",
                self.checkpoint_dir.clone().map(Value::from).unwrap_or(Value::Null),
            ),
            ("checkpoint_interval", Value::from(self.checkpoint_interval)),
            ("resume", Value::from(self.resume)),
            ("faults", self.faults.clone().map(Value::from).unwrap_or(Value::Null)),
            (
                "die_at_step",
                self.die_at_step.map(Value::from).unwrap_or(Value::Null),
            ),
            ("die_rank", Value::from(self.die_rank)),
            ("join", Value::from(self.join)),
            ("rejoin_wait_secs", Value::from(self.rejoin_wait_secs as usize)),
        ])
    }

    /// Apply CLI overrides. `--policy <json|path>` replaces the whole
    /// policy first (an inline value starts with `{`; anything else is a
    /// file path); the shorthand flags then override individual fields.
    pub fn apply_cli(mut self, args: &Args) -> anyhow::Result<RunPolicy> {
        if let Some(p) = args.str("policy") {
            let v = if p.trim_start().starts_with('{') {
                Value::parse(p).map_err(|e| anyhow::anyhow!("--policy inline JSON: {e}"))?
            } else {
                super::load_json(p)?
            };
            self = RunPolicy::from_json(&v)?;
        }
        if args.str("elastic").is_some() {
            self.elastic = args.bool("elastic");
        }
        if let Some(d) = args.str("checkpoint-dir") {
            self.checkpoint_dir = Some(d.to_string());
        }
        if let Some(i) = args.usize("checkpoint-interval") {
            self.checkpoint_interval = i;
        }
        if args.str("resume").is_some() {
            self.resume = args.bool("resume");
        }
        if let Some(f) = args.str("faults") {
            self.faults = Some(f.to_string());
        }
        if let Some(s) = args.usize("die-at-step") {
            self.die_at_step = Some(s);
        }
        self.die_rank = args.usize_or("die-rank", self.die_rank);
        if args.str("join").is_some() {
            self.join = args.bool("join");
        }
        if let Some(w) = args.usize("rejoin-wait-secs") {
            self.rejoin_wait_secs = w as u64;
        }
        self.validate()?;
        Ok(self)
    }
}

/// Fluent constructor for [`RunPolicy`]; [`RunPolicyBuilder::build`]
/// validates the assembled policy.
pub struct RunPolicyBuilder {
    policy: RunPolicy,
}

impl RunPolicyBuilder {
    pub fn elastic(mut self, on: bool) -> Self {
        self.policy.elastic = on;
        self
    }

    pub fn checkpoint_dir(mut self, dir: impl Into<String>) -> Self {
        self.policy.checkpoint_dir = Some(dir.into());
        self
    }

    pub fn checkpoint_interval(mut self, steps: usize) -> Self {
        self.policy.checkpoint_interval = steps;
        self
    }

    pub fn resume(mut self, on: bool) -> Self {
        self.policy.resume = on;
        self
    }

    pub fn faults(mut self, spec: impl Into<String>) -> Self {
        self.policy.faults = Some(spec.into());
        self
    }

    pub fn die_at_step(mut self, step: usize, rank: usize) -> Self {
        self.policy.die_at_step = Some(step);
        self.policy.die_rank = rank;
        self
    }

    pub fn join(mut self, on: bool) -> Self {
        self.policy.join = on;
        self
    }

    pub fn rejoin_wait_secs(mut self, secs: u64) -> Self {
        self.policy.rejoin_wait_secs = secs;
        self
    }

    pub fn build(self) -> anyhow::Result<RunPolicy> {
        self.policy.validate()?;
        Ok(self.policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_inert_and_roundtrips() {
        let p = RunPolicy::default();
        assert!(!p.elastic && !p.resume && p.checkpoint_dir.is_none());
        assert!(p.fault_plan().unwrap().is_none());
        let back = RunPolicy::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn builder_builds_and_validates() {
        let p = RunPolicy::builder()
            .elastic(true)
            .checkpoint_dir("ckpts")
            .checkpoint_interval(25)
            .faults("rank=2,delay=2ms")
            .die_at_step(30, 2)
            .build()
            .unwrap();
        assert!(p.elastic);
        assert_eq!(p.checkpoint_dir.as_deref(), Some("ckpts"));
        assert_eq!(p.checkpoint_interval, 25);
        assert_eq!(p.die_at_step, Some(30));
        assert_eq!(p.die_rank, 2);
        let plan = p.fault_plan().unwrap().unwrap();
        assert_eq!(plan.rank, Some(2));

        // Interval without a dir, resume without a dir, junk fault specs:
        // all rejected at build time.
        assert!(RunPolicy::builder().checkpoint_interval(5).build().is_err());
        assert!(RunPolicy::builder().resume(true).build().is_err());
        assert!(
            RunPolicy::builder().faults("warp=9").build().is_err(),
            "fault spec must be validated at build time"
        );
        // Join without a dir, and join+resume together, are rejected too.
        assert!(RunPolicy::builder().join(true).build().is_err());
        assert!(RunPolicy::builder()
            .checkpoint_dir("ck")
            .join(true)
            .resume(true)
            .build()
            .is_err());
        let p = RunPolicy::builder()
            .checkpoint_dir("ck")
            .checkpoint_interval(1)
            .join(true)
            .rejoin_wait_secs(30)
            .build()
            .unwrap();
        assert!(p.join);
        assert_eq!(p.rejoin_wait_secs, 30);
    }

    #[test]
    fn json_roundtrips_full_policy() {
        let p = RunPolicy::builder()
            .elastic(true)
            .checkpoint_dir("out/ck")
            .checkpoint_interval(10)
            .resume(true)
            .faults("delay=1ms")
            .die_at_step(7, 1)
            .rejoin_wait_secs(45)
            .build()
            .unwrap();
        let back = RunPolicy::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        // Malformed embedded fault spec fails the load.
        let mut v = p.to_json();
        v.set("faults", Value::from("rate=0"));
        assert!(RunPolicy::from_json(&v).is_err());
    }

    #[test]
    fn cli_policy_flag_and_shorthands() {
        // Inline --policy JSON replaces the policy wholesale.
        let args = Args::parse(
            ["x", "--policy", r#"{"elastic": true, "checkpoint_dir": "ck"}"#]
                .iter()
                .map(|s| s.to_string()),
        );
        let p = RunPolicy::default().apply_cli(&args).unwrap();
        assert!(p.elastic);
        assert_eq!(p.checkpoint_dir.as_deref(), Some("ck"));

        // Shorthands override on top of --policy.
        let args = Args::parse(
            [
                "x",
                "--policy",
                r#"{"elastic": true}"#,
                "--elastic",
                "false",
                "--checkpoint-dir",
                "other",
                "--checkpoint-interval",
                "5",
                "--die-at-step",
                "12",
                "--die-rank",
                "3",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let p = RunPolicy::default().apply_cli(&args).unwrap();
        assert!(!p.elastic);
        assert_eq!(p.checkpoint_dir.as_deref(), Some("other"));
        assert_eq!(p.checkpoint_interval, 5);
        assert_eq!(p.die_at_step, Some(12));
        assert_eq!(p.die_rank, 3);

        // Bare --elastic is boolean-true; bad inline JSON is an error.
        let args = Args::parse(["x", "--elastic"].iter().map(|s| s.to_string()));
        assert!(RunPolicy::default().apply_cli(&args).unwrap().elastic);
        let args = Args::parse(["x", "--policy", "{oops"].iter().map(|s| s.to_string()));
        assert!(RunPolicy::default().apply_cli(&args).is_err());
    }
}
