//! Compression-schedule specification: which partitioning strategy the
//! coordinator applies (paper §5 Methods compares all four), and *when* the
//! trainer resolves it ([`SchedulingMode`]).

use crate::scheduler::{Partition, SearchParams};

/// When the partition schedule is resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulingMode {
    /// Measure continuously and re-run the search every `resched_interval`
    /// steps via the scheduler driver (`scheduler::driver`). The default:
    /// the schedule tracks the deployed system instead of a one-shot
    /// calibration.
    #[default]
    Online,
    /// Legacy one-shot path: fit costs from warmup measurements, search
    /// once, never revisit.
    Warmup,
    /// Never measure or search: the spec must be a static strategy
    /// (layerwise / fullmerge / naive), resolved up front.
    Fixed,
}

impl SchedulingMode {
    pub fn from_name(name: &str) -> anyhow::Result<SchedulingMode> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "online" => SchedulingMode::Online,
            "warmup" | "warm-up" | "oneshot" => SchedulingMode::Warmup,
            "fixed" | "static" => SchedulingMode::Fixed,
            other => anyhow::bail!("unknown scheduling mode '{other}' (online|warmup|fixed)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulingMode::Online => "online",
            SchedulingMode::Warmup => "warmup",
            SchedulingMode::Fixed => "fixed",
        }
    }
}

/// How to partition the model's gradient tensors into compression groups.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScheduleSpec {
    /// One group per tensor — the framework status quo the paper profiles.
    LayerWise,
    /// One group for the whole model (no WFBP overlap).
    FullMerge,
    /// Evenly split the tensor count into `y` groups (paper Table 3).
    NaiveEven { y: usize },
    /// MergeComp's Algorithm-2 search.
    MergeComp { y_max: usize, alpha: f64 },
}

impl ScheduleSpec {
    /// Parse `layerwise | fullmerge | naive:<y> | mergecomp[:Y[,alpha]]`.
    pub fn parse(s: &str) -> anyhow::Result<ScheduleSpec> {
        let lower = s.to_ascii_lowercase();
        let (head, rest) = match lower.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (lower.as_str(), None),
        };
        Ok(match head {
            "layerwise" | "layer-wise" => ScheduleSpec::LayerWise,
            "fullmerge" | "full-merge" | "merged" => ScheduleSpec::FullMerge,
            "naive" => {
                let y = rest
                    .ok_or_else(|| anyhow::anyhow!("naive:<y> requires a group count"))?
                    .parse()?;
                ScheduleSpec::NaiveEven { y }
            }
            "mergecomp" => {
                let mut y_max = 2usize;
                let mut alpha = 0.02f64;
                if let Some(r) = rest {
                    for part in r.split(',') {
                        if let Some((k, v)) = part.split_once('=') {
                            match k {
                                "y" | "y_max" => y_max = v.parse()?,
                                "alpha" => alpha = v.parse()?,
                                other => anyhow::bail!("unknown mergecomp param '{other}'"),
                            }
                        } else if !part.is_empty() {
                            y_max = part.parse()?;
                        }
                    }
                }
                ScheduleSpec::MergeComp { y_max, alpha }
            }
            other => anyhow::bail!(
                "unknown schedule '{other}' (layerwise|fullmerge|naive:<y>|mergecomp[:Y[,alpha=a]])"
            ),
        })
    }

    pub fn name(&self) -> String {
        match self {
            ScheduleSpec::LayerWise => "layerwise".into(),
            ScheduleSpec::FullMerge => "fullmerge".into(),
            ScheduleSpec::NaiveEven { y } => format!("naive:{y}"),
            ScheduleSpec::MergeComp { y_max, alpha } => {
                format!("mergecomp:{y_max},alpha={alpha}")
            }
        }
    }

    /// Resolve to a concrete partition. Static strategies resolve directly;
    /// MergeComp runs Algorithm 2 against the supplied objective.
    pub fn resolve(
        &self,
        n_tensors: usize,
        objective: &mut dyn crate::scheduler::objective::Objective,
    ) -> Partition {
        match *self {
            ScheduleSpec::LayerWise => Partition::layer_wise(n_tensors),
            ScheduleSpec::FullMerge => Partition::full_merge(n_tensors),
            ScheduleSpec::NaiveEven { y } => Partition::naive_even(n_tensors, y),
            ScheduleSpec::MergeComp { y_max, alpha } => {
                crate::scheduler::mergecomp_search(
                    objective,
                    n_tensors,
                    SearchParams { y_max, alpha },
                )
                .partition
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::objective::MeasuredObjective;

    #[test]
    fn parse_forms() {
        assert_eq!(ScheduleSpec::parse("layerwise").unwrap(), ScheduleSpec::LayerWise);
        assert_eq!(ScheduleSpec::parse("FullMerge").unwrap(), ScheduleSpec::FullMerge);
        assert_eq!(
            ScheduleSpec::parse("naive:3").unwrap(),
            ScheduleSpec::NaiveEven { y: 3 }
        );
        assert_eq!(
            ScheduleSpec::parse("mergecomp").unwrap(),
            ScheduleSpec::MergeComp { y_max: 2, alpha: 0.02 }
        );
        assert_eq!(
            ScheduleSpec::parse("mergecomp:3").unwrap(),
            ScheduleSpec::MergeComp { y_max: 3, alpha: 0.02 }
        );
        assert_eq!(
            ScheduleSpec::parse("mergecomp:y=4,alpha=0.1").unwrap(),
            ScheduleSpec::MergeComp { y_max: 4, alpha: 0.1 }
        );
        assert!(ScheduleSpec::parse("naive").is_err());
        assert!(ScheduleSpec::parse("zigzag").is_err());
    }

    #[test]
    fn resolve_static_strategies() {
        let mut obj = MeasuredObjective::new(|_: &Partition| 0.0);
        let p = ScheduleSpec::LayerWise.resolve(7, &mut obj);
        assert_eq!(p.num_groups(), 7);
        let p = ScheduleSpec::NaiveEven { y: 2 }.resolve(7, &mut obj);
        assert_eq!(p.num_groups(), 2);
        let p = ScheduleSpec::FullMerge.resolve(7, &mut obj);
        assert_eq!(p.num_groups(), 1);
    }

    #[test]
    fn name_roundtrip() {
        for s in ["layerwise", "fullmerge", "naive:2"] {
            let spec = ScheduleSpec::parse(s).unwrap();
            assert_eq!(ScheduleSpec::parse(&spec.name()).unwrap(), spec);
        }
    }

    #[test]
    fn scheduling_mode_roundtrip() {
        for m in [
            SchedulingMode::Online,
            SchedulingMode::Warmup,
            SchedulingMode::Fixed,
        ] {
            assert_eq!(SchedulingMode::from_name(m.name()).unwrap(), m);
        }
        assert_eq!(SchedulingMode::from_name("static").unwrap(), SchedulingMode::Fixed);
        assert!(SchedulingMode::from_name("sometimes").is_err());
        assert_eq!(SchedulingMode::default(), SchedulingMode::Online);
    }
}
