//! Local multi-process launcher: spawn W `mergecomp train --transport tcp`
//! worker processes over loopback and aggregate their results.
//!
//! This is the zero-to-multi-process path for one machine (CI's
//! `multiproc-smoke` job and `examples/tcp_multiproc.rs` both go through
//! it); multi-machine runs start the same `train` command by hand/SSH with
//! `--rendezvous` pointing at rank 0's host (see EXPERIMENTS.md).
//!
//! Aggregation contract: every rank writes its [`RunResult`] JSON to
//! `<out_dir>/rank<N>.json`; the launcher asserts that (a) every rank
//! exited 0 and (b) every rank's `param_digest` equals rank 0's —
//! synchronous SGD over a correct transport cannot produce anything else.
//!
//! Topology: `--topology nodes=G` (like every unrecognized flag) is
//! forwarded verbatim to all workers, which maps the local process group
//! onto `G` synthetic nodes — each rank derives its node from its rank, so
//! one machine can rehearse the full two-level collective path (the
//! rendezvous TABLE's node labels are cross-checked by every worker).
//!
//! [`RunResult`]: super::RunResult

use super::trainer::RESULT_SCHEMA_VERSION;
use crate::config::load_json;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

/// What to launch.
#[derive(Debug, Clone)]
pub struct LaunchOptions {
    /// The `mergecomp` binary to spawn (usually `std::env::current_exe()`).
    pub binary: PathBuf,
    /// Number of worker processes (TCP world size).
    pub world: usize,
    /// Rendezvous address; `None` picks a free loopback port.
    pub rendezvous: Option<String>,
    /// Directory for per-rank JSON results and log files (created).
    pub out_dir: PathBuf,
    /// Extra flags forwarded verbatim to every `train` invocation
    /// (e.g. `["--codec", "efsignsgd", "--steps", "5"]`).
    pub train_flags: Vec<String>,
    /// Kill the whole group after this budget.
    pub timeout: Duration,
    /// Ranks expected to die mid-run (chaos runs: `--die-at-step` under
    /// `--elastic`). Their exit codes and missing results do not fail the
    /// aggregate verdict; `all_exited_zero` and `digests_match` are
    /// computed over the survivors only.
    pub expect_dead: Vec<usize>,
    /// Ranks to respawn **once** with `--join` (and a bumped
    /// `MERGECOMP_GENERATION`) if they exit nonzero mid-run — the
    /// supervisor half of the hot re-join protocol. The replacement's
    /// exit code and result stand in for the rank in the aggregate
    /// verdict, so a rejoined rank must finish 0 with a matching digest
    /// (do not also list it in `expect_dead`).
    pub rejoin: Vec<usize>,
}

/// One worker process's fate.
#[derive(Debug, Clone)]
pub struct RankOutcome {
    pub rank: usize,
    /// Exit code; `None` if the process was killed (timeout/signal).
    pub exit_code: Option<i32>,
    /// The `"schema"` field of the rank's JSON result, if it exited 0
    /// (`None` for pre-versioning outputs).
    pub schema: Option<u64>,
    /// `param_digest` parsed from the rank's JSON result, if it exited 0.
    pub param_digest: Option<String>,
    pub out_path: PathBuf,
    pub log_path: PathBuf,
}

/// Aggregated verdict of one launch.
#[derive(Debug, Clone)]
pub struct LaunchReport {
    pub world: usize,
    pub rendezvous: String,
    pub ranks: Vec<RankOutcome>,
    /// Every rank not listed in `expect_dead` exited 0.
    pub all_exited_zero: bool,
    /// True iff every surviving rank's digest is present and equal to the
    /// first survivor's.
    pub digests_match: bool,
}

impl LaunchReport {
    pub fn ok(&self) -> bool {
        self.all_exited_zero && self.digests_match
    }
}

/// Bind-and-release a loopback port for the rendezvous. The tiny window
/// before rank 0 re-binds it is tolerable on a single machine (ephemeral
/// ports are not reused that fast), and peers retry their dials anyway.
pub fn free_loopback_port() -> anyhow::Result<u16> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")
        .map_err(|e| anyhow::anyhow!("probing for a free port: {e}"))?;
    let port = listener
        .local_addr()
        .map_err(|e| anyhow::anyhow!("free port addr: {e}"))?
        .port();
    Ok(port)
}

/// Spawn `world` local worker processes over loopback TCP and wait for all
/// of them; returns the per-rank outcomes plus the aggregate verdict. Does
/// not error on rank failures or digest mismatches — inspect/assert on the
/// report (`ok()`) so callers can print diagnostics first.
pub fn launch_local(opts: &LaunchOptions) -> anyhow::Result<LaunchReport> {
    anyhow::ensure!(opts.world >= 1, "world must be at least 1");
    std::fs::create_dir_all(&opts.out_dir)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", opts.out_dir.display()))?;
    let rendezvous = match &opts.rendezvous {
        Some(r) => r.clone(),
        None => format!("127.0.0.1:{}", free_loopback_port()?),
    };

    // One spawn recipe for both lives of a rank: the original worker, and
    // (for ranks listed in `rejoin`) its `--join` replacement, which
    // re-HELLOs into the surviving group with a bumped generation and
    // appends to the same log so the death and the rejoin read as one
    // story.
    let spawn_rank = |rank: usize,
                      out_path: &Path,
                      log_path: &Path,
                      join: bool|
     -> anyhow::Result<std::process::Child> {
        let log = if join {
            std::fs::OpenOptions::new().append(true).create(true).open(log_path)
        } else {
            std::fs::File::create(log_path)
        }
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", log_path.display()))?;
        let log_err = log
            .try_clone()
            .map_err(|e| anyhow::anyhow!("cloning log handle: {e}"))?;
        let mut cmd = Command::new(&opts.binary);
        cmd.arg("train")
            .arg("--transport")
            .arg("tcp")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--world")
            .arg(opts.world.to_string())
            .arg("--rendezvous")
            .arg(&rendezvous)
            .arg("--out")
            .arg(out_path)
            .args(&opts.train_flags);
        if join {
            cmd.arg("--join").env("MERGECOMP_GENERATION", "1");
        }
        cmd.stdin(Stdio::null())
            .stdout(Stdio::from(log))
            .stderr(Stdio::from(log_err))
            .spawn()
            .map_err(|e| anyhow::anyhow!("spawning rank {rank} ({}): {e}", opts.binary.display()))
    };

    let mut children = Vec::with_capacity(opts.world);
    for rank in 0..opts.world {
        let out_path = opts.out_dir.join(format!("rank{rank}.json"));
        let log_path = opts.out_dir.join(format!("rank{rank}.log"));
        let child = spawn_rank(rank, &out_path, &log_path, false)?;
        children.push((rank, child, out_path, log_path));
    }

    // Poll until every child exits or the deadline passes.
    let deadline = Instant::now() + opts.timeout;
    let mut exit_codes: Vec<Option<i32>> = vec![None; opts.world];
    let mut done = vec![false; opts.world];
    let mut respawned = vec![false; opts.world];
    while done.iter().any(|d| !d) {
        for (i, (_rank, child, _, _)) in children.iter_mut().enumerate() {
            if done[i] {
                continue;
            }
            match child.try_wait() {
                Ok(Some(status)) => {
                    exit_codes[i] = status.code();
                    done[i] = true;
                }
                Ok(None) => {}
                Err(e) => anyhow::bail!("waiting on rank {i}: {e}"),
            }
        }
        // Hot re-join: a rank listed in `rejoin` that died gets exactly one
        // replacement, launched with `--join` so it streams the live
        // group's state instead of bootstrapping from scratch.
        for i in 0..opts.world {
            if done[i]
                && exit_codes[i] != Some(0)
                && !respawned[i]
                && opts.rejoin.contains(&children[i].0)
            {
                let (rank, _, out_path, log_path) = &children[i];
                let child = spawn_rank(*rank, out_path, log_path, true)?;
                children[i].1 = child;
                done[i] = false;
                exit_codes[i] = None;
                respawned[i] = true;
            }
        }
        if done.iter().any(|d| !d) {
            if Instant::now() >= deadline {
                for (i, (_, child, _, _)) in children.iter_mut().enumerate() {
                    if !done[i] {
                        let _ = child.kill();
                        let _ = child.wait();
                    }
                }
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    let mut ranks = Vec::with_capacity(opts.world);
    for (i, (rank, _child, out_path, log_path)) in children.into_iter().enumerate() {
        let (schema, param_digest) = if exit_codes[i] == Some(0) {
            match load_json(&out_path) {
                Ok(v) => (
                    v.get("schema").and_then(|s| s.as_usize()).map(|s| s as u64),
                    v.get("param_digest").and_then(|d| d.as_str().map(String::from)),
                ),
                Err(_) => (None, None),
            }
        } else {
            (None, None)
        };
        ranks.push(RankOutcome {
            rank,
            exit_code: exit_codes[i],
            schema,
            param_digest,
            out_path,
            log_path,
        });
    }
    // Fail fast on mixed result schemas: aggregating outputs written by
    // different builds (or by one pre-versioning build, schema `None`) is
    // a hard error — a digest comparison across layouts proves nothing.
    let schemas: std::collections::BTreeSet<Option<u64>> = ranks
        .iter()
        .filter(|r| r.exit_code == Some(0) && !opts.expect_dead.contains(&r.rank))
        .map(|r| r.schema)
        .collect();
    anyhow::ensure!(
        schemas.len() <= 1,
        "mixed result schemas across ranks: {schemas:?} — every worker must run the same \
         build (this one writes schema {RESULT_SCHEMA_VERSION})"
    );
    let all_exited_zero;
    let digests_match;
    {
        let survivors: Vec<&RankOutcome> =
            ranks.iter().filter(|r| !opts.expect_dead.contains(&r.rank)).collect();
        all_exited_zero = survivors.iter().all(|r| r.exit_code == Some(0));
        digests_match = match survivors.first().and_then(|r| r.param_digest.as_ref()) {
            Some(d0) => survivors.iter().all(|r| r.param_digest.as_ref() == Some(d0)),
            None => false,
        };
    }
    Ok(LaunchReport {
        world: opts.world,
        rendezvous,
        ranks,
        all_exited_zero,
        digests_match,
    })
}

/// Locate a built `mergecomp` binary for out-of-tree callers (examples):
/// `$MERGECOMP_BIN` if set, else `target/{release,debug}/mergecomp`
/// relative to `dir`.
pub fn find_binary(dir: &Path) -> Option<PathBuf> {
    if let Ok(p) = std::env::var("MERGECOMP_BIN") {
        let p = PathBuf::from(p);
        if p.exists() {
            return Some(p);
        }
    }
    for profile in ["release", "debug"] {
        let p = dir.join("target").join(profile).join("mergecomp");
        if p.exists() {
            return Some(p);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_port_is_usable() {
        let port = free_loopback_port().unwrap();
        assert!(port > 0);
        // Must be re-bindable right away.
        std::net::TcpListener::bind(("127.0.0.1", port)).unwrap();
    }

    #[test]
    fn launch_rejects_empty_world() {
        let opts = LaunchOptions {
            binary: PathBuf::from("/nonexistent"),
            world: 0,
            rendezvous: None,
            out_dir: std::env::temp_dir().join("mergecomp-launch-empty"),
            train_flags: vec![],
            timeout: Duration::from_secs(1),
            expect_dead: vec![],
            rejoin: vec![],
        };
        assert!(launch_local(&opts).is_err());
    }
}
