//! The real execution plane: synchronous data-parallel training of the
//! AOT-compiled L2 model (or a deterministic synthetic step source), with
//! gradients compressed per the MergeComp schedule and exchanged through
//! the pluggable collectives.
//!
//! With `TrainConfig.transport = inproc`, one OS thread per worker; with
//! `tcp`, one OS *process* per worker over real sockets (see
//! [`launch`] for the single-machine process launcher). Each rank owns a
//! step source, a shard of the corpus, its parameter/momentum/EF state,
//! and a [`crate::collectives::Comm`] endpoint. Paper Algorithm 1 is the
//! step loop in [`trainer`].

mod exchange;
mod join;
pub mod launch;
mod optimizer;
mod trainer;

pub use exchange::{ExchangeMode, ExchangeStats, GradExchange, GroupSample, PipelineMode};
pub use launch::{launch_local, LaunchOptions, LaunchReport, RankOutcome};
pub use optimizer::{SgdMomentum, ShardedSgdMomentum};
pub use trainer::{
    init_params as trainer_init_params, params_digest, reshard_sharded, sharded_update, train,
    RunResult, StepRecord, RESULT_SCHEMA_VERSION,
};
