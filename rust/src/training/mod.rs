//! The real execution plane: synchronous data-parallel training of the
//! AOT-compiled L2 model, with gradients compressed per the MergeComp
//! schedule and exchanged through the in-process collectives.
//!
//! One OS thread per worker; each owns a PJRT client, a shard of the
//! corpus, its parameter/momentum/EF state, and a [`collectives::Comm`]
//! endpoint. Paper Algorithm 1 is the step loop in [`trainer`].

mod exchange;
mod optimizer;
mod trainer;

pub use exchange::{ExchangeStats, GradExchange, PipelineMode};
pub use optimizer::SgdMomentum;
pub use trainer::{init_params as trainer_init_params, train, RunResult, StepRecord};
