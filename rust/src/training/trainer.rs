//! The data-parallel trainer: paper Algorithm 1 end to end.
//!
//! Flow (every rank, symmetric):
//!   1. build the step source: the AOT train-step artifact on a
//!      thread-local PJRT client, or the synthetic profile-shaped source
//!      (`--synthetic <profile>`, no XLA required — what CI's
//!      multi-process smoke run uses);
//!   2. initialize identical parameters from the shared seed;
//!   3. warm-up: measure step time + encode/decode/comm costs, fit the
//!      Assumption-5 models, run Algorithm 2 (rank 0) and broadcast the
//!      chosen partition;
//!   4. loop: run step → exchange gradients per the schedule → SGD update;
//!   5. evaluate on held-out batches.
//!
//! Deployment shapes ([`TrainConfig::transport`]):
//! - `inproc`: `train` spawns all `workers` ranks as OS threads over the
//!   channel mesh (the historical single-process mode);
//! - `tcp`: this process IS one rank (`--rank N` of `--world W`); ranks
//!   bootstrap through the rendezvous and exchange over real sockets. The
//!   per-rank loop is byte-for-byte the same code either way, so the two
//!   transports produce bit-identical parameters
//!   (`tests/transport_equivalence.rs`, `tests/multiproc_launch.rs`).
//!
//! Rank 0 collects the loss curve and timing records (Figs. 7–8, Table 4);
//! every rank reports [`RunResult::param_digest`] so a launcher can assert
//! cross-process agreement.

use std::path::{Path, PathBuf};

use super::exchange::{ExchangeStats, GradExchange};
use super::join;
use super::optimizer::{SgdMomentum, ShardedSgdMomentum};
use crate::collectives::{
    run_comm_group, shard_elems, tcp_endpoint_with_nodes, Comm, CommRoute, Error, TcpConfig,
    TransportKind,
};
use crate::compression::{Codec as _, CodecKind, Collective};
use crate::config::{ScheduleSpec, SchedulingMode, TrainConfig};
use crate::coordinator::{AsyncCheckpointer, Checkpoint, ExchangeMode};
use crate::data::{Batcher, SyntheticCorpus};
use crate::profiles::ModelProfile;
use crate::runtime::{StepMeta, TensorMeta, TrainStep};
use crate::scheduler::costmodel::{CostSampler, FittedCost, TwoLevelCost};
use crate::scheduler::objective::AnalyticObjective;
use crate::scheduler::{
    CodecMode, CostEstimator, Decision, Driver, DriverConfig, Partition, RouteChoice, RouteMode,
    SearchParams, ShardedCost,
};
use crate::util::json::Value;
use crate::util::rng::Xoshiro256;
use crate::util::stats::Stopwatch;

/// Version of the [`RunResult::to_json`] layout (the `"schema"` field, and
/// the first key in the object). Bump whenever a field is added, removed,
/// or changes meaning; `mergecomp launch` refuses to aggregate rank outputs
/// with mixed schemas. Every field is documented in `DESIGN.md`.
///
/// v3 added `exchange_mode`, `optimizer_state_bytes`, and
/// `peak_memory_bytes` (the sharded-exchange memory accounting).
/// v4 added `joins` (hot re-joins this rank participated in) and
/// `ckpt_async_write_secs` (background interval-checkpoint write time —
/// cost the training step no longer pays).
pub const RESULT_SCHEMA_VERSION: u64 = 4;

/// Cap on elastic recovery rounds within a single training step — each
/// round shrinks the world by at least one rank, so this only trips on a
/// cascade of failures (at which point bailing out beats thrashing).
const MAX_RECOVERIES_PER_STEP: usize = 4;

/// One logged step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    /// Wall-clock seconds since training started (this testbed).
    pub elapsed: f64,
    /// Projected V100 iteration time for this schedule (simulator plane) —
    /// lets Figs. 7–8 plot a paper-comparable time axis. Seconds/step.
    pub exchange: ExchangeStats,
}

/// Result of one rank's training run. Every rank produces one (the curve
/// records are only collected on rank 0); `param_digest` lets launchers
/// assert that separate processes ended bit-identical.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// The rank that produced this result.
    pub rank: usize,
    pub records: Vec<StepRecord>,
    /// The partition in effect when training *ended* (online mode may have
    /// switched away from the warmup choice).
    pub partition: Partition,
    /// Per-group collective routes in effect when training ended (empty =
    /// every group on the topology's global route). Non-empty only under
    /// `--route auto` on a non-flat topology once the driver has adopted a
    /// routed schedule.
    pub final_routes: Vec<RouteChoice>,
    /// Per-group codec in effect when training ended — the configured
    /// codec everywhere unless `--codec auto` adopted a mixed schedule.
    pub final_codecs: Vec<CodecKind>,
    /// The live per-level comm fits at the end of the run (`None` on flat
    /// fabrics or non-online schedules) — the per-level α+β·size slopes
    /// the driver logs and the route search decides with.
    pub two_level_fit: Option<TwoLevelCost>,
    pub final_train_loss: f32,
    pub eval_loss: f32,
    pub mean_step_secs: f64,
    pub mean_exchange: ExchangeStats,
    /// Objective evaluations across the warmup search and every online
    /// re-search.
    pub search_evals: usize,
    /// Partition switches adopted by the online scheduler.
    pub reschedules: usize,
    /// Final schedule epoch (0 = never repartitioned).
    pub schedule_epoch: u64,
    pub total_bytes_sent: u64,
    /// Bytes sent to peers on other nodes of the configured topology (0
    /// under `--topology flat`) — the slow-fabric traffic the two-level
    /// exchange minimizes.
    pub total_inter_bytes_sent: u64,
    pub steps: usize,
    /// FNV-1a over the exact bit patterns of the final parameters —
    /// synchronous SGD means every rank must report the same value, and a
    /// run over TCP must match the same config over the in-process mesh.
    pub param_digest: u64,
    /// World size when training ended — smaller than the configured world
    /// if elastic recovery shrank the run around dead ranks.
    pub world_at_end: usize,
    /// Elastic recovery rounds this rank performed (0 = no peer was lost).
    /// A peer loss repaired by a hot re-join counts under `joins` instead.
    pub recoveries: usize,
    /// Hot re-joins this rank took part in: the number of times a
    /// replacement rank was streamed back into the group (survivors), or 1
    /// on a rank that itself joined via `--join`.
    pub joins: usize,
    /// Seconds the background checkpoint writer spent serializing and
    /// persisting interval snapshots — work the synchronous path used to
    /// charge to the step it landed on, now fully off the hot path.
    pub ckpt_async_write_secs: f64,
    /// The completed-step count the run resumed from (`--resume`), `None`
    /// for a fresh run.
    pub resumed_from_step: Option<usize>,
    /// How parameters were synchronized (`--exchange-mode`): `Full`
    /// replicates the optimizer everywhere, `Sharded` reduce-scatters
    /// gradients and allgathers updated parameter shards.
    pub exchange_mode: ExchangeMode,
    /// Bytes of live optimizer (momentum) state on THIS rank at the end of
    /// the run — ≈ `full_bytes / world_at_end` under the sharded exchange.
    pub optimizer_state_bytes: u64,
    /// Modeled peak training-state bytes on this rank: parameters +
    /// gradients (4 B/elem each) + optimizer state + codec (EF) state.
    pub peak_memory_bytes: u64,
}

impl RunResult {
    pub fn to_json(&self, cfg: &TrainConfig) -> Value {
        let curve: Vec<Value> = self
            .records
            .iter()
            .map(|r| {
                Value::from_pairs(vec![
                    ("step", Value::from(r.step)),
                    ("loss", Value::from(r.loss as f64)),
                    ("elapsed", Value::from(r.elapsed)),
                ])
            })
            .collect();
        Value::from_pairs(vec![
            ("schema", Value::from(RESULT_SCHEMA_VERSION)),
            ("config", cfg.to_json()),
            ("rank", Value::from(self.rank)),
            ("param_digest", Value::from(format!("{:016x}", self.param_digest))),
            ("world_at_end", Value::from(self.world_at_end)),
            ("recoveries", Value::from(self.recoveries)),
            ("joins", Value::from(self.joins)),
            (
                "resumed_from_step",
                self.resumed_from_step.map(Value::from).unwrap_or(Value::Null),
            ),
            ("exchange_mode", Value::from(self.exchange_mode.name())),
            (
                "optimizer_state_bytes",
                Value::from(self.optimizer_state_bytes),
            ),
            ("peak_memory_bytes", Value::from(self.peak_memory_bytes)),
            (
                "ckpt_async_write_secs",
                Value::from(self.ckpt_async_write_secs),
            ),
            ("partition_bounds", Value::Arr(
                self.partition.bounds().iter().map(|&b| Value::from(b)).collect(),
            )),
            ("groups", Value::from(self.partition.num_groups())),
            ("routes", Value::Arr(
                self.final_routes.iter().map(|r| Value::from(r.name())).collect(),
            )),
            ("codecs", Value::Arr(
                self.final_codecs.iter().map(|k| Value::from(k.name())).collect(),
            )),
            (
                "comm_intra_g",
                self.two_level_fit
                    .map(|tl| Value::from(tl.intra.g))
                    .unwrap_or(Value::Null),
            ),
            (
                "comm_inter_g",
                self.two_level_fit
                    .map(|tl| Value::from(tl.inter.g))
                    .unwrap_or(Value::Null),
            ),
            ("final_train_loss", Value::from(self.final_train_loss as f64)),
            ("eval_loss", Value::from(self.eval_loss as f64)),
            ("mean_step_secs", Value::from(self.mean_step_secs)),
            ("mean_encode_secs", Value::from(self.mean_exchange.encode_secs)),
            ("mean_comm_secs", Value::from(self.mean_exchange.comm_secs)),
            (
                "mean_comm_exposed_secs",
                Value::from(self.mean_exchange.comm_exposed_secs),
            ),
            (
                "comm_overlap_frac",
                Value::from(self.mean_exchange.overlap_frac()),
            ),
            (
                "mean_comm_inter_secs",
                Value::from(self.mean_exchange.comm_inter_secs),
            ),
            ("mean_decode_secs", Value::from(self.mean_exchange.decode_secs)),
            ("search_evals", Value::from(self.search_evals)),
            ("reschedules", Value::from(self.reschedules)),
            ("schedule_epoch", Value::from(self.schedule_epoch)),
            ("total_bytes_sent", Value::from(self.total_bytes_sent)),
            (
                "total_inter_bytes_sent",
                Value::from(self.total_inter_bytes_sent),
            ),
            ("curve", Value::Arr(curve)),
        ])
    }
}

/// FNV-1a over every parameter tensor's length and exact f32 bit patterns.
pub fn params_digest(params: &[Vec<f32>]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = OFFSET;
    let mix = |h: u64, bytes: &[u8]| {
        let mut h = h;
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
        h
    };
    for t in params {
        h = mix(h, &(t.len() as u64).to_le_bytes());
        for v in t {
            h = mix(h, &v.to_bits().to_le_bytes());
        }
    }
    h
}

/// Everything rank-independent a training run needs, prepared once (per
/// process) before ranks start.
struct TrainSetup {
    meta: StepMeta,
    /// Simulator-plane profile matching `meta`'s tensor order — seeds the
    /// schedule search before measured costs exist.
    profile: ModelProfile,
    /// Token corpus; `None` in synthetic mode (no batches are consumed).
    corpus: Option<SyntheticCorpus>,
}

fn prepare_setup(cfg: &TrainConfig) -> anyhow::Result<TrainSetup> {
    if let Some(name) = &cfg.synthetic {
        let profile = crate::profiles::by_name(name)?;
        let tensors: Vec<TensorMeta> = profile
            .tensors
            .iter()
            .map(|t| TensorMeta {
                name: t.name.clone(),
                shape: vec![t.elems],
                elems: t.elems,
            })
            .collect();
        let meta = StepMeta {
            tensors,
            batch: cfg.batch_per_worker,
            seq_len: cfg.seq_len,
            vocab: 96,
            n_layers: 0,
            d_model: 0,
            d_ff: 0,
        };
        return Ok(TrainSetup {
            meta,
            profile,
            corpus: None,
        });
    }
    let meta_path = std::path::Path::new(&cfg.artifact)
        .parent()
        .map(|d| d.join("meta.json"))
        .ok_or_else(|| anyhow::anyhow!("artifact path has no parent dir"))?;
    let meta = StepMeta::load(&meta_path, "e2e")?;
    anyhow::ensure!(
        meta.batch == cfg.batch_per_worker && meta.seq_len == cfg.seq_len,
        "config batch/seq ({}, {}) must match the AOT artifact ({}, {}) — \
         re-run `make artifacts` after changing the model config",
        cfg.batch_per_worker,
        cfg.seq_len,
        meta.batch,
        meta.seq_len
    );
    let profile = meta.to_profile();
    let corpus = SyntheticCorpus::generate(cfg.seed ^ 0xDA7A, 400_000.max(cfg.workers * 50_000));
    Ok(TrainSetup {
        meta,
        profile,
        corpus: Some(corpus),
    })
}

/// One rank's gradient source: the PJRT-executed artifact, or a
/// deterministic synthetic generator shaped like the profile. The
/// synthetic source draws per-(seed, rank, step) gradients so the exchange
/// performs real cross-rank averaging, and its determinism is what makes
/// cross-transport digests comparable.
enum StepRunner {
    Pjrt {
        exec: TrainStep,
        batcher: Batcher,
    },
    Synthetic {
        sizes_fwd: Vec<usize>,
        seed: u64,
        rank: usize,
        next_step: u64,
        last_secs: f64,
    },
}

impl StepRunner {
    fn run(&mut self, params: &[Vec<f32>]) -> anyhow::Result<(f32, Vec<Vec<f32>>)> {
        match self {
            StepRunner::Pjrt { exec, batcher } => {
                let (x, y) = batcher.next_batch();
                exec.run(params, &x, &y)
            }
            StepRunner::Synthetic {
                sizes_fwd,
                seed,
                rank,
                next_step,
                last_secs,
            } => {
                let sw = Stopwatch::start();
                let step = *next_step;
                *next_step += 1;
                let mut rng = Xoshiro256::seed_from_u64(
                    *seed ^ 0x57E9_57E9 ^ ((*rank as u64) << 32) ^ (step << 8),
                );
                let grads: Vec<Vec<f32>> = sizes_fwd
                    .iter()
                    .map(|&n| {
                        let mut g = vec![0f32; n];
                        rng.fill_normal_f32(&mut g, 0.02);
                        g
                    })
                    .collect();
                let mut noise = [0f32; 1];
                rng.fill_normal_f32(&mut noise, 1.0);
                // A smooth synthetic curve: starts at ln(vocab) and decays,
                // with small per-rank noise the loss allreduce averages out.
                let loss = (96f32).ln() * 0.985f32.powi(step as i32) + 0.02 * noise[0];
                *last_secs = sw.elapsed().as_secs_f64();
                Ok((loss, grads))
            }
        }
    }

    fn last_exec_secs(&self) -> f64 {
        match self {
            StepRunner::Pjrt { exec, .. } => exec.last_exec_secs,
            StepRunner::Synthetic { last_secs, .. } => *last_secs,
        }
    }

    /// Force the synthetic stream position — checkpoint resume fast-forwards
    /// past already-completed steps, and an elastic retry rewinds the failed
    /// step. Each synthetic draw reseeds from `(seed, rank, step)`, so the
    /// position fully determines the stream. Returns `false` for the PJRT
    /// runner: a consumed batch cannot be replayed.
    fn seek(&mut self, next: u64) -> bool {
        match self {
            StepRunner::Pjrt { .. } => false,
            StepRunner::Synthetic { next_step, .. } => {
                *next_step = next;
                true
            }
        }
    }
}

/// The codec candidate pool under `--codec auto`: the configured base
/// codec, FP32 ("don't compress" must stay a first-class outcome), and one
/// representative of each overhead regime — a dense truncation (FP16), an
/// EF bitmap (EFSignSGD), and a sparse top-k. Deduplicated, order-stable.
fn codec_pool(cfg: &TrainConfig) -> Vec<CodecKind> {
    let mut pool: Vec<CodecKind> = Vec::new();
    for k in [
        cfg.codec,
        CodecKind::Fp32,
        CodecKind::Fp16,
        CodecKind::EfSignSgd,
        CodecKind::TopK { ratio: 0.01 },
    ] {
        if !pool.contains(&k) {
            pool.push(k);
        }
    }
    pool
}

/// Measure one codec's encode+decode costs at a few group sizes
/// (host-local, no comm) and fit the Assumption-5 models. Under
/// `--codec auto` this runs once per pool codec so the scheduler can price
/// codecs it has never run in production.
fn fit_codec_costs(
    kind: CodecKind,
    seed: u64,
    total_params: usize,
) -> anyhow::Result<(FittedCost, FittedCost)> {
    let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xC0DEC);
    let mut enc_s = CostSampler::new();
    let mut dec_s = CostSampler::new();
    let sizes = [
        1usize << 10,
        1 << 14,
        1 << 18,
        (total_params / 2).max(1 << 19),
    ];
    for &n in &sizes {
        let mut codec = kind.build(n);
        let mut g = vec![0f32; n];
        rng.fill_normal_f32(&mut g, 0.02);
        let mut out = vec![0f32; n];
        // Warm + measure (median of 3).
        let mut enc_t = f64::INFINITY;
        let mut dec_t = f64::INFINITY;
        for _ in 0..3 {
            let sw = Stopwatch::start();
            let enc = codec.encode(&g, &mut rng);
            enc_t = enc_t.min(sw.elapsed().as_secs_f64());
            let sw = Stopwatch::start();
            codec.decode(&enc, &mut out);
            dec_t = dec_t.min(sw.elapsed().as_secs_f64());
        }
        enc_s.record(n, enc_t);
        dec_s.record(n, dec_t);
    }
    Ok((enc_s.fit()?, dec_s.fit()?))
}

/// Measure the collective cost at a few payload sizes. Must be executed by
/// every rank simultaneously (it runs real collectives).
fn fit_comm_costs(
    comm: &mut Comm,
    cfg: &TrainConfig,
    total_params: usize,
) -> anyhow::Result<FittedCost> {
    let mut sampler = CostSampler::new();
    let sizes = [1usize << 10, 1 << 14, 1 << 18, (total_params / 2).max(1 << 19)];
    for &n in &sizes {
        let wire = cfg.codec.wire_size(n);
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let sw = Stopwatch::start();
            match cfg.codec.collective() {
                Collective::AllReduce => {
                    let mut buf = vec![0u8; wire.div_ceil(4) * 4];
                    let codec = cfg.codec.build(n);
                    comm.allreduce_wire(&mut buf, codec.as_ref())?;
                }
                Collective::AllGather => {
                    let _ = comm.allgather(vec![0u8; wire])?;
                }
            }
            best = best.min(sw.elapsed().as_secs_f64());
        }
        sampler.record(n, best);
    }
    Ok(sampler
        .fit()
        .unwrap_or(FittedCost { b: 1e-5, g: 1e-9, r2: 0.0 }))
}

/// Cost models fitted during warmup — the online scheduler's priors.
/// `enc`/`dec` are rank-0 only (only rank 0 searches); `comm` is measured
/// collectively on every rank.
#[derive(Debug, Clone, Copy, Default)]
struct WarmupFits {
    enc: Option<FittedCost>,
    dec: Option<FittedCost>,
    comm: Option<FittedCost>,
}

/// Resolve the initial schedule, then broadcast the partition bounds so all
/// ranks agree bit-for-bit.
///
/// - `Fixed` mode: no measurement at all; the spec must be static.
/// - `Warmup`/`Online`: rank 0 fits the Assumption-5 models from warmup
///   measurements and runs Algorithm 2; `Online` additionally hands the
///   fits back as estimator priors.
///
/// Followers parse the broadcast **strictly**: a malformed bound is an
/// error. (The old path `filter_map(Value::as_usize)` silently dropped bad
/// entries and then asserted — or worse, merged two groups on one rank
/// only.)
fn resolve_schedule(
    comm: &mut Comm,
    cfg: &TrainConfig,
    meta: &StepMeta,
    profile: &ModelProfile,
    measured_step_secs: f64,
) -> anyhow::Result<(Partition, usize, WarmupFits)> {
    let n = meta.tensors.len();

    if cfg.sched_mode == SchedulingMode::Fixed {
        anyhow::ensure!(
            !matches!(cfg.schedule, ScheduleSpec::MergeComp { .. }),
            "--sched-mode fixed cannot resolve a mergecomp schedule (it needs \
             measurements); pick a static --schedule or warmup/online mode"
        );
        let mut noop = crate::scheduler::objective::MeasuredObjective::new(|_: &Partition| 0.0);
        // Static specs resolve identically on every rank — no broadcast.
        return Ok((cfg.schedule.resolve(n, &mut noop), 0, WarmupFits::default()));
    }

    // Comm costs involve all ranks — measure before rank 0 diverges.
    let comm_cost = fit_comm_costs(comm, cfg, meta.total_params())?;
    let mut fits = WarmupFits {
        comm: Some(comm_cost),
        ..Default::default()
    };

    let mut evals = 0usize;
    let partition = if comm.rank() == 0 {
        let spec = cfg.schedule;
        let p = match spec {
            ScheduleSpec::MergeComp { .. } => {
                let (enc, dec) = fit_codec_costs(cfg.codec, cfg.seed, meta.total_params())?;
                fits.enc = Some(enc);
                fits.dec = Some(dec);
                // Backward durations: measured step time split by the
                // profile's FLOPs shares (same shape as the simulator).
                let total_flops = profile.total_flops().max(f64::MIN_POSITIVE);
                let bwd = measured_step_secs * (1.0 - profile.fwd_frac);
                let bwd_dur: Vec<f64> = profile
                    .tensors
                    .iter()
                    .rev()
                    .map(|t| bwd * t.flops / total_flops)
                    .collect();
                let fanin = match cfg.codec.collective() {
                    Collective::AllReduce => 1,
                    Collective::AllGather => comm.world().saturating_sub(1).max(1),
                };
                let mut obj = AnalyticObjective::new(
                    bwd_dur,
                    meta.sizes_backprop_order(),
                    measured_step_secs * profile.fwd_frac,
                    enc,
                    dec,
                    comm_cost,
                    fanin,
                );
                // Sharded exchange reprices comm as reduce-scatter + FP32
                // parameter allgather. The warmup comm fit is per element
                // under the configured codec; convert it to wire-byte
                // space through the codec's wire affine, then to the FP32
                // element basis the allgather term is charged in.
                if cfg.exchange_mode == ExchangeMode::Sharded {
                    let (header, density) = cfg.codec.wire_affine();
                    let g = comm_cost.g / density.max(f64::MIN_POSITIVE);
                    let bytes = FittedCost {
                        b: (comm_cost.b - g * header).max(0.0),
                        g,
                        r2: comm_cost.r2,
                    };
                    obj.set_sharded_exchange(Some(ShardedCost {
                        fp32_comm: bytes.per_elems_for(CodecKind::Fp32),
                        base_codec: cfg.codec,
                    }));
                }
                let out = spec.resolve(n, &mut obj);
                evals = {
                    use crate::scheduler::objective::Objective as _;
                    obj.evals()
                };
                out
            }
            other => {
                let mut noop =
                    crate::scheduler::objective::MeasuredObjective::new(|_: &Partition| 0.0);
                other.resolve(n, &mut noop)
            }
        };
        // Broadcast bounds as a JSON payload.
        let mut payload = p.bounds_to_json().to_string_compact().into_bytes();
        comm.broadcast(0, &mut payload)?;
        p
    } else {
        let mut payload = Vec::new();
        comm.broadcast(0, &mut payload)?;
        let v = Value::parse(std::str::from_utf8(&payload)?)
            .map_err(|e| anyhow::anyhow!("partition broadcast: {e}"))?;
        Partition::from_json_bounds(n, &v)
            .map_err(|e| anyhow::anyhow!("partition broadcast: {e}"))?
    };
    Ok((partition, evals, fits))
}

/// Deterministic parameter init shared by all workers: LN scales = 1,
/// biases = 0, weights ~ N(0, fan_in^-1/2) (embed: 0.02) — mirrors
/// model.init_params.
pub fn init_params(meta: &StepMeta, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    meta.tensors
        .iter()
        .map(|t| {
            if t.name.ends_with(".scale") {
                vec![1f32; t.elems]
            } else if t.name.ends_with(".bias") || t.name.ends_with(".b1") || t.name.ends_with(".b2")
            {
                vec![0f32; t.elems]
            } else {
                let fan_in = *t.shape.first().unwrap_or(&t.elems) as f32;
                let std = if t.name == "embed.weight" {
                    0.02
                } else {
                    fan_in.powf(-0.5)
                };
                let mut v = vec![0f32; t.elems];
                rng.fill_normal_f32(&mut v, std);
                v
            }
        })
        .collect()
}

/// The gradient-exchange RNG for one step: a pure function of
/// `(seed, rank, step)`, so a resumed or elastically-retried step draws
/// exactly the randomness (stochastic rounding, sparsifier sampling) the
/// uninterrupted run drew. The previous stream-across-steps RNG made a
/// restored run diverge on its first stochastic encode.
fn exchange_rng(seed: u64, rank: usize, step: usize) -> Xoshiro256 {
    Xoshiro256::seed_from_u64(
        seed ^ 0xE8C0_0000_0000_0001
            ^ ((rank as u64) << 17)
            ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

/// The rank's optimizer, shaped by `--exchange-mode`: `Full` replicates
/// the momentum on every rank; `Sharded` holds only the owned spans of
/// the Algorithm-2 groups and relies on the parameter allgather in
/// [`sharded_update`] for the rest of the model.
enum Opt {
    Full(SgdMomentum),
    Sharded(ShardedSgdMomentum),
}

impl Opt {
    /// Velocity in the checkpoint interchange format: full-length
    /// per-tensor planes in forward (parameter) order. The sharded
    /// optimizer exports zeros outside its owned spans — summing every
    /// rank's planes reconstructs the full momentum, and the owner's
    /// span survives a same-schedule `--resume` slice verbatim.
    fn velocity_tensors(&self, sizes_fwd: &[usize]) -> Vec<Vec<f32>> {
        match self {
            Opt::Full(o) => o.velocity().to_vec(),
            Opt::Sharded(o) => {
                // Group planes concatenate to the model-flat buffer in
                // backprop tensor order; split per tensor and reverse.
                let mut flat: Vec<f32> = Vec::new();
                for p in o.export_group_planes() {
                    flat.extend_from_slice(&p);
                }
                let mut planes: Vec<Vec<f32>> = Vec::with_capacity(sizes_fwd.len());
                let mut off = 0;
                for &n in sizes_fwd.iter().rev() {
                    planes.push(flat[off..off + n].to_vec());
                    off += n;
                }
                planes.reverse();
                planes
            }
        }
    }

    /// Bytes of live momentum state on this rank.
    fn state_bytes(&self, total_params: usize) -> u64 {
        match self {
            Opt::Full(_) => 4 * total_params as u64,
            Opt::Sharded(o) => o.state_bytes(),
        }
    }
}

/// Convert checkpoint-format velocity (full-length per-tensor planes,
/// forward order) into per-group planes in the engine's merge order —
/// what [`ShardedSgdMomentum::load_group_planes`] slices its spans from.
fn group_planes_from_tensors(velocity_fwd: &[Vec<f32>], group_elems: &[usize]) -> Vec<Vec<f32>> {
    let mut flat: Vec<f32> = Vec::new();
    for t in velocity_fwd.iter().rev() {
        flat.extend_from_slice(t);
    }
    let mut planes = Vec::with_capacity(group_elems.len());
    let mut off = 0;
    for &n in group_elems {
        planes.push(flat[off..off + n].to_vec());
        off += n;
    }
    planes
}

/// One sharded optimizer step: per scheduled group, update this rank's
/// owned span and allgather every rank's updated parameter shard (raw
/// little-endian f32 — the shards are disjoint and cover the group, so
/// the gather rebuilds identical full parameters everywhere).
///
/// `grads_bp` holds the exchanged gradients in backprop tensor order;
/// under an AllReduce codec only the owned span of each group is
/// meaningful on this rank, and [`ShardedSgdMomentum::step_group`] reads
/// exactly that span.
pub fn sharded_update(
    comm: &mut Comm,
    opt: &mut ShardedSgdMomentum,
    exchange: &GradExchange,
    params: &mut [Vec<f32>],
    grads_bp: &[Vec<f32>],
) -> anyhow::Result<()> {
    let n = params.len();
    let world = comm.world();
    for j in 0..exchange.partition().num_groups() {
        let range = exchange.partition().group_range(j);
        let elems = exchange.group_elems()[j];
        // Flatten the group from forward-order params into the engine's
        // merge order (backprop tensor concatenation).
        let mut pflat = Vec::with_capacity(elems);
        let mut gflat = Vec::with_capacity(elems);
        for bp in range.clone() {
            pflat.extend_from_slice(&params[n - 1 - bp]);
            gflat.extend_from_slice(&grads_bp[bp]);
        }
        opt.step_group(j, &mut pflat, &gflat);
        let (lo, hi) = opt.spans()[j];
        let mut mine = Vec::with_capacity((hi - lo) * 4);
        for v in &pflat[lo..hi] {
            mine.extend_from_slice(&v.to_le_bytes());
        }
        let all = comm.allgather(mine)?;
        anyhow::ensure!(
            all.len() == world,
            "sharded update: parameter allgather returned {} payloads for world {world}",
            all.len()
        );
        for (src, payload) in all.iter().enumerate() {
            let (slo, shi) = shard_elems(elems, world, src);
            anyhow::ensure!(
                payload.len() == (shi - slo) * 4,
                "sharded update: group {j} rank {src} sent {} bytes, its shard is {}",
                payload.len(),
                (shi - slo) * 4
            );
            for (i, c) in payload.chunks_exact(4).enumerate() {
                pflat[slo + i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
        let mut off = 0;
        for bp in range {
            let t = &mut params[n - 1 - bp];
            t.copy_from_slice(&pflat[off..off + t.len()]);
            off += t.len();
        }
    }
    Ok(())
}

/// Re-shard the momentum after the group bounds or the world changed
/// (online repartition, elastic shrink): every rank contributes its
/// owned spans as zero-padded model-flat planes, the element-wise sum
/// reconstructs the full momentum (spans are disjoint), and each rank
/// keeps its NEW owned spans. A span whose old owner died contributes
/// nothing — momentum there restarts at zero, deterministically on
/// every survivor. Collective: all ranks must call this together.
pub fn reshard_sharded(
    comm: &mut Comm,
    old: &ShardedSgdMomentum,
    mu: f32,
    exchange: &GradExchange,
) -> anyhow::Result<ShardedSgdMomentum> {
    let mut mine: Vec<u8> = Vec::new();
    for p in old.export_group_planes() {
        for v in &p {
            mine.extend_from_slice(&v.to_le_bytes());
        }
    }
    let group_elems = exchange.group_elems().to_vec();
    let total: usize = group_elems.iter().sum();
    anyhow::ensure!(
        mine.len() == total * 4,
        "velocity reshard: old optimizer covers {} bytes, model has {}",
        mine.len(),
        total * 4
    );
    let all = comm.allgather(mine)?;
    let mut flat = vec![0f32; total];
    for (src, payload) in all.iter().enumerate() {
        anyhow::ensure!(
            payload.len() == total * 4,
            "velocity reshard: rank {src} sent {} bytes, expected {}",
            payload.len(),
            total * 4
        );
        for (i, c) in payload.chunks_exact(4).enumerate() {
            flat[i] += f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
    }
    let spans = exchange.owned_group_ranges(comm.world(), comm.rank());
    let mut fresh = ShardedSgdMomentum::new(old.lr(), mu, &group_elems, &spans);
    let mut off = 0;
    let planes: Vec<Vec<f32>> = group_elems
        .iter()
        .map(|&ge| {
            let p = flat[off..off + ge].to_vec();
            off += ge;
            p
        })
        .collect();
    fresh.load_group_planes(&planes)?;
    Ok(fresh)
}

/// Run `accum` forward/backward micro-steps and average their gradients
/// (and losses). `accum == 1` is bit-for-bit the legacy single-step path
/// — no scaling pass touches the gradients. Returns the summed compute
/// seconds alongside.
fn run_accum(
    runner: &mut StepRunner,
    params: &[Vec<f32>],
    accum: usize,
) -> anyhow::Result<(f32, Vec<Vec<f32>>, f64)> {
    let (mut loss, mut grads) = runner.run(params)?;
    let mut secs = runner.last_exec_secs();
    for _ in 1..accum {
        let (l, g) = runner.run(params)?;
        secs += runner.last_exec_secs();
        loss += l;
        for (a, b) in grads.iter_mut().zip(&g) {
            for (ai, bi) in a.iter_mut().zip(b) {
                *ai += bi;
            }
        }
    }
    if accum > 1 {
        let inv = 1.0 / accum as f32;
        loss *= inv;
        for t in grads.iter_mut() {
            for v in t.iter_mut() {
                *v *= inv;
            }
        }
    }
    Ok((loss, grads, secs))
}

/// Build the online rescheduling driver for the communicator's **current**
/// world — called once after warmup, and again after an elastic shrink
/// (the searched schedule must be re-derived for the surviving world).
/// Returns `None` when the config doesn't run the online scheduler.
fn build_driver(
    comm: &Comm,
    cfg: &TrainConfig,
    meta: &StepMeta,
    profile: &ModelProfile,
    fits: WarmupFits,
    partition: &Partition,
) -> anyhow::Result<Option<Driver>> {
    let online = cfg.sched_mode == SchedulingMode::Online
        && matches!(cfg.schedule, ScheduleSpec::MergeComp { .. });
    if !online {
        return Ok(None);
    }
    let bwd_shares = profile.bwd_flop_shares();
    let search = match cfg.schedule {
        ScheduleSpec::MergeComp { y_max, alpha } => SearchParams { y_max, alpha },
        _ => SearchParams::default(),
    };
    let dcfg = DriverConfig {
        interval: cfg.resched_interval.max(1),
        ewma: cfg.resched_ewma.clamp(1e-3, 1.0),
        hysteresis: cfg.resched_eps.max(0.0),
        search,
        min_samples: 8,
    };
    // The warmup decode fit measured one payload; the engine's
    // per-group decode samples include the allgather fan-in, so
    // scale the prior to match.
    let fanin_of = |k: CodecKind| match k.collective() {
        Collective::AllReduce => 1.0,
        Collective::AllGather => comm.world().saturating_sub(1).max(1) as f64,
    };
    let fanin = fanin_of(cfg.codec);
    let dec_prior = fits.dec.map(|d| FittedCost {
        b: d.b * fanin,
        g: d.g * fanin,
        r2: d.r2,
    });
    // The estimator's comm fits live in wire-byte space; the warmup
    // fit sampled per element under the configured codec, so convert
    // through its wire affine before seeding the prior.
    let (header, density) = cfg.codec.wire_affine();
    let comm_prior = fits.comm.map(|f| {
        let g = f.g / density.max(f64::MIN_POSITIVE);
        FittedCost { b: (f.b - g * header).max(0.0), g, r2: f.r2 }
    });
    let mut est = CostEstimator::new(dcfg.ewma, fits.enc, dec_prior, comm_prior);
    est.set_base_codec(cfg.codec);
    let auto_codecs = cfg.codec_mode == CodecMode::Auto;
    let pool = codec_pool(cfg);
    if auto_codecs && comm.rank() == 0 {
        // One-shot local microcalibration: seed enc/dec fits for every
        // pool codec so the search can price codecs that have never
        // carried production traffic. Rank 0 only — it runs the search.
        for &k in &pool {
            let (enc, dec) = fit_codec_costs(k, cfg.seed, meta.total_params())?;
            let f = fanin_of(k);
            est.seed_codec(k, enc, FittedCost { b: dec.b * f, g: dec.g * f, r2: dec.r2 });
        }
    }
    let mut d = Driver::new(
        dcfg,
        est,
        meta.sizes_backprop_order(),
        bwd_shares,
        profile.fwd_frac,
        partition.clone(),
    );
    // Per-group route search: only meaningful when there is a real
    // hierarchy to route over and the policy is Auto. The ring size
    // handed to the route model is the TOP ring's (the stage the
    // measured inter split times), not the node count — they differ
    // on N-level topologies.
    if cfg.route == RouteMode::Auto && !comm.topology().is_trivial() {
        d = d.with_routing(comm.world(), comm.topology().top_leaders().len());
    }
    // Codec axis: every rank installs it (the broadcast codecs must
    // count against a consistent schedule state), only rank 0 searches.
    if auto_codecs {
        d = d.with_codecs(cfg.codec, &pool, cfg.codec_switch_cost);
    }
    // Sharded exchange: every re-search prices the reduce-scatter +
    // parameter-allgather byte pattern instead of the full allreduce.
    if cfg.exchange_mode == ExchangeMode::Sharded {
        d = d.with_sharded_exchange(cfg.codec);
    }
    Ok(Some(d))
}

/// Assemble the full resumable state after `completed_steps` optimizer
/// steps into a [`Checkpoint`] value. Cloning the planes here is the only
/// cost the caller pays on the hot path — serialization and IO happen in
/// [`write_checkpoint`] (synchronous emergency snapshots) or in the
/// [`AsyncCheckpointer`]'s background thread (interval snapshots), and the
/// same value is what rank 0 streams to a hot joiner.
#[allow(clippy::too_many_arguments)]
fn build_checkpoint(
    completed_steps: usize,
    world: usize,
    rank: usize,
    cfg: &TrainConfig,
    exchange: &GradExchange,
    driver: Option<&Driver>,
    params: &[Vec<f32>],
    velocity: &[Vec<f32>],
) -> Checkpoint {
    Checkpoint {
        step: completed_steps,
        world,
        rank,
        seed: cfg.seed,
        base_codec: cfg.codec,
        exchange_mode: cfg.exchange_mode,
        bounds: exchange.partition().bounds().to_vec(),
        routes: exchange.routes().map(|r| r.to_vec()).unwrap_or_default(),
        codecs: exchange.group_codecs(),
        schedule_epoch: driver.map(|d| d.epoch()).unwrap_or(0),
        params: params.to_vec(),
        velocity: velocity.to_vec(),
        codec_state: exchange.flat_state(),
    }
}

/// Snapshot the full resumable state after `completed_steps` optimizer
/// steps to `dir`'s per-rank checkpoint file (atomic rename),
/// synchronously — the emergency path, where durability beats latency.
#[allow(clippy::too_many_arguments)]
fn write_checkpoint(
    dir: &Path,
    completed_steps: usize,
    world: usize,
    rank: usize,
    cfg: &TrainConfig,
    exchange: &GradExchange,
    driver: Option<&Driver>,
    params: &[Vec<f32>],
    velocity: &[Vec<f32>],
) -> anyhow::Result<()> {
    build_checkpoint(completed_steps, world, rank, cfg, exchange, driver, params, velocity)
        .save(&Checkpoint::rank_path(dir, rank))
}

/// The transport-independent first half of elastic recovery at `step`:
/// roll the codec state back to the pre-step snapshot, write an emergency
/// checkpoint, broadcast the loss, let control traffic settle, and agree
/// locally on the dead set (old-world rank numbering, returned). The
/// caller then either hot re-joins replacements for the dead ranks
/// ([`join::hot_rejoin_survivor`]) or shrinks the world around them
/// ([`shrink_after_peer_loss`]). `reporting_rank` is this rank's
/// **original** identity (checkpoint naming, gradient stream).
#[allow(clippy::too_many_arguments)]
fn recover_prologue(
    comm: &mut Comm,
    cfg: &TrainConfig,
    step: usize,
    err: &Error,
    exchange: &mut GradExchange,
    driver: Option<&Driver>,
    params: &[Vec<f32>],
    velocity: &[Vec<f32>],
    state_backup: &[Vec<f32>],
    ckpt_dir: Option<&Path>,
    reporting_rank: usize,
) -> anyhow::Result<Vec<usize>> {
    // 1. Roll codec state back to the pre-step snapshot: groups that
    //    encoded before the wire died consumed their EF accumulators, and
    //    the retry must not double-apply that feedback.
    exchange.load_flat_state(state_backup)?;

    // 2. Emergency snapshot under `<dir>/emergency/` — written before any
    //    communicator surgery, so even a failed recovery leaves restorable
    //    state. A separate subdirectory keeps it from clobbering the
    //    interval snapshots a full-world restart resumes from (survivors
    //    would overwrite theirs at `step`, the dead rank cannot).
    if let Some(dir) = ckpt_dir {
        write_checkpoint(
            &dir.join("emergency"),
            step,
            comm.world(),
            reporting_rank,
            cfg,
            exchange,
            driver,
            params,
            velocity,
        )?;
    }

    // 3. Tell every peer which rank died (idempotent across survivors —
    //    stale frames are dropped by abort-epoch filtering), then let
    //    in-flight control traffic settle.
    let first_dead = err
        .peer
        .ok_or_else(|| anyhow::anyhow!("recoverable exchange error names no peer: {err}"))?;
    comm.ep.broadcast_abort(first_dead, &err.context);
    if let Some(wait) = err.retry_after() {
        std::thread::sleep(wait);
    }

    // 4. The dead set: everyone we have seen die, directly or via a
    //    peer's abort broadcast. Old-world rank numbering.
    let mut dead = comm.ep.dead_peers();
    if !dead.contains(&first_dead) {
        dead.push(first_dead);
    }
    dead.sort_unstable();
    Ok(dead)
}

/// Degraded-world second half of elastic recovery: shrink the
/// communicator around `dead`, cross-check survivor agreement, drop the
/// now-meaningless per-group routes, and rebuild the online driver for
/// the shrunk world. On return the caller re-runs `step` over it. The
/// communicator's rank may change under `reporting_rank` here.
#[allow(clippy::too_many_arguments)]
fn shrink_after_peer_loss(
    comm: &mut Comm,
    cfg: &TrainConfig,
    meta: &StepMeta,
    profile: &ModelProfile,
    fits: WarmupFits,
    step: usize,
    dead: &[usize],
    exchange: &mut GradExchange,
    driver: &mut Option<Driver>,
    params: &[Vec<f32>],
    reporting_rank: usize,
) -> anyhow::Result<()> {
    let survivors: Vec<usize> = (0..comm.world()).filter(|r| !dead.contains(r)).collect();
    let new_rank = comm.shrink_to_survivors(&survivors)?;

    // 5. Survivor agreement: synchronous SGD means every survivor must
    //    hold identical (step, params). A mismatch survivor set (two ranks
    //    observed different cascades) or diverged state is unrecoverable —
    //    better a loud bail than a silently forked run.
    let digest = params_digest(params);
    let mut tag = Vec::with_capacity(16);
    tag.extend_from_slice(&(step as u64).to_le_bytes());
    tag.extend_from_slice(&digest.to_le_bytes());
    let all = comm.allgather(tag.clone())?;
    for (peer, t) in all.iter().enumerate() {
        anyhow::ensure!(
            t == &tag,
            "elastic recovery: shrunk-world rank {peer} disagrees on (step, param digest) at \
             step {step} — survivors diverged, cannot continue"
        );
    }

    // 6. The shrink reset the topology flat (the old rank→node map no
    //    longer applies), so per-group routes from the old hierarchy are
    //    meaningless: revert to the global route. Per-group codecs stay —
    //    they are world-independent.
    exchange.set_routes(None)?;

    // 7. Rebuild the online driver against the shrunk world, carrying the
    //    adopted schedule and epoch over so the next reschedule broadcast
    //    stays within every survivor's accepted epoch window.
    if let Some(old) = driver.as_ref() {
        let epoch = old.epoch();
        let mut rebuilt = build_driver(comm, cfg, meta, profile, fits, exchange.partition())?;
        if let Some(d) = rebuilt.as_mut() {
            d.restore_schedule(
                exchange.partition().clone(),
                Vec::new(),
                exchange.group_codecs(),
                epoch,
            )?;
        }
        *driver = rebuilt;
    }

    eprintln!(
        "rank {reporting_rank}: peers {dead:?} lost at step {step}; continuing as rank \
         {new_rank} of {}",
        comm.world()
    );
    Ok(())
}

/// One rank's full training run — identical regardless of transport.
/// `join` carries a hot joiner's restore point (the streamed snapshot
/// merged with its local interval checkpoint, see
/// [`join::receive_join_snapshot`]); `None` everywhere else.
fn train_rank(
    comm: &mut Comm,
    cfg: &TrainConfig,
    setup: &TrainSetup,
    join: Option<Checkpoint>,
) -> anyhow::Result<RunResult> {
    // Attach the topology: identical on every rank (same config), so the
    // routed collectives stay a symmetric SPMD program. A non-flat
    // topology switches the gradient exchange to the hierarchical path;
    // `--route flat` forces the flat ring over it instead, and
    // `--route auto` (the default) additionally lets the online scheduler
    // re-route per tensor group.
    comm.set_topology(cfg.topology.build(comm.world())?)?;
    if cfg.route == RouteMode::Flat {
        comm.set_route(CommRoute::Flat);
    }
    // This rank's *original* identity: checkpoint naming, the synthetic
    // gradient stream, and RNG seeding all key off it. `comm.rank()` can
    // change under us when elastic recovery renumbers the shrunk world, so
    // lead-rank checks below always re-read it dynamically.
    let rank = comm.rank();
    let meta = &setup.meta;
    let policy = &cfg.policy;
    let elastic = policy.elastic;
    let ckpt_dir: Option<PathBuf> = policy.checkpoint_dir.as_ref().map(PathBuf::from);
    anyhow::ensure!(
        (!elastic && !policy.resume && !policy.join) || cfg.synthetic.is_some(),
        "--elastic, --resume, and --join require --synthetic: the PJRT batch stream cannot be \
         rewound to replay a failed or already-completed step"
    );
    // Interval snapshots go through a background writer: the step only
    // pays for assembling the Checkpoint value (plane clones);
    // serialization and the tmp-file + atomic-rename IO run on the
    // writer thread, whose accumulated time the run reports as
    // `ckpt_async_write_secs` instead of inflating the steps it lands on.
    let ckptr = (ckpt_dir.is_some() && policy.checkpoint_interval > 0)
        .then(AsyncCheckpointer::new);

    // Restore this rank's snapshot before anything touches the wire; the
    // cheap local checks (seed, world, rank) catch a mispointed
    // --checkpoint-dir (or a mis-streamed join snapshot) without
    // involving the peers.
    let joined = join.is_some();
    let restore: Option<Checkpoint> = if join.is_some() {
        join
    } else if policy.resume {
        let dir = ckpt_dir
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("--resume requires --checkpoint-dir"))?;
        Some(Checkpoint::load(&Checkpoint::rank_path(dir, rank))?)
    } else {
        None
    };
    if let Some(c) = &restore {
        anyhow::ensure!(
            c.seed == cfg.seed,
            "checkpoint was written by a run with --seed {}, this run has {}",
            c.seed,
            cfg.seed
        );
        anyhow::ensure!(
            c.world == comm.world(),
            "checkpoint was written at world {} but this run has {} ranks — relaunch with \
             --world {}",
            c.world,
            comm.world(),
            c.world
        );
        anyhow::ensure!(c.rank == rank, "checkpoint is rank {}'s, this is rank {rank}", c.rank);
        anyhow::ensure!(
            c.base_codec.name() == cfg.codec.name(),
            "checkpoint was written under --codec {}, this run has {}",
            c.base_codec.name(),
            cfg.codec.name()
        );
        // A full-mode snapshot holds replicated momentum, a sharded one
        // only this rank's spans — resuming across modes would silently
        // corrupt the optimizer state, so it is refused outright.
        c.ensure_exchange_mode(cfg.exchange_mode)?;
    }

    let mut params = match &restore {
        Some(c) => c.params.clone(),
        None => init_params(meta, cfg.seed),
    };
    let sizes_fwd: Vec<usize> = meta.tensors.iter().map(|t| t.elems).collect();

    let mut runner = if cfg.synthetic.is_some() {
        StepRunner::Synthetic {
            sizes_fwd: sizes_fwd.clone(),
            seed: cfg.seed,
            rank,
            next_step: 0,
            last_secs: 0.0,
        }
    } else {
        let corpus = setup
            .corpus
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("artifact mode requires a corpus"))?;
        StepRunner::Pjrt {
            exec: TrainStep::load(&cfg.artifact, meta.clone())?,
            batcher: Batcher::new(
                corpus,
                rank,
                comm.world(),
                cfg.batch_per_worker,
                cfg.seq_len,
                cfg.seed,
            ),
        }
    };

    // DGC carries its own momentum correction (it transmits an
    // accumulated-velocity stream); stacking optimizer momentum on
    // top would double-apply it (DGC paper Alg. 1).
    let momentum = match cfg.codec {
        crate::compression::CodecKind::Dgc { .. } => 0.0,
        _ => cfg.momentum,
    };

    // --- warm-up + schedule ----------------------------------------------
    let (partition, warmup_evals, fits) = if let Some(c) = &restore {
        // A resumed run re-adopts the checkpointed schedule verbatim
        // instead of re-searching: a fresh timing-based search could pick
        // a different partition and break bit-exactness against the
        // uninterrupted run. The online estimator restarts cold and
        // re-warms from live measurements (see Driver::restore_schedule).
        // Cross-check that every rank restored the same interval boundary
        // before any real traffic flows.
        let mut tag = Vec::with_capacity(16);
        tag.extend_from_slice(&(c.step as u64).to_le_bytes());
        tag.extend_from_slice(&c.param_digest().to_le_bytes());
        let all = comm.allgather(tag.clone())?;
        for (peer, t) in all.iter().enumerate() {
            anyhow::ensure!(
                t == &tag,
                "resume mismatch: rank {peer} restored a different (step, param digest) than \
                 rank {rank} — all ranks must resume from snapshots of the same interval \
                 boundary"
            );
        }
        (c.partition()?, 0usize, WarmupFits::default())
    } else {
        // One step to measure compute time; average the measurement so all
        // ranks feed rank 0's search comparable numbers on a time-sliced
        // CPU. Under --accum-steps the schedule amortizes one exchange
        // over `accum` micro-steps, so the compute term scales with it.
        let (_, _) = runner.run(&params)?;
        let mut step_secs = runner.last_exec_secs();
        let mut t = [step_secs as f32];
        comm.allreduce_f32(&mut t)?;
        step_secs = (t[0] / comm.world() as f32) as f64;
        resolve_schedule(
            comm,
            cfg,
            meta,
            &setup.profile,
            step_secs * cfg.accum_steps.max(1) as f64,
        )?
    };
    let mut exchange = GradExchange::new(
        cfg.codec,
        partition.clone(),
        meta.sizes_backprop_order(),
    )
    .with_mode(cfg.pipeline)
    .with_exchange_mode(cfg.exchange_mode);
    // The optimizer's shape follows the exchange mode: sharded mode owns
    // one momentum span per scheduled group (so it must be built against
    // the resolved partition), full mode replicates everything.
    let mut opt = match cfg.exchange_mode {
        ExchangeMode::Full => Opt::Full(SgdMomentum::new(cfg.lr, momentum, &sizes_fwd)),
        ExchangeMode::Sharded => {
            let spans = exchange.owned_group_ranges(comm.world(), comm.rank());
            Opt::Sharded(ShardedSgdMomentum::new(
                cfg.lr,
                momentum,
                exchange.group_elems(),
                &spans,
            ))
        }
    };
    if let Some(c) = &restore {
        if !c.routes.is_empty() {
            exchange.set_routes(Some(c.routes.clone()))?;
        }
        if !c.codecs.is_empty() {
            exchange.set_codecs(Some(c.codecs.clone()))?;
        }
        // Last: set_codecs carries/resets EF state, and the snapshot's
        // planes must win over whatever that policy left behind.
        exchange.load_flat_state(&c.codec_state)?;
        match &mut opt {
            Opt::Full(o) => o.load_velocity(&c.velocity)?,
            // The snapshot stores full-length per-tensor planes (zeros
            // outside this rank's spans); the same schedule and world are
            // guaranteed above, so slicing the owned spans restores the
            // momentum bit-exactly.
            Opt::Sharded(o) => o.load_group_planes(&group_planes_from_tensors(
                &c.velocity,
                exchange.group_elems(),
            ))?,
        }
    }

    // --- online rescheduler (measure → search → repartition) -------------
    // Only meaningful for the searched schedule; static specs have
    // nothing to re-search.
    let mut driver = build_driver(comm, cfg, meta, &setup.profile, fits, &partition)?;
    if let (Some(d), Some(c)) = (driver.as_mut(), &restore) {
        d.restore_schedule(partition.clone(), c.routes.clone(), c.codecs.clone(), c.schedule_epoch)?;
    }

    // --- training loop ---------------------------------------------------
    // A fresh run's warmup consumed synthetic step 0, so loop step S draws
    // runner steps S·accum+1 ..= S·accum+accum (exactly S+1 when accum=1);
    // a resumed run fast-forwards to the same position so the gradient
    // streams line up with the uninterrupted run's.
    let accum = cfg.accum_steps.max(1);
    let start_step = restore.as_ref().map(|c| c.step).unwrap_or(0);
    if restore.is_some() {
        anyhow::ensure!(
            runner.seek(start_step as u64 * accum as u64 + 1),
            "--resume/--join require the synthetic step source"
        );
    }
    let t0 = Stopwatch::start();
    let mut records = Vec::new();
    let mut sum_exchange = ExchangeStats::default();
    let mut sum_step = 0.0f64;
    let mut last_loss = 0f32;
    let mut recoveries = 0usize;
    let mut joins = usize::from(joined);
    for step in start_step..cfg.steps {
        if policy.die_at_step == Some(step) && rank == policy.die_rank && !policy.join {
            // The chaos hook: a hard exit with no unwinding or socket
            // shutdown, indistinguishable from a SIGKILLed worker — peers
            // learn about it from the wire, not from us. A `--join`
            // replacement ignores the switch, or it would re-die at the
            // very step it rejoined. Drain the background checkpoint
            // writer first: the replacement restores this rank's EF
            // planes from the snapshot we are about to leave behind.
            if let Some(w) = ckptr.as_ref() {
                let _ = w.flush();
            }
            eprintln!("rank {rank}: --die-at-step {step}: aborting process");
            std::process::abort();
        }

        let mut attempt = 0usize;
        let (loss, stats, compute_secs) = loop {
            // Elastic runs snapshot codec state before the exchange: a
            // partially-failed exchange leaves EF accumulators consumed
            // for the groups that encoded before the wire died, and the
            // retry must start from the pre-step state.
            let state_backup = elastic.then(|| exchange.flat_state());
            let (loss, grads_fwd, step_secs) = run_accum(&mut runner, &params, accum)?;

            // Reorder to backprop order for the exchange, then back.
            let mut grads_bp: Vec<Vec<f32>> = grads_fwd.into_iter().rev().collect();
            let mut rng = exchange_rng(cfg.seed, rank, step);
            match exchange.exchange(comm, &mut grads_bp, &mut rng) {
                Ok(stats) => {
                    sum_step += step_secs;
                    match &mut opt {
                        Opt::Full(o) => {
                            let grads_fwd: Vec<Vec<f32>> =
                                grads_bp.into_iter().rev().collect();
                            o.step(&mut params, &grads_fwd);
                        }
                        Opt::Sharded(o) => {
                            sharded_update(comm, o, &exchange, &mut params, &grads_bp)?;
                        }
                    }
                    break (loss, stats, step_secs);
                }
                Err(e) => {
                    let recoverable = elastic
                        && e.is_recoverable()
                        && attempt < MAX_RECOVERIES_PER_STEP
                        && comm.world() > 1;
                    if !recoverable {
                        return Err(anyhow::anyhow!("step {step}: gradient exchange failed: {e}"));
                    }
                    attempt += 1;
                    let velocity = opt.velocity_tensors(&sizes_fwd);
                    let dead = recover_prologue(
                        comm,
                        cfg,
                        step,
                        &e,
                        &mut exchange,
                        driver.as_ref(),
                        &params,
                        &velocity,
                        state_backup.as_deref().unwrap_or(&[]),
                        ckpt_dir.as_deref(),
                        rank,
                    )?;
                    // Prefer growing the world back over shrinking it:
                    // when a rejoin window is configured, every survivor
                    // re-runs the rendezvous at full world and waits for
                    // a replacement launched with `--join`. Only the
                    // full-world TCP group can re-grow (a previous shrink
                    // renumbered ranks; rank 0 must survive to host the
                    // rendezvous and stream the snapshot).
                    let try_rejoin = policy.rejoin_wait_secs > 0
                        && matches!(cfg.transport, TransportKind::Tcp)
                        && comm.world() == cfg.workers
                        && !dead.contains(&0);
                    let mut rejoined = false;
                    if try_rejoin {
                        let snapshot = (comm.rank() == 0).then(|| {
                            build_checkpoint(
                                step,
                                comm.world(),
                                0,
                                cfg,
                                &exchange,
                                driver.as_ref(),
                                &params,
                                &velocity,
                            )
                        });
                        match join::hot_rejoin_survivor(
                            comm,
                            cfg,
                            step,
                            &dead,
                            snapshot.as_ref(),
                            params_digest(&params),
                        ) {
                            Ok(()) => {
                                rejoined = true;
                                joins += 1;
                                eprintln!(
                                    "rank {rank}: peers {dead:?} hot re-joined at step {step}; \
                                     continuing at full world {}",
                                    comm.world()
                                );
                            }
                            Err(join_err) => eprintln!(
                                "rank {rank}: hot re-join at step {step} failed ({join_err}); \
                                 falling back to elastic shrink"
                            ),
                        }
                    }
                    if !rejoined {
                        recoveries += 1;
                        shrink_after_peer_loss(
                            comm,
                            cfg,
                            meta,
                            &setup.profile,
                            fits,
                            step,
                            &dead,
                            &mut exchange,
                            &mut driver,
                            &params,
                            rank,
                        )?;
                        // The shrink changed the ownership map: every
                        // element span moves to its new owner, and spans
                        // whose owner died restart momentum at zero on
                        // every survivor. A rejoin keeps the world and the
                        // ownership map intact (the joiner restored its
                        // own spans from disk), so it needs no reshard.
                        if let Opt::Sharded(o) = &opt {
                            let fresh = reshard_sharded(comm, o, momentum, &exchange)?;
                            opt = Opt::Sharded(fresh);
                        }
                    }
                    // Rewind the gradient stream so the retried step draws
                    // the same per-rank gradients it failed with.
                    anyhow::ensure!(
                        runner.seek(step as u64 * accum as u64 + 1),
                        "elastic retry requires the synthetic step source"
                    );
                }
            }
        };
        sum_exchange.accumulate(&stats);

        // Online loop: feed measurements; at reschedule boundaries
        // the lead rank re-searches and the epoch-tagged broadcast applies
        // any switch on every rank at the same step, remapping EF
        // state bit-exactly and installing the per-group routes.
        if let Some(d) = driver.as_mut() {
            d.observe(exchange.group_samples(), compute_secs);
            if d.due(step) {
                let decision = if comm.rank() == 0 { d.decide() } else { Decision::Keep };
                if let Some(update) = d.sync(comm, decision)? {
                    // Order matters: repartition first (it normalizes any
                    // mixed codecs back to the base codec before state is
                    // re-chunked), then the routes, then the per-group
                    // codecs of the new schedule.
                    exchange.repartition(update.partition)?;
                    let routes = (!update.routes.is_empty()).then_some(update.routes);
                    exchange.set_routes(routes)?;
                    let codecs = (!update.codecs.is_empty()).then_some(update.codecs);
                    exchange.set_codecs(codecs)?;
                    // New group bounds → new ownership map: move every
                    // momentum span to its new owner bit-exactly (same
                    // element, same value, different custodian).
                    if let Opt::Sharded(o) = &opt {
                        let fresh = reshard_sharded(comm, o, momentum, &exchange)?;
                        opt = Opt::Sharded(fresh);
                    }
                }
            }
        }

        // Mean loss across workers for logging.
        let mut l = [loss];
        comm.allreduce_f32(&mut l)?;
        last_loss = l[0] / comm.world() as f32;
        if comm.rank() == 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            records.push(StepRecord {
                step,
                loss: last_loss,
                elapsed: t0.elapsed().as_secs_f64(),
                exchange: stats,
            });
        }

        // Interval snapshot, taken after the optimizer applied `step` (so
        // it records `step + 1` completed steps). Every rank snapshots
        // its own state at the same boundary — the agreement a later
        // `--resume` (or hot `--join`) cross-checks. Only the state clone
        // happens here; the background writer serializes it (re-rendering
        // only planes whose bits changed) and persists it atomically.
        if let (Some(dir), Some(w)) = (&ckpt_dir, &ckptr) {
            if (step + 1) % policy.checkpoint_interval == 0 {
                let ckpt = build_checkpoint(
                    step + 1,
                    comm.world(),
                    rank,
                    cfg,
                    &exchange,
                    driver.as_ref(),
                    &params,
                    &opt.velocity_tensors(&sizes_fwd),
                );
                w.submit(Checkpoint::rank_path(dir, rank), ckpt)?;
            }
        }
    }

    // --- held-out evaluation ---------------------------------------------
    let eval_loss = match &mut runner {
        StepRunner::Pjrt { exec, .. } => {
            let corpus = setup
                .corpus
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("artifact mode requires a corpus"))?;
            let mut eval_batcher = Batcher::new(
                corpus,
                rank,
                comm.world(),
                cfg.batch_per_worker,
                cfg.seq_len,
                cfg.seed ^ 0xE7A1_5EED,
            );
            let mut eval_sum = 0f32;
            let eval_batches = 4;
            for _ in 0..eval_batches {
                let (x, y) = eval_batcher.next_batch();
                let (loss, _) = exec.run(&params, &x, &y)?;
                eval_sum += loss;
            }
            let mut e = [eval_sum / eval_batches as f32];
            comm.allreduce_f32(&mut e)?;
            e[0] / comm.world() as f32
        }
        // Synthetic losses carry no held-out signal; report the final
        // (already rank-averaged) training loss. No collective here, so
        // the op sequence stays symmetric across ranks by construction.
        StepRunner::Synthetic { .. } => last_loss,
    };

    // Means are over the steps this process actually executed (a resumed
    // run skips the checkpointed prefix).
    let steps = cfg.steps.saturating_sub(start_step).max(1) as f64;
    let (reschedules, online_evals, schedule_epoch) = driver
        .as_ref()
        .map(|d| (d.reschedules, d.search_evals, d.epoch()))
        .unwrap_or((0, 0, 0));
    let final_routes = exchange.routes().map(|r| r.to_vec()).unwrap_or_default();
    let final_codecs = exchange.group_codecs();
    let two_level_fit = driver.as_ref().and_then(|d| d.estimator().two_level_fit());
    // Per-rank memory accounting (the sharded exchange's selling point):
    // params + one live gradient set at 4 B/elem each, plus momentum —
    // full/world-ish under sharded — plus the rank-local EF planes.
    let total_params: usize = sizes_fwd.iter().sum();
    let optimizer_state_bytes = opt.state_bytes(total_params);
    let codec_state_bytes: u64 = exchange.flat_state().iter().map(|p| 4 * p.len() as u64).sum();
    let peak_memory_bytes = 8 * total_params as u64 + optimizer_state_bytes + codec_state_bytes;
    // Drain the background checkpoint writer (surfacing any write error it
    // latched) and report its accumulated write time — the cost the hot
    // path no longer pays.
    let ckpt_async_write_secs = match &ckptr {
        Some(w) => {
            w.flush()?;
            w.write_secs()
        }
        None => 0.0,
    };
    Ok(RunResult {
        rank,
        records,
        partition: exchange.partition().clone(),
        final_routes,
        final_codecs,
        two_level_fit,
        final_train_loss: last_loss,
        eval_loss,
        mean_step_secs: sum_step / steps,
        mean_exchange: sum_exchange.scaled(steps),
        search_evals: warmup_evals + online_evals,
        reschedules,
        schedule_epoch,
        total_bytes_sent: sum_exchange.bytes_sent,
        total_inter_bytes_sent: sum_exchange.inter_bytes_sent,
        steps: cfg.steps,
        param_digest: params_digest(&params),
        world_at_end: comm.world(),
        recoveries,
        joins,
        resumed_from_step: restore.as_ref().map(|c| c.step),
        exchange_mode: cfg.exchange_mode,
        optimizer_state_bytes,
        peak_memory_bytes,
        ckpt_async_write_secs,
    })
}

/// The bootstrap generation for this process: a relaunched rank re-HELLOs
/// with a generation above its dead predecessor's so the rendezvous
/// supersedes the stale registration (`MERGECOMP_GENERATION`, default 0 =
/// first launch). An environment variable rather than a flag because the
/// supervisor relaunching the rank sets it, not the user.
fn bootstrap_generation() -> u64 {
    std::env::var("MERGECOMP_GENERATION").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

/// Run one data-parallel training job.
///
/// - `transport = inproc`: spawns all `cfg.workers` ranks as threads in
///   this process and returns **rank 0's** result (any rank failing fails
///   the run).
/// - `transport = tcp`: this process is rank `cfg.rank` of `cfg.workers`;
///   bootstraps through `cfg.rendezvous` and returns **this rank's**
///   result. Launch one process per rank (`mergecomp launch` automates the
///   single-machine case).
pub fn train(cfg: &TrainConfig) -> anyhow::Result<RunResult> {
    let setup = prepare_setup(cfg)?;
    anyhow::ensure!(
        !cfg.policy.join || matches!(cfg.transport, TransportKind::Tcp),
        "--join requires --transport tcp: a hot joiner re-HELLOs into a live process group"
    );
    match cfg.transport {
        TransportKind::InProc => {
            let results: Vec<anyhow::Result<RunResult>> =
                run_comm_group(cfg.workers, |comm: &mut Comm| train_rank(comm, cfg, &setup, None));
            let mut rank0 = None;
            for r in results {
                let r = r.map_err(|e| anyhow::anyhow!("worker failed: {e}"))?;
                if r.rank == 0 {
                    rank0 = Some(r);
                }
            }
            rank0.ok_or_else(|| anyhow::anyhow!("rank 0 produced no result"))
        }
        TransportKind::Tcp => {
            anyhow::ensure!(
                cfg.rank < cfg.workers,
                "--rank {} out of range for --world {}",
                cfg.rank,
                cfg.workers
            );
            let topo = cfg.topology.build(cfg.workers)?;
            let tcp_cfg = TcpConfig {
                rank: cfg.rank,
                world: cfg.workers,
                rendezvous: cfg.rendezvous.clone(),
                advertise_host: cfg.advertise_host.clone(),
                node_label: topo.node_label(cfg.rank),
                timeout: std::time::Duration::from_secs(cfg.bootstrap_timeout_secs.max(1)),
                generation: bootstrap_generation(),
                faults: cfg.policy.fault_plan()?,
                config_token: Some(join::config_token(cfg)),
            };
            let (ep, peer_nodes) = tcp_endpoint_with_nodes(&tcp_cfg, None)?;
            // Cross-check: every peer must have been launched with the
            // same --topology, or its registered node label disagrees with
            // the one this rank derives for it — mismatched topologies
            // would make ranks route collectives differently and deadlock.
            for (peer, label) in peer_nodes.iter().enumerate() {
                let expect = topo.node_label(peer);
                anyhow::ensure!(
                    label == &expect,
                    "rank {peer} registered node label '{label}' but this rank's \
                     --topology {} places it on '{expect}' — all ranks must be \
                     launched with the same --topology",
                    cfg.topology.name()
                );
            }
            let mut comm = Comm::new(ep);
            // A `--join` process's bootstrap WAS the group's re-rendezvous;
            // collect the snapshot stream before entering the training
            // loop at the announced resume step.
            let join_ckpt = if cfg.policy.join {
                Some(join::receive_join_snapshot(&mut comm, cfg)?)
            } else {
                None
            };
            let result = train_rank(&mut comm, cfg, &setup, join_ckpt)?;
            // Final barrier: no rank tears its sockets down while a peer
            // still has collectives in flight.
            comm.barrier()?;
            Ok(result)
        }
    }
}
