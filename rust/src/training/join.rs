//! The online join protocol: grow a shrunk-or-about-to-shrink world back
//! to full size **without restarting the run** (DESIGN.md "Online join").
//!
//! Two halves, one collective program:
//!
//! - **Survivors** ([`hot_rejoin_survivor`]): after a peer loss (and the
//!   usual rollback + emergency snapshot), instead of shrinking they
//!   re-run the rendezvous at the *full* configured world with a bumped
//!   generation and wait up to `rejoin_wait_secs` for a replacement rank
//!   to HELLO in. Rank 0 announces `(generation, resume step)` on
//!   [`JOIN_TAG`] and streams each joiner the replicated state as a
//!   chunk-framed [`Checkpoint`] on `SNAPSHOT_TAG`; everyone then adopts
//!   the fresh endpoint, re-attaches the topology, and cross-checks
//!   `(step, param digest)` before the retried step runs at full world.
//! - **The joiner** ([`receive_join_snapshot`]): a respawned process
//!   launched with `--join`. Its bootstrap *is* the re-rendezvous; it
//!   then learns the generation and resume step from the JOIN
//!   announcement, receives the snapshot stream, and merges it with its
//!   own last interval checkpoint — replicated state (params, schedule,
//!   full-mode velocity) comes off the wire, rank-local state (EF/codec
//!   planes, sharded velocity spans) comes from its own disk, because
//!   no survivor ever held it.
//!
//! The merge is only sound when the joiner's local snapshot sits at the
//! exact step the group resumes from — which `--checkpoint-interval 1`
//! guarantees (every completed step leaves a restorable snapshot, written
//! asynchronously so the hot path does not pay for it).

use std::path::Path;
use std::time::Duration;

use crate::collectives::snapshot::{decode_join, encode_join};
use crate::collectives::{
    recv_snapshot, send_snapshot, tcp_endpoint_with_nodes, Comm, CommRoute, TcpConfig, JOIN_TAG,
};
use crate::config::TrainConfig;
use crate::coordinator::{Checkpoint, ExchangeMode};
use crate::scheduler::RouteMode;

/// The compatibility token every rank registers at the rendezvous
/// (`HELLO ... c<token>`). Rank 0 refuses a HELLO whose token disagrees
/// with its own, so a joiner relaunched with the wrong `--seed`,
/// `--codec`, `--topology`, or `--exchange-mode` is rejected with an
/// actionable error instead of silently corrupting the run.
pub(crate) fn config_token(cfg: &TrainConfig) -> String {
    format!(
        "seed={:016x}:codec={}:topo={}:xmode={}",
        cfg.seed,
        cfg.codec.name(),
        cfg.topology.name(),
        cfg.exchange_mode.name()
    )
}

/// Survivor half of the hot re-join at `step` (the step being retried;
/// equivalently, the number of completed optimizer steps). `dead` lists
/// the lost ranks in old-world numbering; `snapshot` is rank 0's
/// replicated-state checkpoint (`None` on every other rank); `digest` is
/// the FNV-1a digest of the current parameters.
///
/// On success the communicator runs the *full* configured world again,
/// with the topology re-attached exactly as `train_rank` attaches it at
/// startup — the joined group's collective program (reduction order
/// included) is indistinguishable from a never-failed run's. On failure
/// before the endpoint swap the old communicator is untouched and the
/// caller falls back to the elastic shrink; on failure after the swap the
/// joiners are told to abort and the shrink fallback operates on the new
/// endpoint with the same dead set.
pub(crate) fn hot_rejoin_survivor(
    comm: &mut Comm,
    cfg: &TrainConfig,
    step: usize,
    dead: &[usize],
    snapshot: Option<&Checkpoint>,
    digest: u64,
) -> anyhow::Result<()> {
    let world = cfg.workers;
    anyhow::ensure!(
        comm.world() == world,
        "hot re-join requires the full-world communicator (have {}, configured {world}) — a \
         previously shrunk run cannot re-grow",
        comm.world()
    );
    anyhow::ensure!(
        snapshot.is_some() == (comm.rank() == 0),
        "hot re-join: exactly rank 0 streams the snapshot"
    );
    // The generation every post-join frame is tagged with: one above the
    // abort epoch the loss bumped us to, so stale old-generation traffic
    // is filtered on arrival.
    let generation = comm.ep.abort_epoch() + 1;

    // Re-rendezvous at full world. Rank 0 re-binds the original
    // rendezvous address (the bootstrap listener is not held open between
    // uses); everyone else dials with retry, which also covers the
    // joiner racing ahead of slow survivors. A timeout here — no
    // replacement showed up within `rejoin_wait_secs` — leaves the old
    // endpoint untouched.
    let topo = cfg.topology.build(world)?;
    let tcp_cfg = TcpConfig {
        rank: comm.rank(),
        world,
        rendezvous: cfg.rendezvous.clone(),
        advertise_host: cfg.advertise_host.clone(),
        node_label: topo.node_label(comm.rank()),
        timeout: Duration::from_secs(cfg.policy.rejoin_wait_secs.max(1)),
        generation,
        faults: None,
        config_token: Some(config_token(cfg)),
    };
    let (mut ep, _peer_nodes) = tcp_endpoint_with_nodes(&tcp_cfg, None)?;

    // JOIN announcement + snapshot stream, on the raw endpoint before
    // adoption (control traffic, not part of the tagged collective
    // sequence). Rank 0 is authoritative for the (generation, step) pair;
    // survivors sanity-check it against their own computation.
    if comm.rank() == 0 {
        let snap = snapshot.expect("checked above");
        for peer in 1..world {
            ep.send(peer, JOIN_TAG, encode_join(generation, step as u64))?;
        }
        for &d in dead {
            let mut c = snap.clone();
            c.rank = d;
            send_snapshot(&mut ep, d, &c.to_bytes())?;
        }
    } else {
        let (g, s) = decode_join(&ep.recv(0, JOIN_TAG)?)?;
        anyhow::ensure!(
            g == generation && s == step as u64,
            "hot re-join: rank 0 announced generation {g} / step {s} but this survivor computed \
             generation {generation} / step {step} — survivors disagree on the join point"
        );
    }

    // Point of no return: swap the communicator onto the full-world
    // endpoint. Everything after this must either succeed or abort the
    // joiners before erroring, so nobody blocks on a half-joined group.
    comm.adopt_endpoint(ep, generation)?;
    let verify = |comm: &mut Comm| -> anyhow::Result<()> {
        comm.barrier()?;
        // Re-attach the topology exactly as train_rank does at startup
        // (the joiner runs that very code): hierarchical reduction order
        // is part of bit-exactness, so the joined world must route — and
        // reduce — like the original one.
        comm.set_topology(cfg.topology.build(world)?)?;
        if cfg.route == RouteMode::Flat {
            comm.set_route(CommRoute::Flat);
        }
        let mut tag = Vec::with_capacity(16);
        tag.extend_from_slice(&(step as u64).to_le_bytes());
        tag.extend_from_slice(&digest.to_le_bytes());
        let all = comm.allgather(tag.clone())?;
        for (peer, t) in all.iter().enumerate() {
            anyhow::ensure!(
                t == &tag,
                "hot re-join: rank {peer} disagrees on (step, param digest) at step {step} — \
                 the joined world diverged, cannot continue"
            );
        }
        Ok(())
    };
    if let Err(e) = verify(comm) {
        for &d in dead {
            comm.ep.broadcast_abort(d, &format!("hot re-join failed: {e}"));
        }
        return Err(e);
    }
    Ok(())
}

/// Joiner half: called right after the `--join` process's bootstrap (its
/// re-rendezvous), before `train_rank`. Returns the restore point the
/// training loop resumes from: the streamed replicated state merged with
/// this rank's own interval checkpoint.
pub(crate) fn receive_join_snapshot(
    comm: &mut Comm,
    cfg: &TrainConfig,
) -> anyhow::Result<Checkpoint> {
    anyhow::ensure!(
        cfg.rank != 0,
        "--join: rank 0 hosts the rendezvous and streams the snapshot; it cannot hot-join a \
         live group"
    );
    let (generation, step) = decode_join(&comm.ep.recv(0, JOIN_TAG)?)?;
    comm.align_generation(generation);
    let streamed = Checkpoint::from_bytes(&recv_snapshot(&mut comm.ep, 0)?)?;
    anyhow::ensure!(
        streamed.step == step as usize,
        "--join: rank 0 announced resume step {step} but streamed a step-{} snapshot",
        streamed.step
    );
    anyhow::ensure!(
        streamed.rank == cfg.rank,
        "--join: rank 0 streamed rank {}'s snapshot to rank {}",
        streamed.rank,
        cfg.rank
    );

    // Merge: replicated state off the wire, rank-local state from this
    // rank's own last interval snapshot. The EF/codec planes a dead rank
    // accumulated exist nowhere else — without them (or with stale ones)
    // the joined run would diverge from the never-failed run.
    let dir = cfg
        .policy
        .checkpoint_dir
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("--join requires --checkpoint-dir"))?;
    let path = Checkpoint::rank_path(Path::new(dir), cfg.rank);
    let local = Checkpoint::load(&path).map_err(|e| {
        anyhow::anyhow!(
            "--join: cannot load this rank's interval checkpoint ({}): {e} — hot join restores \
             rank-local EF/codec planes from disk; run with --checkpoint-dir/--checkpoint-interval \
             so the dying rank left one behind",
            path.display()
        )
    })?;
    anyhow::ensure!(
        local.step == streamed.step,
        "--join: this rank's interval checkpoint is at step {} but the group resumes at step {} \
         — rank-local EF planes must match the join boundary exactly; run with \
         --checkpoint-interval 1 so every completed step leaves a snapshot",
        local.step,
        streamed.step
    );
    anyhow::ensure!(
        local.bounds == streamed.bounds && local.codecs == streamed.codecs,
        "--join: this rank's interval checkpoint was written under a different schedule \
         (bounds/codecs) than the live group's — its EF planes do not line up with the group \
         boundaries"
    );
    let mut merged = streamed;
    merged.codec_state = local.codec_state;
    if merged.exchange_mode == ExchangeMode::Sharded {
        // Sharded velocity spans are rank-local too; the streamed planes
        // are rank 0's and zero outside rank 0's spans.
        merged.velocity = local.velocity;
    }
    // Mirror the survivors' post-adoption barrier; the (step, digest)
    // cross-check that completes the join handshake is train_rank's
    // standard restore verification.
    comm.barrier()?;
    Ok(merged)
}
