//! Gradient exchange: paper Algorithm 1's inner loop.
//!
//! Per group (in backprop order): merge the group's tensors into one flat
//! buffer, encode with the codec (EF state lives in the per-group codec
//! instance), synchronize with the codec's collective (Table 1), decode +
//! average, and scatter back into the per-tensor buffers.

use crate::collectives::Comm;
use crate::compression::{Codec, CodecKind, Collective, Encoded};
use crate::scheduler::Partition;
use crate::util::rng::Xoshiro256;
use crate::util::stats::Stopwatch;

/// Per-step timing/size accounting (feeds the measured cost models and the
/// EXPERIMENTS.md overhead tables).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExchangeStats {
    pub encode_secs: f64,
    pub comm_secs: f64,
    pub decode_secs: f64,
    pub bytes_sent: u64,
    pub groups: usize,
}

impl ExchangeStats {
    pub fn total_secs(&self) -> f64 {
        self.encode_secs + self.comm_secs + self.decode_secs
    }
}

/// One worker's exchange state for a fixed (codec, partition) pair.
pub struct GradExchange {
    kind: CodecKind,
    partition: Partition,
    /// Per-tensor element counts, backprop order.
    sizes: Vec<usize>,
    /// One stateful codec per group (EF granularity = group, §4.2).
    codecs: Vec<Box<dyn Codec>>,
    group_elems: Vec<usize>,
    flat: Vec<f32>, // merge scratch
}

impl GradExchange {
    pub fn new(kind: CodecKind, partition: Partition, sizes_backprop: Vec<usize>) -> Self {
        let group_elems = partition.group_elems(&sizes_backprop);
        let codecs = group_elems.iter().map(|&n| kind.build(n)).collect();
        let max_group = group_elems.iter().copied().max().unwrap_or(0);
        GradExchange {
            kind,
            partition,
            sizes: sizes_backprop,
            codecs,
            group_elems,
            flat: Vec::with_capacity(max_group),
        }
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    pub fn kind(&self) -> CodecKind {
        self.kind
    }

    /// Aggregate gradients across the group. `grads` holds per-tensor
    /// buffers in **backprop order**; on return each buffer contains the
    /// mean of the (compressed) gradients over all workers.
    pub fn exchange(
        &mut self,
        comm: &mut Comm,
        grads: &mut [Vec<f32>],
        rng: &mut Xoshiro256,
    ) -> ExchangeStats {
        assert_eq!(grads.len(), self.sizes.len());
        let world = comm.world() as f32;
        let mut stats = ExchangeStats {
            groups: self.partition.num_groups(),
            ..Default::default()
        };
        let bytes_before = comm.bytes_sent();

        for j in 0..self.partition.num_groups() {
            let range = self.partition.group_range(j);
            let n = self.group_elems[j];

            // --- merge -----------------------------------------------------
            self.flat.clear();
            for i in range.clone() {
                self.flat.extend_from_slice(&grads[i]);
            }
            debug_assert_eq!(self.flat.len(), n);

            // --- encode ----------------------------------------------------
            let sw = Stopwatch::start();
            let enc = self.codecs[j].encode(&self.flat, rng);
            stats.encode_secs += sw.elapsed().as_secs_f64();

            // --- communicate + decode --------------------------------------
            match self.kind.collective() {
                Collective::AllReduce => {
                    let mut wire = enc.bytes;
                    let sw = Stopwatch::start();
                    comm.allreduce_wire(&mut wire, self.codecs[j].as_ref());
                    stats.comm_secs += sw.elapsed().as_secs_f64();

                    let sw = Stopwatch::start();
                    let summed = Encoded { bytes: wire, n };
                    self.codecs[j].decode(&summed, &mut self.flat);
                    for v in self.flat.iter_mut() {
                        *v /= world;
                    }
                    stats.decode_secs += sw.elapsed().as_secs_f64();
                }
                Collective::AllGather => {
                    let sw = Stopwatch::start();
                    let payloads = comm.allgather(enc.bytes);
                    stats.comm_secs += sw.elapsed().as_secs_f64();

                    let sw = Stopwatch::start();
                    self.flat.clear();
                    self.flat.resize(n, 0.0);
                    let w = 1.0 / world;
                    for bytes in payloads {
                        let e = Encoded { bytes, n };
                        self.codecs[j].decode_add(&e, &mut self.flat, w);
                    }
                    stats.decode_secs += sw.elapsed().as_secs_f64();
                }
            }

            // --- scatter back ---------------------------------------------
            let mut off = 0;
            for i in range {
                let len = self.sizes[i];
                grads[i].copy_from_slice(&self.flat[off..off + len]);
                off += len;
            }
        }

        stats.bytes_sent = comm.bytes_sent() - bytes_before;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_comm_group;

    fn make_grads(rank: usize, sizes: &[usize]) -> Vec<Vec<f32>> {
        sizes
            .iter()
            .enumerate()
            .map(|(t, &n)| {
                (0..n)
                    .map(|i| (rank + 1) as f32 * (t as f32 + 1.0) + i as f32 * 0.001)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn fp32_exchange_is_exact_mean() {
        let sizes = vec![5usize, 3, 7];
        for partition in [
            Partition::layer_wise(3),
            Partition::full_merge(3),
            Partition::naive_even(3, 2),
        ] {
            let sizes2 = sizes.clone();
            let partition2 = partition.clone();
            let results = run_comm_group(3, move |c| {
                let mut ex =
                    GradExchange::new(CodecKind::Fp32, partition2.clone(), sizes2.clone());
                let mut rng = Xoshiro256::seed_from_u64(c.rank() as u64);
                let mut grads = make_grads(c.rank(), &sizes2);
                ex.exchange(c, &mut grads, &mut rng);
                grads
            });
            // Expected mean over ranks: mean(rank+1) = 2.
            for r in &results {
                for (t, buf) in r.iter().enumerate() {
                    for (i, v) in buf.iter().enumerate() {
                        let want = 2.0 * (t as f32 + 1.0) + i as f32 * 0.001;
                        assert!(
                            (v - want).abs() < 1e-4,
                            "partition {partition}: tensor {t} idx {i}: {v} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_workers_agree_after_exchange() {
        // Model consistency: every codec must leave identical aggregated
        // gradients on every worker (the heart of synchronous SGD).
        let sizes = vec![40usize, 25, 70];
        for kind in [
            CodecKind::Fp16,
            CodecKind::Qsgd { bits: 8 },
            CodecKind::TopK { ratio: 0.1 },
            CodecKind::Dgc { ratio: 0.1 },
            CodecKind::EfSignSgd,
            CodecKind::SignSgd,
            CodecKind::OneBit,
        ] {
            let sizes2 = sizes.clone();
            let results = run_comm_group(2, move |c| {
                let mut ex = GradExchange::new(
                    kind,
                    Partition::naive_even(3, 2),
                    sizes2.clone(),
                );
                let mut rng = Xoshiro256::seed_from_u64(100 + c.rank() as u64);
                let mut grads = make_grads(c.rank(), &sizes2);
                ex.exchange(c, &mut grads, &mut rng);
                grads
            });
            assert_eq!(
                results[0], results[1],
                "{}: workers disagree after exchange",
                kind.name()
            );
        }
    }

    #[test]
    fn stats_account_bytes() {
        let sizes = vec![100usize];
        let results = run_comm_group(2, move |c| {
            let mut ex = GradExchange::new(
                CodecKind::Fp32,
                Partition::full_merge(1),
                sizes.clone(),
            );
            let mut rng = Xoshiro256::seed_from_u64(0);
            let mut grads = vec![vec![1.0f32; 100]];
            ex.exchange(c, &mut grads, &mut rng)
        });
        for s in results {
            // Ring allreduce, 2 ranks: each sends ~bytes of the buffer.
            assert!(s.bytes_sent >= 400);
            assert_eq!(s.groups, 1);
            assert!(s.encode_secs >= 0.0 && s.decode_secs >= 0.0);
        }
    }

    #[test]
    fn ef_state_persists_across_steps() {
        // With EF codecs, repeating the same gradient must transmit the
        // leftover residual: the 2-step mean gets closer to the truth than
        // the 1-step mean.
        let sizes = vec![256usize];
        let results = run_comm_group(2, move |c| {
            let mut ex = GradExchange::new(
                CodecKind::EfSignSgd,
                Partition::full_merge(1),
                sizes.clone(),
            );
            let mut rng = Xoshiro256::seed_from_u64(5 + c.rank() as u64);
            let mut base = vec![0f32; 256];
            Xoshiro256::seed_from_u64(99).fill_normal_f32(&mut base, 1.0);

            let mut g1 = vec![base.clone()];
            ex.exchange(c, &mut g1, &mut rng);
            let mut g2 = vec![base.clone()];
            ex.exchange(c, &mut g2, &mut rng);

            let err1: f32 = g1[0]
                .iter()
                .zip(&base)
                .map(|(a, b)| (a - b).abs())
                .sum();
            let two_step_mean: Vec<f32> = g1[0]
                .iter()
                .zip(&g2[0])
                .map(|(a, b)| 0.5 * (a + b))
                .collect();
            let err2: f32 = two_step_mean
                .iter()
                .zip(&base)
                .map(|(a, b)| (a - b).abs())
                .sum();
            (err1, err2)
        });
        for (err1, err2) in results {
            assert!(
                err2 < err1,
                "EF should reduce accumulated error: {err1} -> {err2}"
            );
        }
    }
}
