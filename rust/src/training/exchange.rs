//! Gradient exchange: paper Algorithm 1's inner loop, executed by the
//! coordinator's [`ExchangeEngine`].
//!
//! Per group (in backprop order): merge the group's tensors into one flat
//! buffer, encode with the codec (EF state lives in the per-group codec
//! instance), synchronize with the codec's collective (Table 1), decode +
//! average, and scatter back into the per-tensor buffers. With
//! [`PipelineMode::Pipelined`] the collective for group *j* overlaps the
//! encode of group *j+1* and the decode of group *j−1* on a dedicated comm
//! lane; [`PipelineMode::Serial`] keeps the legacy strictly-sequential
//! schedule. Both modes are bit-identical in results and codec state (see
//! `tests/pipeline_equivalence.rs`).

use crate::collectives::{Comm, Error};
use crate::compression::CodecKind;
use crate::coordinator::ExchangeEngine;
pub use crate::coordinator::{ExchangeMode, ExchangeStats, GroupSample, PipelineMode};
use crate::scheduler::{Partition, RouteChoice};
use crate::util::rng::Xoshiro256;

/// One worker's exchange state for a fixed (codec, partition) pair — a thin
/// mode-carrying wrapper over [`ExchangeEngine`].
pub struct GradExchange {
    engine: ExchangeEngine,
    mode: PipelineMode,
    xmode: ExchangeMode,
}

impl GradExchange {
    /// Build with the conservative [`PipelineMode::Serial`] default; use
    /// [`GradExchange::with_mode`] (or the trainer's `pipeline` config) to
    /// enable overlap.
    pub fn new(kind: CodecKind, partition: Partition, sizes_backprop: Vec<usize>) -> Self {
        GradExchange {
            engine: ExchangeEngine::new(kind, partition, sizes_backprop),
            mode: PipelineMode::default(),
            xmode: ExchangeMode::default(),
        }
    }

    pub fn with_mode(mut self, mode: PipelineMode) -> Self {
        self.mode = mode;
        self
    }

    /// Select the gradient-distribution mode (DESIGN.md "Sharded exchange").
    /// Under [`ExchangeMode::Sharded`], after [`GradExchange::exchange`]
    /// only the spans reported by [`GradExchange::owned_group_ranges`] hold
    /// valid averaged gradients for allreduce-codec groups; allgather-codec
    /// groups stay fully valid everywhere.
    pub fn with_exchange_mode(mut self, xmode: ExchangeMode) -> Self {
        self.xmode = xmode;
        self
    }

    pub fn set_mode(&mut self, mode: PipelineMode) {
        self.mode = mode;
    }

    pub fn mode(&self) -> PipelineMode {
        self.mode
    }

    pub fn exchange_mode(&self) -> ExchangeMode {
        self.xmode
    }

    pub fn partition(&self) -> &Partition {
        self.engine.partition()
    }

    /// Merged element count per scheduled group (backprop flat order).
    pub fn group_elems(&self) -> &[usize] {
        self.engine.group_elems()
    }

    /// Element span `[lo, hi)` of each group's flat buffer that `rank` owns
    /// under the sharded exchange — the shard-ownership contract shared
    /// with the sharded optimizer and the checkpoint layer.
    pub fn owned_group_ranges(&self, world: usize, rank: usize) -> Vec<(usize, usize)> {
        self.engine.owned_group_ranges(world, rank)
    }

    pub fn kind(&self) -> CodecKind {
        self.engine.kind()
    }

    /// Fingerprint of all per-group codec state (EF residual, momentum) —
    /// used to prove Serial/Pipelined equivalence.
    pub fn state_digest(&self) -> u64 {
        self.engine.state_digest()
    }

    /// Per-group measured timings of the most recent exchange (the online
    /// scheduler's measurement feed).
    pub fn group_samples(&self) -> &[GroupSample] {
        self.engine.group_samples()
    }

    /// Switch to a new partition, remapping codec state bit-exactly (see
    /// [`crate::coordinator::ExchangeEngine::repartition`]).
    pub fn repartition(&mut self, new: Partition) -> anyhow::Result<()> {
        self.engine.repartition(new)
    }

    /// Install per-group collective routes (`None` reverts to the
    /// communicator's global route); see
    /// [`crate::coordinator::ExchangeEngine::set_routes`].
    pub fn set_routes(&mut self, routes: Option<Vec<RouteChoice>>) -> anyhow::Result<()> {
        self.engine.set_routes(routes)
    }

    /// Current per-group routes (`None` = global route).
    pub fn routes(&self) -> Option<&[RouteChoice]> {
        self.engine.routes()
    }

    /// Install per-group codecs (`None` reverts every group to the base
    /// codec); see [`crate::coordinator::ExchangeEngine::set_codecs`] for
    /// the error-feedback carry/reset policy.
    pub fn set_codecs(&mut self, kinds: Option<Vec<CodecKind>>) -> anyhow::Result<()> {
        self.engine.set_codecs(kinds)
    }

    /// The codec kind each group currently runs.
    pub fn group_codecs(&self) -> Vec<CodecKind> {
        self.engine.group_codecs()
    }

    /// Codec state planes flattened to full-model length (test support,
    /// checkpointing).
    pub fn flat_state(&self) -> Vec<Vec<f32>> {
        self.engine.flat_state()
    }

    /// Overwrite all per-group codec state from full-model-length planes —
    /// the inverse of [`GradExchange::flat_state`], used by checkpoint
    /// restore; see [`crate::coordinator::ExchangeEngine::load_flat_state`].
    pub fn load_flat_state(&mut self, planes: &[Vec<f32>]) -> anyhow::Result<()> {
        self.engine.load_flat_state(planes)
    }

    /// Aggregate gradients across the group. `grads` holds per-tensor
    /// buffers in **backprop order**; on success each buffer contains the
    /// mean of the (compressed) gradients over all workers. A dead rank
    /// fails the step with a typed [`Error`] whose
    /// [`is_recoverable`](Error::is_recoverable) classification drives the
    /// trainer's elastic recovery.
    pub fn exchange(
        &mut self,
        comm: &mut Comm,
        grads: &mut [Vec<f32>],
        rng: &mut Xoshiro256,
    ) -> Result<ExchangeStats, Error> {
        self.engine.exchange_mode(comm, grads, rng, self.mode, self.xmode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_comm_group;

    fn make_grads(rank: usize, sizes: &[usize]) -> Vec<Vec<f32>> {
        sizes
            .iter()
            .enumerate()
            .map(|(t, &n)| {
                (0..n)
                    .map(|i| (rank + 1) as f32 * (t as f32 + 1.0) + i as f32 * 0.001)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn fp32_exchange_is_exact_mean() {
        let sizes = vec![5usize, 3, 7];
        for mode in [PipelineMode::Serial, PipelineMode::Pipelined] {
            for partition in [
                Partition::layer_wise(3),
                Partition::full_merge(3),
                Partition::naive_even(3, 2),
            ] {
                let sizes2 = sizes.clone();
                let partition2 = partition.clone();
                let results = run_comm_group(3, move |c| {
                    let mut ex =
                        GradExchange::new(CodecKind::Fp32, partition2.clone(), sizes2.clone())
                            .with_mode(mode);
                    let mut rng = Xoshiro256::seed_from_u64(c.rank() as u64);
                    let mut grads = make_grads(c.rank(), &sizes2);
                    ex.exchange(c, &mut grads, &mut rng).unwrap();
                    grads
                });
                // Expected mean over ranks: mean(rank+1) = 2.
                for r in &results {
                    for (t, buf) in r.iter().enumerate() {
                        for (i, v) in buf.iter().enumerate() {
                            let want = 2.0 * (t as f32 + 1.0) + i as f32 * 0.001;
                            assert!(
                                (v - want).abs() < 1e-4,
                                "{} {partition}: tensor {t} idx {i}: {v} vs {want}",
                                mode.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn all_workers_agree_after_exchange() {
        // Model consistency: every codec must leave identical aggregated
        // gradients on every worker (the heart of synchronous SGD).
        let sizes = vec![40usize, 25, 70];
        for mode in [PipelineMode::Serial, PipelineMode::Pipelined] {
            for kind in [
                CodecKind::Fp16,
                CodecKind::Qsgd { bits: 8 },
                CodecKind::TopK { ratio: 0.1 },
                CodecKind::Dgc { ratio: 0.1 },
                CodecKind::EfSignSgd,
                CodecKind::SignSgd,
                CodecKind::OneBit,
            ] {
                let sizes2 = sizes.clone();
                let results = run_comm_group(2, move |c| {
                    let mut ex =
                        GradExchange::new(kind, Partition::naive_even(3, 2), sizes2.clone())
                            .with_mode(mode);
                    let mut rng = Xoshiro256::seed_from_u64(100 + c.rank() as u64);
                    let mut grads = make_grads(c.rank(), &sizes2);
                    ex.exchange(c, &mut grads, &mut rng).unwrap();
                    grads
                });
                assert_eq!(
                    results[0],
                    results[1],
                    "{} ({}): workers disagree after exchange",
                    kind.name(),
                    mode.name()
                );
            }
        }
    }

    #[test]
    fn stats_account_bytes() {
        let sizes = vec![100usize];
        let results = run_comm_group(2, move |c| {
            let mut ex =
                GradExchange::new(CodecKind::Fp32, Partition::full_merge(1), sizes.clone());
            let mut rng = Xoshiro256::seed_from_u64(0);
            let mut grads = vec![vec![1.0f32; 100]];
            ex.exchange(c, &mut grads, &mut rng).unwrap()
        });
        for s in results {
            // Ring allreduce, 2 ranks: each sends ~bytes of the buffer.
            assert!(s.bytes_sent >= 400);
            assert_eq!(s.groups, 1);
            assert!(s.encode_secs >= 0.0 && s.decode_secs >= 0.0);
            // Serial mode exposes every comm second.
            assert_eq!(s.comm_exposed_secs, s.comm_secs);
        }
    }

    #[test]
    fn ef_state_persists_across_steps() {
        // With EF codecs, repeating the same gradient must transmit the
        // leftover residual: the 2-step mean gets closer to the truth than
        // the 1-step mean.
        let sizes = vec![256usize];
        let results = run_comm_group(2, move |c| {
            let mut ex =
                GradExchange::new(CodecKind::EfSignSgd, Partition::full_merge(1), sizes.clone());
            let mut rng = Xoshiro256::seed_from_u64(5 + c.rank() as u64);
            let mut base = vec![0f32; 256];
            Xoshiro256::seed_from_u64(99).fill_normal_f32(&mut base, 1.0);

            let mut g1 = vec![base.clone()];
            ex.exchange(c, &mut g1, &mut rng).unwrap();
            let mut g2 = vec![base.clone()];
            ex.exchange(c, &mut g2, &mut rng).unwrap();

            let err1: f32 = g1[0]
                .iter()
                .zip(&base)
                .map(|(a, b)| (a - b).abs())
                .sum();
            let two_step_mean: Vec<f32> = g1[0]
                .iter()
                .zip(&g2[0])
                .map(|(a, b)| 0.5 * (a + b))
                .collect();
            let err2: f32 = two_step_mean
                .iter()
                .zip(&base)
                .map(|(a, b)| (a - b).abs())
                .sum();
            (err1, err2)
        });
        for (err1, err2) in results {
            assert!(
                err2 < err1,
                "EF should reduce accumulated error: {err1} -> {err2}"
            );
        }
    }
}
