//! Host-side optimizer: SGD with (optional) heavy-ball momentum over the
//! per-tensor parameter buffers. The update runs in rust — PJRT only ever
//! sees the forward/backward computation.

/// SGD + momentum: `v ← μ·v + g; p ← p − lr·v`.
pub struct SgdMomentum {
    lr: f32,
    mu: f32,
    velocity: Vec<Vec<f32>>,
}

impl SgdMomentum {
    pub fn new(lr: f32, mu: f32, tensor_sizes: &[usize]) -> SgdMomentum {
        assert!(lr > 0.0);
        assert!((0.0..1.0).contains(&mu));
        SgdMomentum {
            lr,
            mu,
            velocity: tensor_sizes.iter().map(|&n| vec![0f32; n]).collect(),
        }
    }

    pub fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Per-tensor momentum buffers (construction order) — checkpointed
    /// alongside the parameters so a restored run resumes bit-exactly.
    pub fn velocity(&self) -> &[Vec<f32>] {
        &self.velocity
    }

    /// Overwrite the momentum buffers from a checkpoint. Shapes must match
    /// construction exactly.
    pub fn load_velocity(&mut self, velocity: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            velocity.len() == self.velocity.len(),
            "load_velocity: {} tensors, optimizer has {}",
            velocity.len(),
            self.velocity.len()
        );
        for (t, (src, dst)) in velocity.iter().zip(&mut self.velocity).enumerate() {
            anyhow::ensure!(
                src.len() == dst.len(),
                "load_velocity: tensor {t} has {} elements, optimizer has {}",
                src.len(),
                dst.len()
            );
            dst.copy_from_slice(src);
        }
        Ok(())
    }

    /// Apply one update. `params` and `grads` are per-tensor buffers in the
    /// same order as construction.
    pub fn step(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        assert_eq!(params.len(), self.velocity.len());
        assert_eq!(grads.len(), self.velocity.len());
        for ((p, g), v) in params.iter_mut().zip(grads).zip(&mut self.velocity) {
            debug_assert_eq!(p.len(), g.len());
            if self.mu == 0.0 {
                for (pi, gi) in p.iter_mut().zip(g) {
                    *pi -= self.lr * gi;
                }
            } else {
                for ((pi, gi), vi) in p.iter_mut().zip(g).zip(v.iter_mut()) {
                    *vi = self.mu * *vi + gi;
                    *pi -= self.lr * *vi;
                }
            }
        }
    }
}

/// Sharded SGD + momentum for the sharded exchange mode: each rank holds
/// momentum only for the element spans it owns (one span per scheduled
/// group, by the [`crate::collectives::shard_elems`] contract) and updates
/// only those spans; the trainer allgathers the updated parameter shards
/// afterwards. The span arithmetic replicates [`SgdMomentum::step`]
/// operation-for-operation — including the μ = 0 fast path that never
/// touches `v` — so sharded parameters are bit-identical to full mode's.
pub struct ShardedSgdMomentum {
    lr: f32,
    mu: f32,
    /// Owned-span momentum per scheduled group (group-flat element order).
    velocity: Vec<Vec<f32>>,
    /// Owned element span `[lo, hi)` within each group's flat buffer.
    spans: Vec<(usize, usize)>,
    /// Total merged elements per group (full-plane export shape).
    group_elems: Vec<usize>,
}

impl ShardedSgdMomentum {
    /// `spans[j]` is this rank's owned range of group `j`'s flat buffer
    /// (from [`crate::coordinator::ExchangeEngine::owned_group_ranges`]).
    pub fn new(
        lr: f32,
        mu: f32,
        group_elems: &[usize],
        spans: &[(usize, usize)],
    ) -> ShardedSgdMomentum {
        assert!(lr > 0.0);
        assert!((0.0..1.0).contains(&mu));
        assert_eq!(group_elems.len(), spans.len());
        for (j, &(lo, hi)) in spans.iter().enumerate() {
            assert!(lo <= hi && hi <= group_elems[j], "group {j}: bad span");
        }
        ShardedSgdMomentum {
            lr,
            mu,
            velocity: spans.iter().map(|&(lo, hi)| vec![0f32; hi - lo]).collect(),
            spans: spans.to_vec(),
            group_elems: group_elems.to_vec(),
        }
    }

    pub fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    pub fn spans(&self) -> &[(usize, usize)] {
        &self.spans
    }

    /// Owned-span momentum buffers (group order) — the elastic rollback
    /// backup unit.
    pub fn velocity(&self) -> &[Vec<f32>] {
        &self.velocity
    }

    /// Overwrite the owned-span momentum (inverse of
    /// [`ShardedSgdMomentum::velocity`]); shapes must match construction.
    pub fn load_velocity(&mut self, velocity: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            velocity.len() == self.velocity.len(),
            "load_velocity: {} groups, optimizer has {}",
            velocity.len(),
            self.velocity.len()
        );
        for (j, (src, dst)) in velocity.iter().zip(&mut self.velocity).enumerate() {
            anyhow::ensure!(
                src.len() == dst.len(),
                "load_velocity: group {j} has {} elements, optimizer owns {}",
                src.len(),
                dst.len()
            );
            dst.copy_from_slice(src);
        }
        Ok(())
    }

    /// Bytes of live optimizer state on this rank (the sharded mode's
    /// memory win: ≈ full-mode bytes / world).
    pub fn state_bytes(&self) -> u64 {
        self.velocity.iter().map(|v| 4 * v.len() as u64).sum()
    }

    /// Update this rank's owned span of group `j`. `params` and `grads`
    /// are the group's **full** flat buffers (backprop merge order); only
    /// `[lo, hi)` is read and written.
    pub fn step_group(&mut self, j: usize, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.group_elems[j]);
        assert_eq!(grads.len(), self.group_elems[j]);
        let (lo, hi) = self.spans[j];
        let v = &mut self.velocity[j];
        if self.mu == 0.0 {
            for (pi, gi) in params[lo..hi].iter_mut().zip(&grads[lo..hi]) {
                *pi -= self.lr * gi;
            }
        } else {
            for ((pi, gi), vi) in
                params[lo..hi].iter_mut().zip(&grads[lo..hi]).zip(v.iter_mut())
            {
                *vi = self.mu * *vi + gi;
                *pi -= self.lr * *vi;
            }
        }
    }

    /// Export momentum as full-group-length planes with zeros outside the
    /// owned span — the checkpoint/reshard interchange format: summing
    /// (or span-slicing) all ranks' planes reconstructs the full momentum.
    pub fn export_group_planes(&self) -> Vec<Vec<f32>> {
        self.spans
            .iter()
            .zip(&self.velocity)
            .zip(&self.group_elems)
            .map(|((&(lo, _hi), v), &n)| {
                let mut plane = vec![0f32; n];
                plane[lo..lo + v.len()].copy_from_slice(v);
                plane
            })
            .collect()
    }

    /// Load momentum from full-group-length planes, taking only this
    /// rank's owned span of each (inverse of
    /// [`ShardedSgdMomentum::export_group_planes`], and the reshard entry
    /// point after a repartition or world change).
    pub fn load_group_planes(&mut self, planes: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            planes.len() == self.velocity.len(),
            "load_group_planes: {} planes, optimizer has {} groups",
            planes.len(),
            self.velocity.len()
        );
        for (j, plane) in planes.iter().enumerate() {
            anyhow::ensure!(
                plane.len() == self.group_elems[j],
                "load_group_planes: group {j} plane has {} elements, group has {}",
                plane.len(),
                self.group_elems[j]
            );
            let (lo, hi) = self.spans[j];
            self.velocity[j].copy_from_slice(&plane[lo..hi]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_update() {
        let mut opt = SgdMomentum::new(0.1, 0.0, &[2]);
        let mut p = vec![vec![1.0f32, 2.0]];
        opt.step(&mut p, &[vec![10.0, -10.0]]);
        assert_eq!(p[0], vec![0.0, 3.0]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = SgdMomentum::new(1.0, 0.5, &[1]);
        let mut p = vec![vec![0.0f32]];
        opt.step(&mut p, &[vec![1.0]]); // v=1, p=-1
        opt.step(&mut p, &[vec![1.0]]); // v=1.5, p=-2.5
        assert_eq!(p[0][0], -2.5);
    }

    #[test]
    fn sharded_spans_match_full_update_bitwise() {
        // Two "ranks" each updating their owned span must reproduce the
        // full optimizer's bits over the whole buffer, μ ∈ {0, 0.9}.
        for mu in [0.0f32, 0.9] {
            let n = 11usize;
            let spans = [(0usize, 6usize), (6, 11)];
            let mut full = SgdMomentum::new(0.05, mu, &[n]);
            let mut p_full = vec![(0..n).map(|i| i as f32 * 0.3 - 1.0).collect::<Vec<f32>>()];
            let mut p_shard = p_full[0].clone();
            let mut shards: Vec<ShardedSgdMomentum> = spans
                .iter()
                .map(|s| ShardedSgdMomentum::new(0.05, mu, &[n], &[*s]))
                .collect();
            for step in 0..3 {
                let g: Vec<f32> = (0..n).map(|i| (i + step) as f32 * 0.11 - 0.5).collect();
                full.step(&mut p_full, &[g.clone()]);
                for s in &mut shards {
                    s.step_group(0, &mut p_shard, &g);
                }
            }
            for i in 0..n {
                assert_eq!(
                    p_full[0][i].to_bits(),
                    p_shard[i].to_bits(),
                    "mu={mu} elem {i}"
                );
            }
            let bytes: u64 = shards.iter().map(|s| s.state_bytes()).sum();
            assert_eq!(bytes, 4 * n as u64);
        }
    }

    #[test]
    fn sharded_planes_roundtrip() {
        let mut opt = ShardedSgdMomentum::new(1.0, 0.5, &[4, 3], &[(1, 3), (0, 2)]);
        let mut p0 = vec![0f32; 4];
        let mut p1 = vec![0f32; 3];
        opt.step_group(0, &mut p0, &[1.0, 2.0, 3.0, 4.0]);
        opt.step_group(1, &mut p1, &[5.0, 6.0, 7.0]);
        let planes = opt.export_group_planes();
        assert_eq!(planes[0], vec![0.0, 2.0, 3.0, 0.0]);
        assert_eq!(planes[1], vec![5.0, 6.0, 0.0]);

        let mut fresh = ShardedSgdMomentum::new(1.0, 0.5, &[4, 3], &[(1, 3), (0, 2)]);
        fresh.load_group_planes(&planes).unwrap();
        assert_eq!(fresh.velocity(), opt.velocity());
        assert!(fresh.load_group_planes(&[vec![0.0; 4]]).is_err());
        assert!(fresh
            .load_group_planes(&[vec![0.0; 5], vec![0.0; 3]])
            .is_err());
    }

    #[test]
    fn quadratic_converges() {
        // minimize f(x) = 0.5*x^2 → g = x.
        let mut opt = SgdMomentum::new(0.2, 0.9, &[1]);
        let mut p = vec![vec![10.0f32]];
        for _ in 0..200 {
            let g = vec![vec![p[0][0]]];
            opt.step(&mut p, &g);
        }
        assert!(p[0][0].abs() < 1e-3, "x = {}", p[0][0]);
    }
}
