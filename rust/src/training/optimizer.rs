//! Host-side optimizer: SGD with (optional) heavy-ball momentum over the
//! per-tensor parameter buffers. The update runs in rust — PJRT only ever
//! sees the forward/backward computation.

/// SGD + momentum: `v ← μ·v + g; p ← p − lr·v`.
pub struct SgdMomentum {
    lr: f32,
    mu: f32,
    velocity: Vec<Vec<f32>>,
}

impl SgdMomentum {
    pub fn new(lr: f32, mu: f32, tensor_sizes: &[usize]) -> SgdMomentum {
        assert!(lr > 0.0);
        assert!((0.0..1.0).contains(&mu));
        SgdMomentum {
            lr,
            mu,
            velocity: tensor_sizes.iter().map(|&n| vec![0f32; n]).collect(),
        }
    }

    pub fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Per-tensor momentum buffers (construction order) — checkpointed
    /// alongside the parameters so a restored run resumes bit-exactly.
    pub fn velocity(&self) -> &[Vec<f32>] {
        &self.velocity
    }

    /// Overwrite the momentum buffers from a checkpoint. Shapes must match
    /// construction exactly.
    pub fn load_velocity(&mut self, velocity: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            velocity.len() == self.velocity.len(),
            "load_velocity: {} tensors, optimizer has {}",
            velocity.len(),
            self.velocity.len()
        );
        for (t, (src, dst)) in velocity.iter().zip(&mut self.velocity).enumerate() {
            anyhow::ensure!(
                src.len() == dst.len(),
                "load_velocity: tensor {t} has {} elements, optimizer has {}",
                src.len(),
                dst.len()
            );
            dst.copy_from_slice(src);
        }
        Ok(())
    }

    /// Apply one update. `params` and `grads` are per-tensor buffers in the
    /// same order as construction.
    pub fn step(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        assert_eq!(params.len(), self.velocity.len());
        assert_eq!(grads.len(), self.velocity.len());
        for ((p, g), v) in params.iter_mut().zip(grads).zip(&mut self.velocity) {
            debug_assert_eq!(p.len(), g.len());
            if self.mu == 0.0 {
                for (pi, gi) in p.iter_mut().zip(g) {
                    *pi -= self.lr * gi;
                }
            } else {
                for ((pi, gi), vi) in p.iter_mut().zip(g).zip(v.iter_mut()) {
                    *vi = self.mu * *vi + gi;
                    *pi -= self.lr * *vi;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_update() {
        let mut opt = SgdMomentum::new(0.1, 0.0, &[2]);
        let mut p = vec![vec![1.0f32, 2.0]];
        opt.step(&mut p, &[vec![10.0, -10.0]]);
        assert_eq!(p[0], vec![0.0, 3.0]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = SgdMomentum::new(1.0, 0.5, &[1]);
        let mut p = vec![vec![0.0f32]];
        opt.step(&mut p, &[vec![1.0]]); // v=1, p=-1
        opt.step(&mut p, &[vec![1.0]]); // v=1.5, p=-2.5
        assert_eq!(p[0][0], -2.5);
    }

    #[test]
    fn quadratic_converges() {
        // minimize f(x) = 0.5*x^2 → g = x.
        let mut opt = SgdMomentum::new(0.2, 0.9, &[1]);
        let mut p = vec![vec![10.0f32]];
        for _ in 0..200 {
            let g = vec![vec![p[0][0]]];
            opt.step(&mut p, &g);
        }
        assert!(p[0][0].abs() < 1e-3, "x = {}", p[0][0]);
    }
}
