//! Ring allgather (variable-size payloads), broadcast and barrier.
//!
//! The allgather is the synchronization primitive for every compressed
//! scheme except FP32/FP16 (paper Table 1): payload sizes differ between
//! ranks (DGC's selection count varies), which is exactly why allreduce
//! cannot be used for sparse tensors (§3.1).

use super::transport::Error;
use super::Comm;

/// Ring allgather among `members` (a sorted subset of ranks containing the
/// calling rank): |members|-1 steps; at step s each member forwards the
/// payload it received at step s-1 (starting with its own) to the right
/// neighbour. Returns payloads indexed by **position in `members`**. `base`
/// is the first of the `|members|` tags the operation may use (reserved by
/// the caller so non-participating ranks stay tag-aligned).
pub(crate) fn subset_ring_allgather(
    comm: &mut Comm,
    members: &[usize],
    base: u64,
    mine: Vec<u8>,
) -> Result<Vec<Vec<u8>>, Error> {
    let l = members.len();
    let me = members
        .iter()
        .position(|&m| m == comm.rank())
        .expect("calling rank must be a member of the ring subset");
    let mut out: Vec<Vec<u8>> = vec![Vec::new(); l];
    out[me] = mine;
    if l == 1 {
        return Ok(out);
    }
    let right = members[(me + 1) % l];
    let left = members[(me + l - 1) % l];

    // The payload that member me holds and forwards at step s originates
    // from member (me - s) mod l. Forwarding borrows the held payload
    // (`send_ref`) instead of cloning it; received payloads become the
    // result, and the caller recycles them once decoded.
    for s in 0..l - 1 {
        let fwd_src = (me + l - s) % l;
        // Tag by originating member so a slow rank can never alias payloads.
        comm.ep
            .send_ref(right, base + fwd_src as u64, &out[fwd_src])?;
        let recv_src = (me + l - s - 1) % l;
        let payload = comm.ep.recv(left, base + recv_src as u64)?;
        out[recv_src] = payload;
    }
    Ok(out)
}

/// Flat ring allgather over all ranks: bytes moved per rank are the sum of
/// all other ranks' payload sizes — bandwidth optimal for a ring.
pub fn ring_allgather(comm: &mut Comm, mine: Vec<u8>) -> Result<Vec<Vec<u8>>, Error> {
    let world = comm.world();
    if world == 1 {
        return Ok(vec![mine]);
    }
    let base = comm.next_tags(world as u64);
    let members: Vec<usize> = (0..world).collect();
    subset_ring_allgather(comm, &members, base, mine)
}

/// Barrier: a zero-byte allgather.
pub fn barrier(comm: &mut Comm) -> Result<(), Error> {
    let _ = ring_allgather(comm, Vec::new())?;
    Ok(())
}

/// Broadcast root's payload to all ranks (ring pipeline).
pub fn broadcast(
    comm: &mut Comm,
    root: usize,
    bytes: &mut Vec<u8>,
) -> Result<(), Error> {
    let world = comm.world();
    let rank = comm.rank();
    if world == 1 {
        return Ok(());
    }
    let base = comm.next_tags(1);
    let right = (rank + 1) % world;
    let left = (rank + world - 1) % world;
    // Pass along the ring, root -> root+1 -> ... -> root-1.
    if rank == root {
        comm.ep.send_ref(right, base, bytes)?;
    } else {
        *bytes = comm.ep.recv(left, base)?;
        if right != root {
            comm.ep.send_ref(right, base, bytes)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::run_comm_group;

    #[test]
    fn allgather_uniform() {
        let results =
            run_comm_group(4, |c| c.allgather(vec![c.rank() as u8; 3]).unwrap());
        for r in &results {
            assert_eq!(r.len(), 4);
            for (src, payload) in r.iter().enumerate() {
                assert_eq!(payload, &vec![src as u8; 3]);
            }
        }
    }

    #[test]
    fn allgather_variable_sizes() {
        // Rank r contributes r+1 bytes — the sparse-codec case.
        let results = run_comm_group(5, |c| {
            c.allgather(vec![c.rank() as u8; c.rank() + 1]).unwrap()
        });
        for r in &results {
            for (src, payload) in r.iter().enumerate() {
                assert_eq!(payload.len(), src + 1);
                assert!(payload.iter().all(|&b| b == src as u8));
            }
        }
    }

    #[test]
    fn allgather_empty_payloads() {
        let results = run_comm_group(3, |c| c.allgather(Vec::new()).unwrap());
        for r in &results {
            assert!(r.iter().all(|p| p.is_empty()));
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..3 {
            let results = run_comm_group(3, move |c| {
                let mut data = if c.rank() == root {
                    vec![42, root as u8]
                } else {
                    Vec::new()
                };
                c.broadcast(root, &mut data).unwrap();
                data
            });
            for r in results {
                assert_eq!(r, vec![42, root as u8]);
            }
        }
    }

    #[test]
    fn allgather_two_ranks() {
        let results = run_comm_group(2, |c| c.allgather(vec![c.rank() as u8 + 10]).unwrap());
        for r in &results {
            assert_eq!(r, &vec![vec![10], vec![11]]);
        }
    }

    #[test]
    fn many_sequential_allgathers() {
        // Stresses tag sequencing: 50 ops, every rank checks every result.
        let results = run_comm_group(3, |c| {
            let mut ok = true;
            for i in 0..50u8 {
                let r = c.allgather(vec![i, c.rank() as u8]).unwrap();
                for (src, p) in r.iter().enumerate() {
                    ok &= p == &vec![i, src as u8];
                }
            }
            ok
        });
        assert!(results.into_iter().all(|b| b));
    }
}
