//! Ring allreduce (Patarasuk & Yuan 2009): reduce-scatter followed by
//! allgather, 2·(n−1)/n · |data| bytes per rank — the bandwidth-optimal
//! algorithm NCCL/Horovod use for dense FP32/FP16 gradients.
//!
//! Two entry points:
//! - [`allreduce_f32`]: sums an f32 slice in place (loss/metric reduction,
//!   and the FP32 baseline's gradient path).
//! - [`allreduce_wire`]: reduces an opaque wire-format buffer using the
//!   codec's `reduce_wire` (FP16 sums in half precision on the wire exactly
//!   like NCCL's `ncclFloat16` reduction would).

use super::transport::Error;
use super::Comm;
use crate::compression::Codec;

/// Chunk boundaries for splitting `len` bytes into `world` pieces aligned
/// to `align` bytes (element size; 4 covers both f32 and 2-byte f16 pairs).
/// This split is the shard-ownership contract: the sharded exchange mode
/// and the checkpoint layer both derive per-rank ownership from it, so it
/// must stay a pure function of `(len, world, align)`.
pub(crate) fn chunk_bounds(len: usize, world: usize, align: usize) -> Vec<(usize, usize)> {
    let elems = len / align;
    let base = elems / world;
    let rem = elems % world;
    let mut bounds = Vec::with_capacity(world);
    let mut off = 0;
    for c in 0..world {
        let e = base + usize::from(c < rem);
        let next = off + e * align;
        bounds.push((off, next));
        off = next;
    }
    assert_eq!(off, len, "alignment must divide the buffer length");
    bounds
}

/// Generic ring allreduce over bytes with a caller-supplied reducer,
/// running among `members` (a sorted subset of ranks that must contain the
/// calling rank). The flat path passes all ranks; the hierarchical path
/// passes the node leaders. `base` is the first of the `2·|members|` tags
/// the operation may use — the caller reserves them so every rank's tag
/// sequence stays aligned whether or not it participates.
pub(crate) fn subset_ring_allreduce_bytes(
    comm: &mut Comm,
    members: &[usize],
    base: u64,
    data: &mut [u8],
    align: usize,
    reduce: &dyn Fn(&mut [u8], &[u8]) -> Result<(), Error>,
) -> Result<(), Error> {
    let l = members.len();
    let me = members
        .iter()
        .position(|&m| m == comm.rank())
        .expect("calling rank must be a member of the ring subset");
    if l == 1 || data.is_empty() {
        return Ok(());
    }
    // Phase 1 — reduce-scatter (shared with the sharded exchange mode so
    // both modes reduce in the exact same order, bit for bit).
    subset_ring_reduce_scatter_bytes(comm, members, base, data, align, reduce)?;
    let bounds = chunk_bounds(data.len(), l, align);
    let right = members[(me + 1) % l];
    let left = members[(me + l - 1) % l];

    // Phase 2 — allgather of the reduced chunks.
    for s in 0..l - 1 {
        let send_c = (me + 1 + l - s) % l;
        let recv_c = (me + l - s) % l;
        let (lo, hi) = bounds[send_c];
        comm.ep
            .send_ref(right, base + (l - 1 + s) as u64, &data[lo..hi])?;
        let incoming = comm.ep.recv(left, base + (l - 1 + s) as u64)?;
        let (lo, hi) = bounds[recv_c];
        data[lo..hi].copy_from_slice(&incoming);
        comm.ep.recycle(incoming);
    }
    Ok(())
}

/// Phase 1 of the ring on its own — reduce-scatter: after `l−1` steps,
/// member `m` holds the fully reduced chunk `(m+1) mod l` of `data` (the
/// rest of the buffer is partial-sum garbage). Returns the byte range of
/// the chunk this rank owns. Sends borrow the chunk in place (`send_ref`)
/// and every received buffer is recycled once reduced — the steady-state
/// ring allocates nothing. `base` is the first tag of the caller's
/// reserved window; only `l−1` tags are consumed, but callers that may
/// later run the allgather phase should reserve the full `2·l` so the tag
/// sequence matches the full allreduce step for step.
pub(crate) fn subset_ring_reduce_scatter_bytes(
    comm: &mut Comm,
    members: &[usize],
    base: u64,
    data: &mut [u8],
    align: usize,
    reduce: &dyn Fn(&mut [u8], &[u8]) -> Result<(), Error>,
) -> Result<(usize, usize), Error> {
    let l = members.len();
    let me = members
        .iter()
        .position(|&m| m == comm.rank())
        .expect("calling rank must be a member of the ring subset");
    if l == 1 || data.is_empty() {
        return Ok((0, data.len()));
    }
    assert_eq!(
        data.len() % align,
        0,
        "buffer length must be a multiple of the element size"
    );
    let bounds = chunk_bounds(data.len(), l, align);
    let right = members[(me + 1) % l];
    let left = members[(me + l - 1) % l];
    for s in 0..l - 1 {
        let send_c = (me + l - s) % l;
        let recv_c = (me + l - s - 1) % l;
        let (lo, hi) = bounds[send_c];
        comm.ep.send_ref(right, base + s as u64, &data[lo..hi])?;
        let incoming = comm.ep.recv(left, base + s as u64)?;
        let (lo, hi) = bounds[recv_c];
        reduce(&mut data[lo..hi], &incoming)?;
        comm.ep.recycle(incoming);
    }
    Ok(bounds[(me + 1) % l])
}

/// Flat ring allreduce over all ranks (reserves its own tags).
fn ring_allreduce_bytes(
    comm: &mut Comm,
    data: &mut [u8],
    align: usize,
    reduce: &dyn Fn(&mut [u8], &[u8]) -> Result<(), Error>,
) -> Result<(), Error> {
    let world = comm.world();
    if world == 1 || data.is_empty() {
        return Ok(());
    }
    // 2·(world−1) steps total; tag per step.
    let base = comm.next_tags(2 * world as u64);
    let members: Vec<usize> = (0..world).collect();
    subset_ring_allreduce_bytes(comm, &members, base, data, align, reduce)
}

/// In-place f32 sum allreduce.
pub fn allreduce_f32(comm: &mut Comm, data: &mut [f32]) -> Result<(), Error> {
    if comm.world() == 1 || data.is_empty() {
        return Ok(());
    }
    // Reinterpret as bytes (little-endian in-memory layout is preserved).
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, data.len() * 4)
    };
    ring_allreduce_bytes(comm, bytes, 4, &|a, b| {
        debug_assert_eq!(a.len(), b.len());
        for i in (0..a.len()).step_by(4) {
            let xa = f32::from_le_bytes([a[i], a[i + 1], a[i + 2], a[i + 3]]);
            let xb = f32::from_le_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]]);
            a[i..i + 4].copy_from_slice(&(xa + xb).to_le_bytes());
        }
        Ok(())
    })?;
    // On big-endian targets the byte reinterpretation above would be wrong;
    // all supported targets (x86-64, aarch64) are little-endian.
    #[cfg(target_endian = "big")]
    compile_error!("ring::allreduce_f32 assumes little-endian layout");
    Ok(())
}

/// In-place allreduce of a codec wire buffer (FP32/FP16).
pub fn allreduce_wire(
    comm: &mut Comm,
    data: &mut [u8],
    codec: &dyn Codec,
) -> Result<(), Error> {
    if comm.world() == 1 || data.is_empty() {
        return Ok(());
    }
    ring_allreduce_bytes(comm, data, codec.wire_align(), &|a, b| {
        codec
            .reduce_wire(a, b)
            .map_err(|e| Error::codec(e.to_string()))
    })
}

#[cfg(test)]
mod tests {
    use super::super::run_comm_group;
    use super::*;
    use crate::compression::{Codec as _, CodecKind};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn chunk_bounds_cover_exactly() {
        for (len, world, align) in [(100, 4, 4), (12, 5, 4), (4, 3, 4), (0, 2, 4), (64, 8, 2)] {
            let b = chunk_bounds(len, world, align);
            assert_eq!(b.len(), world);
            assert_eq!(b[0].0, 0);
            assert_eq!(b[world - 1].1, len);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            for (lo, hi) in b {
                assert_eq!((hi - lo) % align, 0, "aligned");
            }
        }
    }

    #[test]
    fn f32_sum_matches_serial() {
        for world in [2usize, 3, 4, 8] {
            let n = 101; // not divisible by world: exercises ragged chunks
            let results = run_comm_group(world, move |c| {
                let mut data: Vec<f32> =
                    (0..n).map(|i| (i * (c.rank() + 1)) as f32).collect();
                c.allreduce_f32(&mut data).unwrap();
                data
            });
            let factor: f32 = (1..=world).map(|r| r as f32).sum();
            for r in &results {
                for (i, v) in r.iter().enumerate() {
                    assert_eq!(*v, i as f32 * factor, "world={world} i={i}");
                }
            }
        }
    }

    #[test]
    fn tiny_buffer_fewer_elems_than_ranks() {
        // 2 f32 elements across 4 ranks: some chunks are empty.
        let results = run_comm_group(4, |c| {
            let mut data = vec![c.rank() as f32, 1.0];
            c.allreduce_f32(&mut data).unwrap();
            data
        });
        for r in &results {
            assert_eq!(r[0], 0.0 + 1.0 + 2.0 + 3.0);
            assert_eq!(r[1], 4.0);
        }
    }

    #[test]
    fn wire_allreduce_fp32_matches_f32_path() {
        let n = 64;
        let results = run_comm_group(3, move |c| {
            let mut rng = Xoshiro256::seed_from_u64(c.rank() as u64);
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g, 1.0);

            let mut codec = CodecKind::Fp32.build(n);
            let enc = codec.encode(&g, &mut rng);
            let mut wire = enc.bytes.clone();
            c.allreduce_wire(&mut wire, codec.as_ref()).unwrap();

            let mut direct = g.clone();
            c.allreduce_f32(&mut direct).unwrap();

            let mut out = vec![0f32; n];
            codec.decode(
                &crate::compression::Encoded { bytes: wire, n },
                &mut out,
            );
            (out, direct)
        });
        for (wire_out, direct) in results {
            for i in 0..n {
                assert!(
                    (wire_out[i] - direct[i]).abs() < 1e-4,
                    "wire {} vs direct {}",
                    wire_out[i],
                    direct[i]
                );
            }
        }
    }

    #[test]
    fn wire_allreduce_fp16() {
        let n = 32;
        let results = run_comm_group(2, move |c| {
            // Rank r contributes constant r+1; sum = 3.0 exactly in f16.
            let g = vec![(c.rank() + 1) as f32; n];
            let mut rng = Xoshiro256::seed_from_u64(0);
            let mut codec = CodecKind::Fp16.build(n);
            let enc = codec.encode(&g, &mut rng);
            let mut wire = enc.bytes.clone();
            c.allreduce_wire(&mut wire, codec.as_ref()).unwrap();
            let mut out = vec![0f32; n];
            codec.decode(&crate::compression::Encoded { bytes: wire, n }, &mut out);
            out
        });
        for r in &results {
            assert!(r.iter().all(|&v| v == 3.0), "{:?}", &r[..4]);
        }
    }

    #[test]
    fn subset_ring_sums_among_members_only() {
        // Ranks {0, 2, 3} of a 4-rank world run a ring; rank 1 idles. The
        // hierarchical collectives use exactly this to ring over leaders.
        let results = run_comm_group(4, |c| {
            let members = vec![0usize, 2, 3];
            if !members.iter().any(|&m| m == c.rank()) {
                return Vec::new();
            }
            let base = c.next_tags(2 * members.len() as u64);
            let mut data = vec![c.rank() as u8 + 1; 9];
            subset_ring_allreduce_bytes(c, &members, base, &mut data, 1, &|a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x = x.wrapping_add(*y);
                }
                Ok(())
            })
            .unwrap();
            data
        });
        assert!(results[1].is_empty());
        for r in [0usize, 2, 3] {
            // 1 + 3 + 4 from ranks 0, 2, 3.
            assert_eq!(results[r], vec![8u8; 9], "member rank {r}");
        }
    }

    #[test]
    fn reduce_scatter_owned_chunk_matches_full_allreduce() {
        // The standalone phase 1 must leave each member's owned chunk
        // bit-identical to what the full ring allreduce produces there —
        // the contract the sharded exchange mode is built on. 101 floats
        // over 4 ranks exercises ragged chunks.
        let n = 101usize;
        for world in [2usize, 3, 4] {
            let results = run_comm_group(world, move |c| {
                let mk = |rank: usize| -> Vec<u8> {
                    (0..n)
                        .flat_map(|i| ((i * (rank + 1)) as f32).to_le_bytes())
                        .collect()
                };
                let reduce = |a: &mut [u8], b: &[u8]| -> Result<(), Error> {
                    for i in (0..a.len()).step_by(4) {
                        let xa = f32::from_le_bytes([a[i], a[i + 1], a[i + 2], a[i + 3]]);
                        let xb = f32::from_le_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]]);
                        a[i..i + 4].copy_from_slice(&(xa + xb).to_le_bytes());
                    }
                    Ok(())
                };
                let members: Vec<usize> = (0..c.world()).collect();
                let mut full = mk(c.rank());
                let base = c.next_tags(2 * members.len() as u64);
                subset_ring_allreduce_bytes(c, &members, base, &mut full, 4, &reduce)
                    .unwrap();
                let mut rs = mk(c.rank());
                let base = c.next_tags(2 * members.len() as u64);
                let (lo, hi) =
                    subset_ring_reduce_scatter_bytes(c, &members, base, &mut rs, 4, &reduce)
                        .unwrap();
                (full[lo..hi].to_vec(), rs[lo..hi].to_vec(), lo, hi)
            });
            let mut covered = vec![false; n * 4];
            for (full_chunk, rs_chunk, lo, hi) in &results {
                assert_eq!(full_chunk, rs_chunk, "world={world}");
                for b in covered.iter_mut().take(*hi).skip(*lo) {
                    assert!(!*b, "chunks overlap");
                    *b = true;
                }
            }
            assert!(covered.iter().all(|&b| b), "chunks must cover the buffer");
        }
    }

    #[test]
    fn bytes_on_wire_are_bandwidth_optimal() {
        // Ring allreduce moves 2·(w−1)/w·N bytes per rank.
        let n_bytes = 400usize;
        let world = 4;
        let results = run_comm_group(world, move |c| {
            let mut data = vec![1.0f32; n_bytes / 4];
            c.allreduce_f32(&mut data).unwrap();
            c.bytes_sent()
        });
        let expect = (2 * (world - 1) * n_bytes / world) as u64;
        for sent in results {
            assert_eq!(sent, expect);
        }
    }
}
