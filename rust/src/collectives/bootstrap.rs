//! Rendezvous bootstrap for the TCP transport.
//!
//! Protocol (all line-based ASCII, one connection per step):
//!
//! 1. Every rank binds a **data listener** on an ephemeral port.
//! 2. Rank 0 listens on the rendezvous address; every other rank dials it
//!    (with retry until the deadline) and sends
//!    `HELLO <rank> <data-addr> <node>` — the node label is the rank's
//!    position in the configured [`Topology`](super::Topology) (`n0`,
//!    `n1`, …), which lets the trainer cross-check that every launched
//!    process was handed the same `--topology`.
//! 3. Once all `world - 1` hellos have arrived, rank 0 answers each peer
//!    with the full peer table: `TABLE <addr0>/<node0> … <addrW-1>/<nodeW-1>`.
//!    The rendezvous connections then close — they carry no training
//!    traffic.
//! 4. Mesh formation ([`connect_mesh`]): every rank dials all ranks
//!    **below** it (handshake line `PEER <rank>`) and accepts one
//!    connection from every rank above it, yielding one stream per peer.
//!
//! Because each rank registers its data address only *after* binding its
//! listener, and rank 0 releases the table only after all ranks have
//! registered, every dial in step 4 targets a listener that is already
//! bound — the only retries needed are against the rendezvous itself
//! (rank 0's process may simply not have started yet).

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Read one `\n`-terminated line byte-by-byte (no buffering, so handshake
/// reads can never swallow the binary frames that follow on data sockets).
pub(crate) fn read_line_raw(stream: &mut TcpStream, max_len: usize) -> anyhow::Result<String> {
    let mut line = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        stream
            .read_exact(&mut byte)
            .map_err(|e| anyhow::anyhow!("reading handshake line: {e}"))?;
        if byte[0] == b'\n' {
            break;
        }
        line.push(byte[0]);
        anyhow::ensure!(line.len() <= max_len, "handshake line exceeds {max_len} bytes");
    }
    String::from_utf8(line).map_err(|e| anyhow::anyhow!("non-utf8 handshake: {e}"))
}

fn dial_with_retry(addr: &str, deadline: Instant) -> anyhow::Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    anyhow::bail!("dialing {addr}: {e} (deadline exceeded)");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn accept_with_deadline(
    listener: &TcpListener,
    deadline: Instant,
    what: &str,
) -> anyhow::Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .map_err(|e| anyhow::anyhow!("listener nonblocking: {e}"))?;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream
                    .set_nonblocking(false)
                    .map_err(|e| anyhow::anyhow!("stream blocking: {e}"))?;
                stream
                    .set_read_timeout(Some(Duration::from_secs(30)))
                    .map_err(|e| anyhow::anyhow!("read timeout: {e}"))?;
                return Ok(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    anyhow::bail!("timed out waiting to accept {what}");
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => anyhow::bail!("accepting {what}: {e}"),
        }
    }
}

/// One peer in the rendezvous table: its data address and the node label
/// it registered with (`n<id>` from the configured topology; `-` when the
/// peer did not say).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PeerEntry {
    pub addr: String,
    pub node: String,
}

impl PeerEntry {
    fn to_wire(&self) -> String {
        format!("{}/{}", self.addr, self.node)
    }

    fn from_wire(entry: &str) -> PeerEntry {
        match entry.split_once('/') {
            Some((addr, node)) => PeerEntry {
                addr: addr.to_string(),
                node: node.to_string(),
            },
            // Tolerate a label-less entry (pre-topology peers).
            None => PeerEntry {
                addr: entry.to_string(),
                node: "-".to_string(),
            },
        }
    }
}

fn validate_node_label(label: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        !label.is_empty() && !label.contains(char::is_whitespace) && !label.contains('/'),
        "node label '{label}' must be non-empty with no whitespace or '/'"
    );
    Ok(())
}

/// Run the rendezvous: every rank learns every rank's data address and
/// node label.
///
/// `hosted`: rank 0 may pass a pre-bound listener (tests bind port 0 to
/// pick a free port); otherwise rank 0 binds `rendezvous_addr` itself.
pub fn exchange_peer_table(
    rank: usize,
    world: usize,
    rendezvous_addr: &str,
    my_data_addr: &str,
    my_node_label: &str,
    hosted: Option<TcpListener>,
    deadline: Instant,
) -> anyhow::Result<Vec<PeerEntry>> {
    anyhow::ensure!(rank < world, "rank {rank} out of range for world {world}");
    validate_node_label(my_node_label)?;
    if world == 1 {
        return Ok(vec![PeerEntry {
            addr: my_data_addr.to_string(),
            node: my_node_label.to_string(),
        }]);
    }
    if rank == 0 {
        let listener = match hosted {
            Some(l) => l,
            None => TcpListener::bind(rendezvous_addr)
                .map_err(|e| anyhow::anyhow!("binding rendezvous {rendezvous_addr}: {e}"))?,
        };
        let mut table: Vec<Option<PeerEntry>> = vec![None; world];
        table[0] = Some(PeerEntry {
            addr: my_data_addr.to_string(),
            node: my_node_label.to_string(),
        });
        let mut peers: Vec<(usize, TcpStream)> = Vec::with_capacity(world - 1);
        while peers.len() < world - 1 {
            let mut stream = accept_with_deadline(&listener, deadline, "rendezvous hello")?;
            let line = read_line_raw(&mut stream, 512)?;
            let mut parts = line.split_whitespace();
            anyhow::ensure!(
                parts.next() == Some("HELLO"),
                "rendezvous: expected HELLO, got '{line}'"
            );
            let peer: usize = parts
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| anyhow::anyhow!("rendezvous: bad rank in '{line}'"))?;
            let addr = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("rendezvous: missing addr in '{line}'"))?;
            let node = parts.next().unwrap_or("-");
            anyhow::ensure!(peer > 0 && peer < world, "rendezvous: rank {peer} out of range");
            anyhow::ensure!(
                table[peer].is_none(),
                "rendezvous: duplicate registration for rank {peer}"
            );
            table[peer] = Some(PeerEntry {
                addr: addr.to_string(),
                node: node.to_string(),
            });
            peers.push((peer, stream));
        }
        let table: Vec<PeerEntry> = table.into_iter().map(|a| a.unwrap()).collect();
        let entries: Vec<String> = table.iter().map(PeerEntry::to_wire).collect();
        let reply = format!("TABLE {}\n", entries.join(" "));
        for (peer, mut stream) in peers {
            stream
                .write_all(reply.as_bytes())
                .map_err(|e| anyhow::anyhow!("sending table to rank {peer}: {e}"))?;
            let _ = stream.shutdown(Shutdown::Write);
        }
        Ok(table)
    } else {
        let mut stream = dial_with_retry(rendezvous_addr, deadline)?;
        let remaining = deadline
            .saturating_duration_since(Instant::now())
            .max(Duration::from_millis(100));
        stream
            .set_read_timeout(Some(remaining))
            .map_err(|e| anyhow::anyhow!("read timeout: {e}"))?;
        stream
            .write_all(format!("HELLO {rank} {my_data_addr} {my_node_label}\n").as_bytes())
            .map_err(|e| anyhow::anyhow!("sending hello: {e}"))?;
        let line = read_line_raw(&mut stream, 8192)?;
        let mut parts = line.split_whitespace();
        anyhow::ensure!(
            parts.next() == Some("TABLE"),
            "rendezvous: expected TABLE, got '{line}'"
        );
        let table: Vec<PeerEntry> = parts.map(PeerEntry::from_wire).collect();
        anyhow::ensure!(
            table.len() == world,
            "rendezvous: table has {} entries, expected {world}",
            table.len()
        );
        Ok(table)
    }
}

/// Form the full mesh: one stream per peer, `conns[p]` is the connection
/// to rank `p` (`None` at index `rank`). Dials every lower rank, accepts
/// from every higher rank.
pub fn connect_mesh(
    rank: usize,
    world: usize,
    table: &[String],
    listener: &TcpListener,
    deadline: Instant,
) -> anyhow::Result<Vec<Option<TcpStream>>> {
    anyhow::ensure!(table.len() == world, "peer table size mismatch");
    let mut conns: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
    for peer in 0..rank {
        let mut stream = dial_with_retry(&table[peer], deadline)?;
        stream
            .set_nodelay(true)
            .map_err(|e| anyhow::anyhow!("nodelay: {e}"))?;
        stream
            .write_all(format!("PEER {rank}\n").as_bytes())
            .map_err(|e| anyhow::anyhow!("peer handshake to rank {peer}: {e}"))?;
        conns[peer] = Some(stream);
    }
    let mut remaining = world - 1 - rank;
    while remaining > 0 {
        let mut stream = accept_with_deadline(listener, deadline, "mesh peer")?;
        stream
            .set_nodelay(true)
            .map_err(|e| anyhow::anyhow!("nodelay: {e}"))?;
        let line = read_line_raw(&mut stream, 128)?;
        let mut parts = line.split_whitespace();
        anyhow::ensure!(
            parts.next() == Some("PEER"),
            "mesh handshake: expected PEER, got '{line}'"
        );
        let peer: usize = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("mesh handshake: bad rank in '{line}'"))?;
        anyhow::ensure!(
            peer > rank && peer < world,
            "mesh handshake: unexpected rank {peer} (I am {rank} of {world})"
        );
        anyhow::ensure!(
            conns[peer].is_none(),
            "mesh handshake: duplicate connection from rank {peer}"
        );
        // Clear the handshake-phase read timeout: collective receives may
        // legitimately block for a long time.
        stream
            .set_read_timeout(None)
            .map_err(|e| anyhow::anyhow!("read timeout: {e}"))?;
        conns[peer] = Some(stream);
        remaining -= 1;
    }
    for (p, c) in conns.iter().enumerate() {
        if p != rank {
            anyhow::ensure!(c.is_some(), "mesh: no connection to rank {p}");
        }
    }
    Ok(conns)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn deadline() -> Instant {
        Instant::now() + Duration::from_secs(20)
    }

    #[test]
    fn rendezvous_distributes_consistent_table_with_node_labels() {
        let world = 4;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let rdv = listener.local_addr().unwrap().to_string();
        let mut hosted = Some(listener);
        let tables: Vec<Vec<PeerEntry>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..world)
                .map(|rank| {
                    let hosted = if rank == 0 { hosted.take() } else { None };
                    let rdv = rdv.clone();
                    s.spawn(move || {
                        exchange_peer_table(
                            rank,
                            world,
                            &rdv,
                            &format!("127.0.0.1:{}", 9000 + rank),
                            // Ranks 0–1 on node 0, ranks 2–3 on node 1.
                            &format!("n{}", rank / 2),
                            hosted,
                            deadline(),
                        )
                        .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for t in &tables {
            assert_eq!(t, &tables[0]);
            assert_eq!(t.len(), world);
            for (r, entry) in t.iter().enumerate() {
                assert_eq!(entry.addr, format!("127.0.0.1:{}", 9000 + r));
                assert_eq!(entry.node, format!("n{}", r / 2));
            }
        }
    }

    #[test]
    fn world_of_one_needs_no_network() {
        let t = exchange_peer_table(0, 1, "127.0.0.1:1", "127.0.0.1:9000", "n0", None, deadline())
            .unwrap();
        assert_eq!(
            t,
            vec![PeerEntry { addr: "127.0.0.1:9000".to_string(), node: "n0".to_string() }]
        );
    }

    #[test]
    fn bad_node_labels_rejected_and_unlabelled_entries_tolerated() {
        for bad in ["", "two words", "a/b"] {
            assert!(
                exchange_peer_table(0, 1, "127.0.0.1:1", "127.0.0.1:9000", bad, None, deadline())
                    .is_err(),
                "label '{bad}' should be rejected"
            );
        }
        let e = PeerEntry::from_wire("127.0.0.1:9000");
        assert_eq!(e.addr, "127.0.0.1:9000");
        assert_eq!(e.node, "-");
        let e = PeerEntry::from_wire("127.0.0.1:9000/n3");
        assert_eq!(e.node, "n3");
    }

    #[test]
    fn full_mesh_connects_every_pair() {
        let world = 3;
        // Bind real data listeners and build the table from them.
        let listeners: Vec<TcpListener> = (0..world)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let table: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let counts: Vec<usize> = std::thread::scope(|s| {
            let handles: Vec<_> = listeners
                .iter()
                .enumerate()
                .map(|(rank, listener)| {
                    let table = table.clone();
                    s.spawn(move || {
                        let conns =
                            connect_mesh(rank, world, &table, listener, deadline()).unwrap();
                        conns.iter().filter(|c| c.is_some()).count()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(counts, vec![world - 1; world]);
    }
}
