//! Rendezvous bootstrap for the TCP transport.
//!
//! Protocol (all line-based ASCII, one connection per step):
//!
//! 1. Every rank binds a **data listener** on an ephemeral port.
//! 2. Rank 0 listens on the rendezvous address; every other rank dials it
//!    (with retry until the deadline) and sends
//!    `HELLO <rank> <data-addr> <node> g<generation>` — the node label is
//!    the rank's position in the configured [`Topology`](super::Topology)
//!    (`n0`, `n1`, …), which lets the trainer cross-check that every
//!    launched process was handed the same `--topology`; the generation
//!    tag makes re-registration after a crash unambiguous (see below).
//! 3. Once all `world - 1` hellos have arrived, rank 0 answers each peer
//!    with the full peer table: `TABLE <addr0>/<node0> … <addrW-1>/<nodeW-1>`.
//!    The rendezvous connections then close — they carry no training
//!    traffic.
//! 4. Mesh formation ([`connect_mesh`]): every rank dials all ranks
//!    **below** it (handshake line `PEER <rank>`) and accepts one
//!    connection from every rank above it, yielding one stream per peer.
//!
//! Because each rank registers its data address only *after* binding its
//! listener, and rank 0 releases the table only after all ranks have
//! registered, every dial in step 4 targets a listener that is already
//! bound — the only retries needed are against the rendezvous itself
//! (rank 0's process may simply not have started yet).
//!
//! ## Generations and re-join
//!
//! A rank that dies during bootstrap and is relaunched re-dials the
//! rendezvous and re-HELLOs. The [`Registry`] arbitrates with the
//! generation tag: a re-HELLO with a **higher** generation replaces the
//! stale entry (the restarted process supersedes its dead predecessor), a
//! **lower** generation is silently ignored (a straggling pre-crash
//! process), and an **equal** generation is a duplicate-registration error
//! (two live processes claim the same rank). Peers without a tag are
//! generation 0, which preserves the legacy strict behaviour: double
//! registration is always an error until generations are used explicitly
//! (`--generation`, bumped by the elastic relaunch path).

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Read one `\n`-terminated line byte-by-byte (no buffering, so handshake
/// reads can never swallow the binary frames that follow on data sockets).
pub(crate) fn read_line_raw(stream: &mut TcpStream, max_len: usize) -> anyhow::Result<String> {
    let mut line = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        stream
            .read_exact(&mut byte)
            .map_err(|e| anyhow::anyhow!("reading handshake line: {e}"))?;
        if byte[0] == b'\n' {
            break;
        }
        line.push(byte[0]);
        anyhow::ensure!(line.len() <= max_len, "handshake line exceeds {max_len} bytes");
    }
    String::from_utf8(line).map_err(|e| anyhow::anyhow!("non-utf8 handshake: {e}"))
}

fn dial_with_retry(addr: &str, deadline: Instant) -> anyhow::Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    anyhow::bail!("dialing {addr}: {e} (deadline exceeded)");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn accept_with_deadline(
    listener: &TcpListener,
    deadline: Instant,
    what: &str,
) -> anyhow::Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .map_err(|e| anyhow::anyhow!("listener nonblocking: {e}"))?;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream
                    .set_nonblocking(false)
                    .map_err(|e| anyhow::anyhow!("stream blocking: {e}"))?;
                stream
                    .set_read_timeout(Some(Duration::from_secs(30)))
                    .map_err(|e| anyhow::anyhow!("read timeout: {e}"))?;
                return Ok(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    anyhow::bail!("timed out waiting to accept {what}");
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => anyhow::bail!("accepting {what}: {e}"),
        }
    }
}

/// One peer in the rendezvous table: its data address and the node label
/// it registered with (`n<id>` from the configured topology; `-` when the
/// peer did not say).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PeerEntry {
    pub addr: String,
    pub node: String,
}

impl PeerEntry {
    fn to_wire(&self) -> String {
        format!("{}/{}", self.addr, self.node)
    }

    fn from_wire(entry: &str) -> PeerEntry {
        match entry.split_once('/') {
            Some((addr, node)) => PeerEntry {
                addr: addr.to_string(),
                node: node.to_string(),
            },
            // Tolerate a label-less entry (pre-topology peers).
            None => PeerEntry {
                addr: entry.to_string(),
                node: "-".to_string(),
            },
        }
    }
}

fn validate_node_label(label: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        !label.is_empty() && !label.contains(char::is_whitespace) && !label.contains('/'),
        "node label '{label}' must be non-empty with no whitespace or '/'"
    );
    Ok(())
}

/// A parsed `HELLO` registration line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    pub rank: usize,
    pub addr: String,
    pub node: String,
    /// Bootstrap generation of the sender (0 when the line carries no
    /// `g<gen>` token — legacy peers).
    pub generation: u64,
    /// Whitespace-free run-config token (`c<token>` on the wire): the
    /// sender's `seed=…:codec=…:topo=…:xmode=…` fingerprint. Rank 0
    /// refuses registration (a `REFUSE` reply) when a peer's token
    /// differs from its own, so a joiner launched with a mismatched
    /// codec/topology/seed fails at HELLO with an actionable error
    /// instead of training to a divergent digest. `None` on legacy lines
    /// (no cross-check).
    pub config: Option<String>,
}

impl Hello {
    pub fn to_wire(&self) -> String {
        let mut line =
            format!("HELLO {} {} {} g{}", self.rank, self.addr, self.node, self.generation);
        if let Some(cfg) = &self.config {
            line.push_str(" c");
            line.push_str(cfg);
        }
        line
    }
}

/// Parse a `HELLO <rank> <addr> [<node>] [g<gen>] [c<config>]` line. Pure
/// — fed by the property tests with truncated/junk/duplicate-token input.
/// `world` bounds the rank (rank 0 hosts the rendezvous and never HELLOs).
pub fn parse_hello(line: &str, world: usize) -> anyhow::Result<Hello> {
    let mut parts = line.split_whitespace();
    anyhow::ensure!(parts.next() == Some("HELLO"), "rendezvous: expected HELLO, got '{line}'");
    let rank: usize = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("rendezvous: bad rank in '{line}'"))?;
    anyhow::ensure!(rank > 0 && rank < world, "rendezvous: rank {rank} out of range");
    let addr = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("rendezvous: missing addr in '{line}'"))?;
    anyhow::ensure!(!addr.contains('/'), "rendezvous: addr '{addr}' contains '/'");
    let node = parts.next().unwrap_or("-");
    validate_node_label(node)?;
    let mut tok = parts.next();
    let generation = match tok {
        Some(t) if t.starts_with('g') => {
            let gen = t
                .strip_prefix('g')
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| anyhow::anyhow!("rendezvous: bad generation token in '{line}'"))?;
            tok = parts.next();
            gen
        }
        None => 0,
        // A non-g token here can only be a config token; the generation
        // defaults to 0 (the legacy strict behaviour).
        Some(_) => 0,
    };
    let config = match tok {
        None => None,
        Some(t) => {
            let cfg = t.strip_prefix('c').ok_or_else(|| {
                anyhow::anyhow!("rendezvous: unexpected token '{t}' in '{line}'")
            })?;
            anyhow::ensure!(!cfg.is_empty(), "rendezvous: empty config token in '{line}'");
            Some(cfg.to_string())
        }
    };
    anyhow::ensure!(parts.next().is_none(), "rendezvous: trailing tokens in '{line}'");
    Ok(Hello {
        rank,
        addr: addr.to_string(),
        node: node.to_string(),
        generation,
        config,
    })
}

/// Human-readable explanation of a config-token mismatch, naming the CLI
/// flag behind the first differing `key=value` component (so the error a
/// refused joiner sees says *which* of `--seed` / `--codec` /
/// `--topology` / `--exchange-mode` to fix).
pub fn describe_config_mismatch(mine: &str, theirs: &str) -> String {
    fn flag_for(key: &str) -> String {
        match key {
            "seed" => "--seed".to_string(),
            "codec" => "--codec".to_string(),
            "topo" => "--topology".to_string(),
            "xmode" => "--exchange-mode".to_string(),
            other => format!("--{other}"),
        }
    }
    let a: Vec<&str> = mine.split(':').collect();
    let b: Vec<&str> = theirs.split(':').collect();
    if a.len() == b.len() {
        for (ka, kb) in a.iter().zip(&b) {
            if ka == kb {
                continue;
            }
            if let (Some((key_a, va)), Some((key_b, vb))) = (ka.split_once('='), kb.split_once('='))
            {
                if key_a == key_b {
                    return format!(
                        "{} mismatch: the group runs '{va}' but the joining rank was launched \
                         with '{vb}'",
                        flag_for(key_a)
                    );
                }
            }
            break;
        }
    }
    format!("config mismatch: the group token is '{mine}', the joining rank sent '{theirs}'")
}

/// Parse a `TABLE <addr0/node0> …` line into exactly `world` entries. Pure
/// — fed by the property tests with truncated/junk input.
pub fn parse_table(line: &str, world: usize) -> anyhow::Result<Vec<PeerEntry>> {
    let mut parts = line.split_whitespace();
    anyhow::ensure!(parts.next() == Some("TABLE"), "rendezvous: expected TABLE, got '{line}'");
    let table: Vec<PeerEntry> = parts.map(PeerEntry::from_wire).collect();
    anyhow::ensure!(
        table.len() == world,
        "rendezvous: table has {} entries, expected {world}",
        table.len()
    );
    Ok(table)
}

/// What [`Registry::register`] did with a `HELLO`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HelloOutcome {
    /// First registration for this rank.
    Registered,
    /// A newer generation replaced a stale entry (the rank restarted
    /// before the table was released).
    Replaced,
    /// Older generation than the registered one — ignored.
    Stale,
}

/// Rank 0's registration table during the rendezvous, with the generation
/// arbitration described in the module docs.
#[derive(Debug)]
pub struct Registry {
    entries: Vec<Option<(PeerEntry, u64)>>,
}

impl Registry {
    /// `self_entry` pre-registers rank 0 (the host never HELLOs itself).
    pub fn new(world: usize, self_entry: PeerEntry) -> Registry {
        assert!(world >= 1);
        let mut entries: Vec<Option<(PeerEntry, u64)>> = vec![None; world];
        entries[0] = Some((self_entry, 0));
        Registry { entries }
    }

    /// Arbitrate one registration. Errors mean a protocol violation by a
    /// live peer (duplicate same-generation registration); stale lines are
    /// reported, not errored, so a straggler cannot wedge the bootstrap.
    pub fn register(&mut self, h: &Hello) -> anyhow::Result<HelloOutcome> {
        anyhow::ensure!(
            h.rank > 0 && h.rank < self.entries.len(),
            "rendezvous: rank {} out of range",
            h.rank
        );
        let entry = PeerEntry { addr: h.addr.clone(), node: h.node.clone() };
        match &self.entries[h.rank] {
            None => {
                self.entries[h.rank] = Some((entry, h.generation));
                Ok(HelloOutcome::Registered)
            }
            Some((_, old_gen)) if h.generation > *old_gen => {
                self.entries[h.rank] = Some((entry, h.generation));
                Ok(HelloOutcome::Replaced)
            }
            Some((_, old_gen)) if h.generation < *old_gen => Ok(HelloOutcome::Stale),
            Some(_) => anyhow::bail!(
                "rendezvous: duplicate registration for rank {} (generation {})",
                h.rank,
                h.generation
            ),
        }
    }

    /// Registered generation for `rank`, if any.
    pub fn generation(&self, rank: usize) -> Option<u64> {
        self.entries.get(rank).and_then(|e| e.as_ref().map(|(_, g)| *g))
    }

    pub fn is_complete(&self) -> bool {
        self.entries.iter().all(Option::is_some)
    }

    /// The finished table (every rank registered).
    pub fn table(&self) -> anyhow::Result<Vec<PeerEntry>> {
        self.entries
            .iter()
            .enumerate()
            .map(|(r, e)| {
                e.as_ref()
                    .map(|(p, _)| p.clone())
                    .ok_or_else(|| anyhow::anyhow!("rendezvous: rank {r} never registered"))
            })
            .collect()
    }
}

/// Run the rendezvous: every rank learns every rank's data address and
/// node label. `generation` tags this process's registration so a
/// relaunched rank supersedes its dead predecessor (see the module docs);
/// pass 0 outside elastic restarts.
///
/// `config_token`: when `Some`, non-zero ranks attach it to their HELLO
/// and rank 0 cross-checks every attached token against its own —
/// a mismatch (e.g. a hot-joiner launched with a different
/// `--codec`/`--topology`/`--seed`) is answered with a `REFUSE <detail>`
/// line and fails the bootstrap on both sides with an error naming the
/// offending flag. Tokens are only checked when both sides supply one, so
/// legacy peers interoperate.
///
/// `hosted`: rank 0 may pass a pre-bound listener (tests bind port 0 to
/// pick a free port); otherwise rank 0 binds `rendezvous_addr` itself.
#[allow(clippy::too_many_arguments)]
pub fn exchange_peer_table(
    rank: usize,
    world: usize,
    rendezvous_addr: &str,
    my_data_addr: &str,
    my_node_label: &str,
    generation: u64,
    config_token: Option<&str>,
    hosted: Option<TcpListener>,
    deadline: Instant,
) -> anyhow::Result<Vec<PeerEntry>> {
    anyhow::ensure!(rank < world, "rank {rank} out of range for world {world}");
    validate_node_label(my_node_label)?;
    if let Some(cfg) = config_token {
        anyhow::ensure!(
            !cfg.is_empty() && !cfg.contains(char::is_whitespace),
            "config token '{cfg}' must be non-empty with no whitespace"
        );
    }
    if world == 1 {
        return Ok(vec![PeerEntry {
            addr: my_data_addr.to_string(),
            node: my_node_label.to_string(),
        }]);
    }
    if rank == 0 {
        let listener = match hosted {
            Some(l) => l,
            None => TcpListener::bind(rendezvous_addr)
                .map_err(|e| anyhow::anyhow!("binding rendezvous {rendezvous_addr}: {e}"))?,
        };
        let mut registry = Registry::new(
            world,
            PeerEntry { addr: my_data_addr.to_string(), node: my_node_label.to_string() },
        );
        // One pending reply stream per rank; a replacing re-HELLO drops
        // (closes) the dead predecessor's stream.
        let mut streams: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
        while !registry.is_complete() {
            let mut stream = accept_with_deadline(&listener, deadline, "rendezvous hello")?;
            let line = read_line_raw(&mut stream, 512)?;
            let hello = parse_hello(&line, world)?;
            if let (Some(mine), Some(theirs)) = (config_token, hello.config.as_deref()) {
                if mine != theirs {
                    let detail = describe_config_mismatch(mine, theirs);
                    // Tell the offender why before failing the bootstrap:
                    // the joiner surfaces this line as its own error.
                    let _ = stream.write_all(format!("REFUSE {detail}\n").as_bytes());
                    let _ = stream.shutdown(Shutdown::Write);
                    anyhow::bail!(
                        "rendezvous: refused registration from rank {}: {detail}",
                        hello.rank
                    );
                }
            }
            match registry.register(&hello)? {
                HelloOutcome::Registered | HelloOutcome::Replaced => {
                    streams[hello.rank] = Some(stream);
                }
                HelloOutcome::Stale => drop(stream),
            }
        }
        let table = registry.table()?;
        let entries: Vec<String> = table.iter().map(PeerEntry::to_wire).collect();
        let reply = format!("TABLE {}\n", entries.join(" "));
        for (peer, stream) in streams.iter_mut().enumerate() {
            if let Some(stream) = stream {
                stream
                    .write_all(reply.as_bytes())
                    .map_err(|e| anyhow::anyhow!("sending table to rank {peer}: {e}"))?;
                let _ = stream.shutdown(Shutdown::Write);
            }
        }
        Ok(table)
    } else {
        let mut stream = dial_with_retry(rendezvous_addr, deadline)?;
        let remaining = deadline
            .saturating_duration_since(Instant::now())
            .max(Duration::from_millis(100));
        stream
            .set_read_timeout(Some(remaining))
            .map_err(|e| anyhow::anyhow!("read timeout: {e}"))?;
        let hello = Hello {
            rank,
            addr: my_data_addr.to_string(),
            node: my_node_label.to_string(),
            generation,
            config: config_token.map(str::to_string),
        };
        stream
            .write_all(format!("{}\n", hello.to_wire()).as_bytes())
            .map_err(|e| anyhow::anyhow!("sending hello: {e}"))?;
        let line = read_line_raw(&mut stream, 8192)?;
        if let Some(detail) = line.strip_prefix("REFUSE ") {
            anyhow::bail!("rendezvous: registration refused by the group: {detail}");
        }
        parse_table(&line, world)
    }
}

/// Form the full mesh: one stream per peer, `conns[p]` is the connection
/// to rank `p` (`None` at index `rank`). Dials every lower rank, accepts
/// from every higher rank.
pub fn connect_mesh(
    rank: usize,
    world: usize,
    table: &[String],
    listener: &TcpListener,
    deadline: Instant,
) -> anyhow::Result<Vec<Option<TcpStream>>> {
    anyhow::ensure!(table.len() == world, "peer table size mismatch");
    let mut conns: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
    for peer in 0..rank {
        let mut stream = dial_with_retry(&table[peer], deadline)?;
        stream
            .set_nodelay(true)
            .map_err(|e| anyhow::anyhow!("nodelay: {e}"))?;
        stream
            .write_all(format!("PEER {rank}\n").as_bytes())
            .map_err(|e| anyhow::anyhow!("peer handshake to rank {peer}: {e}"))?;
        conns[peer] = Some(stream);
    }
    let mut remaining = world - 1 - rank;
    while remaining > 0 {
        let mut stream = accept_with_deadline(listener, deadline, "mesh peer")?;
        stream
            .set_nodelay(true)
            .map_err(|e| anyhow::anyhow!("nodelay: {e}"))?;
        let line = read_line_raw(&mut stream, 128)?;
        let mut parts = line.split_whitespace();
        anyhow::ensure!(
            parts.next() == Some("PEER"),
            "mesh handshake: expected PEER, got '{line}'"
        );
        let peer: usize = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("mesh handshake: bad rank in '{line}'"))?;
        anyhow::ensure!(
            peer > rank && peer < world,
            "mesh handshake: unexpected rank {peer} (I am {rank} of {world})"
        );
        anyhow::ensure!(
            conns[peer].is_none(),
            "mesh handshake: duplicate connection from rank {peer}"
        );
        // Clear the handshake-phase read timeout: collective receives may
        // legitimately block for a long time.
        stream
            .set_read_timeout(None)
            .map_err(|e| anyhow::anyhow!("read timeout: {e}"))?;
        conns[peer] = Some(stream);
        remaining -= 1;
    }
    for (p, c) in conns.iter().enumerate() {
        if p != rank {
            anyhow::ensure!(c.is_some(), "mesh: no connection to rank {p}");
        }
    }
    Ok(conns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};
    use crate::util::rng::Xoshiro256;

    fn deadline() -> Instant {
        Instant::now() + Duration::from_secs(20)
    }

    #[test]
    fn rendezvous_distributes_consistent_table_with_node_labels() {
        let world = 4;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let rdv = listener.local_addr().unwrap().to_string();
        let mut hosted = Some(listener);
        let tables: Vec<Vec<PeerEntry>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..world)
                .map(|rank| {
                    let hosted = if rank == 0 { hosted.take() } else { None };
                    let rdv = rdv.clone();
                    s.spawn(move || {
                        exchange_peer_table(
                            rank,
                            world,
                            &rdv,
                            &format!("127.0.0.1:{}", 9000 + rank),
                            // Ranks 0–1 on node 0, ranks 2–3 on node 1.
                            &format!("n{}", rank / 2),
                            0,
                            None,
                            hosted,
                            deadline(),
                        )
                        .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for t in &tables {
            assert_eq!(t, &tables[0]);
            assert_eq!(t.len(), world);
            for (r, entry) in t.iter().enumerate() {
                assert_eq!(entry.addr, format!("127.0.0.1:{}", 9000 + r));
                assert_eq!(entry.node, format!("n{}", r / 2));
            }
        }
    }

    #[test]
    fn rejoining_rank_supersedes_its_dead_predecessor() {
        // Rank 2 registers, "dies", and a relaunched process re-HELLOs with
        // a higher generation and a new data address; rank 0 must release a
        // table pointing at the replacement.
        let world = 3;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let rdv = listener.local_addr().unwrap().to_string();
        let host = {
            let rdv = rdv.clone();
            std::thread::spawn(move || {
                exchange_peer_table(
                    0,
                    world,
                    &rdv,
                    "127.0.0.1:9000",
                    "n0",
                    0,
                    None,
                    Some(listener),
                    deadline(),
                )
                .unwrap()
            })
        };
        // First incarnation of rank 2: HELLO then die before the table.
        {
            let mut s = dial_with_retry(&rdv, deadline()).unwrap();
            s.write_all(b"HELLO 2 127.0.0.1:9002 n1 g0\n").unwrap();
            // Dropped: the connection closes without reading the table.
        }
        std::thread::sleep(Duration::from_millis(50));
        let rdv1 = rdv.clone();
        let second = std::thread::spawn(move || {
            exchange_peer_table(2, world, &rdv1, "127.0.0.1:9102", "n1", 1, None, None, deadline())
                .unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let rank1 = std::thread::spawn(move || {
            exchange_peer_table(1, world, &rdv, "127.0.0.1:9001", "n0", 0, None, None, deadline())
                .unwrap()
        });
        let t0 = host.join().unwrap();
        let t2 = second.join().unwrap();
        let t1 = rank1.join().unwrap();
        assert_eq!(t0, t1);
        assert_eq!(t0, t2);
        assert_eq!(t0[2].addr, "127.0.0.1:9102", "table must point at the relaunched rank 2");
    }

    #[test]
    fn world_of_one_needs_no_network() {
        let t = exchange_peer_table(
            0,
            1,
            "127.0.0.1:1",
            "127.0.0.1:9000",
            "n0",
            0,
            None,
            None,
            deadline(),
        )
        .unwrap();
        assert_eq!(
            t,
            vec![PeerEntry { addr: "127.0.0.1:9000".to_string(), node: "n0".to_string() }]
        );
    }

    #[test]
    fn bad_node_labels_rejected_and_unlabelled_entries_tolerated() {
        for bad in ["", "two words", "a/b"] {
            assert!(
                exchange_peer_table(
                    0,
                    1,
                    "127.0.0.1:1",
                    "127.0.0.1:9000",
                    bad,
                    0,
                    None,
                    None,
                    deadline()
                )
                .is_err(),
                "label '{bad}' should be rejected"
            );
        }
        let e = PeerEntry::from_wire("127.0.0.1:9000");
        assert_eq!(e.addr, "127.0.0.1:9000");
        assert_eq!(e.node, "-");
        let e = PeerEntry::from_wire("127.0.0.1:9000/n3");
        assert_eq!(e.node, "n3");
    }

    #[test]
    fn hello_parser_accepts_legacy_and_tagged_lines() {
        let h = parse_hello("HELLO 2 127.0.0.1:9002 n1 g7", 4).unwrap();
        assert_eq!(
            h,
            Hello {
                rank: 2,
                addr: "127.0.0.1:9002".to_string(),
                node: "n1".to_string(),
                generation: 7,
                config: None,
            }
        );
        // Legacy forms: no generation, and no node label at all.
        assert_eq!(parse_hello("HELLO 1 a:1 n0", 2).unwrap().generation, 0);
        let h = parse_hello("HELLO 1 a:1", 2).unwrap();
        assert_eq!((h.node.as_str(), h.generation), ("-", 0));
        // Config-tagged forms, with and without a generation.
        let h = parse_hello("HELLO 2 a:2 n1 g3 cseed=1:codec=topk", 4).unwrap();
        assert_eq!((h.generation, h.config.as_deref()), (3, Some("seed=1:codec=topk")));
        let h = parse_hello("HELLO 2 a:2 n1 cseed=1", 4).unwrap();
        assert_eq!((h.generation, h.config.as_deref()), (0, Some("seed=1")));

        for bad in [
            "HELO 1 a:1",                // wrong verb
            "HELLO",                     // truncated
            "HELLO x a:1",               // junk rank
            "HELLO 0 a:1",               // rank 0 never HELLOs
            "HELLO 4 a:1",               // out of range for world 4
            "HELLO 1 a/b n0",            // '/' would corrupt the TABLE line
            "HELLO 1 a:1 n0 7",          // generation without the g prefix
            "HELLO 1 a:1 n0 gx",         // junk generation
            "HELLO 1 a:1 n0 g1 tail",    // trailing tokens
            "HELLO 1 a:1 n0 g1 c",       // empty config token
            "HELLO 1 a:1 n0 g1 cx tail", // trailing tokens after config
        ] {
            assert!(parse_hello(bad, 4).is_err(), "'{bad}' should be rejected");
        }
    }

    #[test]
    fn registry_arbitrates_generations() {
        let mk = |gen: u64, addr: &str| Hello {
            rank: 1,
            addr: addr.to_string(),
            node: "n0".to_string(),
            generation: gen,
            config: None,
        };
        let r0 = PeerEntry { addr: "a0".to_string(), node: "n0".to_string() };
        let mut reg = Registry::new(3, r0);
        assert!(!reg.is_complete());
        assert_eq!(reg.register(&mk(1, "a1")).unwrap(), HelloOutcome::Registered);
        // Newer generation replaces; the table tracks the replacement.
        assert_eq!(reg.register(&mk(2, "a1-new")).unwrap(), HelloOutcome::Replaced);
        assert_eq!(reg.generation(1), Some(2));
        // Stale and duplicate generations.
        assert_eq!(reg.register(&mk(1, "a1-old")).unwrap(), HelloOutcome::Stale);
        assert!(reg.register(&mk(2, "a1-dup")).is_err(), "same-generation duplicate");
        // Out-of-range ranks never panic the registry.
        assert!(reg.register(&Hello { rank: 0, ..mk(0, "x") }).is_err());
        assert!(reg.register(&Hello { rank: 3, ..mk(0, "x") }).is_err());
        // Table completes once rank 2 shows up, pointing at the newest gen.
        assert!(reg.table().is_err());
        assert_eq!(
            reg.register(&Hello { rank: 2, ..mk(0, "a2") }).unwrap(),
            HelloOutcome::Registered
        );
        assert!(reg.is_complete());
        assert_eq!(reg.table().unwrap()[1].addr, "a1-new");
    }

    #[test]
    fn mismatch_description_names_the_offending_flag() {
        let mine = "seed=000000000000002a:codec=topk:topo=flat:xmode=full";
        let theirs = "seed=000000000000002a:codec=randomk:topo=flat:xmode=full";
        let d = describe_config_mismatch(mine, theirs);
        assert!(d.contains("--codec"), "should name the flag: {d}");
        assert!(d.contains("topk") && d.contains("randomk"), "should show both values: {d}");
        let d = describe_config_mismatch("seed=1:topo=ring", "seed=2:topo=ring");
        assert!(d.contains("--seed"), "{d}");
        let d = describe_config_mismatch("xmode=full", "xmode=sharded");
        assert!(d.contains("--exchange-mode"), "{d}");
        let d = describe_config_mismatch("topo=flat", "topo=two-level");
        assert!(d.contains("--topology"), "{d}");
        // Structurally different tokens fall back to quoting both sides.
        let d = describe_config_mismatch("a=1:b=2", "weird");
        assert!(d.contains("a=1:b=2") && d.contains("weird"), "{d}");
    }

    #[test]
    fn mismatched_config_is_refused_in_both_directions() {
        // The host errors naming the offending rank; the joiner errors with
        // the REFUSE detail naming the flag to fix. Both sides must fail —
        // a refused joiner must never receive a peer table.
        let world = 2;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let rdv = listener.local_addr().unwrap().to_string();
        let host = {
            let rdv = rdv.clone();
            std::thread::spawn(move || {
                exchange_peer_table(
                    0,
                    world,
                    &rdv,
                    "127.0.0.1:9000",
                    "n0",
                    0,
                    Some("seed=1:codec=topk"),
                    Some(listener),
                    deadline(),
                )
            })
        };
        let joiner = std::thread::spawn(move || {
            exchange_peer_table(
                1,
                world,
                &rdv,
                "127.0.0.1:9001",
                "n0",
                0,
                Some("seed=1:codec=randomk"),
                None,
                deadline(),
            )
        });
        let host_err = host.join().unwrap().unwrap_err().to_string();
        assert!(
            host_err.contains("refused registration from rank 1") && host_err.contains("--codec"),
            "host error should name the rank and the flag: {host_err}"
        );
        let join_err = joiner.join().unwrap().unwrap_err().to_string();
        assert!(
            join_err.contains("registration refused")
                && join_err.contains("--codec")
                && join_err.contains("topk")
                && join_err.contains("randomk"),
            "joiner error should carry the actionable detail: {join_err}"
        );
    }

    #[test]
    fn matching_config_tokens_bootstrap_normally_and_legacy_peers_skip_the_check() {
        let world = 3;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let rdv = listener.local_addr().unwrap().to_string();
        let mut hosted = Some(listener);
        let tables: Vec<Vec<PeerEntry>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..world)
                .map(|rank| {
                    let hosted = if rank == 0 { hosted.take() } else { None };
                    let rdv = rdv.clone();
                    s.spawn(move || {
                        // Rank 2 is a legacy peer with no token: rank 0 only
                        // checks tokens that are actually attached.
                        let token = if rank == 2 { None } else { Some("seed=7:codec=fp32") };
                        exchange_peer_table(
                            rank,
                            world,
                            &rdv,
                            &format!("127.0.0.1:{}", 9100 + rank),
                            "n0",
                            0,
                            token,
                            hosted,
                            deadline(),
                        )
                        .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for t in &tables {
            assert_eq!(t, &tables[0]);
        }
    }

    /// Generates syntactically valid `Hello` values (world is fixed by the
    /// caller); shrinks towards rank 1 / generation 0 / short strings.
    struct HelloGen {
        world: usize,
    }

    impl Gen for HelloGen {
        type Value = Hello;
        fn generate(&self, rng: &mut Xoshiro256) -> Hello {
            let token = |rng: &mut Xoshiro256, len: usize| -> String {
                const ALPHA: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789.:-";
                (0..1 + len)
                    .map(|_| ALPHA[rng.gen_range(ALPHA.len())] as char)
                    .collect()
            };
            Hello {
                rank: 1 + rng.gen_range(self.world - 1),
                addr: token(rng, rng.gen_range(20)),
                node: token(rng, rng.gen_range(8)),
                generation: rng.next_u64() % 1000,
                config: if rng.gen_range(2) == 0 {
                    None
                } else {
                    Some(token(rng, rng.gen_range(16)))
                },
            }
        }
        fn shrink(&self, v: &Hello) -> Vec<Hello> {
            let mut out = Vec::new();
            if v.rank > 1 {
                out.push(Hello { rank: 1, ..v.clone() });
            }
            if v.generation > 0 {
                out.push(Hello { generation: 0, ..v.clone() });
            }
            if v.addr.len() > 1 {
                out.push(Hello { addr: v.addr[..1].to_string(), ..v.clone() });
            }
            if v.node.len() > 1 {
                out.push(Hello { node: v.node[..1].to_string(), ..v.clone() });
            }
            if v.config.is_some() {
                out.push(Hello { config: None, ..v.clone() });
            }
            out
        }
    }

    #[test]
    fn prop_hello_wire_roundtrip() {
        let world = 64;
        check("hello wire roundtrip", 300, HelloGen { world }, |h| {
            // Addresses with '/' can't round-trip through the TABLE line;
            // the parser rejects them, which is also a valid outcome.
            match parse_hello(&h.to_wire(), world) {
                Ok(back) if back == *h => Ok(()),
                Ok(back) => Err(format!("parsed {back:?} from {h:?}")),
                Err(_) if h.addr.contains('/') || h.node.contains('/') => Ok(()),
                Err(e) => Err(format!("rejected valid line: {e}")),
            }
        });
    }

    #[test]
    fn prop_hello_parser_survives_truncation_and_junk() {
        // Truncating a valid line at any byte, or injecting junk bytes,
        // must yield Ok-with-in-range-rank or Err — never a panic and never
        // an out-of-range rank.
        let world = 8;
        check(
            "hello truncation/junk",
            400,
            crate::util::proptest::gens::pair(
                HelloGen { world },
                crate::util::proptest::gens::usize_in(0..64),
            ),
            |(h, cut)| {
                let wire = h.to_wire();
                let cut = (*cut).min(wire.len());
                let mutations = [
                    wire[..cut].to_string(),                     // truncated
                    format!("{} {}", wire, &wire[..cut]),        // duplicated tail
                    wire.replace(' ', "  "),                     // extra separators
                    format!("{}{}", &wire[..cut], "\u{7f}junk"), // junk bytes
                ];
                for m in mutations {
                    if let Ok(h) = parse_hello(&m, world) {
                        if h.rank == 0 || h.rank >= world {
                            return Err(format!("out-of-range rank {} from '{m}'", h.rank));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_table_roundtrip_and_truncation() {
        let world = 5;
        check(
            "table roundtrip/truncation",
            300,
            crate::util::proptest::gens::pair(
                HelloGen { world },
                crate::util::proptest::gens::usize_in(0..200),
            ),
            |(h, cut)| {
                // Build a full table out of variations of the generated
                // entry, serialize like rank 0 does, and reparse.
                let table: Vec<PeerEntry> = (0..world)
                    .map(|r| PeerEntry {
                        addr: format!("{}:{r}", h.addr),
                        node: h.node.clone(),
                    })
                    .collect();
                let entries: Vec<String> = table.iter().map(PeerEntry::to_wire).collect();
                let line = format!("TABLE {}", entries.join(" "));
                if h.addr.contains('/') || h.node.contains('/') {
                    return Ok(()); // '/' in tokens corrupts the framing
                }
                match parse_table(&line, world) {
                    Ok(back) if back == table => {}
                    other => return Err(format!("roundtrip failed: {other:?}")),
                }
                // A truncated line must never parse as a full table, and a
                // wrong world size must be rejected.
                let cut = (*cut).min(line.len().saturating_sub(1));
                if let Ok(t) = parse_table(&line[..cut], world) {
                    if t.len() != world {
                        return Err("short parse returned wrong-size table".to_string());
                    }
                }
                if parse_table(&line, world + 1).is_ok() {
                    return Err("accepted table with wrong world size".to_string());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_registry_never_regresses_generations() {
        // Any sequence of registrations leaves each rank at the maximum
        // accepted generation, without panicking.
        let world = 4;
        check(
            "registry generation monotonicity",
            300,
            crate::util::proptest::gens::usize_in(1..20),
            |&n| {
                let mut rng = Xoshiro256::seed_from_u64(n as u64 * 7919);
                let r0 = PeerEntry { addr: "a0".to_string(), node: "-".to_string() };
                let mut reg = Registry::new(world, r0);
                let mut best: Vec<Option<u64>> = vec![None; world];
                for i in 0..n {
                    let h = Hello {
                        rank: 1 + rng.gen_range(world - 1),
                        addr: format!("addr{i}"),
                        node: "-".to_string(),
                        generation: rng.next_u64() % 4,
                    };
                    if let Ok(out) = reg.register(&h) {
                        if out != HelloOutcome::Stale {
                            best[h.rank] = Some(h.generation);
                        }
                    }
                    let got = reg.generation(h.rank);
                    if got < best[h.rank] {
                        return Err(format!(
                            "rank {} regressed: registry {got:?} < accepted {:?}",
                            h.rank, best[h.rank]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn full_mesh_connects_every_pair() {
        let world = 3;
        // Bind real data listeners and build the table from them.
        let listeners: Vec<TcpListener> = (0..world)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let table: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let counts: Vec<usize> = std::thread::scope(|s| {
            let handles: Vec<_> = listeners
                .iter()
                .enumerate()
                .map(|(rank, listener)| {
                    let table = table.clone();
                    s.spawn(move || {
                        let conns =
                            connect_mesh(rank, world, &table, listener, deadline()).unwrap();
                        conns.iter().filter(|c| c.is_some()).count()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(counts, vec![world - 1; world]);
    }
}
