//! Non-blocking collectives: a dedicated communication thread (the "comm
//! lane") that executes collectives while the caller keeps computing.
//!
//! The tagged transport is strictly blocking (MPI-style matched send/recv),
//! so true overlap needs a second OS thread per worker — exactly the
//! GPU-stream/comm-stream split the simulator's two-resource model (and the
//! paper's Fig. 1 / Eq. 7) assumes. [`lane_scope`] borrows the worker's
//! [`Comm`] into that thread for a bounded region; inside it,
//! [`CommLane::start_allreduce`] / [`CommLane::start_allgather`] enqueue
//! collectives and return a [`CommHandle`] whose `wait()` blocks only the
//! moment the result is actually needed.
//!
//! Ordering contract: the lane executes operations strictly in submission
//! order, so as long as every rank submits the same sequence of collectives
//! (the symmetric-SPMD invariant the serial path already relies on), tag
//! sequencing works out identically to the blocking path — the pipelined
//! exchange is bit-for-bit equivalent to the serial one.
//!
//! Failure semantics: a collective that dies mid-flight (peer gone,
//! connection reset) surfaces as a typed [`Error`] from
//! [`CommHandle::wait`], carried through from whichever backend the `Comm`
//! runs over.

use super::hierarchical::CommBreakdown;
use super::transport::Error;
use super::{Comm, CommRoute};
use crate::compression::{CodecKind, Collective};
use crate::util::stats::Stopwatch;
use std::sync::mpsc::{channel, Receiver, Sender};

/// What a completed collective hands back.
pub enum CommOutcome {
    /// Allreduce: the wire buffer, reduced in place across ranks (summed,
    /// not yet averaged — identical to `Comm::allreduce_wire`). A
    /// reduce-scatter completes through this variant too — only the owned
    /// chunk (a pure function of `(len, world, rank)`, see
    /// [`super::reduce_scatter`]) is valid then, which the submitter knows
    /// from having chosen the operation.
    Reduced(Vec<u8>),
    /// Allgather: every rank's payload, indexed by source rank. Entry
    /// `[rank]` is the very buffer this rank submitted (reusable).
    Gathered(Vec<Vec<u8>>),
}

/// Result of one asynchronous collective.
pub struct CommCompletion {
    pub outcome: CommOutcome,
    /// Seconds the comm lane spent inside this collective (includes time
    /// blocked on peers — the real occupancy of the comm resource).
    pub secs: f64,
    /// Per-level timing when the collective ran the two-level route
    /// (`None` on the flat ring).
    pub breakdown: Option<CommBreakdown>,
    /// Payload bytes this collective sent to peers on other nodes (0 under
    /// a flat topology).
    pub inter_bytes: u64,
}

enum Op {
    AllReduce {
        wire: Vec<u8>,
        kind: CodecKind,
        n: usize,
    },
    ReduceScatter {
        wire: Vec<u8>,
        kind: CodecKind,
        n: usize,
    },
    AllGather {
        wire: Vec<u8>,
    },
}

struct Job {
    op: Op,
    /// Route to apply on the lane's communicator before this collective
    /// (`None` keeps whatever route is already set) — how the exchange
    /// engine runs per-group [`CommRoute`]s through the comm lane.
    route: Option<CommRoute>,
    done: Sender<Result<CommCompletion, Error>>,
}

/// Waitable handle to an in-flight collective.
pub struct CommHandle {
    rx: Receiver<Result<CommCompletion, Error>>,
}

impl CommHandle {
    /// Block until the collective completes and take its result. A dead
    /// peer mid-collective surfaces here as a typed [`Error`].
    pub fn wait(self) -> Result<CommCompletion, Error> {
        match self.rx.recv() {
            Ok(result) => result,
            Err(_) => Err(Error::disconnected(
                "comm lane terminated before completing the operation",
            )),
        }
    }
}

/// Submission side of the comm lane (lives on the compute thread).
pub struct CommLane {
    jobs: Sender<Job>,
}

impl CommLane {
    /// Begin an in-place wire-format allreduce (FP32/FP16). `kind` must be
    /// an allreduce codec; its wire reducer is stateless, so the lane builds
    /// its own instance and the caller's codec state is never shared across
    /// threads.
    pub fn start_allreduce(&self, wire: Vec<u8>, kind: CodecKind, n: usize) -> CommHandle {
        self.start_allreduce_routed(wire, kind, n, None)
    }

    /// [`CommLane::start_allreduce`] with an explicit per-collective
    /// [`CommRoute`] applied on the lane's communicator first (`None`
    /// keeps the current route).
    pub fn start_allreduce_routed(
        &self,
        wire: Vec<u8>,
        kind: CodecKind,
        n: usize,
        route: Option<CommRoute>,
    ) -> CommHandle {
        // Validation fires on submit, before any cross-rank traffic — but
        // as a typed error through the handle, not a panic: a mixed-codec
        // engine that misroutes a group must fail the step, not the process.
        if kind.collective() != Collective::AllReduce {
            let (done, rx) = channel();
            let _ = done.send(Err(Error::codec(format!(
                "{}: start_allreduce needs an allreduce codec",
                kind.name()
            ))));
            return CommHandle { rx };
        }
        self.submit(Op::AllReduce { wire, kind, n }, route)
    }

    /// Begin an in-place wire-format reduce-scatter (FP32/FP16) with an
    /// explicit per-collective [`CommRoute`] (`None` keeps the current
    /// route). Completes as [`CommOutcome::Reduced`]; only the owned chunk
    /// of the returned buffer is valid (see [`super::reduce_scatter`]).
    pub fn start_reduce_scatter_routed(
        &self,
        wire: Vec<u8>,
        kind: CodecKind,
        n: usize,
        route: Option<CommRoute>,
    ) -> CommHandle {
        if kind.collective() != Collective::AllReduce {
            let (done, rx) = channel();
            let _ = done.send(Err(Error::codec(format!(
                "{}: start_reduce_scatter needs an allreduce codec",
                kind.name()
            ))));
            return CommHandle { rx };
        }
        self.submit(Op::ReduceScatter { wire, kind, n }, route)
    }

    /// Begin a variable-size allgather of this rank's payload.
    pub fn start_allgather(&self, wire: Vec<u8>) -> CommHandle {
        self.start_allgather_routed(wire, None)
    }

    /// [`CommLane::start_allgather`] with an explicit per-collective
    /// [`CommRoute`] (`None` keeps the current route).
    pub fn start_allgather_routed(
        &self,
        wire: Vec<u8>,
        route: Option<CommRoute>,
    ) -> CommHandle {
        self.submit(Op::AllGather { wire }, route)
    }

    fn submit(&self, op: Op, route: Option<CommRoute>) -> CommHandle {
        let (done, rx) = channel();
        self.jobs
            .send(Job { op, route, done })
            .expect("comm lane is gone (worker thread died)");
        CommHandle { rx }
    }
}

/// Run `f` with a dedicated comm thread owning `comm` for the duration.
///
/// Returns `(f's result, lane busy seconds)` — the busy time is the sum of
/// all collective durations executed by the lane (`comm_total` in
/// exchange-stats terms). The lane drains every submitted operation before
/// `lane_scope` returns, so no collective is ever lost.
pub fn lane_scope<R>(comm: &mut Comm, f: impl FnOnce(&CommLane) -> R) -> (R, f64) {
    let (jobs, jrx) = channel::<Job>();
    std::thread::scope(|s| {
        let worker = s.spawn(move || {
            let mut busy = 0.0f64;
            while let Ok(job) = jrx.recv() {
                if let Some(route) = job.route {
                    comm.set_route(route);
                }
                let inter_before = comm.inter_node_bytes();
                let sw = Stopwatch::start();
                let result = match job.op {
                    Op::AllReduce { mut wire, kind, n } => {
                        let reducer = kind.build(n);
                        comm.allreduce_wire(&mut wire, reducer.as_ref())
                            .map(|()| CommOutcome::Reduced(wire))
                    }
                    Op::ReduceScatter { mut wire, kind, n } => {
                        let reducer = kind.build(n);
                        comm.reduce_scatter_wire(&mut wire, reducer.as_ref())
                            .map(|_owned| CommOutcome::Reduced(wire))
                    }
                    Op::AllGather { wire } => comm.allgather(wire).map(CommOutcome::Gathered),
                };
                let secs = sw.elapsed().as_secs_f64();
                busy += secs;
                let breakdown = comm.take_last_breakdown();
                let inter_bytes = comm.inter_node_bytes() - inter_before;
                // A dropped handle just means the caller didn't care about
                // the result; the collective itself already ran on every
                // rank, so ignore the send error.
                let _ = job.done.send(result.map(|outcome| CommCompletion {
                    outcome,
                    secs,
                    breakdown,
                    inter_bytes,
                }));
            }
            busy
        });
        let lane = CommLane { jobs };
        let r = f(&lane);
        drop(lane); // close the job channel: the worker drains, then exits
        let busy = worker.join().expect("comm lane panicked");
        (r, busy)
    })
}

#[cfg(test)]
mod tests {
    use super::super::run_comm_group;
    use super::super::transport::ErrorKind;
    use super::*;
    use crate::compression::Codec as _;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn async_allgather_matches_blocking() {
        let results = run_comm_group(3, |c| {
            let rank = c.rank() as u8;
            // Blocking reference first (advances the tag space identically
            // on every rank).
            let blocking = c.allgather(vec![rank; 2]).unwrap();
            let (async_out, busy) = lane_scope(c, |lane| {
                lane.start_allgather(vec![rank; 2]).wait().unwrap().outcome
            });
            let gathered = match async_out {
                CommOutcome::Gathered(g) => g,
                _ => panic!("wrong outcome variant"),
            };
            assert!(busy >= 0.0);
            (blocking, gathered)
        });
        for (blocking, gathered) in results {
            assert_eq!(blocking, gathered);
        }
    }

    #[test]
    fn async_ops_execute_in_submission_order() {
        // Two back-to-back allgathers started before either wait: results
        // must match their submission, not interleave.
        let results = run_comm_group(4, |c| {
            let rank = c.rank() as u8;
            let ((first, second), _) = lane_scope(c, |lane| {
                let h1 = lane.start_allgather(vec![rank]);
                let h2 = lane.start_allgather(vec![rank + 100]);
                (h1.wait().unwrap(), h2.wait().unwrap())
            });
            let f = match first.outcome {
                CommOutcome::Gathered(g) => g,
                _ => panic!(),
            };
            let s = match second.outcome {
                CommOutcome::Gathered(g) => g,
                _ => panic!(),
            };
            (f, s)
        });
        for (f, s) in results {
            for (src, p) in f.iter().enumerate() {
                assert_eq!(p, &vec![src as u8]);
            }
            for (src, p) in s.iter().enumerate() {
                assert_eq!(p, &vec![src as u8 + 100]);
            }
        }
    }

    #[test]
    fn async_allreduce_matches_blocking() {
        use crate::compression::CodecKind;
        let n = 96;
        let results = run_comm_group(2, move |c| {
            let mut rng = Xoshiro256::seed_from_u64(c.rank() as u64);
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g, 1.0);
            let mut codec = CodecKind::Fp32.build(n);
            let mut wire = Vec::new();
            codec.encode_into(&g, &mut rng, &mut wire);

            // Blocking reference on a copy.
            let mut blocking = wire.clone();
            c.allreduce_wire(&mut blocking, codec.as_ref()).unwrap();

            let (completion, _) = lane_scope(c, |lane| {
                lane.start_allreduce(wire, CodecKind::Fp32, n).wait().unwrap()
            });
            let reduced = match completion.outcome {
                CommOutcome::Reduced(w) => w,
                _ => panic!("wrong outcome variant"),
            };
            (blocking, reduced)
        });
        for (blocking, reduced) in results {
            assert_eq!(blocking, reduced, "async allreduce must be bit-identical");
        }
    }

    #[test]
    fn async_reduce_scatter_owned_chunk_matches_blocking_allreduce() {
        use crate::compression::CodecKind;
        let n = 53; // ragged over 3 ranks
        let results = run_comm_group(3, move |c| {
            let mut rng = Xoshiro256::seed_from_u64(11 + c.rank() as u64);
            let mut g = vec![0f32; n];
            rng.fill_normal_f32(&mut g, 1.0);
            let mut codec = CodecKind::Fp32.build(n);
            let mut wire = Vec::new();
            codec.encode_into(&g, &mut rng, &mut wire);

            let mut blocking = wire.clone();
            c.allreduce_wire(&mut blocking, codec.as_ref()).unwrap();
            let (elo, ehi) = super::super::shard_elems(n, c.world(), c.rank());

            let (completion, _) = lane_scope(c, |lane| {
                lane.start_reduce_scatter_routed(wire, CodecKind::Fp32, n, None)
                    .wait()
                    .unwrap()
            });
            let scattered = match completion.outcome {
                CommOutcome::Reduced(w) => w,
                _ => panic!("wrong outcome variant"),
            };
            (
                blocking[4 * elo..4 * ehi].to_vec(),
                scattered[4 * elo..4 * ehi].to_vec(),
            )
        });
        for (blocking, scattered) in results {
            assert_eq!(blocking, scattered, "owned chunk must be bit-identical");
        }
    }

    #[test]
    fn allgather_codec_rejected_for_reduce_scatter() {
        use crate::compression::CodecKind;
        let (jobs, _jrx) = channel();
        let lane = CommLane { jobs };
        let handle = lane.start_reduce_scatter_routed(vec![0u8; 4], CodecKind::TopK { ratio: 0.01 }, 8, None);
        match handle.wait() {
            Err(e) if e.kind() == ErrorKind::Codec => {}
            Err(other) => panic!("wrong error: {other}"),
            Ok(_) => panic!("allgather codec must be rejected"),
        }
    }

    #[test]
    fn allgather_codec_rejected_for_allreduce() {
        use crate::compression::CodecKind;
        // Validation fires on submit, before any cross-rank traffic, and
        // surfaces as a typed error through the handle — never a panic.
        let (jobs, _jrx) = channel();
        let lane = CommLane { jobs };
        let handle = lane.start_allreduce(vec![0u8; 4], CodecKind::SignSgd, 8);
        match handle.wait() {
            Err(e) if e.kind() == ErrorKind::Codec => {
                let detail = &e.context;
                assert!(detail.contains("signsgd"), "context must name the codec: {detail}");
            }
            Err(other) => panic!("wrong error: {other}"),
            Ok(_) => panic!("allgather codec must be rejected"),
        }
    }

    #[test]
    fn wait_on_dead_lane_is_typed_error() {
        let (jobs, jrx) = channel::<Job>();
        let lane = CommLane { jobs };
        let (done, rx) = channel();
        // Emulate a lane that died before running the op: the job (and its
        // completion sender) is dropped without a reply.
        lane.jobs
            .send(Job {
                op: Op::AllGather { wire: vec![] },
                route: None,
                done,
            })
            .unwrap();
        drop(jrx);
        drop(lane);
        let handle = CommHandle { rx };
        match handle.wait() {
            Err(e) if e.kind() == ErrorKind::Disconnected => {}
            Err(other) => panic!("wrong error: {other}"),
            Ok(_) => panic!("expected an error from a dead lane"),
        }
    }
}
