//! Collective communication over a pluggable transport (paper Table 1:
//! allreduce for FP32/FP16, allgather for everything else).
//!
//! [`Comm`] wraps a [`transport::Endpoint`] with a sequence number so every
//! collective operation gets a unique tag space — consecutive collectives
//! can never cross-talk even when rank arrival order skews. The endpoint's
//! backend is either the in-process channel mesh ([`transport::mesh`] /
//! [`run_group`]) or real TCP sockets ([`tcp`] + [`bootstrap`]); the
//! collectives themselves are backend-agnostic.
//!
//! Failure semantics: every collective returns `Result<_,
//! [`Error`]>`. A peer dying mid-collective fails the operation
//! with the rank/peer/tag context instead of panicking the worker.
//!
//! Topology: a [`Comm`] carries a [`Topology`] (rank→node mapping,
//! optionally extended by racks/pods levels) and a [`CommRoute`]. With a
//! non-trivial topology the gradient collectives (`allgather`,
//! `allreduce_wire`) run the **hierarchical** exchange in [`hierarchical`]
//! — fan-in up the leader chain, a ring among the top-level leaders only,
//! fan-out back down — instead of the flat ring, and the per-level timing
//! split is available via [`Comm::take_last_breakdown`]. The route is
//! per-collective state ([`Comm::set_route`]): the exchange engine flips
//! it per tensor group when the scheduler emits per-group
//! [`RouteChoice`](crate::scheduler::RouteChoice)s, so small groups can
//! ride the flat ring while large groups go hierarchical within the same
//! step.

pub mod allgather;
pub mod bootstrap;
pub mod elastic;
pub mod faults;
pub mod hierarchical;
pub mod nonblocking;
pub mod reduce_scatter;
pub mod ring;
pub mod snapshot;
pub mod tcp;
pub mod topology;
pub mod transport;

pub use bootstrap::{parse_hello, parse_table, Hello, HelloOutcome, PeerEntry, Registry};
pub use elastic::{RemapTransport, RECOVERY_TAG_STRIDE};
pub use faults::{FaultPlan, FaultSpec, FaultTransport};
pub use hierarchical::CommBreakdown;
pub use nonblocking::{lane_scope, CommCompletion, CommHandle, CommLane, CommOutcome};
pub use reduce_scatter::shard_elems;
pub use snapshot::{recv_snapshot, send_snapshot, JOIN_TAG, SNAPSHOT_TAG};
pub use tcp::{run_tcp_group, tcp_endpoint, tcp_endpoint_with_nodes, TcpConfig, TcpTransport};
pub use topology::{LevelShape, LevelSpec, Topology, TopologySpec, TOPOLOGY_GRAMMAR};
pub use transport::{
    mesh, mesh_transports, run_group, AllocStats, BufferPool, Endpoint, Error, ErrorKind,
    InProcTransport, Transport, TransportKind,
};
#[allow(deprecated)]
pub use transport::TransportError;

/// Which algorithm the gradient collectives use (the f32 loss/metric
/// allreduce always rings flat — it moves a handful of bytes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CommRoute {
    /// Single-level ring over all ranks (the historical path).
    #[default]
    Flat,
    /// Hierarchical exchange over the attached [`Topology`], recursing
    /// over however many levels it has (see [`hierarchical`]). The name
    /// predates N-level topologies; "two-level" is the common case.
    TwoLevel,
}

/// Communicator: an endpoint plus a per-group op counter and the topology
/// the collectives route over. The topology is shared (`Arc`) so the
/// hierarchical collectives can hold it across their mutable endpoint
/// use without deep-copying the fan-stage structure per call.
pub struct Comm {
    pub ep: Endpoint,
    seq: u64,
    topology: std::sync::Arc<Topology>,
    route: CommRoute,
    /// Per-level timing of the most recent routed collective (set by the
    /// hierarchical path, cleared by every collective).
    last_breakdown: Option<CommBreakdown>,
}

impl Comm {
    pub fn new(ep: Endpoint) -> Self {
        let world = ep.world();
        Self {
            ep,
            seq: 0,
            topology: std::sync::Arc::new(Topology::flat(world)),
            route: CommRoute::Flat,
            last_breakdown: None,
        }
    }

    pub fn rank(&self) -> usize {
        self.ep.rank()
    }

    pub fn world(&self) -> usize {
        self.ep.world()
    }

    /// Reserve `slots` distinct tags for one collective invocation.
    pub(crate) fn next_tags(&mut self, slots: u64) -> u64 {
        let base = self.seq;
        self.seq += slots;
        base
    }

    pub fn bytes_sent(&self) -> u64 {
        self.ep.bytes_sent()
    }

    /// Attach a topology. Every rank must attach the same one (the route
    /// is part of the symmetric-SPMD contract, exactly like the collective
    /// call sequence). A trivial topology (one node, or all-singleton
    /// nodes) keeps the flat route; anything else switches the gradient
    /// collectives to the two-level exchange.
    pub fn set_topology(&mut self, topology: Topology) -> anyhow::Result<()> {
        anyhow::ensure!(
            topology.world() == self.world(),
            "topology is for {} ranks but the communicator has {}",
            topology.world(),
            self.world()
        );
        self.route = if topology.is_trivial() {
            CommRoute::Flat
        } else {
            CommRoute::TwoLevel
        };
        self.topology = std::sync::Arc::new(topology);
        Ok(())
    }

    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Cheap shared handle on the attached topology — what the
    /// hierarchical collectives hold while they drive the endpoint.
    pub(crate) fn topology_shared(&self) -> std::sync::Arc<Topology> {
        std::sync::Arc::clone(&self.topology)
    }

    /// Override the route: run the flat ring over a node-labelled topology
    /// (to compare inter-node byte counts against the hierarchical
    /// exchange, as `benches/hierarchy.rs` does), or flip routes per
    /// tensor group (the exchange engine, following the scheduler's
    /// per-group [`RouteChoice`](crate::scheduler::RouteChoice)s). On a
    /// trivial topology the hierarchical route is meaningless, so it
    /// clamps to `Flat` — deterministically on every rank, which keeps the
    /// SPMD tag sequences aligned.
    pub fn set_route(&mut self, route: CommRoute) {
        self.route = if self.topology.is_trivial() {
            CommRoute::Flat
        } else {
            route
        };
    }

    /// Restore the topology-default route (`TwoLevel` for a non-trivial
    /// topology, `Flat` otherwise) — what the exchange engine calls after
    /// a per-group-routed exchange so collectives outside the engine see a
    /// canonical route regardless of which group ran last.
    pub fn reset_route(&mut self) {
        self.route = if self.topology.is_trivial() {
            CommRoute::Flat
        } else {
            CommRoute::TwoLevel
        };
    }

    pub fn route(&self) -> CommRoute {
        self.route
    }

    pub(crate) fn note_breakdown(&mut self, b: CommBreakdown) {
        self.last_breakdown = Some(b);
    }

    /// Per-level timing of the most recent `allgather`/`allreduce_wire`,
    /// if it ran the two-level route. Consumed on read.
    pub fn take_last_breakdown(&mut self) -> Option<CommBreakdown> {
        self.last_breakdown.take()
    }

    /// Payload bytes this rank has sent to peers on **other** nodes
    /// (under a flat topology every peer shares the node, so this is 0).
    pub fn inter_node_bytes(&self) -> u64 {
        let rank = self.rank();
        self.ep
            .per_peer_sent()
            .iter()
            .enumerate()
            .filter(|&(peer, _)| !self.topology.same_node(rank, peer))
            .map(|(_, &bytes)| bytes)
            .sum()
    }

    // -- collectives (implemented in submodules) ---------------------------

    /// Synchronize all ranks.
    pub fn barrier(&mut self) -> Result<(), Error> {
        self.last_breakdown = None;
        allgather::barrier(self)
    }

    /// Root's payload ends up on every rank.
    pub fn broadcast(&mut self, root: usize, bytes: &mut Vec<u8>) -> Result<(), Error> {
        self.last_breakdown = None;
        allgather::broadcast(self, root, bytes)
    }

    /// Every rank contributes a (variable-size) payload; all ranks get all
    /// payloads, indexed by source rank. Routed: flat ring, or the
    /// two-level leader-concatenated exchange (bit-identical results).
    pub fn allgather(&mut self, mine: Vec<u8>) -> Result<Vec<Vec<u8>>, Error> {
        self.last_breakdown = None;
        match self.route {
            CommRoute::Flat => allgather::ring_allgather(self, mine),
            CommRoute::TwoLevel => hierarchical::hier_allgather(self, mine),
        }
    }

    /// In-place ring allreduce over an f32 buffer (sum). Always flat: the
    /// trainer uses it for scalar loss/metric reductions where a two-level
    /// exchange would only add latency.
    pub fn allreduce_f32(&mut self, data: &mut [f32]) -> Result<(), Error> {
        self.last_breakdown = None;
        ring::allreduce_f32(self, data)
    }

    /// In-place allreduce over a wire-format buffer, reducing with the
    /// codec's `reduce_wire` (FP32/FP16 payloads). Routed: flat ring, or
    /// the two-level reduce (deterministic; see [`hierarchical`] for the
    /// exactness contract).
    pub fn allreduce_wire(
        &mut self,
        data: &mut [u8],
        codec: &dyn crate::compression::Codec,
    ) -> Result<(), Error> {
        // Reject a misdispatched codec before any cross-rank traffic: once
        // a rank is mid-ring a reduce failure would strand its peers.
        if codec.collective() != crate::compression::Collective::AllReduce {
            return Err(Error::codec(format!(
                "{}: allreduce_wire needs an allreduce codec",
                codec.kind().name()
            )));
        }
        self.last_breakdown = None;
        match self.route {
            CommRoute::Flat => ring::allreduce_wire(self, data, codec),
            CommRoute::TwoLevel => hierarchical::hier_allreduce_wire(self, data, codec),
        }
    }

    /// In-place reduce-scatter over a wire-format buffer (FP32/FP16): on
    /// return, the owned byte range — see [`reduce_scatter`] for the
    /// ownership rule — holds this rank's fully reduced shard, bit-identical
    /// to what [`Comm::allreduce_wire`] would have left there; the rest of
    /// the buffer is partial-sum garbage and must not be consumed. Routed:
    /// flat ring phase 1, or the hierarchical fallback (full hierarchical
    /// allreduce, ownership at the consumer).
    pub fn reduce_scatter_wire(
        &mut self,
        data: &mut [u8],
        codec: &dyn crate::compression::Codec,
    ) -> Result<(usize, usize), Error> {
        // Same pre-traffic guard as allreduce_wire: a misdispatched codec
        // mid-ring would strand the peers.
        if codec.collective() != crate::compression::Collective::AllReduce {
            return Err(Error::codec(format!(
                "{}: reduce_scatter_wire needs an allreduce codec",
                codec.kind().name()
            )));
        }
        self.last_breakdown = None;
        match self.route {
            CommRoute::Flat => reduce_scatter::ring_reduce_scatter_wire(self, data, codec),
            CommRoute::TwoLevel => {
                reduce_scatter::hier_reduce_scatter_wire(self, data, codec)
            }
        }
    }

    // -- elastic recovery --------------------------------------------------

    /// Shrink this communicator to `survivors` (sorted old-rank indices
    /// including this rank) after a peer death, keeping the existing
    /// transport connections: the endpoint's backend is rewrapped in a
    /// [`RemapTransport`] that renumbers the survivors densely from 0 and
    /// drops every in-flight frame from excluded ranks.
    ///
    /// Every surviving rank must call this with the **same** survivor set
    /// (it is part of the SPMD contract, like the collective call
    /// sequence). The shrink starts a new recovery generation: the abort
    /// epoch increments (so stale [`transport::CTRL_ABORT_TAG`] frames
    /// from the failed step are ignored), and the collective tag space
    /// jumps to `generation * `[`RECOVERY_TAG_STRIDE`] — survivors may
    /// have consumed *different* tag counts in the step that failed, so an
    /// agreed jump is the only way to realign them. The topology resets to
    /// flat over the shrunk world (the old rank→node mapping no longer
    /// applies); callers re-attach a topology and re-run the schedule
    /// search for the new world afterwards.
    ///
    /// Returns this rank's index in the shrunk world.
    pub fn shrink_to_survivors(&mut self, survivors: &[usize]) -> anyhow::Result<usize> {
        // Validate before swapping anything out of the endpoint, so a bad
        // survivor set cannot strand the communicator on a dead transport.
        anyhow::ensure!(!survivors.is_empty(), "survivor set must be non-empty");
        anyhow::ensure!(
            survivors.windows(2).all(|w| w[0] < w[1]),
            "survivors must be sorted and unique"
        );
        anyhow::ensure!(
            *survivors.last().unwrap() < self.world(),
            "survivor rank {} out of range for world {}",
            survivors.last().unwrap(),
            self.world()
        );
        anyhow::ensure!(
            survivors.contains(&self.rank()),
            "rank {} is not in the survivor set {survivors:?}",
            self.rank()
        );
        let generation = self.ep.abort_epoch() + 1;
        let old = std::mem::replace(
            &mut self.ep,
            Endpoint::new(Box::new(elastic::NullTransport)),
        );
        let remap = RemapTransport::new(old.into_transport(), survivors)?;
        self.ep = Endpoint::new(Box::new(remap));
        self.ep.set_abort_epoch(generation);
        self.seq = generation * RECOVERY_TAG_STRIDE;
        let world = self.ep.world();
        self.topology = std::sync::Arc::new(Topology::flat(world));
        self.route = CommRoute::Flat;
        self.last_breakdown = None;
        Ok(self.ep.rank())
    }

    /// Swap in a freshly bootstrapped endpoint (the hot re-join path: the
    /// old mesh grew a replacement rank, so survivors and the joiner all
    /// re-ran the rendezvous and hold brand-new connections). The world and
    /// rank must be unchanged — growing back to the original world is the
    /// point. Like [`Comm::shrink_to_survivors`] this starts recovery
    /// generation `generation`: the abort epoch and the collective tag
    /// space jump in lockstep on every rank (survivors may have consumed
    /// different tag counts in the failed step), and the topology resets to
    /// flat — callers re-attach the real topology afterwards, exactly as
    /// at first bootstrap.
    pub fn adopt_endpoint(&mut self, ep: Endpoint, generation: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            ep.world() == self.world(),
            "adopted endpoint has world {} but the communicator has {}",
            ep.world(),
            self.world()
        );
        anyhow::ensure!(
            ep.rank() == self.rank(),
            "adopted endpoint has rank {} but the communicator is rank {}",
            ep.rank(),
            self.rank()
        );
        self.ep = ep;
        self.align_generation(generation);
        let world = self.ep.world();
        self.topology = std::sync::Arc::new(Topology::flat(world));
        self.route = CommRoute::Flat;
        self.last_breakdown = None;
        Ok(())
    }

    /// Jump to recovery generation `generation`: abort epoch and tag space
    /// move together, mirroring [`Comm::shrink_to_survivors`]. A hot
    /// joiner calls this on its fresh communicator so its tag sequence
    /// lands exactly where the survivors' [`Comm::adopt_endpoint`] put
    /// theirs.
    pub fn align_generation(&mut self, generation: u64) {
        self.ep.set_abort_epoch(generation);
        self.seq = generation * RECOVERY_TAG_STRIDE;
    }
}

/// Spawn a fresh `world`-rank group over the in-process mesh, one thread
/// per rank, each with a Comm.
pub fn run_comm_group<T: Send>(
    world: usize,
    f: impl Fn(&mut Comm) -> T + Send + Sync,
) -> Vec<T> {
    run_group(world, |ep| {
        let mut comm = Comm::new(ep);
        f(&mut comm)
    })
}

/// Spawn a fresh `world`-rank group over loopback TCP sockets, one thread
/// per rank, each with a Comm — the socket-path twin of
/// [`run_comm_group`], used by the transport-equivalence suite.
pub fn run_comm_group_tcp<T: Send>(
    world: usize,
    f: impl Fn(&mut Comm) -> T + Send + Sync,
) -> Vec<T> {
    run_tcp_group(world, |ep| {
        let mut comm = Comm::new(ep);
        f(&mut comm)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_all_ranks_pass() {
        let results = run_comm_group(4, |c| {
            c.barrier().unwrap();
            c.rank()
        });
        assert_eq!(results, vec![0, 1, 2, 3]);
    }

    #[test]
    fn sequence_numbers_isolate_ops() {
        // Two allgathers back-to-back: payloads must not cross between ops.
        let results = run_comm_group(3, |c| {
            let first = c.allgather(vec![c.rank() as u8]).unwrap();
            let second = c.allgather(vec![10 + c.rank() as u8]).unwrap();
            (first, second)
        });
        for (first, second) in results {
            assert_eq!(first, vec![vec![0], vec![1], vec![2]]);
            assert_eq!(second, vec![vec![10], vec![11], vec![12]]);
        }
    }

    #[test]
    fn world_of_one_is_noop() {
        let results = run_comm_group(1, |c| {
            c.barrier().unwrap();
            let g = c.allgather(vec![7]).unwrap();
            let mut x = vec![3.0f32];
            c.allreduce_f32(&mut x).unwrap();
            (g, x)
        });
        assert_eq!(results[0].0, vec![vec![7]]);
        assert_eq!(results[0].1, vec![3.0]);
    }

    #[test]
    fn two_level_allgather_matches_flat_ring() {
        // 6 ranks split 4+2 (non-divisible): the routed allgather must
        // return exactly what the flat ring returns, on every rank.
        let results = run_comm_group(6, |c| {
            let flat = c.allgather(vec![c.rank() as u8; c.rank() + 1]).unwrap();
            c.set_topology(Topology::from_sizes(&[4, 2]).unwrap()).unwrap();
            assert_eq!(c.route(), CommRoute::TwoLevel);
            let hier = c.allgather(vec![c.rank() as u8; c.rank() + 1]).unwrap();
            let breakdown = c.take_last_breakdown();
            (flat, hier, breakdown)
        });
        for (rank, (flat, hier, breakdown)) in results.iter().enumerate() {
            assert_eq!(flat, hier, "rank {rank}");
            let b = breakdown.expect("two-level route records a breakdown");
            assert!(b.intra_secs >= 0.0 && b.inter_secs >= 0.0);
        }
    }

    #[test]
    fn two_level_allreduce_sums_exactly_on_integer_grads() {
        use crate::compression::{Codec as _, CodecKind, Encoded};
        let n = 48;
        let results = run_comm_group(6, move |c| {
            c.set_topology(Topology::from_sizes(&[4, 2]).unwrap()).unwrap();
            // Integer-valued f32s: any reduction grouping sums exactly.
            let g: Vec<f32> = (0..n).map(|i| (c.rank() * 10 + i % 7) as f32).collect();
            let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(0);
            let mut codec = CodecKind::Fp32.build(n);
            let enc = codec.encode(&g, &mut rng);
            let mut wire = enc.bytes;
            c.allreduce_wire(&mut wire, codec.as_ref()).unwrap();
            let mut out = vec![0f32; n];
            codec.decode(&Encoded { bytes: wire, n }, &mut out);
            out
        });
        for r in &results {
            for (i, v) in r.iter().enumerate() {
                // Σ_rank (10·rank + i%7) over ranks 0..6; Σ rank = 15.
                let want = (10 * 15 + 6 * (i % 7)) as f32;
                assert_eq!(*v, want, "elem {i}");
            }
        }
    }

    #[test]
    fn three_level_allgather_matches_flat_ring() {
        // 8 ranks, 4 nodes of 2, 2 racks of 2 nodes: the recursion climbs
        // two fan stages and rings over the two rack leaders, yet must
        // return exactly what the flat ring returns, on every rank.
        let results = run_comm_group(8, |c| {
            let flat = c.allgather(vec![c.rank() as u8; c.rank() + 1]).unwrap();
            let spec = TopologySpec::parse("nodes=4;racks=2").unwrap();
            c.set_topology(spec.build(8).unwrap()).unwrap();
            assert_eq!(c.route(), CommRoute::TwoLevel);
            let hier = c.allgather(vec![c.rank() as u8; c.rank() + 1]).unwrap();
            (flat, hier, c.take_last_breakdown())
        });
        for (rank, (flat, hier, breakdown)) in results.iter().enumerate() {
            assert_eq!(flat, hier, "rank {rank}");
            let b = breakdown.expect("hierarchical route records a breakdown");
            assert!(b.intra_secs >= 0.0 && b.inter_secs >= 0.0);
        }
    }

    #[test]
    fn three_level_allreduce_sums_exactly_on_integer_grads() {
        use crate::compression::{Codec as _, CodecKind, Encoded};
        let n = 32;
        let results = run_comm_group(6, move |c| {
            // world=6: uneven nodes (1+1+2+2) under 2 racks.
            let spec = TopologySpec::parse("nodes=1+1+2+2;racks=2+2").unwrap();
            c.set_topology(spec.build(6).unwrap()).unwrap();
            let g: Vec<f32> = (0..n).map(|i| (c.rank() * 10 + i % 5) as f32).collect();
            let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(0);
            let mut codec = CodecKind::Fp32.build(n);
            let enc = codec.encode(&g, &mut rng);
            let mut wire = enc.bytes;
            c.allreduce_wire(&mut wire, codec.as_ref()).unwrap();
            let mut out = vec![0f32; n];
            codec.decode(&Encoded { bytes: wire, n }, &mut out);
            out
        });
        for r in &results {
            for (i, v) in r.iter().enumerate() {
                // Σ_rank (10·rank + i%5) over ranks 0..6; Σ rank = 15.
                let want = (10 * 15 + 6 * (i % 5)) as f32;
                assert_eq!(*v, want, "elem {i}");
            }
        }
    }

    #[test]
    fn set_route_clamps_to_flat_on_trivial_topologies() {
        let results = run_comm_group(2, |c| {
            // Default topology is flat: a hierarchical override must clamp.
            c.set_route(CommRoute::TwoLevel);
            let clamped = c.route();
            c.set_topology(Topology::from_sizes(&[1, 1]).unwrap()).unwrap();
            c.set_route(CommRoute::TwoLevel);
            let singleton = c.route();
            c.reset_route();
            (clamped, singleton, c.route())
        });
        for (clamped, singleton, reset) in results {
            assert_eq!(clamped, CommRoute::Flat);
            assert_eq!(singleton, CommRoute::Flat);
            assert_eq!(reset, CommRoute::Flat);
        }
    }

    #[test]
    fn trivial_topologies_keep_the_flat_route() {
        let results = run_comm_group(3, |c| {
            c.set_topology(Topology::flat(3)).unwrap();
            let single = c.route();
            c.set_topology(Topology::balanced(3, 3).unwrap()).unwrap();
            let singletons = c.route();
            // Collectives still work after the re-attachments.
            let g = c.allgather(vec![c.rank() as u8]).unwrap();
            (single, singletons, g)
        });
        for (single, singletons, g) in results {
            assert_eq!(single, CommRoute::Flat);
            assert_eq!(singletons, CommRoute::Flat);
            assert_eq!(g, vec![vec![0], vec![1], vec![2]]);
        }
    }

    #[test]
    fn topology_world_mismatch_rejected() {
        let results = run_comm_group(2, |c| c.set_topology(Topology::flat(3)).is_err());
        assert!(results.into_iter().all(|e| e));
    }

    #[test]
    fn inter_node_bytes_counted_against_topology() {
        // Under a 2+2 split, rank 0's flat-ring neighbour (rank 1) is
        // intra-node, so a flat allgather from rank 0 crosses no node
        // boundary — while rank 1 forwards everything to rank 2 inter-node.
        let results = run_comm_group(4, |c| {
            c.set_topology(Topology::from_sizes(&[2, 2]).unwrap()).unwrap();
            c.set_route(CommRoute::Flat);
            c.allgather(vec![0u8; 10]).unwrap();
            (c.inter_node_bytes(), c.bytes_sent())
        });
        for (rank, (inter, total)) in results.iter().enumerate() {
            assert_eq!(*total, 30, "rank {rank} forwards 3 payloads");
            match rank {
                // Ranks 0 and 2 send to an intra-node right neighbour.
                0 | 2 => assert_eq!(*inter, 0, "rank {rank}"),
                // Ranks 1 and 3 send to the next node.
                _ => assert_eq!(*inter, 30, "rank {rank}"),
            }
        }
    }

    #[test]
    fn collectives_identical_over_tcp_group() {
        let results = run_comm_group_tcp(3, |c| {
            c.barrier().unwrap();
            let g = c.allgather(vec![c.rank() as u8; 2]).unwrap();
            let mut x = vec![c.rank() as f32, 1.0];
            c.allreduce_f32(&mut x).unwrap();
            (g, x)
        });
        for (g, x) in &results {
            assert_eq!(g, &vec![vec![0, 0], vec![1, 1], vec![2, 2]]);
            assert_eq!(x, &vec![3.0, 3.0]);
        }
    }
}
