//! Collective communication over a pluggable transport (paper Table 1:
//! allreduce for FP32/FP16, allgather for everything else).
//!
//! [`Comm`] wraps a [`transport::Endpoint`] with a sequence number so every
//! collective operation gets a unique tag space — consecutive collectives
//! can never cross-talk even when rank arrival order skews. The endpoint's
//! backend is either the in-process channel mesh ([`transport::mesh`] /
//! [`run_group`]) or real TCP sockets ([`tcp`] + [`bootstrap`]); the
//! collectives themselves are backend-agnostic.
//!
//! Failure semantics: every collective returns `Result<_,
//! [`TransportError`]>`. A peer dying mid-collective fails the operation
//! with the rank/peer/tag context instead of panicking the worker.

pub mod allgather;
pub mod bootstrap;
pub mod nonblocking;
pub mod ring;
pub mod tcp;
pub mod transport;

pub use nonblocking::{lane_scope, CommCompletion, CommHandle, CommLane, CommOutcome};
pub use tcp::{run_tcp_group, tcp_endpoint, TcpConfig, TcpTransport};
pub use transport::{
    mesh, run_group, Endpoint, InProcTransport, Transport, TransportError, TransportKind,
};

/// Communicator: an endpoint plus a per-group op counter.
pub struct Comm {
    pub ep: Endpoint,
    seq: u64,
}

impl Comm {
    pub fn new(ep: Endpoint) -> Self {
        Self { ep, seq: 0 }
    }

    pub fn rank(&self) -> usize {
        self.ep.rank()
    }

    pub fn world(&self) -> usize {
        self.ep.world()
    }

    /// Reserve `slots` distinct tags for one collective invocation.
    pub(crate) fn next_tags(&mut self, slots: u64) -> u64 {
        let base = self.seq;
        self.seq += slots;
        base
    }

    pub fn bytes_sent(&self) -> u64 {
        self.ep.bytes_sent()
    }

    // -- collectives (implemented in submodules) ---------------------------

    /// Synchronize all ranks.
    pub fn barrier(&mut self) -> Result<(), TransportError> {
        allgather::barrier(self)
    }

    /// Root's payload ends up on every rank.
    pub fn broadcast(&mut self, root: usize, bytes: &mut Vec<u8>) -> Result<(), TransportError> {
        allgather::broadcast(self, root, bytes)
    }

    /// Every rank contributes a (variable-size) payload; all ranks get all
    /// payloads, indexed by source rank.
    pub fn allgather(&mut self, mine: Vec<u8>) -> Result<Vec<Vec<u8>>, TransportError> {
        allgather::ring_allgather(self, mine)
    }

    /// In-place ring allreduce over an f32 buffer (sum).
    pub fn allreduce_f32(&mut self, data: &mut [f32]) -> Result<(), TransportError> {
        ring::allreduce_f32(self, data)
    }

    /// In-place ring allreduce over a wire-format buffer, reducing with the
    /// codec's `reduce_wire` (FP32/FP16 payloads).
    pub fn allreduce_wire(
        &mut self,
        data: &mut [u8],
        codec: &dyn crate::compression::Codec,
    ) -> Result<(), TransportError> {
        ring::allreduce_wire(self, data, codec)
    }
}

/// Spawn a fresh `world`-rank group over the in-process mesh, one thread
/// per rank, each with a Comm.
pub fn run_comm_group<T: Send>(
    world: usize,
    f: impl Fn(&mut Comm) -> T + Send + Sync,
) -> Vec<T> {
    run_group(world, |ep| {
        let mut comm = Comm::new(ep);
        f(&mut comm)
    })
}

/// Spawn a fresh `world`-rank group over loopback TCP sockets, one thread
/// per rank, each with a Comm — the socket-path twin of
/// [`run_comm_group`], used by the transport-equivalence suite.
pub fn run_comm_group_tcp<T: Send>(
    world: usize,
    f: impl Fn(&mut Comm) -> T + Send + Sync,
) -> Vec<T> {
    run_tcp_group(world, |ep| {
        let mut comm = Comm::new(ep);
        f(&mut comm)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_all_ranks_pass() {
        let results = run_comm_group(4, |c| {
            c.barrier().unwrap();
            c.rank()
        });
        assert_eq!(results, vec![0, 1, 2, 3]);
    }

    #[test]
    fn sequence_numbers_isolate_ops() {
        // Two allgathers back-to-back: payloads must not cross between ops.
        let results = run_comm_group(3, |c| {
            let first = c.allgather(vec![c.rank() as u8]).unwrap();
            let second = c.allgather(vec![10 + c.rank() as u8]).unwrap();
            (first, second)
        });
        for (first, second) in results {
            assert_eq!(first, vec![vec![0], vec![1], vec![2]]);
            assert_eq!(second, vec![vec![10], vec![11], vec![12]]);
        }
    }

    #[test]
    fn world_of_one_is_noop() {
        let results = run_comm_group(1, |c| {
            c.barrier().unwrap();
            let g = c.allgather(vec![7]).unwrap();
            let mut x = vec![3.0f32];
            c.allreduce_f32(&mut x).unwrap();
            (g, x)
        });
        assert_eq!(results[0].0, vec![vec![7]]);
        assert_eq!(results[0].1, vec![3.0]);
    }

    #[test]
    fn collectives_identical_over_tcp_group() {
        let results = run_comm_group_tcp(3, |c| {
            c.barrier().unwrap();
            let g = c.allgather(vec![c.rank() as u8; 2]).unwrap();
            let mut x = vec![c.rank() as f32, 1.0];
            c.allreduce_f32(&mut x).unwrap();
            (g, x)
        });
        for (g, x) in &results {
            assert_eq!(g, &vec![vec![0, 0], vec![1, 1], vec![2, 2]]);
            assert_eq!(x, &vec![3.0, 3.0]);
        }
    }
}
