//! Two-level (topology-aware) collectives: intra-node reduce/gather to the
//! node leader, an inter-node ring **among leaders only**, then an
//! intra-node broadcast — the hierarchy MG-WFBP and ScaleCom show flat
//! rings need on multi-node fabrics.
//!
//! Why: a flat ring drags `2·(w−1)/w · S` bytes per rank across *every*
//! link class, so the slow inter-node fabric gates all `2·(w−1)` steps.
//! The two-level exchange confines the slow level to a ring over the `L`
//! node leaders (`2·(L−1)` steps, `2·(L−1)/L · S` bytes per leader), while
//! the cheap intra-node level absorbs the member fan-in/fan-out. The
//! measured per-level split (`CommBreakdown`) feeds the scheduler's
//! per-level α+β·size fits (`scheduler::estimator`), and the predicted
//! counterpart lives in `netsim::hierarchy`.
//!
//! ## Exactness
//!
//! - **Allgather codecs** (every compressed scheme in paper Table 1): the
//!   two-level path is **bit-identical to the flat ring unconditionally**.
//!   Leaders exchange *concatenated frames* of their node's encoded
//!   payloads; every rank ends up with the same rank-indexed payload table
//!   the flat allgather delivers, and decodes it in the same rank order —
//!   no floating-point reduction happens on the wire at all.
//! - **Allreduce codecs** (FP32/FP16): sums are deterministic on every
//!   rank (leader folds its members in ascending rank order, then the
//!   leader ring reduces node partials), but the reduction *grouping*
//!   differs from the flat ring's, so results are bit-identical exactly
//!   when the sums involved are exact in the wire precision — the same
//!   caveat NCCL documents for tree vs ring reductions.
//!   `tests/hierarchy_equivalence.rs` pins both properties.
//!
//! Tag discipline: each operation reserves `3·world + 1` tags on **every**
//! rank (leader or member) so rank-local tag sequences stay aligned across
//! the whole group even though only leaders run the inter-node stage.

use super::allgather::subset_ring_allgather;
use super::ring::subset_ring_allreduce_bytes;
use super::transport::TransportError;
use super::Comm;
use crate::compression::Codec;
use crate::util::stats::Stopwatch;

/// Per-level timing of one hierarchical collective, as measured by the
/// calling rank. Leaders attribute the inter-node ring to `inter_secs`;
/// non-leaders spend the same wall time blocked in the intra-node fan-out
/// stage (their `inter_secs` is 0) — rank 0, which drives the scheduler's
/// cost fits, is always a leader.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommBreakdown {
    /// Seconds in the intra-node stages (member→leader fan-in and
    /// leader→member fan-out).
    pub intra_secs: f64,
    /// Seconds in the inter-node stage (the ring among node leaders).
    pub inter_secs: f64,
}

/// Tags one hierarchical collective may use; reserved identically on every
/// rank. Layout: `[0, world)` intra fan-in (by node-local index),
/// `[world, 3·world)` the leader ring, `[3·world]` intra fan-out.
pub(crate) fn hier_tag_slots(world: usize) -> u64 {
    3 * world as u64 + 1
}

/// Two-level allreduce of a codec wire buffer (FP32/FP16): intra-node fold
/// to the leader, ring allreduce among leaders, intra-node broadcast.
pub fn hier_allreduce_wire(
    comm: &mut Comm,
    data: &mut [u8],
    codec: &dyn Codec,
) -> Result<(), TransportError> {
    let world = comm.world();
    let rank = comm.rank();
    if world == 1 || data.is_empty() {
        return Ok(());
    }
    let align = codec.wire_align();
    assert_eq!(
        data.len() % align,
        0,
        "buffer length must be a multiple of the element size"
    );
    let members = comm.topology().node_members_of(rank).to_vec();
    let leaders = comm.topology().leaders();
    let leader = members[0];
    let base = comm.next_tags(hier_tag_slots(world));
    let ring_base = base + world as u64;
    let fanout_tag = base + 3 * world as u64;

    // Stage A — intra-node fan-in: the leader folds member buffers in
    // ascending rank order (deterministic; no election traffic).
    let sw = Stopwatch::start();
    if rank == leader {
        for (idx, &m) in members.iter().enumerate().skip(1) {
            let incoming = comm.ep.recv(m, base + idx as u64)?;
            codec.reduce_wire(data, &incoming);
        }
    } else {
        let idx = members
            .iter()
            .position(|&m| m == rank)
            .expect("rank missing from its own node");
        comm.ep.send(leader, base + idx as u64, data.to_vec())?;
    }
    let mut intra_secs = sw.elapsed().as_secs_f64();

    // Stage B — inter-node ring among leaders over the node partials.
    let sw = Stopwatch::start();
    if rank == leader && leaders.len() > 1 {
        subset_ring_allreduce_bytes(comm, &leaders, ring_base, data, align, &|a, b| {
            codec.reduce_wire(a, b)
        })?;
    }
    let inter_secs = sw.elapsed().as_secs_f64();

    // Stage C — intra-node fan-out of the fully reduced buffer.
    let sw = Stopwatch::start();
    if rank == leader {
        for &m in members.iter().skip(1) {
            comm.ep.send(m, fanout_tag, data.to_vec())?;
        }
    } else {
        let reduced = comm.ep.recv(leader, fanout_tag)?;
        debug_assert_eq!(reduced.len(), data.len());
        data.copy_from_slice(&reduced);
    }
    intra_secs += sw.elapsed().as_secs_f64();

    comm.note_breakdown(CommBreakdown {
        intra_secs,
        inter_secs: if rank == leader { inter_secs } else { 0.0 },
    });
    Ok(())
}

/// Two-level allgather (variable-size payloads): members hand their
/// payloads to the leader, leaders ring-exchange **concatenated node
/// frames**, the leader fans the full rank-indexed table back out. The
/// result is exactly what the flat ring allgather returns, on every rank.
pub fn hier_allgather(comm: &mut Comm, mine: Vec<u8>) -> Result<Vec<Vec<u8>>, TransportError> {
    let world = comm.world();
    let rank = comm.rank();
    if world == 1 {
        return Ok(vec![mine]);
    }
    let members = comm.topology().node_members_of(rank).to_vec();
    let leaders = comm.topology().leaders();
    let node_lists: Vec<Vec<usize>> = (0..comm.topology().num_nodes())
        .map(|n| comm.topology().node_members(n).to_vec())
        .collect();
    let my_node = comm.topology().node_of(rank);
    let leader = members[0];
    let base = comm.next_tags(hier_tag_slots(world));
    let ring_base = base + world as u64;
    let fanout_tag = base + 3 * world as u64;

    let mut out: Vec<Vec<u8>> = vec![Vec::new(); world];

    // Stage A — intra-node fan-in of raw payloads.
    let sw = Stopwatch::start();
    if rank == leader {
        out[rank] = mine;
        for (idx, &m) in members.iter().enumerate().skip(1) {
            out[m] = comm.ep.recv(m, base + idx as u64)?;
        }
    } else {
        let idx = members
            .iter()
            .position(|&m| m == rank)
            .expect("rank missing from its own node");
        comm.ep.send(leader, base + idx as u64, mine)?;
    }
    let mut intra_secs = sw.elapsed().as_secs_f64();

    // Stage B — leaders exchange concatenated node frames (one
    // length-prefixed entry per member, ascending rank order).
    let sw = Stopwatch::start();
    if rank == leader && leaders.len() > 1 {
        let frame = encode_frame(&members, &out);
        let gathered = subset_ring_allgather(comm, &leaders, ring_base, frame)?;
        for (node, frame) in gathered.iter().enumerate() {
            if node != my_node {
                decode_frame_into(&node_lists[node], frame, &mut out)?;
            }
        }
    }
    let inter_secs = sw.elapsed().as_secs_f64();

    // Stage C — intra-node fan-out of the full rank-indexed table.
    let sw = Stopwatch::start();
    if rank == leader {
        if members.len() > 1 {
            let all_ranks: Vec<usize> = (0..world).collect();
            let table = encode_frame(&all_ranks, &out);
            for &m in members.iter().skip(1) {
                comm.ep.send(m, fanout_tag, table.clone())?;
            }
        }
    } else {
        let table = comm.ep.recv(leader, fanout_tag)?;
        let all_ranks: Vec<usize> = (0..world).collect();
        decode_frame_into(&all_ranks, &table, &mut out)?;
    }
    intra_secs += sw.elapsed().as_secs_f64();

    comm.note_breakdown(CommBreakdown {
        intra_secs,
        inter_secs: if rank == leader { inter_secs } else { 0.0 },
    });
    Ok(out)
}

/// Concatenate `out[r]` for each rank in `ranks` as `[u32 len][bytes]`
/// entries, in the given (ascending) order.
fn encode_frame(ranks: &[usize], out: &[Vec<u8>]) -> Vec<u8> {
    let total: usize = ranks.iter().map(|&r| 4 + out[r].len()).sum();
    let mut frame = Vec::with_capacity(total);
    for &r in ranks {
        frame.extend_from_slice(&(out[r].len() as u32).to_le_bytes());
        frame.extend_from_slice(&out[r]);
    }
    frame
}

/// Inverse of [`encode_frame`]: scatter the entries back into `out` at the
/// given rank indices. A malformed frame is a transport-level failure (it
/// can only come from a corrupt or truncated peer stream).
fn decode_frame_into(
    ranks: &[usize],
    frame: &[u8],
    out: &mut [Vec<u8>],
) -> Result<(), TransportError> {
    let corrupt = |what: &str| TransportError::Disconnected {
        detail: format!("hierarchical allgather: corrupt node frame ({what})"),
    };
    let mut off = 0usize;
    for &r in ranks {
        let hdr = frame
            .get(off..off + 4)
            .ok_or_else(|| corrupt("truncated length header"))?;
        let len = u32::from_le_bytes(hdr.try_into().unwrap()) as usize;
        off += 4;
        let payload = frame
            .get(off..off + len)
            .ok_or_else(|| corrupt("truncated payload"))?;
        out[r] = payload.to_vec();
        off += len;
    }
    if off != frame.len() {
        return Err(corrupt("trailing bytes"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_preserves_payloads() {
        let out = vec![vec![1u8, 2], Vec::new(), vec![9u8; 5], vec![7u8]];
        let ranks = vec![0usize, 2, 3];
        let frame = encode_frame(&ranks, &out);
        assert_eq!(frame.len(), 4 * 3 + 2 + 5 + 1);
        let mut back = vec![Vec::new(); 4];
        decode_frame_into(&ranks, &frame, &mut back).unwrap();
        assert_eq!(back[0], out[0]);
        assert!(back[1].is_empty(), "rank 1 is not in the frame");
        assert_eq!(back[2], out[2]);
        assert_eq!(back[3], out[3]);
    }

    #[test]
    fn corrupt_frames_fail_loudly() {
        let mut out = vec![Vec::new(); 2];
        // Truncated header.
        assert!(decode_frame_into(&[0], &[1, 0, 0], &mut out).is_err());
        // Header promises more payload than exists.
        assert!(decode_frame_into(&[0], &[5, 0, 0, 0, 1], &mut out).is_err());
        // Trailing garbage after the last entry.
        assert!(decode_frame_into(&[0], &[1, 0, 0, 0, 7, 9], &mut out).is_err());
        // Exact fit parses.
        assert!(decode_frame_into(&[0], &[1, 0, 0, 0, 7], &mut out).is_ok());
        assert_eq!(out[0], vec![7]);
    }
}
