//! Hierarchical (topology-aware) collectives: recursive fan-in along the
//! topology's leader chain, a ring among the **top-level leaders only**,
//! then a fan-out back down — the hierarchy MG-WFBP and ScaleCom show flat
//! rings need on multi-node fabrics, generalized from two levels to the
//! N-level hierarchies [`Topology`](super::Topology) can describe
//! (`nodes=…;racks=…;…`).
//!
//! Why: a flat ring drags `2·(w−1)/w · S` bytes per rank across *every*
//! link class, so the slowest fabric gates all `2·(w−1)` steps. The
//! hierarchical exchange confines the slow level to a ring over the `L`
//! top-level leaders (`2·(L−1)` steps, `2·(L−1)/L · S` bytes per leader),
//! while the cheaper lower levels absorb the member fan-in/fan-out stage
//! by stage. The measured split (`CommBreakdown`: top ring vs everything
//! below it) feeds the scheduler's per-level α+β·size fits
//! (`scheduler::estimator`), and the predicted counterpart lives in
//! `netsim::hierarchy`.
//!
//! ## Exactness
//!
//! - **Allgather codecs** (every compressed scheme in paper Table 1): the
//!   hierarchical path is **bit-identical to the flat ring
//!   unconditionally**. Leaders exchange *concatenated frames* of the
//!   encoded payloads they hold; every rank ends up with the same
//!   rank-indexed payload table the flat allgather delivers, and decodes
//!   it in the same rank order — no floating-point reduction happens on
//!   the wire at all. This is also why per-group **route switches**
//!   (flat ↔ hierarchical, `tests/route_choice.rs`) are invisible to
//!   gradients and EF state.
//! - **Allreduce codecs** (FP32/FP16): sums are deterministic on every
//!   rank (each leader folds its subordinates in ascending rank order,
//!   then the top ring reduces the partials), but the reduction *grouping*
//!   differs from the flat ring's, so results are bit-identical exactly
//!   when the sums involved are exact in the wire precision — the same
//!   caveat NCCL documents for tree vs ring reductions.
//!   `tests/hierarchy_equivalence.rs` pins both properties.
//!
//! Tag discipline: each operation reserves `stages·(world+1) + 2·world`
//! tags on **every** rank (leader or member) — one fan-in tag block plus a
//! fan-out tag per stage, then the top ring's block — so rank-local tag
//! sequences stay aligned across the whole group even though only leaders
//! climb the chain.

use super::allgather::subset_ring_allgather;
use super::ring::subset_ring_allreduce_bytes;
use super::transport::Error;
use super::Comm;
use crate::compression::Codec;
use crate::util::stats::Stopwatch;

/// Per-level timing of one hierarchical collective, as measured by the
/// calling rank. Top-level leaders attribute the top ring to `inter_secs`;
/// other ranks spend the same wall time blocked in a fan-out wait (their
/// `inter_secs` is 0) — rank 0, which drives the scheduler's cost fits, is
/// always a top-level leader.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommBreakdown {
    /// Seconds in the fan stages (member→leader fan-in and leader→member
    /// fan-out, every level below the top ring).
    pub intra_secs: f64,
    /// Seconds in the top ring among the topmost-level leaders.
    pub inter_secs: f64,
}

/// Tags one hierarchical collective may use; reserved identically on every
/// rank. Layout: stage `k` owns `[k·(world+1), k·(world+1)+world)` for
/// fan-in (by participant index within the group) plus `k·(world+1)+world`
/// for fan-out; the top ring owns the final `2·world` slots.
pub(crate) fn hier_tag_slots(world: usize, stages: usize) -> u64 {
    stages as u64 * (world as u64 + 1) + 2 * world as u64
}

/// Hierarchical allreduce of a codec wire buffer (FP32/FP16): fold up the
/// leader chain, ring allreduce among the top leaders, fan back out.
pub fn hier_allreduce_wire(
    comm: &mut Comm,
    data: &mut [u8],
    codec: &dyn Codec,
) -> Result<(), Error> {
    let world = comm.world();
    let rank = comm.rank();
    if world == 1 || data.is_empty() {
        return Ok(());
    }
    let align = codec.wire_align();
    assert_eq!(
        data.len() % align,
        0,
        "buffer length must be a multiple of the element size"
    );
    let topo = comm.topology_shared();
    let stages = topo.fan_stages();
    let ring = topo.top_leaders();
    let base = comm.next_tags(hier_tag_slots(world, stages.len()));
    let ring_base = base + stages.len() as u64 * (world as u64 + 1);

    // Fan-in, bottom-up: at each stage the group leader folds the other
    // participants' partials in ascending rank order (deterministic; no
    // election traffic). A rank stops climbing once it is not the leader
    // of its group.
    let mut intra_secs = 0.0;
    for (k, stage) in stages.iter().enumerate() {
        let Some(group) = stage.iter().find(|g| g.contains(&rank)) else {
            continue;
        };
        let stage_base = base + k as u64 * (world as u64 + 1);
        let leader = group[0];
        let sw = Stopwatch::start();
        if rank == leader {
            for (idx, &p) in group.iter().enumerate().skip(1) {
                let incoming = comm.ep.recv(p, stage_base + idx as u64)?;
                codec
                    .reduce_wire(data, &incoming)
                    .map_err(|e| Error::codec(e.to_string()))?;
                comm.ep.recycle(incoming);
            }
        } else {
            let idx = group
                .iter()
                .position(|&p| p == rank)
                .expect("rank missing from its own fan group");
            comm.ep.send_ref(leader, stage_base + idx as u64, data)?;
        }
        intra_secs += sw.elapsed().as_secs_f64();
        if rank != leader {
            break;
        }
    }

    // Top ring among the topmost leaders over the subtree partials.
    let mut inter_secs = 0.0;
    if ring.len() > 1 && ring.contains(&rank) {
        let sw = Stopwatch::start();
        subset_ring_allreduce_bytes(comm, ring, ring_base, data, align, &|a, b| {
            codec
                .reduce_wire(a, b)
                .map_err(|e| Error::codec(e.to_string()))
        })?;
        inter_secs = sw.elapsed().as_secs_f64();
    }

    // Fan-out, top-down: each group leader pushes the fully reduced buffer
    // to its participants; they in turn lead the stage below.
    for (k, stage) in stages.iter().enumerate().rev() {
        let Some(group) = stage.iter().find(|g| g.contains(&rank)) else {
            continue;
        };
        let fanout_tag = base + k as u64 * (world as u64 + 1) + world as u64;
        let leader = group[0];
        let sw = Stopwatch::start();
        if rank == leader {
            for &p in group.iter().skip(1) {
                comm.ep.send_ref(p, fanout_tag, data)?;
            }
        } else {
            let reduced = comm.ep.recv(leader, fanout_tag)?;
            debug_assert_eq!(reduced.len(), data.len());
            data.copy_from_slice(&reduced);
            comm.ep.recycle(reduced);
        }
        intra_secs += sw.elapsed().as_secs_f64();
    }

    comm.note_breakdown(CommBreakdown {
        intra_secs,
        inter_secs,
    });
    Ok(())
}

/// Hierarchical allgather (variable-size payloads): participants hand
/// length-prefixed frames of everything they hold up the leader chain, the
/// top leaders ring-exchange **subtree frames**, and the full rank-indexed
/// table fans back down. The result is exactly what the flat ring
/// allgather returns, on every rank.
pub fn hier_allgather(comm: &mut Comm, mine: Vec<u8>) -> Result<Vec<Vec<u8>>, Error> {
    let world = comm.world();
    let rank = comm.rank();
    if world == 1 {
        return Ok(vec![mine]);
    }
    let topo = comm.topology_shared();
    let stages = topo.fan_stages();
    let ring = topo.top_leaders();
    let base = comm.next_tags(hier_tag_slots(world, stages.len()));
    let ring_base = base + stages.len() as u64 * (world as u64 + 1);

    let mut out: Vec<Vec<u8>> = vec![Vec::new(); world];
    out[rank] = mine;

    // Fan-in, bottom-up: a participant forwards a frame of every payload
    // it holds (its own at stage 0, its whole subtree above that).
    let mut intra_secs = 0.0;
    for (k, stage) in stages.iter().enumerate() {
        let Some(group) = stage.iter().find(|g| g.contains(&rank)) else {
            continue;
        };
        let stage_base = base + k as u64 * (world as u64 + 1);
        let leader = group[0];
        let sw = Stopwatch::start();
        if rank == leader {
            for (idx, &p) in group.iter().enumerate().skip(1) {
                let frame = comm.ep.recv(p, stage_base + idx as u64)?;
                decode_frame_into(topo.held_cover(k, p), &frame, &mut out)?;
                comm.ep.recycle(frame);
            }
        } else {
            let idx = group
                .iter()
                .position(|&p| p == rank)
                .expect("rank missing from its own fan group");
            let frame = encode_frame(topo.held_cover(k, rank), &out);
            comm.ep.send(leader, stage_base + idx as u64, frame)?;
        }
        intra_secs += sw.elapsed().as_secs_f64();
        if rank != leader {
            break;
        }
    }

    // Top ring: leaders exchange their full-subtree frames.
    let mut inter_secs = 0.0;
    if ring.len() > 1 && ring.contains(&rank) {
        let sw = Stopwatch::start();
        let frame = encode_frame(topo.held_cover(stages.len(), rank), &out);
        let gathered = subset_ring_allgather(comm, ring, ring_base, frame)?;
        for (pos, frame) in gathered.into_iter().enumerate() {
            let p = ring[pos];
            if p != rank {
                decode_frame_into(topo.held_cover(stages.len(), p), &frame, &mut out)?;
            }
            comm.ep.recycle(frame);
        }
        inter_secs = sw.elapsed().as_secs_f64();
    }

    // Fan-out, top-down: the full rank-indexed table travels down the
    // chain unchanged. It is encoded at most once per rank — a leader
    // that received the table frame from the stage above forwards those
    // exact bytes instead of re-encoding the identical table.
    let all_ranks: Vec<usize> = (0..world).collect();
    let mut table: Option<Vec<u8>> = None;
    for (k, stage) in stages.iter().enumerate().rev() {
        let Some(group) = stage.iter().find(|g| g.contains(&rank)) else {
            continue;
        };
        let fanout_tag = base + k as u64 * (world as u64 + 1) + world as u64;
        let leader = group[0];
        let sw = Stopwatch::start();
        if rank == leader {
            if group.len() > 1 {
                let frame = table.get_or_insert_with(|| encode_frame(&all_ranks, &out));
                for &p in group.iter().skip(1) {
                    comm.ep.send_ref(p, fanout_tag, frame)?;
                }
            }
        } else {
            let frame = comm.ep.recv(leader, fanout_tag)?;
            decode_frame_into(&all_ranks, &frame, &mut out)?;
            table = Some(frame);
        }
        intra_secs += sw.elapsed().as_secs_f64();
    }
    if let Some(frame) = table {
        comm.ep.recycle(frame);
    }

    comm.note_breakdown(CommBreakdown {
        intra_secs,
        inter_secs,
    });
    Ok(out)
}

/// Concatenate `out[r]` for each rank in `ranks` as `[u32 len][bytes]`
/// entries, in the given (ascending) order.
fn encode_frame(ranks: &[usize], out: &[Vec<u8>]) -> Vec<u8> {
    let total: usize = ranks.iter().map(|&r| 4 + out[r].len()).sum();
    let mut frame = Vec::with_capacity(total);
    for &r in ranks {
        frame.extend_from_slice(&(out[r].len() as u32).to_le_bytes());
        frame.extend_from_slice(&out[r]);
    }
    frame
}

/// Inverse of [`encode_frame`]: scatter the entries back into `out` at the
/// given rank indices. A malformed frame is a transport-level failure (it
/// can only come from a corrupt or truncated peer stream).
fn decode_frame_into(
    ranks: &[usize],
    frame: &[u8],
    out: &mut [Vec<u8>],
) -> Result<(), Error> {
    let corrupt = |what: &str| {
        Error::disconnected(format!("hierarchical allgather: corrupt node frame ({what})"))
    };
    let mut off = 0usize;
    for &r in ranks {
        let hdr = frame
            .get(off..off + 4)
            .ok_or_else(|| corrupt("truncated length header"))?;
        let len = u32::from_le_bytes(hdr.try_into().unwrap()) as usize;
        off += 4;
        let payload = frame
            .get(off..off + len)
            .ok_or_else(|| corrupt("truncated payload"))?;
        out[r] = payload.to_vec();
        off += len;
    }
    if off != frame.len() {
        return Err(corrupt("trailing bytes"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_preserves_payloads() {
        let out = vec![vec![1u8, 2], Vec::new(), vec![9u8; 5], vec![7u8]];
        let ranks = vec![0usize, 2, 3];
        let frame = encode_frame(&ranks, &out);
        assert_eq!(frame.len(), 4 * 3 + 2 + 5 + 1);
        let mut back = vec![Vec::new(); 4];
        decode_frame_into(&ranks, &frame, &mut back).unwrap();
        assert_eq!(back[0], out[0]);
        assert!(back[1].is_empty(), "rank 1 is not in the frame");
        assert_eq!(back[2], out[2]);
        assert_eq!(back[3], out[3]);
    }

    #[test]
    fn corrupt_frames_fail_loudly() {
        let mut out = vec![Vec::new(); 2];
        // Truncated header.
        assert!(decode_frame_into(&[0], &[1, 0, 0], &mut out).is_err());
        // Header promises more payload than exists.
        assert!(decode_frame_into(&[0], &[5, 0, 0, 0, 1], &mut out).is_err());
        // Trailing garbage after the last entry.
        assert!(decode_frame_into(&[0], &[1, 0, 0, 0, 7, 9], &mut out).is_err());
        // Exact fit parses.
        assert!(decode_frame_into(&[0], &[1, 0, 0, 0, 7], &mut out).is_ok());
        assert_eq!(out[0], vec![7]);
    }

    #[test]
    fn tag_slots_cover_every_stage_and_the_ring() {
        // Two-level (1 stage): world + 1 fan tags + 2·world ring tags.
        assert_eq!(hier_tag_slots(6, 1), 6 + 1 + 12);
        // Three-level (2 stages): one more (world+1) block.
        assert_eq!(hier_tag_slots(6, 2), 2 * 7 + 12);
    }
}
