//! Chunked snapshot streaming for the online-join protocol.
//!
//! When a rank hot-joins a live group, rank 0 streams it the current
//! training snapshot (a serialized [`crate::coordinator::Checkpoint`])
//! over the data fabric. Snapshots are far larger than any single
//! collective frame the steady state moves, so the transfer is framed as
//! a fixed-size **header frame** followed by raw payload **chunks**, all
//! on a reserved tag:
//!
//! | tag | purpose |
//! |-----|---------|
//! | [`JOIN_TAG`] (`u64::MAX - 17`) | rank 0's authoritative `JOIN {generation, step}` announcement |
//! | [`SNAPSHOT_TAG`] (`u64::MAX - 16`) | snapshot header frame + payload chunks, rank 0 → joiner |
//!
//! Both sit far above the sequence-numbered collective tag space (which
//! counts up from `generation * RECOVERY_TAG_STRIDE`) and below the
//! control tags ([`CTRL_PEER_DOWN_TAG`](super::transport::CTRL_PEER_DOWN_TAG),
//! [`CTRL_ABORT_TAG`](super::transport::CTRL_ABORT_TAG)), so a snapshot
//! in flight can never collide with either.
//!
//! The header records the total payload length, the chunk size, the chunk
//! count, and an FNV-1a digest of the whole payload. The [`Endpoint`]
//! stash is FIFO per `(source, tag)`, so chunks arrive in order; the
//! [`Assembler`] validates every chunk length against the header and the
//! reassembled bytes against the digest, so a truncated or corrupted
//! stream surfaces as a typed [`Error`] ([`ErrorKind::Protocol`]) instead
//! of silently resuming from garbage. The framing functions are pure
//! (no sockets), which is what the property suite drives.

use super::transport::{Endpoint, Error, ErrorKind};

/// Reserved tag for rank 0's `JOIN {generation, step}` announcement at
/// the start of a hot re-join (see [`encode_join`]).
pub const JOIN_TAG: u64 = u64::MAX - 17;

/// Reserved tag carrying the snapshot header frame and its payload
/// chunks.
pub const SNAPSHOT_TAG: u64 = u64::MAX - 16;

/// First four bytes of every header frame ("MCSS" little-endian).
pub const SNAPSHOT_MAGIC: u32 = 0x4D43_5353;

/// Bump when the frame layout changes incompatibly.
pub const SNAPSHOT_STREAM_VERSION: u32 = 1;

/// Serialized size of a [`FrameHeader`].
pub const HEADER_LEN: usize = 32;

/// Default chunk size for [`send_snapshot`] (1 MiB — far below the
/// transport's frame ceiling, large enough that header overhead is
/// negligible).
pub const SNAPSHOT_CHUNK_BYTES: usize = 1 << 20;

/// FNV-1a over a byte string — the integrity digest the header carries.
pub fn fnv64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// The decoded header frame of a snapshot stream: layout
/// `[magic u32][version u32][total_len u64][chunk_len u32][chunk_count
/// u32][digest u64]`, all little-endian.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// Total payload bytes across all chunks.
    pub total_len: u64,
    /// Bytes per chunk (every chunk but the last is exactly this long).
    pub chunk_len: u32,
    /// Number of payload chunks that follow the header
    /// (`ceil(total_len / chunk_len)`; 0 for an empty payload).
    pub chunk_count: u32,
    /// FNV-1a digest of the whole payload.
    pub digest: u64,
}

impl FrameHeader {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN);
        out.extend_from_slice(&SNAPSHOT_MAGIC.to_le_bytes());
        out.extend_from_slice(&SNAPSHOT_STREAM_VERSION.to_le_bytes());
        out.extend_from_slice(&self.total_len.to_le_bytes());
        out.extend_from_slice(&self.chunk_len.to_le_bytes());
        out.extend_from_slice(&self.chunk_count.to_le_bytes());
        out.extend_from_slice(&self.digest.to_le_bytes());
        out
    }
}

/// Frame a payload: the header frame followed by `ceil(len / chunk_len)`
/// raw chunks. Pure — the property tests drive it directly.
///
/// # Panics
///
/// If `chunk_len` is 0 or exceeds `u32::MAX`.
pub fn encode_frames(payload: &[u8], chunk_len: usize) -> Vec<Vec<u8>> {
    assert!(
        chunk_len >= 1 && chunk_len <= u32::MAX as usize,
        "snapshot chunk_len {chunk_len} out of range"
    );
    let header = FrameHeader {
        total_len: payload.len() as u64,
        chunk_len: chunk_len as u32,
        chunk_count: payload.len().div_ceil(chunk_len) as u32,
        digest: fnv64(payload),
    };
    let mut frames = Vec::with_capacity(1 + header.chunk_count as usize);
    frames.push(header.encode());
    for chunk in payload.chunks(chunk_len) {
        frames.push(chunk.to_vec());
    }
    frames
}

/// Decode and validate a header frame. Wrong length, bad magic, an
/// unknown version, a zero chunk length, or a chunk count inconsistent
/// with `total_len` are all typed errors.
pub fn decode_header(bytes: &[u8]) -> Result<FrameHeader, Error> {
    if bytes.len() != HEADER_LEN {
        return Err(Error::protocol(format!(
            "snapshot header: {} bytes, expected {HEADER_LEN}",
            bytes.len()
        )));
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != SNAPSHOT_MAGIC {
        return Err(Error::protocol(format!(
            "snapshot header: bad magic {magic:#010x}"
        )));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != SNAPSHOT_STREAM_VERSION {
        return Err(Error::protocol(format!(
            "snapshot header: version {version} (this build speaks {SNAPSHOT_STREAM_VERSION})"
        )));
    }
    let header = FrameHeader {
        total_len: u64::from_le_bytes(bytes[8..16].try_into().unwrap()),
        chunk_len: u32::from_le_bytes(bytes[16..20].try_into().unwrap()),
        chunk_count: u32::from_le_bytes(bytes[20..24].try_into().unwrap()),
        digest: u64::from_le_bytes(bytes[24..32].try_into().unwrap()),
    };
    if header.chunk_len == 0 {
        return Err(Error::protocol("snapshot header: zero chunk length"));
    }
    let want = header.total_len.div_ceil(header.chunk_len as u64);
    if header.chunk_count as u64 != want {
        return Err(Error::protocol(format!(
            "snapshot header: {} chunks for {} bytes at {}-byte chunks (expected {want})",
            header.chunk_count, header.total_len, header.chunk_len
        )));
    }
    Ok(header)
}

/// Reassembles a snapshot from its chunks, validating every chunk length
/// against the header and the final bytes against the payload digest.
#[derive(Debug)]
pub struct Assembler {
    header: FrameHeader,
    buf: Vec<u8>,
    received: u32,
}

impl Assembler {
    pub fn new(header: FrameHeader) -> Assembler {
        Assembler {
            header,
            buf: Vec::with_capacity(header.total_len as usize),
            received: 0,
        }
    }

    /// Accept the next chunk, in stream order. Overruns and wrong-size
    /// chunks (a mid-stream truncation) are typed errors.
    pub fn push(&mut self, chunk: &[u8]) -> Result<(), Error> {
        if self.received >= self.header.chunk_count {
            return Err(Error::protocol(format!(
                "snapshot stream: chunk {} beyond the advertised {}",
                self.received + 1,
                self.header.chunk_count
            )));
        }
        let last = self.received + 1 == self.header.chunk_count;
        let want = if last {
            self.header.total_len as usize - self.buf.len()
        } else {
            self.header.chunk_len as usize
        };
        if chunk.len() != want {
            return Err(Error::protocol(format!(
                "snapshot stream: chunk {} is {} bytes, expected {want}",
                self.received,
                chunk.len()
            )));
        }
        self.buf.extend_from_slice(chunk);
        self.received += 1;
        Ok(())
    }

    /// Finish the stream: every advertised chunk must have arrived and
    /// the reassembled payload must match the header digest.
    pub fn finish(self) -> Result<Vec<u8>, Error> {
        if self.received != self.header.chunk_count {
            return Err(Error::protocol(format!(
                "snapshot stream truncated: {} of {} chunks arrived",
                self.received, self.header.chunk_count
            )));
        }
        let got = fnv64(&self.buf);
        if got != self.header.digest {
            return Err(Error::protocol(format!(
                "snapshot stream corrupted: payload digest {got:016x} != advertised {:016x}",
                self.header.digest
            )));
        }
        Ok(self.buf)
    }
}

/// Stream a snapshot payload to `to` on [`SNAPSHOT_TAG`] in
/// `chunk_len`-byte chunks.
pub fn send_snapshot_chunked(
    ep: &mut Endpoint,
    to: usize,
    payload: &[u8],
    chunk_len: usize,
) -> Result<(), Error> {
    for frame in encode_frames(payload, chunk_len) {
        ep.send(to, SNAPSHOT_TAG, frame)?;
    }
    Ok(())
}

/// [`send_snapshot_chunked`] at the default chunk size.
pub fn send_snapshot(ep: &mut Endpoint, to: usize, payload: &[u8]) -> Result<(), Error> {
    send_snapshot_chunked(ep, to, payload, SNAPSHOT_CHUNK_BYTES)
}

/// Receive one snapshot stream from `from`: header frame, then exactly
/// the advertised chunks. A peer dying mid-stream surfaces as the
/// transport's typed [`ErrorKind::PeerGone`] from the pending receive; a
/// malformed stream as [`ErrorKind::Protocol`]. Never hangs past the
/// transport's own failure detection.
pub fn recv_snapshot(ep: &mut Endpoint, from: usize) -> Result<Vec<u8>, Error> {
    let header = decode_header(&ep.recv(from, SNAPSHOT_TAG)?)?;
    let mut asm = Assembler::new(header);
    for _ in 0..header.chunk_count {
        let chunk = ep.recv(from, SNAPSHOT_TAG)?;
        asm.push(&chunk)?;
        ep.recycle(chunk);
    }
    asm.finish()
}

/// Encode rank 0's join announcement: `[generation u64 LE][step u64 LE]`,
/// sent to every peer on [`JOIN_TAG`] before the snapshot stream.
pub fn encode_join(generation: u64, step: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    out.extend_from_slice(&generation.to_le_bytes());
    out.extend_from_slice(&step.to_le_bytes());
    out
}

/// Decode a join announcement into `(generation, step)`.
pub fn decode_join(bytes: &[u8]) -> Result<(u64, u64), Error> {
    if bytes.len() != 16 {
        return Err(Error::protocol(format!(
            "join announcement: {} bytes, expected 16",
            bytes.len()
        )));
    }
    let generation = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let step = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    Ok((generation, step))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::mesh;
    use crate::util::proptest::{check, gens};

    fn roundtrip(payload: &[u8], chunk_len: usize) -> Vec<u8> {
        let frames = encode_frames(payload, chunk_len);
        let header = decode_header(&frames[0]).unwrap();
        let mut asm = Assembler::new(header);
        for chunk in &frames[1..] {
            asm.push(chunk).unwrap();
        }
        asm.finish().unwrap()
    }

    #[test]
    fn frames_roundtrip_empty_exact_and_ragged() {
        // Empty payload: header only, zero chunks.
        assert_eq!(roundtrip(b"", 8), b"");
        assert_eq!(encode_frames(b"", 8).len(), 1);
        // Exact multiple of the chunk size.
        let exact: Vec<u8> = (0..64u8).collect();
        assert_eq!(roundtrip(&exact, 16), exact);
        // Ragged tail shorter than a chunk.
        let ragged: Vec<u8> = (0..61u8).collect();
        assert_eq!(roundtrip(&ragged, 16), ragged);
        // Single chunk larger than the payload.
        assert_eq!(roundtrip(b"abc", 1024), b"abc");
    }

    #[test]
    fn truncated_stream_is_a_typed_error_not_a_hang() {
        let payload: Vec<u8> = (0..100u8).collect();
        let frames = encode_frames(&payload, 16);
        let header = decode_header(&frames[0]).unwrap();
        let mut asm = Assembler::new(header);
        for chunk in &frames[1..frames.len() - 1] {
            asm.push(chunk).unwrap();
        }
        let err = asm.finish().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Protocol, "got {err}");
        assert!(err.to_string().contains("truncated"), "{err}");
        assert!(!err.is_recoverable());
    }

    #[test]
    fn corrupted_payload_fails_the_digest_check() {
        let payload: Vec<u8> = (0..40u8).collect();
        let mut frames = encode_frames(&payload, 16);
        frames[1][0] ^= 0xff;
        let header = decode_header(&frames[0]).unwrap();
        let mut asm = Assembler::new(header);
        for chunk in &frames[1..] {
            asm.push(chunk).unwrap();
        }
        let err = asm.finish().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Protocol);
        assert!(err.to_string().contains("corrupted"), "{err}");
    }

    #[test]
    fn malformed_headers_are_rejected() {
        let good = encode_frames(b"xyz", 2).remove(0);
        assert!(decode_header(&good[..HEADER_LEN - 1]).is_err(), "short header");
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 1;
        assert!(decode_header(&bad_magic).is_err(), "bad magic");
        let mut bad_version = good.clone();
        bad_version[4] = 99;
        assert!(decode_header(&bad_version).is_err(), "unknown version");
        let mut zero_chunk = good.clone();
        zero_chunk[16..20].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_header(&zero_chunk).is_err(), "zero chunk length");
        let mut bad_count = good.clone();
        bad_count[20..24].copy_from_slice(&7u32.to_le_bytes());
        assert!(decode_header(&bad_count).is_err(), "inconsistent chunk count");
    }

    #[test]
    fn wrong_size_and_surplus_chunks_are_rejected() {
        let payload: Vec<u8> = (0..32u8).collect();
        let frames = encode_frames(&payload, 16);
        let header = decode_header(&frames[0]).unwrap();
        let mut asm = Assembler::new(header);
        assert!(asm.push(&frames[1][..7]).is_err(), "short mid-stream chunk");
        let mut asm = Assembler::new(header);
        asm.push(&frames[1]).unwrap();
        asm.push(&frames[2]).unwrap();
        assert!(asm.push(b"extra").is_err(), "surplus chunk");
    }

    #[test]
    fn join_announcement_roundtrips() {
        let wire = encode_join(3, 17);
        assert_eq!(decode_join(&wire).unwrap(), (3, 17));
        assert!(decode_join(&wire[..10]).is_err());
        assert_eq!(
            decode_join(&wire[..10]).unwrap_err().kind(),
            ErrorKind::Protocol
        );
    }

    #[test]
    fn endpoint_stream_roundtrips_multi_chunk_payloads() {
        let mut eps = mesh(2);
        let mut ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        // 10_000 bytes at 1 KiB chunks: 10 frames, ragged tail.
        let payload: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        send_snapshot_chunked(&mut ep0, 1, &payload, 1024).unwrap();
        let got = recv_snapshot(&mut ep1, 0).unwrap();
        assert_eq!(got, payload);
    }

    #[test]
    fn prop_frames_roundtrip_for_random_shapes() {
        check(
            "snapshot framing roundtrip",
            300,
            gens::pair(gens::usize_in(0..5000), gens::usize_in(1..600)),
            |&(len, chunk_len)| {
                let payload: Vec<u8> = (0..len).map(|i| (i * 37 % 256) as u8).collect();
                let frames = encode_frames(&payload, chunk_len);
                let header = decode_header(&frames[0])
                    .map_err(|e| format!("header rejected: {e}"))?;
                if header.chunk_count as usize != len.div_ceil(chunk_len) {
                    return Err(format!("chunk count {}", header.chunk_count));
                }
                let mut asm = Assembler::new(header);
                for chunk in &frames[1..] {
                    asm.push(chunk).map_err(|e| format!("push: {e}"))?;
                }
                let got = asm.finish().map_err(|e| format!("finish: {e}"))?;
                if got != payload {
                    return Err("payload mismatch after reassembly".to_string());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_truncation_never_passes_validation() {
        // Dropping any suffix of the chunk list (or cutting bytes off one
        // chunk) must yield a typed error from push/finish — never Ok.
        check(
            "snapshot truncation detected",
            300,
            gens::pair(gens::usize_in(1..3000), gens::usize_in(1..400)),
            |&(len, chunk_len)| {
                let payload: Vec<u8> = (0..len).map(|i| (i * 13 % 256) as u8).collect();
                let frames = encode_frames(&payload, chunk_len);
                let header = decode_header(&frames[0]).unwrap();
                let chunks = &frames[1..];
                for keep in 0..chunks.len() {
                    let mut asm = Assembler::new(header);
                    let mut failed = false;
                    for chunk in &chunks[..keep] {
                        if asm.push(chunk).is_err() {
                            failed = true;
                            break;
                        }
                    }
                    if !failed && asm.finish().is_ok() {
                        return Err(format!("{keep}/{} chunks passed", chunks.len()));
                    }
                }
                // Cut the final chunk short by one byte.
                let mut asm = Assembler::new(header);
                let mut failed = false;
                for chunk in &chunks[..chunks.len() - 1] {
                    if asm.push(chunk).is_err() {
                        failed = true;
                        break;
                    }
                }
                let last = &chunks[chunks.len() - 1];
                if !failed && last.len() > 1 && asm.push(&last[..last.len() - 1]).is_ok() {
                    return Err("short final chunk accepted".to_string());
                }
                Ok(())
            },
        );
    }
}
