//! Reduce-scatter for the sharded exchange mode.
//!
//! The sharded exchange (DESIGN.md "Sharded exchange") replaces the ring
//! allreduce's second phase with nothing: each rank keeps only the chunk it
//! finished reducing, the optimizer updates that shard, and an allgather of
//! the *updated parameters* replaces the allgather of reduced gradients.
//!
//! Bit-exactness contract: the reduce-scatter here IS phase 1 of the full
//! ring ([`ring::subset_ring_reduce_scatter_bytes`]) — same schedule, same
//! tag layout, same reduce order — so the chunk a rank owns is bit-identical
//! to the bytes the full allreduce would have left there. Ownership is a
//! pure function of `(len, world, align, rank)`: rank `r` owns chunk
//! `(r+1) mod world` of the [`ring::chunk_bounds`] split (what phase 1
//! leaves fully reduced at ring position `r`). The same rule is applied on
//! every route, so a per-group route flip never reshards state.
//!
//! The hierarchical route currently runs the full hierarchical allreduce
//! and takes ownership at the consumer: the comm bytes are unchanged but
//! the memory win (optimizer state ∝ 1/world) is intact, and the result is
//! trivially bit-identical to the full exchange on the same route. A true
//! hierarchical reduce-scatter (fan-in, leader ring phase 1 only, scatter
//! inside the node) is future work.

use super::ring::{chunk_bounds, subset_ring_reduce_scatter_bytes};
use super::transport::Error;
use super::{hierarchical, Comm};
use crate::compression::Codec;

/// Element range `[lo, hi)` of the shard rank `r` owns in an `elems`-long
/// flat buffer sharded over `world` ranks — the element-space twin of the
/// wire-chunk split the ring uses (`chunk_bounds(len, world, wire_align)`
/// maps to exactly this range once byte offsets are divided by the
/// per-element wire width, for every fixed-width allreduce codec).
///
/// This is the shard-ownership contract shared by the exchange engine, the
/// sharded optimizer, and the checkpoint layer: keep it a pure function.
pub fn shard_elems(elems: usize, world: usize, rank: usize) -> (usize, usize) {
    assert!(rank < world, "rank {rank} out of range for world {world}");
    if world == 1 {
        return (0, elems);
    }
    let bounds = chunk_bounds(elems, world, 1);
    bounds[(rank + 1) % world]
}

/// Flat ring reduce-scatter over a codec wire buffer: phase 1 of the ring
/// allreduce, stopping once this rank's chunk is fully reduced. Returns the
/// owned byte range; the rest of `data` holds partial sums and must not be
/// consumed. Reserves the same `2·world` tag window the full allreduce
/// would, so the per-collective tag budget is mode-independent.
pub(crate) fn ring_reduce_scatter_wire(
    comm: &mut Comm,
    data: &mut [u8],
    codec: &dyn Codec,
) -> Result<(usize, usize), Error> {
    let world = comm.world();
    if world == 1 || data.is_empty() {
        return Ok((0, data.len()));
    }
    let base = comm.next_tags(2 * world as u64);
    let members: Vec<usize> = (0..world).collect();
    subset_ring_reduce_scatter_bytes(comm, &members, base, data, codec.wire_align(), &|a, b| {
        codec
            .reduce_wire(a, b)
            .map_err(|e| Error::codec(e.to_string()))
    })
}

/// Hierarchical "reduce-scatter": the full hierarchical allreduce with
/// ownership taken at the consumer (see the module docs for why). The
/// owned range follows the same `(rank+1) mod world` chunk rule as the
/// flat ring, so shard ownership is route-invariant.
pub(crate) fn hier_reduce_scatter_wire(
    comm: &mut Comm,
    data: &mut [u8],
    codec: &dyn Codec,
) -> Result<(usize, usize), Error> {
    hierarchical::hier_allreduce_wire(comm, data, codec)?;
    let world = comm.world();
    if world == 1 || data.is_empty() {
        return Ok((0, data.len()));
    }
    let bounds = chunk_bounds(data.len(), world, codec.wire_align());
    Ok(bounds[(comm.rank() + 1) % world])
}

#[cfg(test)]
mod tests {
    use super::super::{run_comm_group, Topology};
    use super::*;
    use crate::compression::{Codec as _, CodecKind};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn shard_elems_partition_the_buffer() {
        for (elems, world) in [(101usize, 4usize), (7, 3), (12, 12), (3, 5), (64, 1)] {
            let mut covered = vec![0u8; elems];
            for r in 0..world {
                let (lo, hi) = shard_elems(elems, world, r);
                assert!(lo <= hi && hi <= elems);
                for c in covered.iter_mut().take(hi).skip(lo) {
                    *c += 1;
                }
            }
            assert!(
                covered.iter().all(|&c| c == 1),
                "elems={elems} world={world}: every element owned exactly once"
            );
        }
    }

    #[test]
    fn shard_elems_matches_wire_chunk_ownership() {
        // The element-space rule must agree with the byte-space chunk the
        // ring's phase 1 leaves on each rank, for both allreduce widths.
        for (elems, world) in [(101usize, 4usize), (33, 3), (5, 8)] {
            for width in [4usize, 2] {
                let wire_bounds = chunk_bounds(elems * width, world, width);
                for r in 0..world {
                    let (lo, hi) = shard_elems(elems, world, r);
                    let (wlo, whi) = wire_bounds[(r + 1) % world];
                    assert_eq!((wlo / width, whi / width), (lo, hi));
                }
            }
        }
    }

    #[test]
    fn flat_reduce_scatter_owned_bytes_match_full_allreduce() {
        for kind in [CodecKind::Fp32, CodecKind::Fp16] {
            let n = 101usize; // ragged over 4 ranks
            let results = run_comm_group(4, move |c| {
                let mut rng = Xoshiro256::seed_from_u64(7 + c.rank() as u64);
                let mut g = vec![0f32; n];
                rng.fill_normal_f32(&mut g, 1.0);
                let mut codec = kind.build(n);
                let mut rng_e = Xoshiro256::seed_from_u64(1);
                let enc = codec.encode(&g, &mut rng_e);

                let mut full = enc.bytes.clone();
                c.allreduce_wire(&mut full, codec.as_ref()).unwrap();

                let mut rs = enc.bytes.clone();
                let (lo, hi) =
                    ring_reduce_scatter_wire(c, &mut rs, codec.as_ref()).unwrap();
                (full[lo..hi].to_vec(), rs[lo..hi].to_vec())
            });
            for (rank, (full_chunk, rs_chunk)) in results.iter().enumerate() {
                assert_eq!(full_chunk, rs_chunk, "{} rank {rank}", kind.name());
            }
        }
    }

    #[test]
    fn hier_wrapper_owns_the_same_range_and_bytes() {
        let n = 67usize;
        let results = run_comm_group(6, move |c| {
            c.set_topology(Topology::from_sizes(&[4, 2]).unwrap()).unwrap();
            // Integer-valued grads so any reduction grouping sums exactly.
            let g: Vec<f32> = (0..n).map(|i| (c.rank() + i % 5) as f32).collect();
            let mut codec = CodecKind::Fp32.build(n);
            let mut rng = Xoshiro256::seed_from_u64(1);
            let enc = codec.encode(&g, &mut rng);

            let mut full = enc.bytes.clone();
            c.allreduce_wire(&mut full, codec.as_ref()).unwrap();

            let mut rs = enc.bytes.clone();
            let (lo, hi) = hier_reduce_scatter_wire(c, &mut rs, codec.as_ref()).unwrap();
            let (elo, ehi) = shard_elems(n, c.world(), c.rank());
            assert_eq!((lo / 4, hi / 4), (elo, ehi), "route-invariant ownership");
            (full[lo..hi].to_vec(), rs[lo..hi].to_vec())
        });
        for (rank, (full_chunk, rs_chunk)) in results.iter().enumerate() {
            assert_eq!(full_chunk, rs_chunk, "rank {rank}");
        }
    }

    #[test]
    fn world_of_one_owns_everything() {
        let results = run_comm_group(1, |c| {
            let mut codec = CodecKind::Fp32.build(3);
            let mut rng = Xoshiro256::seed_from_u64(0);
            let enc = codec.encode(&[1.0, 2.0, 3.0], &mut rng);
            let mut wire = enc.bytes.clone();
            let range = ring_reduce_scatter_wire(c, &mut wire, codec.as_ref()).unwrap();
            (range, wire == enc.bytes)
        });
        assert_eq!(results[0].0, (0, 12));
        assert!(results[0].1, "no peers: buffer untouched");
    }
}
