//! Cluster topology: which ranks share a node (and which nodes share a
//! rack, and so on), plus who leads each level.
//!
//! The paper's testbed is a single 8-GPU box, so its collectives treat all
//! ranks as one flat NVLink-or-PCIe mesh. Multi-node deployments are not
//! flat: intra-node links (NVLink/shared memory) are orders of magnitude
//! faster than the inter-node fabric (TCP/IB), which is itself faster than
//! a cross-rack or cross-site link. [`Topology`] is the rank→node mapping —
//! optionally extended by further grouping levels (racks, pods, …) — that
//! the hierarchical collectives in [`hierarchical`](super::hierarchical)
//! exchange over: traffic stays inside a level whenever it can, and only
//! the **leaders** of each level (lowest covered rank, deterministic on
//! every rank without election traffic) talk across the next level up.
//!
//! [`TopologySpec`] is the config/CLI-facing description
//! (`--topology flat|nodes=G|nodes=a+b+…`, extendable level by level as
//! `nodes=…;racks=…;pods=…`); [`TopologySpec::build`] turns it into a
//! concrete [`Topology`] for a world size. Ranks are assigned to nodes in
//! contiguous blocks, and nodes to racks in contiguous blocks, which
//! matches how `mergecomp launch` (and any sane multi-node launcher)
//! numbers ranks: node 0 hosts ranks `0..s0`, node 1 hosts `s0..s0+s1`,
//! and so on.

use std::fmt;

/// The `--topology` grammar, echoed by every parse/build error so a typo
/// in a launch script fails with the accepted syntax in hand.
pub const TOPOLOGY_GRAMMAR: &str =
    "flat | nodes=G | nodes=a+b+... [;LEVEL=G | ;LEVEL=a+b+...]* \
     (LEVEL is a name like 'racks'; each level groups the previous one)";

/// Rank→node mapping for one communicator world, optionally extended by
/// upper grouping levels (racks over nodes, pods over racks, …).
///
/// Invariants (enforced by every constructor): unit ids are dense at every
/// level (`0..count`), every unit is non-empty, and each unit's member
/// list is sorted ascending — the leader of a unit is its lowest covered
/// rank.
///
/// ```
/// use mergecomp::collectives::Topology;
/// let t = Topology::from_sizes(&[4, 2]).unwrap();
/// assert_eq!(t.world(), 6);
/// assert_eq!(t.leaders(), vec![0, 4]);
/// assert!(t.is_leader(4) && !t.is_leader(5));
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// `node_of[rank]` = node id.
    node_of: Vec<usize>,
    /// `nodes[n]` = sorted ranks on node `n`.
    nodes: Vec<Vec<usize>>,
    /// Upper grouping levels: `upper[0]` groups node ids into racks,
    /// `upper[1]` groups rack ids into pods, … Each entry is a list of
    /// groups, each a sorted list of lower-level unit ids. Empty for the
    /// classic (at most two-level) topology.
    upper: Vec<Vec<Vec<usize>>>,
    /// Names of the upper levels ("racks", "pods", …), parallel to `upper`.
    upper_names: Vec<String>,
    // -- caches (pure functions of the fields above, rebuilt by every
    // -- constructor and by push_level; borrowed by the per-group
    // -- hot path in `hierarchical`) ------------------------------------
    /// Fan stages: `stages[k]` is the participant groups of stage `k`.
    stages: Vec<Vec<Vec<usize>>>,
    /// Members of the top ring (leaders of the topmost level's units).
    ring: Vec<usize>,
    /// Held covers per stage: `held[k]` lists `(participant, covered
    /// ranks)` for stage `k`; `held[num_stages()]` holds the ring
    /// members' full subtrees.
    held: Vec<Vec<(usize, Vec<usize>)>>,
}

impl Topology {
    /// The degenerate single-level topology: every rank on one node. The
    /// collectives treat it (and the all-singletons case) as "no
    /// hierarchy" and route flat.
    pub fn flat(world: usize) -> Topology {
        assert!(world >= 1);
        Topology::assemble(
            vec![0; world],
            vec![(0..world).collect()],
            Vec::new(),
            Vec::new(),
        )
    }

    /// Build from validated fields and populate the derived caches.
    fn assemble(
        node_of: Vec<usize>,
        nodes: Vec<Vec<usize>>,
        upper: Vec<Vec<Vec<usize>>>,
        upper_names: Vec<String>,
    ) -> Topology {
        let mut t = Topology {
            node_of,
            nodes,
            upper,
            upper_names,
            stages: Vec::new(),
            ring: Vec::new(),
            held: Vec::new(),
        };
        t.rebuild_cache();
        t
    }

    /// Recompute the fan-stage / ring / cover caches from the core
    /// fields.
    fn rebuild_cache(&mut self) {
        self.stages = self.compute_fan_stages();
        let top = self.num_stages() - 1;
        self.ring = (0..self.units_at(top))
            .map(|u| self.unit_leader(top, u))
            .collect();
        let nstages = self.stages.len();
        let mut held = Vec::with_capacity(nstages + 1);
        held.push((0..self.world()).map(|r| (r, vec![r])).collect());
        for k in 1..=nstages {
            let level = (0..self.units_at(k - 1))
                .map(|u| (self.unit_leader(k - 1, u), self.cover(k - 1, u)))
                .collect();
            held.push(level);
        }
        self.held = held;
    }

    /// `num_nodes` contiguous blocks of near-equal size (the first
    /// `world % num_nodes` nodes get one extra rank) — what
    /// `--topology nodes=G` builds.
    pub fn balanced(world: usize, num_nodes: usize) -> anyhow::Result<Topology> {
        anyhow::ensure!(num_nodes >= 1, "need at least one node");
        anyhow::ensure!(
            num_nodes <= world,
            "{num_nodes} nodes cannot host only {world} ranks"
        );
        Topology::from_sizes(&balanced_sizes(world, num_nodes))
    }

    /// Contiguous blocks of explicit sizes (`--topology nodes=4+2` for a
    /// 6-rank world split 4 and 2).
    pub fn from_sizes(sizes: &[usize]) -> anyhow::Result<Topology> {
        anyhow::ensure!(!sizes.is_empty(), "topology needs at least one node");
        anyhow::ensure!(
            sizes.iter().all(|&s| s >= 1),
            "every node must host at least one rank (got {sizes:?})"
        );
        let world: usize = sizes.iter().sum();
        let mut node_of = Vec::with_capacity(world);
        let mut nodes = Vec::with_capacity(sizes.len());
        let mut next = 0;
        for (n, &s) in sizes.iter().enumerate() {
            nodes.push((next..next + s).collect());
            node_of.extend((0..s).map(|_| n));
            next += s;
        }
        Ok(Topology::assemble(node_of, nodes, Vec::new(), Vec::new()))
    }

    /// Arbitrary (not necessarily contiguous) mapping: `node_of[rank]` =
    /// node id. Ids must be dense `0..K` with every node non-empty.
    pub fn from_node_of(node_of: Vec<usize>) -> anyhow::Result<Topology> {
        anyhow::ensure!(!node_of.is_empty(), "topology needs at least one rank");
        let num_nodes = node_of.iter().max().unwrap() + 1;
        let mut nodes = vec![Vec::new(); num_nodes];
        for (rank, &n) in node_of.iter().enumerate() {
            nodes[n].push(rank);
        }
        for (n, members) in nodes.iter().enumerate() {
            anyhow::ensure!(!members.is_empty(), "node {n} has no ranks (ids must be dense)");
        }
        Ok(Topology::assemble(node_of, nodes, Vec::new(), Vec::new()))
    }

    /// Stack one more grouping level on top of the current topmost one:
    /// `groups[g]` lists the lower-level unit ids (nodes for the first
    /// call, racks for the second, …) forming upper unit `g`. Ids must be
    /// dense, each used exactly once.
    pub fn push_level(&mut self, name: &str, groups: Vec<Vec<usize>>) -> anyhow::Result<()> {
        let units_below = self.units_at(self.upper.len());
        anyhow::ensure!(!groups.is_empty(), "level '{name}' needs at least one group");
        let mut seen = vec![false; units_below];
        for (g, members) in groups.iter().enumerate() {
            anyhow::ensure!(!members.is_empty(), "level '{name}' group {g} is empty");
            for &u in members {
                anyhow::ensure!(
                    u < units_below,
                    "level '{name}' group {g} references unit {u}, but the level \
                     below has only {units_below} units"
                );
                anyhow::ensure!(!seen[u], "level '{name}': unit {u} appears twice");
                seen[u] = true;
            }
        }
        anyhow::ensure!(
            seen.iter().all(|&s| s),
            "level '{name}' must cover every unit of the level below"
        );
        let mut groups = groups;
        for members in groups.iter_mut() {
            members.sort_unstable();
        }
        self.upper.push(groups);
        self.upper_names.push(name.to_string());
        self.rebuild_cache();
        Ok(())
    }

    pub fn world(&self) -> usize {
        self.node_of.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Number of fan-in stages a hierarchical exchange runs: 1 (nodes)
    /// plus one per upper level.
    pub fn num_stages(&self) -> usize {
        1 + self.upper.len()
    }

    /// Number of units at hierarchy level `level` (0 = nodes, 1 = the
    /// first upper level, …; `level == num_stages()` would be the single
    /// implicit root).
    fn units_at(&self, level: usize) -> usize {
        if level == 0 {
            self.nodes.len()
        } else {
            self.upper[level - 1].len()
        }
    }

    /// Node hosting `rank`.
    pub fn node_of(&self, rank: usize) -> usize {
        self.node_of[rank]
    }

    /// Sorted ranks on node `node`.
    pub fn node_members(&self, node: usize) -> &[usize] {
        &self.nodes[node]
    }

    /// Sorted ranks sharing `rank`'s node (including `rank` itself).
    pub fn node_members_of(&self, rank: usize) -> &[usize] {
        &self.nodes[self.node_of[rank]]
    }

    /// The leader of `node`: its lowest rank. Deterministic on every rank,
    /// so leader election needs no communication.
    pub fn leader_of(&self, node: usize) -> usize {
        self.nodes[node][0]
    }

    /// One leader per node, in node-id order.
    pub fn leaders(&self) -> Vec<usize> {
        self.nodes.iter().map(|m| m[0]).collect()
    }

    pub fn is_leader(&self, rank: usize) -> bool {
        self.leader_of(self.node_of[rank]) == rank
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }

    /// All ranks covered by unit `u` at hierarchy level `level` (level 0 =
    /// nodes), sorted ascending.
    pub fn cover(&self, level: usize, u: usize) -> Vec<usize> {
        if level == 0 {
            return self.nodes[u].clone();
        }
        let mut out = Vec::new();
        for &lower in &self.upper[level - 1][u] {
            out.extend(self.cover(level - 1, lower));
        }
        out.sort_unstable();
        out
    }

    /// The leader (lowest covered rank) of unit `u` at `level`. The
    /// minimum is taken over the lower units' *leader ranks*, not their
    /// unit ids — the two differ when `from_node_of` built a
    /// non-contiguous mapping.
    pub fn unit_leader(&self, level: usize, u: usize) -> usize {
        if level == 0 {
            self.nodes[u][0]
        } else {
            self.upper[level - 1][u]
                .iter()
                .map(|&l| self.unit_leader(level - 1, l))
                .min()
                .expect("every unit is non-empty")
        }
    }

    /// The fan-in stages of a hierarchical exchange, bottom-up. Stage `k`
    /// is a list of participant groups: at stage 0 each group is a node's
    /// full member list; at stage `k ≥ 1` each group holds the leaders of
    /// the level-`(k−1)` units forming one level-`k` unit. The leader of a
    /// group is always its first (lowest) rank. Served from the prebuilt
    /// cache.
    pub fn fan_stages(&self) -> &[Vec<Vec<usize>>] {
        &self.stages
    }

    fn compute_fan_stages(&self) -> Vec<Vec<Vec<usize>>> {
        let mut stages = vec![self.nodes.clone()];
        for (k, level) in self.upper.iter().enumerate() {
            let groups = level
                .iter()
                .map(|units| {
                    let mut g: Vec<usize> =
                        units.iter().map(|&u| self.unit_leader(k, u)).collect();
                    g.sort_unstable();
                    g
                })
                .collect();
            stages.push(groups);
        }
        stages
    }

    /// Leaders of the topmost-level units, in unit order — the members of
    /// the hierarchical exchange's top ring. Served from the prebuilt
    /// cache.
    pub fn top_leaders(&self) -> &[usize] {
        &self.ring
    }

    /// The set of ranks whose payloads a participant `p` of fan stage
    /// `stage` already holds when that stage begins: only itself at stage
    /// 0; the cover of the level-`(stage−1)` unit it leads otherwise.
    /// `stage == num_stages()` gives a top leader's full subtree (what it
    /// contributes to the top ring). Served from the prebuilt cache — the
    /// hierarchical collectives call this per peer per stage.
    pub fn held_cover(&self, stage: usize, p: usize) -> &[usize] {
        self.held[stage]
            .iter()
            .find(|(participant, _)| *participant == p)
            .map(|(_, cover)| cover.as_slice())
            .unwrap_or_else(|| panic!("rank {p} holds no cover at stage {stage}"))
    }

    /// Largest node size (the fan-in the leader stages serialize over).
    pub fn max_node_size(&self) -> usize {
        self.nodes.iter().map(Vec::len).max().unwrap_or(1)
    }

    /// True when there is no real hierarchy: a single node, or one rank
    /// per node, with no upper levels. Either way a hierarchical exchange
    /// degenerates to the flat ring, so `Comm` routes flat. An explicit
    /// upper level is always honored (grouping singleton nodes into racks
    /// is a real two-stage hierarchy).
    pub fn is_trivial(&self) -> bool {
        if self.world() == 1 {
            return true;
        }
        self.upper.is_empty() && (self.num_nodes() <= 1 || self.num_nodes() == self.world())
    }

    /// The node label this rank advertises during the TCP bootstrap
    /// (carried in the rendezvous `TABLE`, cross-checked by the trainer).
    /// Encodes the full level chain (`n1`, or `n1.racks0.pods0` for
    /// deeper hierarchies) so ranks launched with mismatched `--topology`
    /// specs disagree at *any* level and fail at bootstrap.
    pub fn node_label(&self, rank: usize) -> String {
        let mut unit = self.node_of[rank];
        let mut label = format!("n{unit}");
        for (k, level) in self.upper.iter().enumerate() {
            let g = level
                .iter()
                .position(|units| units.contains(&unit))
                .expect("upper levels cover every unit");
            label.push_str(&format!(".{}{}", self.upper_names[k], g));
            unit = g;
        }
        label
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sizes: Vec<String> = self.nodes.iter().map(|m| m.len().to_string()).collect();
        write!(
            f,
            "{} ranks over {} nodes ({})",
            self.world(),
            self.num_nodes(),
            sizes.join("+")
        )?;
        for (k, level) in self.upper.iter().enumerate() {
            let sizes: Vec<String> = level.iter().map(|g| g.len().to_string()).collect();
            write!(f, ", {}={}", self.upper_names[k], sizes.join("+"))?;
        }
        Ok(())
    }
}

/// Near-even contiguous split of `count` units into `groups` groups (the
/// first `count % groups` groups get one extra unit).
fn balanced_sizes(count: usize, groups: usize) -> Vec<usize> {
    let base = count / groups;
    let rem = count % groups;
    (0..groups).map(|g| base + usize::from(g < rem)).collect()
}

/// One level's shape in a [`TopologySpec`]: a group count (near-even
/// contiguous split) or explicit group sizes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LevelShape {
    /// `G` near-even contiguous groups.
    Count(usize),
    /// Explicit contiguous group sizes (must sum to the unit count of the
    /// level below).
    Sizes(Vec<usize>),
}

impl LevelShape {
    fn name(&self) -> String {
        match self {
            LevelShape::Count(g) => g.to_string(),
            LevelShape::Sizes(sizes) => {
                let parts: Vec<String> = sizes.iter().map(|s| s.to_string()).collect();
                parts.join("+")
            }
        }
    }

    /// Concrete group sizes once the unit count of the level below is
    /// known.
    fn resolve(&self, units: usize, level: &str, spec: &str) -> anyhow::Result<Vec<usize>> {
        match self {
            LevelShape::Count(g) => {
                anyhow::ensure!(
                    *g >= 1 && *g <= units,
                    "topology '{spec}': level '{level}' asks for {g} groups of {units} \
                     units; expected {TOPOLOGY_GRAMMAR}"
                );
                Ok(balanced_sizes(units, *g))
            }
            LevelShape::Sizes(sizes) => {
                let sum: usize = sizes.iter().sum();
                anyhow::ensure!(
                    sum == units,
                    "topology '{spec}': level '{level}' sizes sum to {sum} but the level \
                     below has {units} units; expected {TOPOLOGY_GRAMMAR}"
                );
                Ok(sizes.clone())
            }
        }
    }
}

/// One named level of an N-level [`TopologySpec`] (`racks=2`,
/// `pods=1+2`, …).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LevelSpec {
    pub name: String,
    pub shape: LevelShape,
}

/// Config/CLI-facing topology description; [`TopologySpec::build`] turns it
/// into a [`Topology`] once the world size is known.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum TopologySpec {
    /// Single-level: the historical flat ring over all ranks.
    #[default]
    Flat,
    /// `nodes=G`: G near-equal contiguous node groups.
    Nodes(usize),
    /// `nodes=a+b+…`: explicit contiguous node sizes (must sum to world).
    Sized(Vec<usize>),
    /// `nodes=…;racks=…;…`: an explicit N-level hierarchy. The first
    /// level groups ranks into nodes; each subsequent level groups the
    /// previous level's units into named upper units (racks, pods, …).
    Levels(Vec<LevelSpec>),
}

impl TopologySpec {
    /// Parse the `--topology` flag: `flat`, `nodes=G`, `nodes=a+b+…`, or
    /// the N-level form `nodes=…;racks=…;…`. Errors echo the offending
    /// input and the accepted grammar.
    pub fn parse(spec: &str) -> anyhow::Result<TopologySpec> {
        let s = spec.trim().to_ascii_lowercase();
        if s == "flat" {
            return Ok(TopologySpec::Flat);
        }
        let segments: Vec<&str> = s.split(';').collect();
        let mut levels = Vec::with_capacity(segments.len());
        for (i, seg) in segments.iter().enumerate() {
            let Some((name, shape)) = seg.split_once('=') else {
                anyhow::bail!(
                    "unknown topology '{spec}' (segment '{seg}' has no '='); \
                     expected {TOPOLOGY_GRAMMAR}"
                );
            };
            let name = name.trim();
            anyhow::ensure!(
                !name.is_empty()
                    && name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
                "topology '{spec}': bad level name '{name}'; expected {TOPOLOGY_GRAMMAR}"
            );
            if i == 0 {
                anyhow::ensure!(
                    name == "nodes",
                    "topology '{spec}': the first level must be 'nodes', got '{name}'; \
                     expected {TOPOLOGY_GRAMMAR}"
                );
            }
            let shape = Self::parse_shape(shape, name, spec)?;
            levels.push(LevelSpec {
                name: name.to_string(),
                shape,
            });
        }
        // Single-segment specs keep the historical variants so existing
        // configs and matches keep working unchanged.
        if levels.len() == 1 {
            return Ok(match levels.remove(0).shape {
                LevelShape::Count(g) => TopologySpec::Nodes(g),
                LevelShape::Sizes(sizes) => TopologySpec::Sized(sizes),
            });
        }
        Ok(TopologySpec::Levels(levels))
    }

    fn parse_shape(shape: &str, level: &str, spec: &str) -> anyhow::Result<LevelShape> {
        if shape.contains('+') {
            let sizes: Vec<usize> = shape
                .split('+')
                .map(|p| {
                    p.parse::<usize>().map_err(|_| {
                        anyhow::anyhow!(
                            "topology '{spec}': bad size '{p}' in level '{level}'; \
                             expected {TOPOLOGY_GRAMMAR}"
                        )
                    })
                })
                .collect::<anyhow::Result<_>>()?;
            anyhow::ensure!(
                sizes.iter().all(|&x| x >= 1),
                "topology '{spec}': level '{level}' sizes must be >= 1; \
                 expected {TOPOLOGY_GRAMMAR}"
            );
            Ok(LevelShape::Sizes(sizes))
        } else {
            let g: usize = shape.parse().map_err(|_| {
                anyhow::anyhow!(
                    "topology '{spec}': bad group count '{shape}' in level '{level}'; \
                     expected {TOPOLOGY_GRAMMAR}"
                )
            })?;
            anyhow::ensure!(
                g >= 1,
                "topology '{spec}': level '{level}' needs at least one group; \
                 expected {TOPOLOGY_GRAMMAR}"
            );
            Ok(LevelShape::Count(g))
        }
    }

    /// Canonical name (round-trips through [`TopologySpec::parse`]).
    pub fn name(&self) -> String {
        match self {
            TopologySpec::Flat => "flat".to_string(),
            TopologySpec::Nodes(g) => format!("nodes={g}"),
            TopologySpec::Sized(sizes) => {
                let parts: Vec<String> = sizes.iter().map(|s| s.to_string()).collect();
                format!("nodes={}", parts.join("+"))
            }
            TopologySpec::Levels(levels) => {
                let parts: Vec<String> = levels
                    .iter()
                    .map(|l| format!("{}={}", l.name, l.shape.name()))
                    .collect();
                parts.join(";")
            }
        }
    }

    /// Concretize for a world size.
    pub fn build(&self, world: usize) -> anyhow::Result<Topology> {
        match self {
            TopologySpec::Flat => Ok(Topology::flat(world)),
            TopologySpec::Nodes(g) => Topology::balanced(world, *g),
            TopologySpec::Sized(sizes) => {
                let sum: usize = sizes.iter().sum();
                anyhow::ensure!(
                    sum == world,
                    "topology '{}' hosts {sum} ranks but the world is {world}; \
                     expected {TOPOLOGY_GRAMMAR}",
                    self.name()
                );
                Topology::from_sizes(sizes)
            }
            TopologySpec::Levels(levels) => {
                let spec = self.name();
                let node_sizes = levels[0].shape.resolve(world, "nodes", &spec)?;
                let mut topo = Topology::from_sizes(&node_sizes)?;
                let mut units = node_sizes.len();
                for level in &levels[1..] {
                    let group_sizes = level.shape.resolve(units, &level.name, &spec)?;
                    let mut groups = Vec::with_capacity(group_sizes.len());
                    let mut next = 0;
                    for &s in &group_sizes {
                        groups.push((next..next + s).collect());
                        next += s;
                    }
                    topo.push_level(&level.name, groups)?;
                    units = group_sizes.len();
                }
                Ok(topo)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_is_one_node_and_trivial() {
        let t = Topology::flat(4);
        assert_eq!(t.world(), 4);
        assert_eq!(t.num_nodes(), 1);
        assert!(t.is_trivial());
        assert_eq!(t.leaders(), vec![0]);
        assert!(t.same_node(0, 3));
        assert_eq!(t.num_stages(), 1);
        assert_eq!(t.top_leaders(), vec![0]);
    }

    #[test]
    fn balanced_splits_contiguously_with_remainder_up_front() {
        let t = Topology::balanced(6, 4).unwrap();
        assert_eq!(t.num_nodes(), 4);
        assert_eq!(t.node_members(0), &[0, 1]);
        assert_eq!(t.node_members(1), &[2, 3]);
        assert_eq!(t.node_members(2), &[4]);
        assert_eq!(t.node_members(3), &[5]);
        assert_eq!(t.leaders(), vec![0, 2, 4, 5]);
        assert!(!t.is_trivial());
    }

    #[test]
    fn sized_split_handles_non_divisible_worlds() {
        let t = Topology::from_sizes(&[4, 2]).unwrap();
        assert_eq!(t.world(), 6);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.leader_of(1), 4);
        assert!(t.is_leader(0));
        assert!(t.is_leader(4));
        assert!(!t.is_leader(5));
        assert_eq!(t.max_node_size(), 4);
        assert_eq!(t.node_label(5), "n1");
        assert_eq!(t.top_leaders(), vec![0, 4]);
    }

    #[test]
    fn singleton_nodes_are_trivial() {
        let t = Topology::balanced(3, 3).unwrap();
        assert!(t.is_trivial());
        assert_eq!(t.leaders(), vec![0, 1, 2]);
    }

    #[test]
    fn from_node_of_accepts_non_contiguous_and_rejects_sparse_ids() {
        let t = Topology::from_node_of(vec![0, 1, 0, 1]).unwrap();
        assert_eq!(t.node_members(0), &[0, 2]);
        assert_eq!(t.node_members(1), &[1, 3]);
        assert_eq!(t.leader_of(1), 1);
        assert!(Topology::from_node_of(vec![0, 2]).is_err());
        assert!(Topology::from_node_of(Vec::new()).is_err());
    }

    #[test]
    fn constructors_reject_degenerate_input() {
        assert!(Topology::balanced(2, 3).is_err());
        assert!(Topology::balanced(2, 0).is_err());
        assert!(Topology::from_sizes(&[]).is_err());
        assert!(Topology::from_sizes(&[2, 0]).is_err());
    }

    #[test]
    fn spec_parse_roundtrips() {
        for (text, spec) in [
            ("flat", TopologySpec::Flat),
            ("nodes=2", TopologySpec::Nodes(2)),
            ("nodes=4+2", TopologySpec::Sized(vec![4, 2])),
            ("nodes=1+2+1", TopologySpec::Sized(vec![1, 2, 1])),
            (
                "nodes=4+2;racks=2",
                TopologySpec::Levels(vec![
                    LevelSpec {
                        name: "nodes".to_string(),
                        shape: LevelShape::Sizes(vec![4, 2]),
                    },
                    LevelSpec {
                        name: "racks".to_string(),
                        shape: LevelShape::Count(2),
                    },
                ]),
            ),
        ] {
            let parsed = TopologySpec::parse(text).unwrap();
            assert_eq!(parsed, spec);
            assert_eq!(TopologySpec::parse(&parsed.name()).unwrap(), parsed);
        }
        assert!(TopologySpec::parse("star").is_err());
        assert!(TopologySpec::parse("nodes=").is_err());
        assert!(TopologySpec::parse("nodes=4+x").is_err());
        assert!(TopologySpec::parse("nodes=0").is_err());
        assert!(TopologySpec::parse("nodes=4+0").is_err());
        assert_eq!(TopologySpec::default(), TopologySpec::Flat);
    }

    #[test]
    fn parse_errors_echo_spec_and_grammar() {
        // The satellite bugfix: a bad spec must name itself AND the
        // accepted grammar in the error, at parse and at build time.
        for bad in ["star", "nodes=4+x", "racks=2;nodes=4", "nodes=2;=3", "nodes=2;racks=zz"] {
            let err = TopologySpec::parse(bad).unwrap_err().to_string();
            assert!(err.contains(bad), "error '{err}' must echo '{bad}'");
            assert!(
                err.contains("nodes=a+b+..."),
                "error '{err}' must state the grammar"
            );
        }
        let err = TopologySpec::Sized(vec![4, 2]).build(7).unwrap_err().to_string();
        assert!(err.contains("nodes=4+2") && err.contains("nodes=a+b+..."));
        let err = TopologySpec::parse("nodes=4+2;racks=3")
            .unwrap()
            .build(6)
            .unwrap_err()
            .to_string();
        assert!(err.contains("racks") && err.contains("nodes=a+b+..."));
    }

    #[test]
    fn spec_build_validates_world() {
        let t = TopologySpec::parse("nodes=4+2").unwrap().build(6).unwrap();
        assert_eq!(t.num_nodes(), 2);
        assert!(TopologySpec::Sized(vec![4, 2]).build(7).is_err());
        assert_eq!(TopologySpec::Flat.build(3).unwrap(), Topology::flat(3));
        let b = TopologySpec::Nodes(2).build(8).unwrap();
        assert_eq!(b.node_members(0), &[0, 1, 2, 3]);
        assert_eq!(b.node_members(1), &[4, 5, 6, 7]);
    }

    #[test]
    fn three_level_spec_builds_leader_chain() {
        // 8 ranks, 4 nodes of 2, 2 racks of 2 nodes.
        let t = TopologySpec::parse("nodes=4;racks=2").unwrap().build(8).unwrap();
        assert_eq!(t.num_nodes(), 4);
        assert_eq!(t.num_stages(), 2);
        assert!(!t.is_trivial());
        // Stage 0: the nodes; stage 1: node leaders grouped by rack.
        let stages = t.fan_stages();
        assert_eq!(stages[0], vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]]);
        assert_eq!(stages[1], vec![vec![0, 2], vec![4, 6]]);
        assert_eq!(t.top_leaders(), vec![0, 4]);
        assert_eq!(t.cover(1, 0), vec![0, 1, 2, 3]);
        assert_eq!(t.cover(1, 1), vec![4, 5, 6, 7]);
        assert_eq!(t.unit_leader(1, 1), 4);
        // Labels carry the whole chain, so a rank launched with a
        // different rack split disagrees at bootstrap.
        assert_eq!(t.node_label(3), "n1.racks0");
        assert_eq!(t.node_label(6), "n3.racks1");
    }

    #[test]
    fn uneven_three_level_builds() {
        // world=6: nodes 1+1+2+2, racks 2+2 (first two nodes vs last two).
        let t = TopologySpec::parse("nodes=1+1+2+2;racks=2+2")
            .unwrap()
            .build(6)
            .unwrap();
        let stages = t.fan_stages();
        assert_eq!(stages[0], vec![vec![0], vec![1], vec![2, 3], vec![4, 5]]);
        assert_eq!(stages[1], vec![vec![0, 1], vec![2, 4]]);
        assert_eq!(t.top_leaders(), vec![0, 2]);
        // Singleton nodes under explicit racks are NOT trivial: the rack
        // stage is a real hierarchy.
        let t = TopologySpec::parse("nodes=6;racks=2").unwrap().build(6).unwrap();
        assert!(!t.is_trivial());
        assert_eq!(t.fan_stages()[1], vec![vec![0, 1, 2], vec![3, 4, 5]]);
    }

    #[test]
    fn non_contiguous_nodes_elect_leaders_by_rank_not_unit_id() {
        // node0 = {1, 3}, node1 = {0, 2}: node ids and leader ranks
        // disagree. Racks over them must elect by lowest covered RANK.
        let mut t = Topology::from_node_of(vec![1, 0, 1, 0]).unwrap();
        assert_eq!(t.leaders(), vec![1, 0]);
        t.push_level("racks", vec![vec![0, 1]]).unwrap();
        assert_eq!(t.unit_leader(1, 0), 0, "leader is rank 0, not node 0's leader");
        assert_eq!(t.top_leaders(), vec![0]);
        // The fan stage and the cached covers agree with that election.
        let stages = t.fan_stages();
        assert_eq!(stages[1], vec![vec![0, 1]]);
        assert_eq!(t.held_cover(2, 0), &[0, 1, 2, 3]);
        assert_eq!(t.held_cover(1, 0), &[0, 2]);
        assert_eq!(t.held_cover(1, 1), &[1, 3]);
    }

    #[test]
    fn push_level_validates_coverage() {
        let mut t = Topology::from_sizes(&[2, 2]).unwrap();
        assert!(t.push_level("racks", vec![vec![0], vec![0]]).is_err());
        assert!(t.push_level("racks", vec![vec![0]]).is_err());
        assert!(t.push_level("racks", vec![vec![0, 2]]).is_err());
        assert!(t.push_level("racks", vec![vec![1, 0]]).is_ok());
        assert_eq!(t.num_stages(), 2);
        assert_eq!(t.top_leaders(), vec![0]);
    }

    #[test]
    fn display_shows_shape() {
        let t = Topology::from_sizes(&[4, 2]).unwrap();
        assert_eq!(t.to_string(), "6 ranks over 2 nodes (4+2)");
        let t = TopologySpec::parse("nodes=4;racks=2").unwrap().build(8).unwrap();
        assert_eq!(t.to_string(), "8 ranks over 4 nodes (2+2+2+2), racks=2+2");
    }
}
