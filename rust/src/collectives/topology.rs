//! Cluster topology: which ranks share a node, and who leads each node.
//!
//! The paper's testbed is a single 8-GPU box, so its collectives treat all
//! ranks as one flat NVLink-or-PCIe mesh. Multi-node deployments are not
//! flat: intra-node links (NVLink/shared memory) are orders of magnitude
//! faster than the inter-node fabric (TCP/IB), and a flat ring drags every
//! byte across the slow level `2·(w−1)/w` times. [`Topology`] is the
//! rank→node mapping the two-level collectives in
//! [`hierarchical`](super::hierarchical) exchange over: intra-node traffic
//! stays inside a node, and only the **node leaders** (lowest rank of each
//! node, deterministic on every rank without election traffic) talk across
//! the inter-node level.
//!
//! [`TopologySpec`] is the config/CLI-facing description
//! (`--topology flat|nodes=G|nodes=a+b+…`); [`TopologySpec::build`] turns
//! it into a concrete [`Topology`] for a world size. Ranks are assigned to
//! nodes in contiguous blocks, which matches how `mergecomp launch` (and
//! any sane multi-node launcher) numbers ranks: node 0 hosts ranks
//! `0..s0`, node 1 hosts `s0..s0+s1`, and so on.

use std::fmt;

/// Rank→node mapping for one communicator world.
///
/// Invariants (enforced by every constructor): node ids are dense
/// (`0..num_nodes`), every node is non-empty, and each node's member list
/// is sorted ascending — the leader of a node is its lowest rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// `node_of[rank]` = node id.
    node_of: Vec<usize>,
    /// `nodes[n]` = sorted ranks on node `n`.
    nodes: Vec<Vec<usize>>,
}

impl Topology {
    /// The degenerate single-level topology: every rank on one node. The
    /// collectives treat it (and the all-singletons case) as "no
    /// hierarchy" and route flat.
    pub fn flat(world: usize) -> Topology {
        assert!(world >= 1);
        Topology {
            node_of: vec![0; world],
            nodes: vec![(0..world).collect()],
        }
    }

    /// `num_nodes` contiguous blocks of near-equal size (the first
    /// `world % num_nodes` nodes get one extra rank) — what
    /// `--topology nodes=G` builds.
    pub fn balanced(world: usize, num_nodes: usize) -> anyhow::Result<Topology> {
        anyhow::ensure!(num_nodes >= 1, "need at least one node");
        anyhow::ensure!(
            num_nodes <= world,
            "{num_nodes} nodes cannot host only {world} ranks"
        );
        let base = world / num_nodes;
        let rem = world % num_nodes;
        let sizes: Vec<usize> = (0..num_nodes)
            .map(|n| base + usize::from(n < rem))
            .collect();
        Topology::from_sizes(&sizes)
    }

    /// Contiguous blocks of explicit sizes (`--topology nodes=4+2` for a
    /// 6-rank world split 4 and 2).
    pub fn from_sizes(sizes: &[usize]) -> anyhow::Result<Topology> {
        anyhow::ensure!(!sizes.is_empty(), "topology needs at least one node");
        anyhow::ensure!(
            sizes.iter().all(|&s| s >= 1),
            "every node must host at least one rank (got {sizes:?})"
        );
        let world: usize = sizes.iter().sum();
        let mut node_of = Vec::with_capacity(world);
        let mut nodes = Vec::with_capacity(sizes.len());
        let mut next = 0;
        for (n, &s) in sizes.iter().enumerate() {
            nodes.push((next..next + s).collect());
            node_of.extend((0..s).map(|_| n));
            next += s;
        }
        Ok(Topology { node_of, nodes })
    }

    /// Arbitrary (not necessarily contiguous) mapping: `node_of[rank]` =
    /// node id. Ids must be dense `0..K` with every node non-empty.
    pub fn from_node_of(node_of: Vec<usize>) -> anyhow::Result<Topology> {
        anyhow::ensure!(!node_of.is_empty(), "topology needs at least one rank");
        let num_nodes = node_of.iter().max().unwrap() + 1;
        let mut nodes = vec![Vec::new(); num_nodes];
        for (rank, &n) in node_of.iter().enumerate() {
            nodes[n].push(rank);
        }
        for (n, members) in nodes.iter().enumerate() {
            anyhow::ensure!(!members.is_empty(), "node {n} has no ranks (ids must be dense)");
        }
        Ok(Topology { node_of, nodes })
    }

    pub fn world(&self) -> usize {
        self.node_of.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Node hosting `rank`.
    pub fn node_of(&self, rank: usize) -> usize {
        self.node_of[rank]
    }

    /// Sorted ranks on node `node`.
    pub fn node_members(&self, node: usize) -> &[usize] {
        &self.nodes[node]
    }

    /// Sorted ranks sharing `rank`'s node (including `rank` itself).
    pub fn node_members_of(&self, rank: usize) -> &[usize] {
        &self.nodes[self.node_of[rank]]
    }

    /// The leader of `node`: its lowest rank. Deterministic on every rank,
    /// so leader election needs no communication.
    pub fn leader_of(&self, node: usize) -> usize {
        self.nodes[node][0]
    }

    /// One leader per node, in node-id order.
    pub fn leaders(&self) -> Vec<usize> {
        self.nodes.iter().map(|m| m[0]).collect()
    }

    pub fn is_leader(&self, rank: usize) -> bool {
        self.leader_of(self.node_of[rank]) == rank
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }

    /// Largest node size (the fan-in the leader stages serialize over).
    pub fn max_node_size(&self) -> usize {
        self.nodes.iter().map(Vec::len).max().unwrap_or(1)
    }

    /// True when there is no real hierarchy: a single node, or one rank per
    /// node. Either way a two-level exchange degenerates to the flat ring,
    /// so `Comm` routes flat.
    pub fn is_trivial(&self) -> bool {
        self.num_nodes() <= 1 || self.num_nodes() == self.world()
    }

    /// The node label this rank advertises during the TCP bootstrap
    /// (carried in the rendezvous `TABLE`, cross-checked by the trainer).
    pub fn node_label(&self, rank: usize) -> String {
        format!("n{}", self.node_of[rank])
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sizes: Vec<String> = self.nodes.iter().map(|m| m.len().to_string()).collect();
        write!(f, "{} ranks over {} nodes ({})", self.world(), self.num_nodes(), sizes.join("+"))
    }
}

/// Config/CLI-facing topology description; [`TopologySpec::build`] turns it
/// into a [`Topology`] once the world size is known.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum TopologySpec {
    /// Single-level: the historical flat ring over all ranks.
    #[default]
    Flat,
    /// `nodes=G`: G near-equal contiguous node groups.
    Nodes(usize),
    /// `nodes=a+b+…`: explicit contiguous node sizes (must sum to world).
    Sized(Vec<usize>),
}

impl TopologySpec {
    /// Parse `flat`, `nodes=G`, or `nodes=a+b+…` (the `--topology` flag).
    pub fn parse(spec: &str) -> anyhow::Result<TopologySpec> {
        let s = spec.trim().to_ascii_lowercase();
        if s == "flat" {
            return Ok(TopologySpec::Flat);
        }
        let Some(rest) = s.strip_prefix("nodes=") else {
            anyhow::bail!("unknown topology '{spec}' (flat|nodes=G|nodes=a+b+...)");
        };
        if rest.contains('+') {
            let sizes: Vec<usize> = rest
                .split('+')
                .map(|p| {
                    p.parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("bad node size '{p}' in topology '{spec}'"))
                })
                .collect::<anyhow::Result<_>>()?;
            anyhow::ensure!(
                sizes.iter().all(|&x| x >= 1),
                "node sizes must be >= 1 in topology '{spec}'"
            );
            Ok(TopologySpec::Sized(sizes))
        } else {
            let g: usize = rest
                .parse()
                .map_err(|_| anyhow::anyhow!("bad node count in topology '{spec}'"))?;
            anyhow::ensure!(g >= 1, "topology needs at least one node");
            Ok(TopologySpec::Nodes(g))
        }
    }

    /// Canonical name (round-trips through [`TopologySpec::parse`]).
    pub fn name(&self) -> String {
        match self {
            TopologySpec::Flat => "flat".to_string(),
            TopologySpec::Nodes(g) => format!("nodes={g}"),
            TopologySpec::Sized(sizes) => {
                let parts: Vec<String> = sizes.iter().map(|s| s.to_string()).collect();
                format!("nodes={}", parts.join("+"))
            }
        }
    }

    /// Concretize for a world size.
    pub fn build(&self, world: usize) -> anyhow::Result<Topology> {
        match self {
            TopologySpec::Flat => Ok(Topology::flat(world)),
            TopologySpec::Nodes(g) => Topology::balanced(world, *g),
            TopologySpec::Sized(sizes) => {
                let sum: usize = sizes.iter().sum();
                anyhow::ensure!(
                    sum == world,
                    "topology '{}' hosts {sum} ranks but the world is {world}",
                    self.name()
                );
                Topology::from_sizes(sizes)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_is_one_node_and_trivial() {
        let t = Topology::flat(4);
        assert_eq!(t.world(), 4);
        assert_eq!(t.num_nodes(), 1);
        assert!(t.is_trivial());
        assert_eq!(t.leaders(), vec![0]);
        assert!(t.same_node(0, 3));
    }

    #[test]
    fn balanced_splits_contiguously_with_remainder_up_front() {
        let t = Topology::balanced(6, 4).unwrap();
        assert_eq!(t.num_nodes(), 4);
        assert_eq!(t.node_members(0), &[0, 1]);
        assert_eq!(t.node_members(1), &[2, 3]);
        assert_eq!(t.node_members(2), &[4]);
        assert_eq!(t.node_members(3), &[5]);
        assert_eq!(t.leaders(), vec![0, 2, 4, 5]);
        assert!(!t.is_trivial());
    }

    #[test]
    fn sized_split_handles_non_divisible_worlds() {
        let t = Topology::from_sizes(&[4, 2]).unwrap();
        assert_eq!(t.world(), 6);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.leader_of(1), 4);
        assert!(t.is_leader(0));
        assert!(t.is_leader(4));
        assert!(!t.is_leader(5));
        assert_eq!(t.max_node_size(), 4);
        assert_eq!(t.node_label(5), "n1");
    }

    #[test]
    fn singleton_nodes_are_trivial() {
        let t = Topology::balanced(3, 3).unwrap();
        assert!(t.is_trivial());
        assert_eq!(t.leaders(), vec![0, 1, 2]);
    }

    #[test]
    fn from_node_of_accepts_non_contiguous_and_rejects_sparse_ids() {
        let t = Topology::from_node_of(vec![0, 1, 0, 1]).unwrap();
        assert_eq!(t.node_members(0), &[0, 2]);
        assert_eq!(t.node_members(1), &[1, 3]);
        assert_eq!(t.leader_of(1), 1);
        assert!(Topology::from_node_of(vec![0, 2]).is_err());
        assert!(Topology::from_node_of(Vec::new()).is_err());
    }

    #[test]
    fn constructors_reject_degenerate_input() {
        assert!(Topology::balanced(2, 3).is_err());
        assert!(Topology::balanced(2, 0).is_err());
        assert!(Topology::from_sizes(&[]).is_err());
        assert!(Topology::from_sizes(&[2, 0]).is_err());
    }

    #[test]
    fn spec_parse_roundtrips() {
        for (text, spec) in [
            ("flat", TopologySpec::Flat),
            ("nodes=2", TopologySpec::Nodes(2)),
            ("nodes=4+2", TopologySpec::Sized(vec![4, 2])),
            ("nodes=1+2+1", TopologySpec::Sized(vec![1, 2, 1])),
        ] {
            let parsed = TopologySpec::parse(text).unwrap();
            assert_eq!(parsed, spec);
            assert_eq!(TopologySpec::parse(&parsed.name()).unwrap(), parsed);
        }
        assert!(TopologySpec::parse("star").is_err());
        assert!(TopologySpec::parse("nodes=").is_err());
        assert!(TopologySpec::parse("nodes=4+x").is_err());
        assert!(TopologySpec::parse("nodes=0").is_err());
        assert!(TopologySpec::parse("nodes=4+0").is_err());
        assert_eq!(TopologySpec::default(), TopologySpec::Flat);
    }

    #[test]
    fn spec_build_validates_world() {
        let t = TopologySpec::parse("nodes=4+2").unwrap().build(6).unwrap();
        assert_eq!(t.num_nodes(), 2);
        assert!(TopologySpec::Sized(vec![4, 2]).build(7).is_err());
        assert_eq!(TopologySpec::Flat.build(3).unwrap(), Topology::flat(3));
        let b = TopologySpec::Nodes(2).build(8).unwrap();
        assert_eq!(b.node_members(0), &[0, 1, 2, 3]);
        assert_eq!(b.node_members(1), &[4, 5, 6, 7]);
    }

    #[test]
    fn display_shows_shape() {
        let t = Topology::from_sizes(&[4, 2]).unwrap();
        assert_eq!(t.to_string(), "6 ranks over 2 nodes (4+2)");
    }
}
