//! Degraded-world continuation: after a rank dies, the survivors remap
//! themselves onto a dense `[0, S)` world **over their existing
//! connections** — no re-bootstrap, no socket churn — and keep training at
//! world−1 while the scheduler re-runs its search for the shrunk world.
//!
//! [`RemapTransport`] is the whole trick: it wraps the surviving backend,
//! translates rank indices on every send/receive, and silently drops any
//! frame from an excluded rank (stale data frames of the failed step, the
//! dead rank's own teardown control frames) so the new world never
//! observes the old one. `Comm::shrink_to_survivors` wires it in, resets
//! the topology to flat over the survivors, and jumps the collective tag
//! space to a fresh recovery stride — survivors may have consumed
//! *different* tag counts in the step that failed (a rank whose sends all
//! completed can be a group ahead of one that failed early), so continuing
//! from a local counter would desynchronize the mesh.
//!
//! Re-expansion back to the full world goes through the checkpointed
//! restart path (`--resume-step` + the rendezvous generation tag in
//! `bootstrap`), not through live re-splicing of a grown mesh — restoring
//! a bigger world's sockets mid-run is future work recorded in ROADMAP.

use super::transport::{AllocStats, Error, Msg, Transport};

/// Tag-space stride per recovery generation: after the N-th shrink the
/// communicator's tags restart at `N * RECOVERY_TAG_STRIDE`, far above
/// anything the failed generation consumed (a run burns a handful of tags
/// per collective) and far below the reserved control tags near
/// `u64::MAX`.
pub const RECOVERY_TAG_STRIDE: u64 = 1 << 40;

/// A [`Transport`] view presenting a surviving subset of ranks as a dense
/// world `[0, S)`, over the wrapped backend's existing connections.
pub struct RemapTransport {
    inner: Box<dyn Transport>,
    /// new rank -> old rank (the sorted survivor list).
    old_of_new: Vec<usize>,
    /// old rank -> new rank (`None`: excluded from the new world).
    new_of_old: Vec<Option<usize>>,
    /// This rank's position in the new world.
    rank: usize,
}

impl RemapTransport {
    /// Wrap `inner` so only `survivors` (sorted, unique, old-rank indices
    /// including `inner.rank()`) exist, renumbered densely from 0.
    /// Shrinking twice composes: a `RemapTransport` can wrap another.
    pub fn new(inner: Box<dyn Transport>, survivors: &[usize]) -> anyhow::Result<RemapTransport> {
        let old_world = inner.world();
        anyhow::ensure!(!survivors.is_empty(), "survivor set must be non-empty");
        anyhow::ensure!(
            survivors.windows(2).all(|w| w[0] < w[1]),
            "survivors must be sorted and unique"
        );
        anyhow::ensure!(
            *survivors.last().unwrap() < old_world,
            "survivor rank {} out of range for world {old_world}",
            survivors.last().unwrap()
        );
        let mut new_of_old = vec![None; old_world];
        for (new, &old) in survivors.iter().enumerate() {
            new_of_old[old] = Some(new);
        }
        let rank = new_of_old[inner.rank()]
            .ok_or_else(|| anyhow::anyhow!("rank {} is not in the survivor set", inner.rank()))?;
        Ok(RemapTransport {
            inner,
            old_of_new: survivors.to_vec(),
            new_of_old,
            rank,
        })
    }

    /// The old-rank identities of the new world, indexed by new rank.
    pub fn survivors(&self) -> &[usize] {
        &self.old_of_new
    }

    /// Translate an error's rank/peer fields from old to new numbering. A
    /// peer outside the new world keeps no rank index (the context string
    /// still names it) — it cannot be retried against anyway.
    fn remap_error(&self, mut e: Error) -> Error {
        e.rank = e.rank.and_then(|r| self.new_of_old.get(r).copied().flatten());
        e.peer = e.peer.and_then(|p| self.new_of_old.get(p).copied().flatten());
        e
    }
}

impl Transport for RemapTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.old_of_new.len()
    }

    fn send(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<(), Error> {
        let old = self.old_of_new[to];
        self.inner.send(old, tag, bytes).map_err(|e| self.remap_error(e))
    }

    fn send_ref(&mut self, to: usize, tag: u64, bytes: &[u8]) -> Result<(), Error> {
        let old = self.old_of_new[to];
        self.inner.send_ref(old, tag, bytes).map_err(|e| self.remap_error(e))
    }

    fn recycle(&mut self, buf: Vec<u8>) {
        self.inner.recycle(buf);
    }

    fn alloc_stats(&self) -> AllocStats {
        self.inner.alloc_stats()
    }

    fn next_msg(&mut self) -> Result<Msg, Error> {
        loop {
            let (src, tag, bytes) = self.inner.next_msg().map_err(|e| self.remap_error(e))?;
            // Frames from excluded ranks — stale data from the failed
            // step, or the dead rank's teardown control frames — must
            // never surface in the new world.
            if let Some(new_src) = self.new_of_old.get(src).copied().flatten() {
                return Ok((new_src, tag, bytes));
            }
        }
    }

    fn try_next_msg(&mut self) -> Result<Option<Msg>, Error> {
        loop {
            match self.inner.try_next_msg().map_err(|e| self.remap_error(e))? {
                None => return Ok(None),
                Some((src, tag, bytes)) => {
                    if let Some(new_src) = self.new_of_old.get(src).copied().flatten() {
                        return Ok(Some((new_src, tag, bytes)));
                    }
                }
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn msgs_sent(&self) -> u64 {
        self.inner.msgs_sent()
    }
}

/// Placeholder backend used only while `Comm::shrink_to_survivors` swaps
/// the real transport out of its endpoint; every operation fails typed.
pub(crate) struct NullTransport;

impl Transport for NullTransport {
    fn rank(&self) -> usize {
        0
    }

    fn world(&self) -> usize {
        1
    }

    fn send(&mut self, _to: usize, _tag: u64, _bytes: Vec<u8>) -> Result<(), Error> {
        Err(Error::disconnected("null transport (mid-shrink)"))
    }

    fn next_msg(&mut self) -> Result<Msg, Error> {
        Err(Error::disconnected("null transport (mid-shrink)"))
    }

    fn try_next_msg(&mut self) -> Result<Option<Msg>, Error> {
        Err(Error::disconnected("null transport (mid-shrink)"))
    }

    fn bytes_sent(&self) -> u64 {
        0
    }

    fn msgs_sent(&self) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::super::transport::mesh_transports;
    use super::*;

    #[test]
    fn remap_renumbers_and_translates() {
        let ts = mesh_transports(4);
        let mut remapped: Vec<RemapTransport> = Vec::new();
        for (old, t) in ts.into_iter().enumerate() {
            if old == 2 {
                // Rank 2 is "dead": drop its transport entirely.
                continue;
            }
            let r = RemapTransport::new(Box::new(t), &[0, 1, 3]).unwrap();
            assert_eq!(r.world(), 3);
            remapped.push(r);
        }
        // Old ranks 0,1,3 become new ranks 0,1,2.
        assert_eq!(remapped[0].rank(), 0);
        assert_eq!(remapped[1].rank(), 1);
        assert_eq!(remapped[2].rank(), 2);
        assert_eq!(remapped[2].survivors(), &[0, 1, 3]);
    }

    #[test]
    fn frames_from_excluded_ranks_are_dropped() {
        let mut ts = mesh_transports(3).into_iter();
        let t0 = ts.next().unwrap();
        let mut t1 = ts.next().unwrap();
        let mut t2 = ts.next().unwrap();
        // Rank 2 (to be excluded) sends a stale frame to 0, then rank 1
        // sends a live one.
        t2.send(0, 7, vec![99]).unwrap();
        t1.send(0, 8, vec![42]).unwrap();
        drop(t2);
        drop(t1); // after this, CTRL teardown frames also sit in 0's inbox
        let mut r0 = RemapTransport::new(Box::new(t0), &[0, 1]).unwrap();
        // The stale frame from excluded rank 2 is skipped; rank 1's frame
        // arrives with its (unchanged) dense index.
        let (src, tag, bytes) = r0.next_msg().unwrap();
        assert_eq!((src, tag), (1, 8));
        assert_eq!(bytes, vec![42]);
    }

    #[test]
    fn double_shrink_composes() {
        let ts = mesh_transports(4);
        let t1 = ts.into_iter().nth(1).unwrap();
        // First shrink: world 4 -> survivors [0,1,3]; old rank 1 -> new 1.
        let r = RemapTransport::new(Box::new(t1), &[0, 1, 3]).unwrap();
        // Second shrink: new-world survivors [1,2] (old ranks 1 and 3).
        let r2 = RemapTransport::new(Box::new(r), &[1, 2]).unwrap();
        assert_eq!(r2.world(), 2);
        assert_eq!(r2.rank(), 0);
    }

    #[test]
    fn bad_survivor_sets_are_rejected() {
        for survivors in [vec![], vec![1, 0], vec![0, 0], vec![0, 9]] {
            let t = mesh_transports(3).remove(0);
            assert!(
                RemapTransport::new(Box::new(t), &survivors).is_err(),
                "{survivors:?} must be rejected"
            );
        }
        // Excluding the wrapped rank itself is also an error.
        let t = mesh_transports(3).remove(1);
        assert!(RemapTransport::new(Box::new(t), &[0, 2]).is_err());
    }
}
