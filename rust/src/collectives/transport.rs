//! Point-to-point transport between ranks.
//!
//! The paper runs NCCL/MPI between 8 GPUs; this module is the pluggable
//! seam under the collectives. A [`Transport`] moves raw `(from, tag,
//! payload)` messages; the [`Endpoint`] on top owns MPI-style tag matching
//! (a receive for `(from, tag)` only matches a message sent with that tag)
//! and the out-of-order stash — shared by every backend, so the collectives
//! in `ring.rs` / `allgather.rs` / `nonblocking.rs` are backend-agnostic.
//!
//! Two backends exist:
//! - [`InProcTransport`] (here): a mesh of unbounded channels between OS
//!   threads in one process — the testing/bench fabric.
//! - [`crate::collectives::tcp::TcpTransport`]: length-prefixed frames over
//!   real sockets between OS processes, bootstrapped by a rendezvous
//!   (`bootstrap.rs`).
//!
//! Every byte that crosses an endpoint is counted, so experiments can
//! report exact bytes-on-wire per collective. Failures are **typed**: a
//! dead peer surfaces as an [`Error`] classified [`ErrorKind::PeerGone`],
//! naming the rank, peer and tag instead of panicking the worker (the TCP
//! backend maps connection reset onto the same error). Recovery logic
//! (the elastic trainer) branches on [`Error::is_recoverable`], not on
//! ad-hoc variant patterns.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A message in flight: (source, tag, payload).
pub type Msg = (usize, u64, Vec<u8>);

/// Reserved tag used by backends to report an unreachable peer in-band
/// (the TCP reader thread injects it on EOF/reset). Never used by
/// collectives: `Comm` tags count up from 0.
pub const CTRL_PEER_DOWN_TAG: u64 = u64::MAX;

/// Reserved tag for the elastic abort protocol: a rank whose exchange
/// failed recoverably broadcasts `ABORT {epoch, dead, detail}` so peers
/// blocked mid-collective on a *live* rank (one that abandoned the failed
/// operation) fail typed instead of hanging. Payload layout:
/// `[epoch: u64 LE][dead: u64 LE][detail: utf8]`.
pub const CTRL_ABORT_TAG: u64 = u64::MAX - 1;

/// Encode an abort control payload (see [`CTRL_ABORT_TAG`]).
pub fn encode_abort(epoch: u64, dead: usize, detail: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + detail.len());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(dead as u64).to_le_bytes());
    out.extend_from_slice(detail.as_bytes());
    out
}

/// Decode an abort control payload; `None` if truncated.
pub fn decode_abort(bytes: &[u8]) -> Option<(u64, usize, String)> {
    if bytes.len() < 16 {
        return None;
    }
    let epoch = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let dead = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let detail = String::from_utf8_lossy(&bytes[16..]).into_owned();
    Some((epoch, dead, detail))
}

/// Classification of a transport failure — the field recovery logic
/// matches on (`Error::kind`), instead of the ad-hoc enum-variant
/// patterns the pre-elastic API required.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// A specific peer is unreachable (worker process died, connection
    /// reset, socket closed). Recoverable: the surviving ranks can agree
    /// on a shrunk world and continue.
    PeerGone,
    /// The whole fabric is gone (mesh torn down, comm lane dead).
    Disconnected,
    /// A codec was dispatched to a collective it cannot serve (e.g. an
    /// allgather codec handed to the wire allreduce) — a schedule bug,
    /// never recoverable by retry.
    Codec,
    /// A malformed, truncated, or corrupted control/snapshot frame — a
    /// protocol violation by a live peer (or a torn stream), never
    /// recoverable by retry. Raised by the join/snapshot framing in
    /// [`crate::collectives::snapshot`].
    Protocol,
}

impl ErrorKind {
    pub fn name(&self) -> &'static str {
        match self {
            ErrorKind::PeerGone => "peer-gone",
            ErrorKind::Disconnected => "disconnected",
            ErrorKind::Codec => "codec",
            ErrorKind::Protocol => "protocol",
        }
    }
}

/// Structured transport failure: a [`ErrorKind`] classification plus where
/// it happened (`rank` observing, `peer` involved, `tag` in flight) and
/// free-form `context`. What a collective returns when a peer dies
/// mid-operation instead of poisoning the process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    /// What failed — the classification recovery logic branches on.
    pub kind: ErrorKind,
    /// The rank observing the failure, when known.
    pub rank: Option<usize>,
    /// The peer involved in the failure (always set for
    /// [`ErrorKind::PeerGone`]).
    pub peer: Option<usize>,
    /// The tag being sent/received when the failure surfaced, if any.
    pub tag: Option<u64>,
    /// Human-readable context (underlying I/O error, group index, …).
    pub context: String,
}

impl Error {
    /// A peer is unreachable: the recoverable failure class.
    pub fn peer_gone(
        rank: usize,
        peer: usize,
        tag: Option<u64>,
        context: impl Into<String>,
    ) -> Error {
        Error {
            kind: ErrorKind::PeerGone,
            rank: Some(rank),
            peer: Some(peer),
            tag,
            context: context.into(),
        }
    }

    /// The whole fabric is gone.
    pub fn disconnected(context: impl Into<String>) -> Error {
        Error {
            kind: ErrorKind::Disconnected,
            rank: None,
            peer: None,
            tag: None,
            context: context.into(),
        }
    }

    /// A codec/collective dispatch mismatch (schedule bug).
    pub fn codec(context: impl Into<String>) -> Error {
        Error {
            kind: ErrorKind::Codec,
            rank: None,
            peer: None,
            tag: None,
            context: context.into(),
        }
    }

    /// A malformed or truncated control/snapshot frame (see
    /// [`ErrorKind::Protocol`]).
    pub fn protocol(context: impl Into<String>) -> Error {
        Error {
            kind: ErrorKind::Protocol,
            rank: None,
            peer: None,
            tag: None,
            context: context.into(),
        }
    }

    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Whether the failure class admits recovery without restarting the
    /// process: `PeerGone` does (checkpoint + shrink to the surviving
    /// world, or wait for the rank to re-join); `Disconnected` and
    /// `Codec` do not.
    pub fn is_recoverable(&self) -> bool {
        matches!(self.kind, ErrorKind::PeerGone)
    }

    /// For recoverable failures, how long the caller should let the wire
    /// settle (in-flight control frames, half-closed sockets) before
    /// starting recovery actions; `None` for unrecoverable failures.
    pub fn retry_after(&self) -> Option<Duration> {
        if self.is_recoverable() {
            Some(Duration::from_millis(100))
        } else {
            None
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            ErrorKind::PeerGone => {
                if let Some(r) = self.rank {
                    write!(f, "rank {r}: ")?;
                }
                match self.peer {
                    Some(p) => write!(f, "peer {p} is gone")?,
                    None => write!(f, "peer is gone")?,
                }
                if let Some(t) = self.tag {
                    write!(f, " (tag {t})")?;
                }
                write!(f, ": {}", self.context)
            }
            ErrorKind::Disconnected => {
                write!(f, "transport disconnected: {}", self.context)
            }
            ErrorKind::Codec => {
                write!(f, "codec dispatch: {}", self.context)
            }
            ErrorKind::Protocol => {
                write!(f, "protocol: {}", self.context)
            }
        }
    }
}

impl std::error::Error for Error {}

/// The pre-elastic name for [`Error`]. The flat enum variants
/// (`TransportError::PeerGone { .. }` etc.) became [`Error::peer_gone`] /
/// [`Error::disconnected`] / [`Error::codec`] constructors with an
/// [`ErrorKind`] classification; match on `err.kind` instead of variants.
#[deprecated(
    since = "0.3.0",
    note = "use collectives::transport::Error and match on ErrorKind / is_recoverable()"
)]
pub type TransportError = Error;

/// Pool-miss counters for the steady-state send/receive hot paths. A miss
/// is a `take` the pool could not serve from its free list (i.e. a fresh
/// allocation); after warm-up both counters must stay flat — asserted by
/// `tests/transport_equivalence.rs`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AllocStats {
    pub send_pool_misses: u64,
    pub recv_pool_misses: u64,
}

/// A bounded free list of byte buffers shared by the hot send/receive
/// paths. [`BufferPool::take`] hands out an *empty* buffer that keeps its
/// previous capacity, so in steady state filling it allocates nothing;
/// [`BufferPool::put`] returns one, dropping it when the pool is full so
/// memory stays bounded.
pub struct BufferPool {
    bufs: Mutex<Vec<Vec<u8>>>,
    misses: AtomicU64,
    cap: usize,
}

impl BufferPool {
    pub fn new(cap: usize) -> Arc<BufferPool> {
        Arc::new(BufferPool {
            bufs: Mutex::new(Vec::new()),
            misses: AtomicU64::new(0),
            cap,
        })
    }

    /// An empty buffer, reusing pooled capacity when available.
    pub fn take(&self) -> Vec<u8> {
        if let Some(buf) = self.bufs.lock().unwrap().pop() {
            return buf;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        Vec::new()
    }

    /// Return a buffer for reuse (cleared; dropped when the pool is full).
    pub fn put(&self, mut buf: Vec<u8>) {
        buf.clear();
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < self.cap {
            bufs.push(buf);
        }
    }

    /// Total `take` calls that had to allocate fresh.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// A point-to-point message mover between `world` ranks. Implementations
/// deliver messages from any peer in arrival order; the [`Endpoint`] above
/// them restores `(from, tag)` matching.
pub trait Transport: Send {
    fn rank(&self) -> usize;
    fn world(&self) -> usize;
    /// Send one tagged payload to `to` (never `self.rank()`).
    fn send(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<(), Error>;
    /// Borrowed-payload send: the transport copies `bytes` into its own
    /// (pooled) outbound buffer, so the caller keeps ownership and the
    /// steady-state path allocates nothing. Backends without a pool fall
    /// back to cloning into an owned [`Transport::send`].
    fn send_ref(&mut self, to: usize, tag: u64, bytes: &[u8]) -> Result<(), Error> {
        self.send(to, tag, bytes.to_vec())
    }
    /// Return a payload buffer received via [`Transport::next_msg`] for
    /// reuse on the receive path (no-op for backends without a pool).
    fn recycle(&mut self, _buf: Vec<u8>) {}
    /// Pool-miss counters for the send/receive hot paths.
    fn alloc_stats(&self) -> AllocStats {
        AllocStats::default()
    }
    /// Blocking: the next inbound message from any peer.
    fn next_msg(&mut self) -> Result<Msg, Error>;
    /// Non-blocking variant of [`Transport::next_msg`].
    fn try_next_msg(&mut self) -> Result<Option<Msg>, Error>;
    /// Total payload bytes this rank has sent.
    fn bytes_sent(&self) -> u64;
    fn msgs_sent(&self) -> u64;
}

/// Which transport backend a run uses (`TrainConfig.transport`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Channel mesh between OS threads in one process.
    #[default]
    InProc,
    /// Length-prefixed TCP sockets between OS processes.
    Tcp,
}

impl TransportKind {
    pub fn from_name(name: &str) -> anyhow::Result<TransportKind> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "inproc" | "in-proc" | "thread" | "threads" => TransportKind::InProc,
            "tcp" | "socket" | "sockets" => TransportKind::Tcp,
            other => anyhow::bail!("unknown transport '{other}' (inproc|tcp)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Rank-local endpoint: a backend plus the tag-matching stash. `recv`
/// requires `&mut self` because out-of-order messages are stashed locally
/// until a matching receive is posted.
pub struct Endpoint {
    transport: Box<dyn Transport>,
    /// Messages that arrived before their matching recv was posted.
    stash: HashMap<(usize, u64), Vec<Vec<u8>>>,
    /// Peers reported down by the backend (via [`CTRL_PEER_DOWN_TAG`]).
    dead: HashMap<usize, String>,
    /// Payload bytes successfully sent to each peer — the per-destination
    /// split `Comm::inter_node_bytes` classifies against the topology.
    per_peer_sent: Vec<u64>,
    /// Elastic recovery generation: [`CTRL_ABORT_TAG`] frames stamped with
    /// an older epoch are leftovers from an already-completed recovery and
    /// are dropped (see [`Endpoint::set_abort_epoch`]).
    abort_epoch: u64,
}

impl Endpoint {
    pub fn new(transport: Box<dyn Transport>) -> Endpoint {
        let world = transport.world();
        Endpoint {
            transport,
            stash: HashMap::new(),
            dead: HashMap::new(),
            per_peer_sent: vec![0; world],
            abort_epoch: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.transport.rank()
    }

    pub fn world(&self) -> usize {
        self.transport.world()
    }

    /// Total payload bytes this endpoint has sent (sum over endpoints =
    /// bytes on the "wire").
    pub fn bytes_sent(&self) -> u64 {
        self.transport.bytes_sent()
    }

    pub fn msgs_sent(&self) -> u64 {
        self.transport.msgs_sent()
    }

    /// Payload bytes successfully sent to each peer, indexed by rank.
    pub fn per_peer_sent(&self) -> &[u64] {
        &self.per_peer_sent
    }

    /// Payload bytes successfully sent to one peer.
    pub fn bytes_sent_to(&self, peer: usize) -> u64 {
        self.per_peer_sent[peer]
    }

    pub fn send(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<(), Error> {
        assert!(to < self.world(), "rank {to} out of range");
        assert_ne!(to, self.rank(), "self-send is a bug in the collective");
        let len = bytes.len() as u64;
        self.transport.send(to, tag, bytes)?;
        self.per_peer_sent[to] += len;
        Ok(())
    }

    /// Borrowed-payload send — same accounting as [`Endpoint::send`], but
    /// the caller keeps ownership of `bytes` (the transport copies into a
    /// pooled outbound buffer instead of taking a fresh `Vec`).
    pub fn send_ref(&mut self, to: usize, tag: u64, bytes: &[u8]) -> Result<(), Error> {
        assert!(to < self.world(), "rank {to} out of range");
        assert_ne!(to, self.rank(), "self-send is a bug in the collective");
        let len = bytes.len() as u64;
        self.transport.send_ref(to, tag, bytes)?;
        self.per_peer_sent[to] += len;
        Ok(())
    }

    /// Return a buffer obtained from [`Endpoint::recv`] once its contents
    /// have been consumed, so the receive path can reuse it.
    pub fn recycle(&mut self, buf: Vec<u8>) {
        self.transport.recycle(buf);
    }

    /// Pool-miss counters for the send/receive hot paths.
    pub fn alloc_stats(&self) -> AllocStats {
        self.transport.alloc_stats()
    }

    /// Blocking tag-matched receive.
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<u8>, Error> {
        if let Some(m) = self.take_stashed(from, tag) {
            return Ok(m);
        }
        if let Some(detail) = self.dead.get(&from) {
            return Err(self.peer_gone(from, Some(tag), detail.clone()));
        }
        loop {
            let (src, t, bytes) = self.transport.next_msg()?;
            if t == CTRL_PEER_DOWN_TAG {
                let detail = String::from_utf8_lossy(&bytes).into_owned();
                self.dead.insert(src, detail.clone());
                if src == from {
                    return Err(self.peer_gone(from, Some(tag), detail));
                }
                continue;
            }
            if t == CTRL_ABORT_TAG {
                if let Some(err) = self.note_abort(src, &bytes) {
                    return Err(err);
                }
                continue;
            }
            if src == from && t == tag {
                return Ok(bytes);
            }
            self.stash.entry((src, t)).or_default().push(bytes);
        }
    }

    /// Non-blocking probe used by failure-injection tests.
    pub fn try_recv(&mut self, from: usize, tag: u64) -> Result<Option<Vec<u8>>, Error> {
        if let Some(m) = self.take_stashed(from, tag) {
            return Ok(Some(m));
        }
        while let Some((src, t, bytes)) = self.transport.try_next_msg()? {
            if t == CTRL_PEER_DOWN_TAG {
                let detail = String::from_utf8_lossy(&bytes).into_owned();
                self.dead.insert(src, detail.clone());
                if src == from {
                    return Err(self.peer_gone(from, Some(tag), detail));
                }
                continue;
            }
            if t == CTRL_ABORT_TAG {
                if let Some(err) = self.note_abort(src, &bytes) {
                    return Err(err);
                }
                continue;
            }
            if src == from && t == tag {
                return Ok(Some(bytes));
            }
            self.stash.entry((src, t)).or_default().push(bytes);
        }
        if let Some(detail) = self.dead.get(&from) {
            return Err(self.peer_gone(from, Some(tag), detail.clone()));
        }
        Ok(None)
    }

    fn take_stashed(&mut self, from: usize, tag: u64) -> Option<Vec<u8>> {
        let q = self.stash.get_mut(&(from, tag))?;
        if q.is_empty() {
            return None;
        }
        let m = q.remove(0);
        if q.is_empty() {
            self.stash.remove(&(from, tag));
        }
        Some(m)
    }

    fn peer_gone(&self, peer: usize, tag: Option<u64>, detail: String) -> Error {
        Error::peer_gone(self.rank(), peer, tag, detail)
    }

    /// Process one inbound [`CTRL_ABORT_TAG`] frame: stale epochs (and
    /// truncated payloads) are dropped; a current-epoch abort marks the
    /// reported dead rank and returns the recoverable error the pending
    /// operation should fail with — every survivor converges on blaming
    /// the same dead rank, whichever peer told it first.
    fn note_abort(&mut self, src: usize, bytes: &[u8]) -> Option<Error> {
        let (epoch, dead, detail) = decode_abort(bytes)?;
        if epoch < self.abort_epoch {
            return None;
        }
        let note = format!("peer {src} aborted (epoch {epoch}): {detail}");
        self.dead.entry(dead).or_insert_with(|| note.clone());
        Some(Error::peer_gone(self.rank(), dead, None, note))
    }

    /// Peers this endpoint has observed as dead (via the in-band
    /// [`CTRL_PEER_DOWN_TAG`] control frame or a peer's abort broadcast),
    /// in ascending rank order. The elastic trainer reads this after a
    /// recoverable failure to decide which ranks the shrunk world
    /// excludes.
    pub fn dead_peers(&self) -> Vec<usize> {
        let mut peers: Vec<usize> = self.dead.keys().copied().collect();
        peers.sort_unstable();
        peers
    }

    /// Drain any inbound control frames without blocking, so peer-down
    /// notifications and abort broadcasts that raced a failed collective
    /// are folded into the dead map before [`Endpoint::dead_peers`] is
    /// read.
    pub fn poll_control(&mut self) {
        while let Ok(Some((src, t, bytes))) = self.transport.try_next_msg() {
            if t == CTRL_PEER_DOWN_TAG {
                let detail = String::from_utf8_lossy(&bytes).into_owned();
                self.dead.insert(src, detail);
            } else if t == CTRL_ABORT_TAG {
                let _ = self.note_abort(src, &bytes);
            } else {
                self.stash.entry((src, t)).or_default().push(bytes);
            }
        }
    }

    /// Best-effort broadcast of an elastic abort (see [`CTRL_ABORT_TAG`])
    /// to every peer except `dead` — peers blocked mid-collective on this
    /// rank fail typed, naming the same dead rank, instead of hanging on
    /// frames the abandoned collective will never send. Send failures are
    /// ignored: an unreachable peer is already down.
    pub fn broadcast_abort(&mut self, dead: usize, detail: &str) {
        let payload = encode_abort(self.abort_epoch, dead, detail);
        let me = self.rank();
        for peer in 0..self.world() {
            if peer == me || peer == dead {
                continue;
            }
            let _ = self.transport.send(peer, CTRL_ABORT_TAG, payload.clone());
        }
    }

    /// The current elastic recovery generation (see
    /// [`Endpoint::set_abort_epoch`]).
    pub fn abort_epoch(&self) -> u64 {
        self.abort_epoch
    }

    /// Install the recovery generation. `Comm::shrink_to_survivors` bumps
    /// this on the rebuilt endpoint so abort frames broadcast during the
    /// recovery that just completed (stamped with the previous epoch) are
    /// recognized as stale and dropped instead of failing the first
    /// post-recovery collective.
    pub fn set_abort_epoch(&mut self, epoch: u64) {
        self.abort_epoch = epoch;
    }

    /// Tear the endpoint down to its backend, dropping the stash and dead
    /// map. Used by elastic recovery to re-wrap surviving sockets in a
    /// remapping shim (`collectives::elastic`) after a world shrink.
    pub fn into_transport(self) -> Box<dyn Transport> {
        self.transport
    }
}

/// In-process backend: a fully-connected mesh of unbounded channels, one
/// inbox per rank. The workers are OS threads in one process.
///
/// Dropping an endpoint notifies every peer in-band (the same
/// [`CTRL_PEER_DOWN_TAG`] control message the TCP reader injects on EOF),
/// so a rank blocked in `recv` on a dead peer gets a typed
/// [`ErrorKind::PeerGone`] failure instead of hanging — per-sender FIFO means
/// the control message can never overtake data the peer sent before dying.
pub struct InProcTransport {
    rank: usize,
    world: usize,
    /// senders[d] delivers to rank d's inbox.
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// Free list shared by the whole mesh: a buffer sent by one rank is
    /// recycled by its receiver back into the same pool.
    pool: Arc<BufferPool>,
    bytes_sent: u64,
    msgs_sent: u64,
}

/// Buffers the in-process mesh keeps on its shared free list.
const INPROC_POOL_CAP: usize = 256;

impl Transport for InProcTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<(), Error> {
        self.bytes_sent += bytes.len() as u64;
        self.msgs_sent += 1;
        // Receiver hung up ⇒ worker died; the collective can't complete.
        self.senders[to]
            .send((self.rank, tag, bytes))
            .map_err(|_| {
                Error::peer_gone(self.rank, to, Some(tag), "worker thread died (inbox closed)")
            })
    }

    fn next_msg(&mut self) -> Result<Msg, Error> {
        self.inbox
            .recv()
            .map_err(|_| Error::disconnected("mesh disconnected while receiving"))
    }

    fn try_next_msg(&mut self) -> Result<Option<Msg>, Error> {
        match self.inbox.try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                Err(Error::disconnected("mesh disconnected while receiving"))
            }
        }
    }

    fn send_ref(&mut self, to: usize, tag: u64, bytes: &[u8]) -> Result<(), Error> {
        let mut buf = self.pool.take();
        buf.extend_from_slice(bytes);
        self.send(to, tag, buf)
    }

    fn recycle(&mut self, buf: Vec<u8>) {
        self.pool.put(buf);
    }

    fn alloc_stats(&self) -> AllocStats {
        // One pool serves the whole mesh; its miss count is reported as
        // send-side (a sent buffer IS the received buffer in-process).
        AllocStats {
            send_pool_misses: self.pool.misses(),
            recv_pool_misses: 0,
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn msgs_sent(&self) -> u64 {
        self.msgs_sent
    }
}

impl Drop for InProcTransport {
    fn drop(&mut self) {
        for (peer, sender) in self.senders.iter().enumerate() {
            if peer != self.rank {
                let _ = sender.send((
                    self.rank,
                    CTRL_PEER_DOWN_TAG,
                    b"worker exited (endpoint dropped)".to_vec(),
                ));
            }
        }
    }
}

/// Build a fully-connected in-process mesh of `world` endpoints.
pub fn mesh(world: usize) -> Vec<Endpoint> {
    mesh_transports(world)
        .into_iter()
        .map(|t| Endpoint::new(Box::new(t)))
        .collect()
}

/// The raw backends of a fully-connected in-process mesh, before the
/// tag-matching [`Endpoint`] wrap. Fault-injection tests use this to
/// interpose a [`crate::collectives::faults::FaultTransport`] shim between
/// the backend and the endpoint.
pub fn mesh_transports(world: usize) -> Vec<InProcTransport> {
    assert!(world >= 1);
    let mut senders = Vec::with_capacity(world);
    let mut receivers = Vec::with_capacity(world);
    for _ in 0..world {
        let (s, r) = channel::<Msg>();
        senders.push(s);
        receivers.push(r);
    }
    let pool = BufferPool::new(INPROC_POOL_CAP);
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| InProcTransport {
            rank,
            world,
            senders: senders.clone(),
            inbox,
            pool: Arc::clone(&pool),
            bytes_sent: 0,
            msgs_sent: 0,
        })
        .collect()
}

/// Run a closure on every rank of a fresh in-process mesh, one OS thread
/// per rank — the harness used by collective tests and the trainer.
pub fn run_group<T: Send>(world: usize, f: impl Fn(Endpoint) -> T + Send + Sync) -> Vec<T> {
    let endpoints = mesh(world);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| scope.spawn(move || f(ep)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_send_recv() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 7, vec![1, 2, 3]).unwrap();
                vec![]
            } else {
                ep.recv(0, 7).unwrap()
            }
        });
        assert_eq!(results[1], vec![1, 2, 3]);
    }

    #[test]
    fn tag_matching_reorders() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 1, vec![1]).unwrap();
                ep.send(1, 2, vec![2]).unwrap();
                ep.send(1, 3, vec![3]).unwrap();
                vec![]
            } else {
                // Receive in reverse tag order; stash must hold the rest.
                let a = ep.recv(0, 3).unwrap();
                let b = ep.recv(0, 2).unwrap();
                let c = ep.recv(0, 1).unwrap();
                vec![a[0], b[0], c[0]]
            }
        });
        assert_eq!(results[1], vec![3, 2, 1]);
    }

    #[test]
    fn same_tag_fifo_per_source() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                for i in 0..5u8 {
                    ep.send(1, 9, vec![i]).unwrap();
                }
                vec![]
            } else {
                (0..5).map(|_| ep.recv(0, 9).unwrap()[0]).collect()
            }
        });
        assert_eq!(results[1], vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn byte_accounting() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 0, vec![0u8; 100]).unwrap();
                ep.send(1, 1, vec![0u8; 28]).unwrap();
                ep.bytes_sent()
            } else {
                ep.recv(0, 0).unwrap();
                ep.recv(0, 1).unwrap();
                ep.bytes_sent()
            }
        });
        assert_eq!(results[0], 128);
        assert_eq!(results[1], 0);
    }

    #[test]
    fn per_peer_accounting_splits_by_destination() {
        let results = run_group(3, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 0, vec![0u8; 10]).unwrap();
                ep.send(2, 0, vec![0u8; 25]).unwrap();
                (ep.bytes_sent_to(1), ep.per_peer_sent().to_vec())
            } else {
                ep.recv(0, 0).unwrap();
                (0, ep.per_peer_sent().to_vec())
            }
        });
        assert_eq!(results[0].0, 10);
        assert_eq!(results[0].1, vec![0, 10, 25]);
        assert_eq!(results[1].1, vec![0, 0, 0]);
    }

    #[test]
    fn all_to_all_stress() {
        let world = 4;
        let results = run_group(world, |mut ep| {
            let me = ep.rank() as u8;
            for d in 0..ep.world() {
                if d != ep.rank() {
                    ep.send(d, 42, vec![me; 10]).unwrap();
                }
            }
            let mut sum = 0u32;
            for s in 0..ep.world() {
                if s != ep.rank() {
                    let m = ep.recv(s, 42).unwrap();
                    assert_eq!(m, vec![s as u8; 10]);
                    sum += m[0] as u32;
                }
            }
            sum
        });
        // Each rank receives the other three ranks' ids.
        for (r, s) in results.iter().enumerate() {
            assert_eq!(*s, (0..4).filter(|&x| x != r).sum::<usize>() as u32);
        }
    }

    #[test]
    fn try_recv_nonblocking() {
        let mut eps = mesh(2);
        let mut ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        assert!(ep1.try_recv(0, 5).unwrap().is_none());
        ep0.send(1, 5, vec![9]).unwrap();
        // Channel delivery is immediate in-process.
        let got = ep1.try_recv(0, 5).unwrap().unwrap();
        assert_eq!(got, vec![9]);
    }

    #[test]
    fn send_to_dead_peer_is_typed_error() {
        let mut eps = mesh(2);
        let ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        drop(ep1);
        let err = ep0.send(1, 3, vec![1]).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::PeerGone, "got {err}");
        assert_eq!(err.rank, Some(0));
        assert_eq!(err.peer, Some(1));
        assert_eq!(err.tag, Some(3));
        assert!(err.is_recoverable());
        assert!(err.retry_after().is_some());
    }

    #[test]
    fn dead_peers_lists_control_notified_ranks() {
        let mut eps = mesh(3);
        let ep2 = eps.pop().unwrap();
        let mut ep1 = eps.pop().unwrap();
        let _ep0 = eps.remove(0);
        drop(ep2);
        ep1.poll_control();
        assert_eq!(ep1.dead_peers(), vec![2]);
    }

    #[test]
    fn send_ref_and_recycle_reuse_buffers() {
        let mut eps = mesh(2);
        let mut ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        let payload = vec![7u8; 64];
        for t in 0..8u64 {
            ep0.send_ref(1, t, &payload).unwrap();
            let m = ep1.recv(0, t).unwrap();
            assert_eq!(m, payload);
            ep1.recycle(m);
        }
        // First send misses (pool empty); every later send reuses the
        // buffer rank 1 recycled into the shared mesh pool.
        assert_eq!(ep0.alloc_stats().send_pool_misses, 1);
        assert_eq!(ep0.bytes_sent(), 8 * 64);
        assert_eq!(ep0.per_peer_sent(), &[0, 8 * 64]);
    }

    #[test]
    fn buffer_pool_caps_and_counts_misses() {
        let pool = BufferPool::new(2);
        let a = pool.take();
        assert_eq!(pool.misses(), 1);
        pool.put(a);
        let b = pool.take();
        assert_eq!(pool.misses(), 1, "pooled buffer served without a miss");
        let mut c = pool.take();
        assert_eq!(pool.misses(), 2);
        c.extend_from_slice(&[1, 2, 3]);
        let cap = c.capacity();
        pool.put(c);
        let c2 = pool.take();
        assert!(c2.is_empty(), "pooled buffers come back cleared");
        assert!(c2.capacity() >= cap, "capacity survives the round trip");
        // Overfilling the pool drops buffers instead of growing unbounded.
        pool.put(b);
        pool.put(c2);
        pool.put(Vec::new());
        assert_eq!(pool.bufs.lock().unwrap().len(), 2);
    }

    #[test]
    fn transport_kind_names_roundtrip() {
        for k in [TransportKind::InProc, TransportKind::Tcp] {
            assert_eq!(TransportKind::from_name(k.name()).unwrap(), k);
        }
        assert!(TransportKind::from_name("carrier-pigeon").is_err());
        assert_eq!(TransportKind::default(), TransportKind::InProc);
    }

    #[test]
    fn error_display_names_rank_peer_and_tag() {
        let e = Error::peer_gone(2, 0, Some(17), "connection reset");
        let s = e.to_string();
        assert!(s.contains("rank 2"), "{s}");
        assert!(s.contains("peer 0"), "{s}");
        assert!(s.contains("tag 17"), "{s}");
    }

    #[test]
    fn error_classification_drives_recovery() {
        let gone = Error::peer_gone(1, 3, None, "reset");
        assert!(gone.is_recoverable());
        assert_eq!(gone.retry_after(), Some(Duration::from_millis(100)));
        for e in [
            Error::disconnected("lane dead"),
            Error::codec("bad dispatch"),
            Error::protocol("torn stream"),
        ] {
            assert!(!e.is_recoverable());
            assert_eq!(e.retry_after(), None);
        }
        assert_eq!(ErrorKind::PeerGone.name(), "peer-gone");
        assert_eq!(ErrorKind::Protocol.name(), "protocol");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_alias_still_names_the_error() {
        // One-release compatibility shim: the old name must keep working.
        let e: TransportError = Error::disconnected("legacy caller");
        assert_eq!(e.kind(), ErrorKind::Disconnected);
    }
}
