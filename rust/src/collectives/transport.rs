//! Point-to-point transport between ranks.
//!
//! The paper runs NCCL/MPI between 8 GPUs; this module is the pluggable
//! seam under the collectives. A [`Transport`] moves raw `(from, tag,
//! payload)` messages; the [`Endpoint`] on top owns MPI-style tag matching
//! (a receive for `(from, tag)` only matches a message sent with that tag)
//! and the out-of-order stash — shared by every backend, so the collectives
//! in `ring.rs` / `allgather.rs` / `nonblocking.rs` are backend-agnostic.
//!
//! Two backends exist:
//! - [`InProcTransport`] (here): a mesh of unbounded channels between OS
//!   threads in one process — the testing/bench fabric.
//! - [`crate::collectives::tcp::TcpTransport`]: length-prefixed frames over
//!   real sockets between OS processes, bootstrapped by a rendezvous
//!   (`bootstrap.rs`).
//!
//! Every byte that crosses an endpoint is counted, so experiments can
//! report exact bytes-on-wire per collective. Failures are **typed**: a
//! dead peer surfaces as [`TransportError::PeerGone`] naming the rank, peer
//! and tag instead of panicking the worker (the TCP backend maps connection
//! reset onto the same error).

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};

/// A message in flight: (source, tag, payload).
pub type Msg = (usize, u64, Vec<u8>);

/// Reserved tag used by backends to report an unreachable peer in-band
/// (the TCP reader thread injects it on EOF/reset). Never used by
/// collectives: `Comm` tags count up from 0.
pub const CTRL_PEER_DOWN_TAG: u64 = u64::MAX;

/// Typed transport failure — what a collective returns when a peer dies
/// mid-operation instead of poisoning the process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// A specific peer is unreachable (worker thread died, connection
    /// reset, socket closed).
    PeerGone {
        /// The rank observing the failure.
        rank: usize,
        /// The unreachable peer.
        peer: usize,
        /// The tag being sent/received when the failure surfaced, if any.
        tag: Option<u64>,
        detail: String,
    },
    /// The whole fabric is gone (mesh torn down, comm lane dead).
    Disconnected { detail: String },
    /// A codec was dispatched to a collective it cannot serve (e.g. an
    /// allgather codec handed to the wire allreduce). The detail names the
    /// codec — and, when the exchange engine raises it, the group index —
    /// so a mixed-codec schedule bug reads as a step failure, not an abort.
    Codec { detail: String },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::PeerGone { rank, peer, tag, detail } => {
                write!(f, "rank {rank}: peer {peer} is gone")?;
                if let Some(t) = tag {
                    write!(f, " (tag {t})")?;
                }
                write!(f, ": {detail}")
            }
            TransportError::Disconnected { detail } => {
                write!(f, "transport disconnected: {detail}")
            }
            TransportError::Codec { detail } => {
                write!(f, "codec dispatch: {detail}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// Pool-miss counters for the steady-state send/receive hot paths. A miss
/// is a `take` the pool could not serve from its free list (i.e. a fresh
/// allocation); after warm-up both counters must stay flat — asserted by
/// `tests/transport_equivalence.rs`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AllocStats {
    pub send_pool_misses: u64,
    pub recv_pool_misses: u64,
}

/// A bounded free list of byte buffers shared by the hot send/receive
/// paths. [`BufferPool::take`] hands out an *empty* buffer that keeps its
/// previous capacity, so in steady state filling it allocates nothing;
/// [`BufferPool::put`] returns one, dropping it when the pool is full so
/// memory stays bounded.
pub struct BufferPool {
    bufs: Mutex<Vec<Vec<u8>>>,
    misses: AtomicU64,
    cap: usize,
}

impl BufferPool {
    pub fn new(cap: usize) -> Arc<BufferPool> {
        Arc::new(BufferPool {
            bufs: Mutex::new(Vec::new()),
            misses: AtomicU64::new(0),
            cap,
        })
    }

    /// An empty buffer, reusing pooled capacity when available.
    pub fn take(&self) -> Vec<u8> {
        if let Some(buf) = self.bufs.lock().unwrap().pop() {
            return buf;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        Vec::new()
    }

    /// Return a buffer for reuse (cleared; dropped when the pool is full).
    pub fn put(&self, mut buf: Vec<u8>) {
        buf.clear();
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < self.cap {
            bufs.push(buf);
        }
    }

    /// Total `take` calls that had to allocate fresh.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// A point-to-point message mover between `world` ranks. Implementations
/// deliver messages from any peer in arrival order; the [`Endpoint`] above
/// them restores `(from, tag)` matching.
pub trait Transport: Send {
    fn rank(&self) -> usize;
    fn world(&self) -> usize;
    /// Send one tagged payload to `to` (never `self.rank()`).
    fn send(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<(), TransportError>;
    /// Borrowed-payload send: the transport copies `bytes` into its own
    /// (pooled) outbound buffer, so the caller keeps ownership and the
    /// steady-state path allocates nothing. Backends without a pool fall
    /// back to cloning into an owned [`Transport::send`].
    fn send_ref(&mut self, to: usize, tag: u64, bytes: &[u8]) -> Result<(), TransportError> {
        self.send(to, tag, bytes.to_vec())
    }
    /// Return a payload buffer received via [`Transport::next_msg`] for
    /// reuse on the receive path (no-op for backends without a pool).
    fn recycle(&mut self, _buf: Vec<u8>) {}
    /// Pool-miss counters for the send/receive hot paths.
    fn alloc_stats(&self) -> AllocStats {
        AllocStats::default()
    }
    /// Blocking: the next inbound message from any peer.
    fn next_msg(&mut self) -> Result<Msg, TransportError>;
    /// Non-blocking variant of [`Transport::next_msg`].
    fn try_next_msg(&mut self) -> Result<Option<Msg>, TransportError>;
    /// Total payload bytes this rank has sent.
    fn bytes_sent(&self) -> u64;
    fn msgs_sent(&self) -> u64;
}

/// Which transport backend a run uses (`TrainConfig.transport`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Channel mesh between OS threads in one process.
    #[default]
    InProc,
    /// Length-prefixed TCP sockets between OS processes.
    Tcp,
}

impl TransportKind {
    pub fn from_name(name: &str) -> anyhow::Result<TransportKind> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "inproc" | "in-proc" | "thread" | "threads" => TransportKind::InProc,
            "tcp" | "socket" | "sockets" => TransportKind::Tcp,
            other => anyhow::bail!("unknown transport '{other}' (inproc|tcp)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Rank-local endpoint: a backend plus the tag-matching stash. `recv`
/// requires `&mut self` because out-of-order messages are stashed locally
/// until a matching receive is posted.
pub struct Endpoint {
    transport: Box<dyn Transport>,
    /// Messages that arrived before their matching recv was posted.
    stash: HashMap<(usize, u64), Vec<Vec<u8>>>,
    /// Peers reported down by the backend (via [`CTRL_PEER_DOWN_TAG`]).
    dead: HashMap<usize, String>,
    /// Payload bytes successfully sent to each peer — the per-destination
    /// split `Comm::inter_node_bytes` classifies against the topology.
    per_peer_sent: Vec<u64>,
}

impl Endpoint {
    pub fn new(transport: Box<dyn Transport>) -> Endpoint {
        let world = transport.world();
        Endpoint {
            transport,
            stash: HashMap::new(),
            dead: HashMap::new(),
            per_peer_sent: vec![0; world],
        }
    }

    pub fn rank(&self) -> usize {
        self.transport.rank()
    }

    pub fn world(&self) -> usize {
        self.transport.world()
    }

    /// Total payload bytes this endpoint has sent (sum over endpoints =
    /// bytes on the "wire").
    pub fn bytes_sent(&self) -> u64 {
        self.transport.bytes_sent()
    }

    pub fn msgs_sent(&self) -> u64 {
        self.transport.msgs_sent()
    }

    /// Payload bytes successfully sent to each peer, indexed by rank.
    pub fn per_peer_sent(&self) -> &[u64] {
        &self.per_peer_sent
    }

    /// Payload bytes successfully sent to one peer.
    pub fn bytes_sent_to(&self, peer: usize) -> u64 {
        self.per_peer_sent[peer]
    }

    pub fn send(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<(), TransportError> {
        assert!(to < self.world(), "rank {to} out of range");
        assert_ne!(to, self.rank(), "self-send is a bug in the collective");
        let len = bytes.len() as u64;
        self.transport.send(to, tag, bytes)?;
        self.per_peer_sent[to] += len;
        Ok(())
    }

    /// Borrowed-payload send — same accounting as [`Endpoint::send`], but
    /// the caller keeps ownership of `bytes` (the transport copies into a
    /// pooled outbound buffer instead of taking a fresh `Vec`).
    pub fn send_ref(&mut self, to: usize, tag: u64, bytes: &[u8]) -> Result<(), TransportError> {
        assert!(to < self.world(), "rank {to} out of range");
        assert_ne!(to, self.rank(), "self-send is a bug in the collective");
        let len = bytes.len() as u64;
        self.transport.send_ref(to, tag, bytes)?;
        self.per_peer_sent[to] += len;
        Ok(())
    }

    /// Return a buffer obtained from [`Endpoint::recv`] once its contents
    /// have been consumed, so the receive path can reuse it.
    pub fn recycle(&mut self, buf: Vec<u8>) {
        self.transport.recycle(buf);
    }

    /// Pool-miss counters for the send/receive hot paths.
    pub fn alloc_stats(&self) -> AllocStats {
        self.transport.alloc_stats()
    }

    /// Blocking tag-matched receive.
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<u8>, TransportError> {
        if let Some(m) = self.take_stashed(from, tag) {
            return Ok(m);
        }
        if let Some(detail) = self.dead.get(&from) {
            return Err(self.peer_gone(from, Some(tag), detail.clone()));
        }
        loop {
            let (src, t, bytes) = self.transport.next_msg()?;
            if t == CTRL_PEER_DOWN_TAG {
                let detail = String::from_utf8_lossy(&bytes).into_owned();
                self.dead.insert(src, detail.clone());
                if src == from {
                    return Err(self.peer_gone(from, Some(tag), detail));
                }
                continue;
            }
            if src == from && t == tag {
                return Ok(bytes);
            }
            self.stash.entry((src, t)).or_default().push(bytes);
        }
    }

    /// Non-blocking probe used by failure-injection tests.
    pub fn try_recv(&mut self, from: usize, tag: u64) -> Result<Option<Vec<u8>>, TransportError> {
        if let Some(m) = self.take_stashed(from, tag) {
            return Ok(Some(m));
        }
        while let Some((src, t, bytes)) = self.transport.try_next_msg()? {
            if t == CTRL_PEER_DOWN_TAG {
                let detail = String::from_utf8_lossy(&bytes).into_owned();
                self.dead.insert(src, detail.clone());
                if src == from {
                    return Err(self.peer_gone(from, Some(tag), detail));
                }
                continue;
            }
            if src == from && t == tag {
                return Ok(Some(bytes));
            }
            self.stash.entry((src, t)).or_default().push(bytes);
        }
        if let Some(detail) = self.dead.get(&from) {
            return Err(self.peer_gone(from, Some(tag), detail.clone()));
        }
        Ok(None)
    }

    fn take_stashed(&mut self, from: usize, tag: u64) -> Option<Vec<u8>> {
        let q = self.stash.get_mut(&(from, tag))?;
        if q.is_empty() {
            return None;
        }
        let m = q.remove(0);
        if q.is_empty() {
            self.stash.remove(&(from, tag));
        }
        Some(m)
    }

    fn peer_gone(&self, peer: usize, tag: Option<u64>, detail: String) -> TransportError {
        TransportError::PeerGone {
            rank: self.rank(),
            peer,
            tag,
            detail,
        }
    }
}

/// In-process backend: a fully-connected mesh of unbounded channels, one
/// inbox per rank. The workers are OS threads in one process.
///
/// Dropping an endpoint notifies every peer in-band (the same
/// [`CTRL_PEER_DOWN_TAG`] control message the TCP reader injects on EOF),
/// so a rank blocked in `recv` on a dead peer gets a typed
/// [`TransportError::PeerGone`] instead of hanging — per-sender FIFO means
/// the control message can never overtake data the peer sent before dying.
pub struct InProcTransport {
    rank: usize,
    world: usize,
    /// senders[d] delivers to rank d's inbox.
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// Free list shared by the whole mesh: a buffer sent by one rank is
    /// recycled by its receiver back into the same pool.
    pool: Arc<BufferPool>,
    bytes_sent: u64,
    msgs_sent: u64,
}

/// Buffers the in-process mesh keeps on its shared free list.
const INPROC_POOL_CAP: usize = 256;

impl Transport for InProcTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<(), TransportError> {
        self.bytes_sent += bytes.len() as u64;
        self.msgs_sent += 1;
        // Receiver hung up ⇒ worker died; the collective can't complete.
        self.senders[to]
            .send((self.rank, tag, bytes))
            .map_err(|_| TransportError::PeerGone {
                rank: self.rank,
                peer: to,
                tag: Some(tag),
                detail: "worker thread died (inbox closed)".to_string(),
            })
    }

    fn next_msg(&mut self) -> Result<Msg, TransportError> {
        self.inbox.recv().map_err(|_| TransportError::Disconnected {
            detail: "mesh disconnected while receiving".to_string(),
        })
    }

    fn try_next_msg(&mut self) -> Result<Option<Msg>, TransportError> {
        match self.inbox.try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(TransportError::Disconnected {
                detail: "mesh disconnected while receiving".to_string(),
            }),
        }
    }

    fn send_ref(&mut self, to: usize, tag: u64, bytes: &[u8]) -> Result<(), TransportError> {
        let mut buf = self.pool.take();
        buf.extend_from_slice(bytes);
        self.send(to, tag, buf)
    }

    fn recycle(&mut self, buf: Vec<u8>) {
        self.pool.put(buf);
    }

    fn alloc_stats(&self) -> AllocStats {
        // One pool serves the whole mesh; its miss count is reported as
        // send-side (a sent buffer IS the received buffer in-process).
        AllocStats {
            send_pool_misses: self.pool.misses(),
            recv_pool_misses: 0,
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn msgs_sent(&self) -> u64 {
        self.msgs_sent
    }
}

impl Drop for InProcTransport {
    fn drop(&mut self) {
        for (peer, sender) in self.senders.iter().enumerate() {
            if peer != self.rank {
                let _ = sender.send((
                    self.rank,
                    CTRL_PEER_DOWN_TAG,
                    b"worker exited (endpoint dropped)".to_vec(),
                ));
            }
        }
    }
}

/// Build a fully-connected in-process mesh of `world` endpoints.
pub fn mesh(world: usize) -> Vec<Endpoint> {
    assert!(world >= 1);
    let mut senders = Vec::with_capacity(world);
    let mut receivers = Vec::with_capacity(world);
    for _ in 0..world {
        let (s, r) = channel::<Msg>();
        senders.push(s);
        receivers.push(r);
    }
    let pool = BufferPool::new(INPROC_POOL_CAP);
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| {
            Endpoint::new(Box::new(InProcTransport {
                rank,
                world,
                senders: senders.clone(),
                inbox,
                pool: Arc::clone(&pool),
                bytes_sent: 0,
                msgs_sent: 0,
            }))
        })
        .collect()
}

/// Run a closure on every rank of a fresh in-process mesh, one OS thread
/// per rank — the harness used by collective tests and the trainer.
pub fn run_group<T: Send>(world: usize, f: impl Fn(Endpoint) -> T + Send + Sync) -> Vec<T> {
    let endpoints = mesh(world);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| scope.spawn(move || f(ep)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_send_recv() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 7, vec![1, 2, 3]).unwrap();
                vec![]
            } else {
                ep.recv(0, 7).unwrap()
            }
        });
        assert_eq!(results[1], vec![1, 2, 3]);
    }

    #[test]
    fn tag_matching_reorders() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 1, vec![1]).unwrap();
                ep.send(1, 2, vec![2]).unwrap();
                ep.send(1, 3, vec![3]).unwrap();
                vec![]
            } else {
                // Receive in reverse tag order; stash must hold the rest.
                let a = ep.recv(0, 3).unwrap();
                let b = ep.recv(0, 2).unwrap();
                let c = ep.recv(0, 1).unwrap();
                vec![a[0], b[0], c[0]]
            }
        });
        assert_eq!(results[1], vec![3, 2, 1]);
    }

    #[test]
    fn same_tag_fifo_per_source() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                for i in 0..5u8 {
                    ep.send(1, 9, vec![i]).unwrap();
                }
                vec![]
            } else {
                (0..5).map(|_| ep.recv(0, 9).unwrap()[0]).collect()
            }
        });
        assert_eq!(results[1], vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn byte_accounting() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 0, vec![0u8; 100]).unwrap();
                ep.send(1, 1, vec![0u8; 28]).unwrap();
                ep.bytes_sent()
            } else {
                ep.recv(0, 0).unwrap();
                ep.recv(0, 1).unwrap();
                ep.bytes_sent()
            }
        });
        assert_eq!(results[0], 128);
        assert_eq!(results[1], 0);
    }

    #[test]
    fn per_peer_accounting_splits_by_destination() {
        let results = run_group(3, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 0, vec![0u8; 10]).unwrap();
                ep.send(2, 0, vec![0u8; 25]).unwrap();
                (ep.bytes_sent_to(1), ep.per_peer_sent().to_vec())
            } else {
                ep.recv(0, 0).unwrap();
                (0, ep.per_peer_sent().to_vec())
            }
        });
        assert_eq!(results[0].0, 10);
        assert_eq!(results[0].1, vec![0, 10, 25]);
        assert_eq!(results[1].1, vec![0, 0, 0]);
    }

    #[test]
    fn all_to_all_stress() {
        let world = 4;
        let results = run_group(world, |mut ep| {
            let me = ep.rank() as u8;
            for d in 0..ep.world() {
                if d != ep.rank() {
                    ep.send(d, 42, vec![me; 10]).unwrap();
                }
            }
            let mut sum = 0u32;
            for s in 0..ep.world() {
                if s != ep.rank() {
                    let m = ep.recv(s, 42).unwrap();
                    assert_eq!(m, vec![s as u8; 10]);
                    sum += m[0] as u32;
                }
            }
            sum
        });
        // Each rank receives the other three ranks' ids.
        for (r, s) in results.iter().enumerate() {
            assert_eq!(*s, (0..4).filter(|&x| x != r).sum::<usize>() as u32);
        }
    }

    #[test]
    fn try_recv_nonblocking() {
        let mut eps = mesh(2);
        let mut ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        assert!(ep1.try_recv(0, 5).unwrap().is_none());
        ep0.send(1, 5, vec![9]).unwrap();
        // Channel delivery is immediate in-process.
        let got = ep1.try_recv(0, 5).unwrap().unwrap();
        assert_eq!(got, vec![9]);
    }

    #[test]
    fn send_to_dead_peer_is_typed_error() {
        let mut eps = mesh(2);
        let ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        drop(ep1);
        let err = ep0.send(1, 3, vec![1]).unwrap_err();
        match err {
            TransportError::PeerGone { rank, peer, tag, .. } => {
                assert_eq!(rank, 0);
                assert_eq!(peer, 1);
                assert_eq!(tag, Some(3));
            }
            other => panic!("expected PeerGone, got {other}"),
        }
    }

    #[test]
    fn send_ref_and_recycle_reuse_buffers() {
        let mut eps = mesh(2);
        let mut ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        let payload = vec![7u8; 64];
        for t in 0..8u64 {
            ep0.send_ref(1, t, &payload).unwrap();
            let m = ep1.recv(0, t).unwrap();
            assert_eq!(m, payload);
            ep1.recycle(m);
        }
        // First send misses (pool empty); every later send reuses the
        // buffer rank 1 recycled into the shared mesh pool.
        assert_eq!(ep0.alloc_stats().send_pool_misses, 1);
        assert_eq!(ep0.bytes_sent(), 8 * 64);
        assert_eq!(ep0.per_peer_sent(), &[0, 8 * 64]);
    }

    #[test]
    fn buffer_pool_caps_and_counts_misses() {
        let pool = BufferPool::new(2);
        let a = pool.take();
        assert_eq!(pool.misses(), 1);
        pool.put(a);
        let b = pool.take();
        assert_eq!(pool.misses(), 1, "pooled buffer served without a miss");
        let mut c = pool.take();
        assert_eq!(pool.misses(), 2);
        c.extend_from_slice(&[1, 2, 3]);
        let cap = c.capacity();
        pool.put(c);
        let c2 = pool.take();
        assert!(c2.is_empty(), "pooled buffers come back cleared");
        assert!(c2.capacity() >= cap, "capacity survives the round trip");
        // Overfilling the pool drops buffers instead of growing unbounded.
        pool.put(b);
        pool.put(c2);
        pool.put(Vec::new());
        assert_eq!(pool.bufs.lock().unwrap().len(), 2);
    }

    #[test]
    fn transport_kind_names_roundtrip() {
        for k in [TransportKind::InProc, TransportKind::Tcp] {
            assert_eq!(TransportKind::from_name(k.name()).unwrap(), k);
        }
        assert!(TransportKind::from_name("carrier-pigeon").is_err());
        assert_eq!(TransportKind::default(), TransportKind::InProc);
    }

    #[test]
    fn error_display_names_rank_peer_and_tag() {
        let e = TransportError::PeerGone {
            rank: 2,
            peer: 0,
            tag: Some(17),
            detail: "connection reset".to_string(),
        };
        let s = e.to_string();
        assert!(s.contains("rank 2"), "{s}");
        assert!(s.contains("peer 0"), "{s}");
        assert!(s.contains("tag 17"), "{s}");
    }
}
