//! Point-to-point transport between ranks.
//!
//! The paper runs NCCL/MPI between 8 GPUs; here the workers are OS threads
//! in one process, so the transport is a mesh of unbounded channels with
//! tag matching (MPI semantics: a receive for `(from, tag)` only matches a
//! message sent with that tag). Every byte that crosses an endpoint is
//! counted, so experiments can report exact bytes-on-wire per collective.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// A message in flight: (source, tag, payload).
type Msg = (usize, u64, Vec<u8>);

/// Rank-local endpoint of the mesh. `recv` requires `&mut self` because
/// out-of-order messages are stashed locally until a matching receive.
pub struct Endpoint {
    rank: usize,
    world: usize,
    /// senders[d] delivers to rank d's inbox.
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// Messages that arrived before their matching recv was posted.
    stash: HashMap<(usize, u64), Vec<Vec<u8>>>,
    bytes_sent: Arc<AtomicU64>,
    msgs_sent: Arc<AtomicU64>,
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Total payload bytes this endpoint has sent (shared counter across the
    /// mesh lives per-endpoint; sum over endpoints = bytes on the "wire").
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn msgs_sent(&self) -> u64 {
        self.msgs_sent.load(Ordering::Relaxed)
    }

    pub fn send(&self, to: usize, tag: u64, bytes: Vec<u8>) {
        assert!(to < self.world, "rank {to} out of range");
        assert_ne!(to, self.rank, "self-send is a bug in the collective");
        self.bytes_sent.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.msgs_sent.fetch_add(1, Ordering::Relaxed);
        // Receiver hung up ⇒ worker died; the collective can't complete.
        self.senders[to]
            .send((self.rank, tag, bytes))
            .unwrap_or_else(|_| panic!("rank {to} is gone (worker thread died)"));
    }

    /// Blocking tag-matched receive.
    pub fn recv(&mut self, from: usize, tag: u64) -> Vec<u8> {
        // Check the stash first.
        if let Some(q) = self.stash.get_mut(&(from, tag)) {
            if !q.is_empty() {
                let m = q.remove(0);
                if q.is_empty() {
                    self.stash.remove(&(from, tag));
                }
                return m;
            }
        }
        loop {
            let (src, t, bytes) = self
                .inbox
                .recv()
                .expect("mesh disconnected while receiving");
            if src == from && t == tag {
                return bytes;
            }
            self.stash.entry((src, t)).or_default().push(bytes);
        }
    }

    /// Non-blocking probe used by failure-injection tests.
    pub fn try_recv(&mut self, from: usize, tag: u64) -> Option<Vec<u8>> {
        if let Some(q) = self.stash.get_mut(&(from, tag)) {
            if !q.is_empty() {
                return Some(q.remove(0));
            }
        }
        while let Ok((src, t, bytes)) = self.inbox.try_recv() {
            if src == from && t == tag {
                return Some(bytes);
            }
            self.stash.entry((src, t)).or_default().push(bytes);
        }
        None
    }
}

/// Build a fully-connected mesh of `world` endpoints.
pub fn mesh(world: usize) -> Vec<Endpoint> {
    assert!(world >= 1);
    let mut senders = Vec::with_capacity(world);
    let mut receivers = Vec::with_capacity(world);
    for _ in 0..world {
        let (s, r) = channel::<Msg>();
        senders.push(s);
        receivers.push(r);
    }
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Endpoint {
            rank,
            world,
            senders: senders.clone(),
            inbox,
            stash: HashMap::new(),
            bytes_sent: Arc::new(AtomicU64::new(0)),
            msgs_sent: Arc::new(AtomicU64::new(0)),
        })
        .collect()
}

/// Run a closure on every rank of a fresh mesh, one OS thread per rank —
/// the harness used by collective tests and the trainer.
pub fn run_group<T: Send>(world: usize, f: impl Fn(Endpoint) -> T + Send + Sync) -> Vec<T> {
    let endpoints = mesh(world);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| scope.spawn(move || f(ep)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_send_recv() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 7, vec![1, 2, 3]);
                vec![]
            } else {
                ep.recv(0, 7)
            }
        });
        assert_eq!(results[1], vec![1, 2, 3]);
    }

    #[test]
    fn tag_matching_reorders() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 1, vec![1]);
                ep.send(1, 2, vec![2]);
                ep.send(1, 3, vec![3]);
                vec![]
            } else {
                // Receive in reverse tag order; stash must hold the rest.
                let a = ep.recv(0, 3);
                let b = ep.recv(0, 2);
                let c = ep.recv(0, 1);
                vec![a[0], b[0], c[0]]
            }
        });
        assert_eq!(results[1], vec![3, 2, 1]);
    }

    #[test]
    fn same_tag_fifo_per_source() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                for i in 0..5u8 {
                    ep.send(1, 9, vec![i]);
                }
                vec![]
            } else {
                (0..5).map(|_| ep.recv(0, 9)[0]).collect()
            }
        });
        assert_eq!(results[1], vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn byte_accounting() {
        let results = run_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 0, vec![0u8; 100]);
                ep.send(1, 1, vec![0u8; 28]);
                ep.bytes_sent()
            } else {
                ep.recv(0, 0);
                ep.recv(0, 1);
                ep.bytes_sent()
            }
        });
        assert_eq!(results[0], 128);
        assert_eq!(results[1], 0);
    }

    #[test]
    fn all_to_all_stress() {
        let world = 4;
        let results = run_group(world, |mut ep| {
            let me = ep.rank() as u8;
            for d in 0..ep.world() {
                if d != ep.rank() {
                    ep.send(d, 42, vec![me; 10]);
                }
            }
            let mut sum = 0u32;
            for s in 0..ep.world() {
                if s != ep.rank() {
                    let m = ep.recv(s, 42);
                    assert_eq!(m, vec![s as u8; 10]);
                    sum += m[0] as u32;
                }
            }
            sum
        });
        // Each rank receives the other three ranks' ids.
        for (r, s) in results.iter().enumerate() {
            assert_eq!(*s, (0..4).filter(|&x| x != r).sum::<usize>() as u32);
        }
    }

    #[test]
    fn try_recv_nonblocking() {
        let mut eps = mesh(2);
        let mut ep1 = eps.pop().unwrap();
        let ep0 = eps.pop().unwrap();
        assert!(ep1.try_recv(0, 5).is_none());
        ep0.send(1, 5, vec![9]);
        // Spin briefly: channel delivery is immediate in-process.
        let got = ep1.try_recv(0, 5).unwrap();
        assert_eq!(got, vec![9]);
    }
}
