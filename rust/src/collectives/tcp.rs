//! TCP transport: length-prefixed, tag-matched frames over real sockets.
//!
//! Wire format per message (after the line-based bootstrap handshake):
//!
//! ```text
//! [ tag: u64 LE ][ len: u32 LE ][ payload: len bytes ]
//! ```
//!
//! The source rank is implicit per connection (established by the
//! `PEER <rank>` handshake in `bootstrap.rs`). Threads per peer:
//!
//! - a **writer** thread drains a bounded outbound queue and writes each
//!   frame with a single vectored write of header + payload (no
//!   frame-assembly copy, no intermediate `BufWriter`), returning written
//!   buffers to the transport's outbound [`BufferPool`]; `Endpoint::send`
//!   never blocks on the network unless the queue is full (real
//!   backpressure). A mid-frame write error is forwarded in-band as a
//!   [`CTRL_PEER_DOWN_TAG`] message naming the peer, the failing tag and
//!   how many queued frames were dropped with it;
//! - a **reader** thread reads frames into buffers drawn from a receive
//!   [`BufferPool`] (refilled by [`Endpoint::recycle`] after decode) and
//!   demuxes them into the same single-inbox + stash structure the
//!   in-process channel mesh uses. On EOF or connection reset it injects a
//!   [`CTRL_PEER_DOWN_TAG`] control message, which `Endpoint::recv`
//!   surfaces as a typed [`Error::peer_gone`] naming the rank,
//!   peer and tag — never a hang, never a process-poisoning panic.
//!
//! Works identically whether the peers are OS processes (the
//! `mergecomp train --transport tcp` worker mode, W processes over a real
//! wire) or threads in one process ([`run_tcp_group`], used by the
//! transport-equivalence tests to drive real sockets over loopback).

use super::bootstrap;
use super::faults::{FaultPlan, FaultTransport};
use super::transport::{
    AllocStats, BufferPool, Endpoint, Error, Msg, Transport, CTRL_PEER_DOWN_TAG,
};
use std::io::{IoSlice, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Hard per-message ceiling (2 GiB): enforced on send so the u32 length
/// header can never wrap, and on receive so a corrupt header fails loudly
/// instead of desyncing the stream.
const MAX_FRAME_BYTES: usize = 1 << 31;

/// Outbound frames queued per peer before `send` blocks (backpressure).
const OUTBOUND_QUEUE_DEPTH: usize = 128;

/// Buffers each of the two per-transport pools (outbound, receive) keeps.
/// Outbound must cover the frames parked in every peer's queue; 2× the
/// queue depth leaves slack for buffers in flight through the writers.
const TCP_POOL_CAP: usize = 2 * OUTBOUND_QUEUE_DEPTH;

/// Connection parameters for one rank of a TCP group.
#[derive(Debug, Clone)]
pub struct TcpConfig {
    pub rank: usize,
    pub world: usize,
    /// Rendezvous address rank 0 listens on and everyone else dials.
    pub rendezvous: String,
    /// Host this rank binds its data listener on and advertises to peers
    /// (must be routable from the other ranks; loopback for single-host).
    pub advertise_host: String,
    /// Node label this rank registers in the rendezvous TABLE (`n<id>`
    /// from the configured topology). The trainer cross-checks every
    /// peer's label against its own `--topology`, catching launches where
    /// ranks were handed different topologies.
    pub node_label: String,
    /// Bootstrap deadline: rendezvous + mesh formation must finish within
    /// this budget (dial retries included).
    pub timeout: Duration,
    /// Bootstrap generation this rank registers with. A relaunched rank
    /// re-HELLOs with a higher generation and supersedes its dead
    /// predecessor's rendezvous entry (see `bootstrap.rs`); 0 outside
    /// elastic restarts.
    pub generation: u64,
    /// On-wire fault plan injected below this rank's [`Endpoint`] when it
    /// applies to `rank` ([`FaultPlan::applies_to`]). `None` also consults
    /// the `MERGECOMP_FAULTS` environment variable, so chaos runs can
    /// straggle a rank without plumbing flags through every launcher.
    pub faults: Option<FaultPlan>,
    /// Run-config fingerprint attached to this rank's HELLO and
    /// cross-checked by rank 0 during the rendezvous (see
    /// [`bootstrap::exchange_peer_table`]): a joiner launched with a
    /// mismatched `--codec`/`--topology`/`--seed` is refused at HELLO with
    /// an error naming the flag, instead of training to a divergent
    /// digest. `None` skips the check (legacy peers).
    pub config_token: Option<String>,
}

impl Default for TcpConfig {
    fn default() -> Self {
        TcpConfig {
            rank: 0,
            world: 1,
            rendezvous: "127.0.0.1:29500".to_string(),
            advertise_host: "127.0.0.1".to_string(),
            node_label: "n0".to_string(),
            timeout: Duration::from_secs(60),
            generation: 0,
            faults: None,
            config_token: None,
        }
    }
}

struct PeerWriter {
    queue: SyncSender<(u64, Vec<u8>)>,
    /// First write error observed by the writer thread, if any.
    failed: Arc<Mutex<Option<String>>>,
    handle: Option<JoinHandle<()>>,
}

/// Socket backend implementing [`Transport`]. Build with
/// [`TcpTransport::connect`] (full bootstrap) and wrap in an
/// [`Endpoint`] via [`tcp_endpoint`].
pub struct TcpTransport {
    rank: usize,
    world: usize,
    writers: Vec<Option<PeerWriter>>,
    inbox: Receiver<Msg>,
    /// Node label each rank registered during the rendezvous.
    peer_nodes: Vec<String>,
    /// Outbound free list: writer threads return frames here after the
    /// vectored write, `send_ref` draws from it.
    out_pool: Arc<BufferPool>,
    /// Receive free list: reader threads draw from it, `recycle` refills.
    recv_pool: Arc<BufferPool>,
    bytes_sent: u64,
    msgs_sent: u64,
}

impl TcpTransport {
    /// Full bootstrap: bind a data listener, run the rendezvous, form the
    /// mesh, and spawn reader/writer threads for every peer.
    ///
    /// `hosted_rendezvous`: rank 0 may pass a pre-bound listener (tests
    /// bind port 0 to pick a free port); `None` makes rank 0 bind
    /// `cfg.rendezvous` itself.
    pub fn connect(
        cfg: &TcpConfig,
        hosted_rendezvous: Option<TcpListener>,
    ) -> anyhow::Result<TcpTransport> {
        anyhow::ensure!(cfg.world >= 1, "world must be at least 1");
        anyhow::ensure!(
            cfg.rank < cfg.world,
            "rank {} out of range for world {}",
            cfg.rank,
            cfg.world
        );
        let deadline = Instant::now() + cfg.timeout;
        let listener = TcpListener::bind((cfg.advertise_host.as_str(), 0))
            .map_err(|e| anyhow::anyhow!("binding data listener on {}: {e}", cfg.advertise_host))?;
        let port = listener
            .local_addr()
            .map_err(|e| anyhow::anyhow!("data listener addr: {e}"))?
            .port();
        let my_addr = format!("{}:{}", cfg.advertise_host, port);
        let table = bootstrap::exchange_peer_table(
            cfg.rank,
            cfg.world,
            &cfg.rendezvous,
            &my_addr,
            &cfg.node_label,
            cfg.generation,
            cfg.config_token.as_deref(),
            hosted_rendezvous,
            deadline,
        )?;
        let peer_nodes: Vec<String> = table.iter().map(|e| e.node.clone()).collect();
        let addrs: Vec<String> = table.into_iter().map(|e| e.addr).collect();
        let conns = bootstrap::connect_mesh(cfg.rank, cfg.world, &addrs, &listener, deadline)?;

        let (inbox_tx, inbox) = channel::<Msg>();
        let out_pool = BufferPool::new(TCP_POOL_CAP);
        let recv_pool = BufferPool::new(TCP_POOL_CAP);
        let mut writers: Vec<Option<PeerWriter>> = Vec::with_capacity(cfg.world);
        for (peer, conn) in conns.into_iter().enumerate() {
            let Some(stream) = conn else {
                writers.push(None);
                continue;
            };
            // One clone per lane; the reader keeps the original so the
            // socket closes only after the peer's FIN has been drained.
            let write_half = stream
                .try_clone()
                .map_err(|e| anyhow::anyhow!("cloning stream to rank {peer}: {e}"))?;
            let failed = Arc::new(Mutex::new(None));
            let (queue, queue_rx) = sync_channel::<(u64, Vec<u8>)>(OUTBOUND_QUEUE_DEPTH);
            let writer_failed = Arc::clone(&failed);
            let writer_tx = inbox_tx.clone();
            let writer_pool = Arc::clone(&out_pool);
            let rank = cfg.rank;
            let handle = std::thread::Builder::new()
                .name(format!("tcp-w{}-{peer}", cfg.rank))
                .spawn(move || {
                    writer_loop(
                        rank,
                        peer,
                        write_half,
                        queue_rx,
                        writer_failed,
                        writer_tx,
                        writer_pool,
                    )
                })
                .map_err(|e| anyhow::anyhow!("spawning writer thread: {e}"))?;
            let reader_tx = inbox_tx.clone();
            let reader_pool = Arc::clone(&recv_pool);
            std::thread::Builder::new()
                .name(format!("tcp-r{}-{peer}", cfg.rank))
                .spawn(move || reader_loop(peer, stream, reader_tx, reader_pool))
                .map_err(|e| anyhow::anyhow!("spawning reader thread: {e}"))?;
            writers.push(Some(PeerWriter {
                queue,
                failed,
                handle: Some(handle),
            }));
        }
        // Drop our own inbox sender: once every reader thread has exited,
        // `next_msg` observes disconnection instead of blocking forever.
        drop(inbox_tx);
        Ok(TcpTransport {
            rank: cfg.rank,
            world: cfg.world,
            writers,
            inbox,
            peer_nodes,
            out_pool,
            recv_pool,
            bytes_sent: 0,
            msgs_sent: 0,
        })
    }

    /// Node label each rank registered during the rendezvous, indexed by
    /// rank.
    pub fn peer_nodes(&self) -> &[String] {
        &self.peer_nodes
    }

    fn peer_gone(&self, peer: usize, tag: u64, detail: String) -> Error {
        Error::peer_gone(self.rank, peer, Some(tag), detail)
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<(), Error> {
        let len = bytes.len() as u64;
        if bytes.len() > MAX_FRAME_BYTES {
            return Err(self.peer_gone(
                to,
                tag,
                format!("payload of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte frame limit"),
            ));
        }
        let Some(writer) = self.writers[to].as_ref() else {
            return Err(self.peer_gone(to, tag, "no connection to peer".to_string()));
        };
        if let Some(detail) = writer.failed.lock().unwrap().clone() {
            return Err(self.peer_gone(to, tag, detail));
        }
        if writer.queue.send((tag, bytes)).is_err() {
            let detail = writer
                .failed
                .lock()
                .unwrap()
                .clone()
                .unwrap_or_else(|| "connection closed".to_string());
            return Err(self.peer_gone(to, tag, detail));
        }
        self.bytes_sent += len;
        self.msgs_sent += 1;
        Ok(())
    }

    fn next_msg(&mut self) -> Result<Msg, Error> {
        self.inbox
            .recv()
            .map_err(|_| Error::disconnected("all peer connections closed"))
    }

    fn try_next_msg(&mut self) -> Result<Option<Msg>, Error> {
        match self.inbox.try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                Err(Error::disconnected("all peer connections closed"))
            }
        }
    }

    fn send_ref(&mut self, to: usize, tag: u64, bytes: &[u8]) -> Result<(), Error> {
        // Steady state: the writer thread has already returned a written
        // frame to the pool, so this copies into recycled capacity and
        // allocates nothing.
        let mut buf = self.out_pool.take();
        buf.extend_from_slice(bytes);
        self.send(to, tag, buf)
    }

    fn recycle(&mut self, buf: Vec<u8>) {
        self.recv_pool.put(buf);
    }

    fn alloc_stats(&self) -> AllocStats {
        AllocStats {
            send_pool_misses: self.out_pool.misses(),
            recv_pool_misses: self.recv_pool.misses(),
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn msgs_sent(&self) -> u64 {
        self.msgs_sent
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // Close every outbound queue, then wait for the writers to flush
        // and FIN. Reader threads are left to drain until the peers'
        // symmetric FINs arrive (they hold the socket, so it closes only
        // once the peer is done writing — no RST races on teardown).
        for slot in &mut self.writers {
            if let Some(writer) = slot.take() {
                let PeerWriter { queue, failed: _, handle } = writer;
                drop(queue);
                if let Some(h) = handle {
                    let _ = h.join();
                }
            }
        }
    }
}

fn record_failure(failed: &Arc<Mutex<Option<String>>>, detail: &str) {
    let mut slot = failed.lock().unwrap();
    if slot.is_none() {
        *slot = Some(detail.to_string());
    }
}

/// Write one frame as a single vectored write of header + payload — the
/// payload goes from the queued buffer straight to the kernel, with no
/// frame-assembly copy. Partial writes walk the logical concatenation.
fn write_frame(w: &mut impl Write, tag: u64, payload: &[u8]) -> std::io::Result<()> {
    let mut header = [0u8; 12];
    header[..8].copy_from_slice(&tag.to_le_bytes());
    header[8..].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    let total = header.len() + payload.len();
    let mut written = 0usize;
    while written < total {
        let n = if written < header.len() {
            w.write_vectored(&[IoSlice::new(&header[written..]), IoSlice::new(payload)])?
        } else {
            w.write(&payload[written - header.len()..])?
        };
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "socket accepted zero bytes mid-frame",
            ));
        }
        written += n;
    }
    Ok(())
}

/// Drain the outbound queue, writing frames until the queue closes (clean
/// shutdown) or the socket errors (peer gone). Written buffers go back to
/// the outbound pool so the steady-state send path never allocates. A
/// write error is recorded for future `send`s AND injected in-band as
/// [`CTRL_PEER_DOWN_TAG`] so a blocked `recv` on this peer fails fast —
/// the message names the peer, the mid-frame tag, and how many queued
/// frames died with it.
fn writer_loop(
    rank: usize,
    peer: usize,
    mut stream: TcpStream,
    rx: Receiver<(u64, Vec<u8>)>,
    failed: Arc<Mutex<Option<String>>>,
    inbox: Sender<Msg>,
    pool: Arc<BufferPool>,
) {
    while let Ok((tag, payload)) = rx.recv() {
        if let Err(e) = write_frame(&mut stream, tag, &payload) {
            let queued = rx.try_iter().count();
            let detail = writer_error_detail(rank, peer, tag, queued, &e);
            record_failure(&failed, &detail);
            let _ = inbox.send((peer, CTRL_PEER_DOWN_TAG, detail.into_bytes()));
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        pool.put(payload);
    }
    // FIN: tells the peer's reader this rank is done sending.
    let _ = stream.shutdown(Shutdown::Write);
}

/// What a failed writer reports: which frame died (peer + tag) and how
/// many queued frames were lost behind it — the detail `Endpoint::recv`
/// surfaces inside [`Error::peer_gone`].
fn writer_error_detail(
    rank: usize,
    peer: usize,
    tag: u64,
    queued: usize,
    e: &std::io::Error,
) -> String {
    format!(
        "rank {rank}: write to peer {peer} failed mid-frame \
         (tag {tag}, {queued} queued frames dropped): {e}"
    )
}

/// Read frames from one peer and demux them into the shared inbox,
/// reusing payload buffers from the receive pool (refilled by
/// [`Endpoint::recycle`] once the collective has decoded them). On any
/// error (EOF after the peer's FIN, connection reset) a control message
/// marks the peer down, then the socket is drained so the peer's writer
/// can never block on a full kernel buffer during teardown.
fn reader_loop(peer: usize, mut stream: TcpStream, inbox: Sender<Msg>, pool: Arc<BufferPool>) {
    let mut header = [0u8; 12];
    loop {
        if let Err(e) = stream.read_exact(&mut header) {
            let _ = inbox.send((peer, CTRL_PEER_DOWN_TAG, e.to_string().into_bytes()));
            return;
        }
        let tag = u64::from_le_bytes(header[..8].try_into().unwrap());
        let len = u32::from_le_bytes(header[8..].try_into().unwrap()) as usize;
        if len > MAX_FRAME_BYTES {
            let msg = format!("corrupt frame: {len} byte payload");
            let _ = inbox.send((peer, CTRL_PEER_DOWN_TAG, msg.into_bytes()));
            return;
        }
        let mut payload = pool.take();
        payload.resize(len, 0);
        if let Err(e) = stream.read_exact(&mut payload) {
            let _ = inbox.send((peer, CTRL_PEER_DOWN_TAG, e.to_string().into_bytes()));
            return;
        }
        if inbox.send((peer, tag, payload)).is_err() {
            // Local transport dropped; keep the socket drained until the
            // peer's FIN so its writer can finish flushing.
            let mut sink = [0u8; 1 << 16];
            while matches!(stream.read(&mut sink), Ok(n) if n > 0) {}
            return;
        }
    }
}

/// Bootstrap a TCP-backed [`Endpoint`] (the worker-mode entry point).
pub fn tcp_endpoint(
    cfg: &TcpConfig,
    hosted_rendezvous: Option<TcpListener>,
) -> anyhow::Result<Endpoint> {
    Ok(tcp_endpoint_with_nodes(cfg, hosted_rendezvous)?.0)
}

/// Like [`tcp_endpoint`], but also returns the node label every rank
/// registered in the rendezvous TABLE (indexed by rank) — the trainer
/// cross-checks these against its own `--topology`.
///
/// This is also where fault injection attaches: a plan from `cfg.faults`
/// (or, when unset, the `MERGECOMP_FAULTS` environment variable) that
/// applies to this rank wraps the socket transport in a [`FaultTransport`]
/// before the [`Endpoint`] is built, so every collective — and the
/// scheduler's cost measurements — sees the perturbed wire.
pub fn tcp_endpoint_with_nodes(
    cfg: &TcpConfig,
    hosted_rendezvous: Option<TcpListener>,
) -> anyhow::Result<(Endpoint, Vec<String>)> {
    let transport = TcpTransport::connect(cfg, hosted_rendezvous)?;
    let nodes = transport.peer_nodes().to_vec();
    let plan = match &cfg.faults {
        Some(p) => Some(p.clone()),
        None => match std::env::var("MERGECOMP_FAULTS") {
            Ok(s) if !s.trim().is_empty() => Some(FaultPlan::parse(&s)?),
            _ => None,
        },
    };
    let boxed: Box<dyn Transport> = match plan {
        Some(plan) if plan.applies_to(cfg.rank) => {
            Box::new(FaultTransport::new(Box::new(transport), plan.spec, cfg.rank as u64))
        }
        _ => Box::new(transport),
    };
    Ok((Endpoint::new(boxed), nodes))
}

/// Run a closure on every rank of a fresh TCP group over loopback, one OS
/// thread per rank — same contract as [`super::run_group`], but every
/// message crosses a real socket. Used by the transport-equivalence tests
/// and benches; multi-process runs go through `training::launch` instead.
pub fn run_tcp_group<T: Send>(world: usize, f: impl Fn(Endpoint) -> T + Send + Sync) -> Vec<T> {
    let listener = TcpListener::bind("127.0.0.1:0").expect("binding loopback rendezvous");
    let rendezvous = listener.local_addr().expect("rendezvous addr").to_string();
    let mut hosted = Some(listener);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let hosted = if rank == 0 { hosted.take() } else { None };
                let rendezvous = rendezvous.clone();
                scope.spawn(move || {
                    let cfg = TcpConfig {
                        rank,
                        world,
                        rendezvous,
                        ..TcpConfig::default()
                    };
                    let ep = tcp_endpoint(&cfg, hosted).expect("tcp bootstrap");
                    f(ep)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_send_recv_over_loopback() {
        let results = run_tcp_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 7, vec![1, 2, 3]).unwrap();
                vec![]
            } else {
                ep.recv(0, 7).unwrap()
            }
        });
        assert_eq!(results[1], vec![1, 2, 3]);
    }

    #[test]
    fn tag_matching_reorders_over_sockets() {
        let results = run_tcp_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 1, vec![1]).unwrap();
                ep.send(1, 2, vec![2]).unwrap();
                ep.send(1, 3, vec![3]).unwrap();
                vec![]
            } else {
                let a = ep.recv(0, 3).unwrap();
                let b = ep.recv(0, 2).unwrap();
                let c = ep.recv(0, 1).unwrap();
                vec![a[0], b[0], c[0]]
            }
        });
        assert_eq!(results[1], vec![3, 2, 1]);
    }

    #[test]
    fn all_to_all_with_large_payloads() {
        let world = 4;
        let n = 100_000;
        let results = run_tcp_group(world, move |mut ep| {
            let me = ep.rank() as u8;
            for d in 0..ep.world() {
                if d != ep.rank() {
                    ep.send(d, 5, vec![me; n]).unwrap();
                }
            }
            let mut ok = true;
            for s in 0..ep.world() {
                if s != ep.rank() {
                    let m = ep.recv(s, 5).unwrap();
                    ok &= m.len() == n && m.iter().all(|&b| b == s as u8);
                }
            }
            ok
        });
        assert!(results.into_iter().all(|b| b));
    }

    #[test]
    fn byte_accounting_counts_payload_bytes() {
        let results = run_tcp_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 0, vec![0u8; 100]).unwrap();
                ep.send(1, 1, vec![0u8; 28]).unwrap();
                // Make teardown deterministic: wait for the ack.
                ep.recv(1, 2).unwrap();
                (ep.bytes_sent(), ep.msgs_sent())
            } else {
                ep.recv(0, 0).unwrap();
                ep.recv(0, 1).unwrap();
                ep.send(0, 2, vec![1]).unwrap();
                (ep.bytes_sent(), ep.msgs_sent())
            }
        });
        assert_eq!(results[0], (128, 2));
        assert_eq!(results[1], (1, 1));
    }

    #[test]
    fn dead_peer_surfaces_as_typed_error_not_hang() {
        let results = run_tcp_group(2, |mut ep| {
            if ep.rank() == 1 {
                // Rank 1 leaves immediately; dropping the transport FINs
                // its sockets.
                return None;
            }
            // Rank 0 blocks in recv: the peer's FIN must surface as
            // PeerGone naming rank, peer and tag.
            match ep.recv(1, 9) {
                Ok(_) => Some("unexpected message".to_string()),
                Err(e) if e.is_recoverable() => {
                    assert_eq!(e.rank, Some(0));
                    assert_eq!(e.peer, Some(1));
                    assert_eq!(e.tag, Some(9));
                    None
                }
                Err(other) => Some(format!("wrong error: {other}")),
            }
        });
        assert_eq!(results, vec![None, None]);
    }

    #[test]
    fn node_labels_propagate_through_rendezvous() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let rendezvous = listener.local_addr().unwrap().to_string();
        let mut hosted = Some(listener);
        let labels: Vec<Vec<String>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2usize)
                .map(|rank| {
                    let hosted = if rank == 0 { hosted.take() } else { None };
                    let rendezvous = rendezvous.clone();
                    s.spawn(move || {
                        let cfg = TcpConfig {
                            rank,
                            world: 2,
                            rendezvous,
                            node_label: format!("n{rank}"),
                            ..TcpConfig::default()
                        };
                        let (ep, nodes) = tcp_endpoint_with_nodes(&cfg, hosted).unwrap();
                        drop(ep);
                        nodes
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for l in &labels {
            assert_eq!(l, &vec!["n0".to_string(), "n1".to_string()]);
        }
    }

    /// A `Write` that accepts at most `budget[i]` bytes on the i-th call
    /// (unlimited once the budget runs out), capturing everything — drives
    /// the partial-write loop in `write_frame` through every split point.
    struct Dribble {
        out: Vec<u8>,
        budget: std::collections::VecDeque<usize>,
    }

    impl Write for Dribble {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = self.budget.pop_front().unwrap_or(buf.len()).min(buf.len());
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn write_vectored(&mut self, bufs: &[IoSlice]) -> std::io::Result<usize> {
            let mut budget = self.budget.pop_front().unwrap_or(usize::MAX);
            let mut n = 0;
            for b in bufs {
                let take = budget.min(b.len());
                self.out.extend_from_slice(&b[..take]);
                n += take;
                budget -= take;
                if budget == 0 {
                    break;
                }
            }
            Ok(n)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_frame_survives_partial_vectored_writes() {
        let payload: Vec<u8> = (0..300u32).map(|i| i as u8).collect();
        // Split inside the header (5, 3), across the header/payload
        // boundary (10), and inside the payload (1, 200).
        let mut w = Dribble {
            out: Vec::new(),
            budget: [5usize, 3, 10, 1, 200].into_iter().collect(),
        };
        write_frame(&mut w, 0xDEAD_BEEF, &payload).unwrap();
        assert_eq!(&w.out[..8], &0xDEAD_BEEFu64.to_le_bytes());
        assert_eq!(&w.out[8..12], &(300u32).to_le_bytes());
        assert_eq!(&w.out[12..], &payload[..]);
    }

    #[test]
    fn write_frame_zero_length_write_is_an_error() {
        let mut w = Dribble {
            out: Vec::new(),
            budget: [4usize, 0].into_iter().collect(),
        };
        let err = write_frame(&mut w, 1, &[9u8; 8]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::WriteZero);
    }

    #[test]
    fn writer_error_detail_names_peer_tag_and_queue() {
        let e = std::io::Error::new(std::io::ErrorKind::BrokenPipe, "broken pipe");
        let d = writer_error_detail(0, 3, 17, 5, &e);
        assert!(d.contains("peer 3"), "{d}");
        assert!(d.contains("tag 17"), "{d}");
        assert!(d.contains("5 queued frames"), "{d}");
        assert!(d.contains("broken pipe"), "{d}");
    }

    #[test]
    fn configured_fault_plan_shims_the_endpoint() {
        // Rank 0 carries a drop-after=1 plan: its first send to rank 1
        // lands, the second fails typed with the fault shim's cut-link
        // error — proving tcp_endpoint wires the shim below the Endpoint.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let rendezvous = listener.local_addr().unwrap().to_string();
        let mut hosted = Some(listener);
        let results: Vec<Option<String>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2usize)
                .map(|rank| {
                    let hosted = if rank == 0 { hosted.take() } else { None };
                    let rendezvous = rendezvous.clone();
                    s.spawn(move || {
                        let faults = (rank == 0)
                            .then(|| FaultPlan::parse("rank=0,drop-after=1").unwrap());
                        let cfg = TcpConfig {
                            rank,
                            world: 2,
                            rendezvous,
                            faults,
                            ..TcpConfig::default()
                        };
                        let mut ep = tcp_endpoint(&cfg, hosted).unwrap();
                        if rank == 0 {
                            ep.send(1, 1, vec![7]).unwrap();
                            match ep.send(1, 2, vec![8]) {
                                Err(e) if e.is_recoverable() && e.peer == Some(1) => None,
                                other => Some(format!("expected cut link, got {other:?}")),
                            }
                        } else {
                            match ep.recv(0, 1) {
                                Ok(m) if m == vec![7] => None,
                                other => Some(format!("bad first frame: {other:?}")),
                            }
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results, vec![None, None]);
    }

    #[test]
    fn empty_payload_frames_roundtrip() {
        let results = run_tcp_group(2, |mut ep| {
            if ep.rank() == 0 {
                ep.send(1, 0, Vec::new()).unwrap();
                ep.recv(1, 1).unwrap().len()
            } else {
                let got = ep.recv(0, 0).unwrap();
                ep.send(0, 1, Vec::new()).unwrap();
                got.len()
            }
        });
        assert_eq!(results, vec![0, 0]);
    }
}
