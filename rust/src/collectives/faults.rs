//! On-wire fault injection: wrap any [`Transport`] in a [`FaultTransport`]
//! shim that delays, rate-limits, or cuts individual links — so tests and
//! benches can watch the online scheduler route around a straggler, and CI
//! can SIGKILL-proof the elastic recovery path against realistic wire
//! behaviour instead of only clean FINs.
//!
//! Faults are declared as a [`FaultPlan`] spec string (the
//! `MERGECOMP_FAULTS` environment variable, or `RunPolicy.faults`):
//!
//! ```text
//! rank=2,delay=2ms,jitter=1ms,rate=65536/100ms,drop-after=40,peers=0|1
//! ```
//!
//! - `rank=K` — the plan applies only to rank K (absent: every rank);
//! - `delay=D` — fixed extra latency per send (`ns`/`us`/`ms`/`s` suffix);
//! - `jitter=J` — additional uniform random latency in `[0, J)` per send;
//! - `rate=BYTES[/WINDOW]` — token-bucket rate limit: `BYTES` of bucket
//!   capacity refilled every `WINDOW` (default window 1s), so sends block
//!   once the bucket drains — the classic burst-then-throttle shape;
//! - `drop-after=N` — after N successful sends to a peer the link is cut:
//!   further sends fail as a recoverable peer-gone error and inbound
//!   frames from that peer are replaced by a single in-band peer-down
//!   control frame (a partition, as the survivors observe it);
//! - `peers=A|B|…` — restrict every fault above to the named peer links.
//!
//! The shim sits *below* the [`Endpoint`] stash, exactly where a slow NIC
//! or an overloaded switch would: collectives observe longer exchange
//! times (the scheduler's cost models fit larger α/β for the straggled
//! level) or typed link failures, never corrupted frames.

use super::transport::{Error, Msg, Transport, CTRL_PEER_DOWN_TAG};
use crate::util::rng::Xoshiro256;
use std::collections::HashSet;
use std::time::{Duration, Instant};

/// Token bucket: `capacity` tokens (bytes), refilled continuously at
/// `capacity / window` per second. [`TokenBucket::consume`] blocks the
/// caller until the requested tokens are available — modelling a
/// rate-limited link by sleeping the sender, the way a full NIC queue
/// would apply backpressure.
#[derive(Debug)]
pub struct TokenBucket {
    capacity: f64,
    tokens: f64,
    refill_per_sec: f64,
    last: Instant,
}

impl TokenBucket {
    /// `size` bytes of burst capacity, refilled every `window`.
    pub fn new(size: u64, window: Duration) -> TokenBucket {
        let capacity = (size.max(1)) as f64;
        let secs = window.as_secs_f64().max(1e-9);
        TokenBucket {
            capacity,
            tokens: capacity,
            refill_per_sec: capacity / secs,
            last: Instant::now(),
        }
    }

    fn refill(&mut self) {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.refill_per_sec).min(self.capacity);
    }

    /// Block until `n` tokens are available, then take them. Requests
    /// larger than the bucket are clamped to its capacity (one full-bucket
    /// wait), so an oversized frame is slowed, never deadlocked.
    pub fn consume(&mut self, n: u64) {
        let need = (n as f64).min(self.capacity);
        loop {
            self.refill();
            if self.tokens >= need {
                self.tokens -= need;
                return;
            }
            let deficit = need - self.tokens;
            let wait = deficit / self.refill_per_sec;
            std::thread::sleep(Duration::from_secs_f64(wait.clamp(1e-6, 0.05)));
        }
    }
}

/// The faults applied to one rank's links (see the module doc for the
/// spec grammar that builds it).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Fixed extra latency per send.
    pub delay: Duration,
    /// Additional uniform random latency in `[0, jitter)` per send.
    pub jitter: Duration,
    /// Token-bucket rate limit: (bucket size in bytes, refill window).
    pub rate: Option<(u64, Duration)>,
    /// Cut each faulted link after this many successful sends to it.
    pub drop_after: Option<u64>,
    /// Restrict the faults to these peer links (`None`: all peers).
    pub peers: Option<Vec<usize>>,
}

impl FaultSpec {
    /// Whether the spec perturbs anything at all.
    pub fn is_noop(&self) -> bool {
        self.delay.is_zero()
            && self.jitter.is_zero()
            && self.rate.is_none()
            && self.drop_after.is_none()
    }

    fn targets(&self, peer: usize) -> bool {
        match &self.peers {
            Some(ps) => ps.contains(&peer),
            None => true,
        }
    }
}

/// A parsed fault plan: which rank it applies to, and what it does there.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Rank the plan applies to (`None`: every rank).
    pub rank: Option<usize>,
    pub spec: FaultSpec,
}

impl FaultPlan {
    /// Parse a spec string (see the module doc for the grammar). An empty
    /// string is a no-op plan.
    pub fn parse(s: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for item in s.split(',').map(str::trim).filter(|i| !i.is_empty()) {
            let (key, val) = item
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault spec item '{item}' is not key=value"))?;
            match key.trim() {
                "rank" => plan.rank = Some(val.trim().parse()?),
                "delay" => plan.spec.delay = parse_duration(val)?,
                "jitter" => plan.spec.jitter = parse_duration(val)?,
                "rate" => {
                    let (bytes, window) = match val.split_once('/') {
                        Some((b, w)) => (b.trim().parse()?, parse_duration(w)?),
                        None => (val.trim().parse()?, Duration::from_secs(1)),
                    };
                    anyhow::ensure!(bytes > 0, "rate needs a positive byte budget");
                    plan.spec.rate = Some((bytes, window));
                }
                "drop-after" | "drop_after" => plan.spec.drop_after = Some(val.trim().parse()?),
                "peers" => {
                    let peers: Vec<usize> = val
                        .split('|')
                        .map(|p| p.trim().parse())
                        .collect::<Result<_, _>>()?;
                    anyhow::ensure!(!peers.is_empty(), "peers= needs at least one rank");
                    plan.spec.peers = Some(peers);
                }
                other => anyhow::bail!(
                    "unknown fault spec key '{other}' \
                     (rank|delay|jitter|rate|drop-after|peers)"
                ),
            }
        }
        Ok(plan)
    }

    /// Whether this plan's faults run on `rank`.
    pub fn applies_to(&self, rank: usize) -> bool {
        !self.spec.is_noop() && self.rank.map_or(true, |r| r == rank)
    }
}

/// Parse `250ns` / `10us` / `2ms` / `1s` (and bare seconds as `1.5`).
fn parse_duration(s: &str) -> anyhow::Result<Duration> {
    let s = s.trim();
    let (num, scale) = if let Some(v) = s.strip_suffix("ns") {
        (v, 1e-9)
    } else if let Some(v) = s.strip_suffix("us") {
        (v, 1e-6)
    } else if let Some(v) = s.strip_suffix("ms") {
        (v, 1e-3)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, 1.0)
    } else {
        (s, 1.0)
    };
    let val: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad duration '{s}' (want e.g. 2ms, 500us, 1s)"))?;
    anyhow::ensure!(val >= 0.0 && val.is_finite(), "duration '{s}' must be >= 0");
    Ok(Duration::from_secs_f64(val * scale))
}

/// [`Transport`] shim injecting the faults of a [`FaultSpec`] on the send
/// and receive paths of the wrapped backend. Deterministic given the seed
/// (jitter draws from a seeded [`Xoshiro256`]); transparent when the spec
/// targets none of the touched links.
pub struct FaultTransport {
    inner: Box<dyn Transport>,
    spec: FaultSpec,
    bucket: Option<TokenBucket>,
    rng: Xoshiro256,
    /// Successful sends per peer (drop-after accounting).
    sent_to: Vec<u64>,
    /// Links this shim has cut (drop-after exhausted).
    cut: HashSet<usize>,
    /// Cut links already surfaced to the receive path as a peer-down
    /// control frame.
    announced: HashSet<usize>,
}

impl FaultTransport {
    pub fn new(inner: Box<dyn Transport>, spec: FaultSpec, seed: u64) -> FaultTransport {
        let world = inner.world();
        let bucket = spec.rate.map(|(bytes, window)| TokenBucket::new(bytes, window));
        FaultTransport {
            inner,
            spec,
            bucket,
            rng: Xoshiro256::seed_from_u64(seed ^ 0xFA17_FA17),
            sent_to: vec![0; world],
            cut: HashSet::new(),
            announced: HashSet::new(),
        }
    }

    /// Links this shim has cut so far (test observability).
    pub fn cut_links(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.cut.iter().copied().collect();
        v.sort_unstable();
        v
    }

    fn cut_error(&self, peer: usize, tag: u64) -> Error {
        Error::peer_gone(
            self.inner.rank(),
            peer,
            Some(tag),
            format!(
                "fault injection: link to peer {peer} cut (drop-after={})",
                self.spec.drop_after.unwrap_or(0)
            ),
        )
    }

    /// Apply pre-send faults for a payload of `len` bytes to `to`;
    /// `Err` means the link is (now) cut.
    fn before_send(&mut self, to: usize, tag: u64, len: usize) -> Result<(), Error> {
        if !self.spec.targets(to) {
            return Ok(());
        }
        if self.cut.contains(&to) {
            return Err(self.cut_error(to, tag));
        }
        if let Some(limit) = self.spec.drop_after {
            if self.sent_to[to] >= limit {
                self.cut.insert(to);
                return Err(self.cut_error(to, tag));
            }
        }
        let mut wait = self.spec.delay;
        if !self.spec.jitter.is_zero() {
            let j = self.spec.jitter.as_nanos() as u64;
            wait += Duration::from_nanos(self.rng.next_u64() % j.max(1));
        }
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        if let Some(bucket) = &mut self.bucket {
            bucket.consume(len as u64);
        }
        self.sent_to[to] += 1;
        Ok(())
    }

    /// Filter one inbound message: data frames from a cut peer are
    /// swallowed after a single synthesized peer-down control frame, so a
    /// receiver blocked on a partitioned link fails typed instead of
    /// consuming stale traffic.
    fn filter(&mut self, msg: Msg) -> Option<Msg> {
        let (src, tag, bytes) = msg;
        if !self.cut.contains(&src) {
            return Some((src, tag, bytes));
        }
        if self.announced.insert(src) {
            let note = format!("fault injection: partitioned from peer {src}");
            return Some((src, CTRL_PEER_DOWN_TAG, note.into_bytes()));
        }
        None
    }
}

impl Transport for FaultTransport {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn world(&self) -> usize {
        self.inner.world()
    }

    fn send(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<(), Error> {
        self.before_send(to, tag, bytes.len())?;
        self.inner.send(to, tag, bytes)
    }

    fn send_ref(&mut self, to: usize, tag: u64, bytes: &[u8]) -> Result<(), Error> {
        self.before_send(to, tag, bytes.len())?;
        self.inner.send_ref(to, tag, bytes)
    }

    fn recycle(&mut self, buf: Vec<u8>) {
        self.inner.recycle(buf);
    }

    fn alloc_stats(&self) -> super::transport::AllocStats {
        self.inner.alloc_stats()
    }

    fn next_msg(&mut self) -> Result<Msg, Error> {
        loop {
            let msg = self.inner.next_msg()?;
            if let Some(m) = self.filter(msg) {
                return Ok(m);
            }
        }
    }

    fn try_next_msg(&mut self) -> Result<Option<Msg>, Error> {
        while let Some(msg) = self.inner.try_next_msg()? {
            if let Some(m) = self.filter(msg) {
                return Ok(Some(m));
            }
        }
        Ok(None)
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn msgs_sent(&self) -> u64 {
        self.inner.msgs_sent()
    }
}

#[cfg(test)]
mod tests {
    use super::super::transport::{mesh_transports, Endpoint, ErrorKind};
    use super::*;

    #[test]
    fn plan_parses_the_full_grammar() {
        let spec = "rank=2, delay=2ms, jitter=1ms, rate=65536/100ms, drop-after=40, peers=0|1";
        let p = FaultPlan::parse(spec).unwrap();
        assert_eq!(p.rank, Some(2));
        assert_eq!(p.spec.delay, Duration::from_millis(2));
        assert_eq!(p.spec.jitter, Duration::from_millis(1));
        assert_eq!(p.spec.rate, Some((65536, Duration::from_millis(100))));
        assert_eq!(p.spec.drop_after, Some(40));
        assert_eq!(p.spec.peers, Some(vec![0, 1]));
        assert!(p.applies_to(2));
        assert!(!p.applies_to(0));
    }

    #[test]
    fn plan_defaults_and_rejects_junk() {
        let p = FaultPlan::parse("").unwrap();
        assert_eq!(p, FaultPlan::default());
        assert!(!p.applies_to(0), "a no-op plan applies nowhere");
        let all = FaultPlan::parse("delay=1ms").unwrap();
        assert!(all.applies_to(0) && all.applies_to(7));
        assert!(FaultPlan::parse("delay").is_err());
        assert!(FaultPlan::parse("warp=9").is_err());
        assert!(FaultPlan::parse("delay=fast").is_err());
        assert!(FaultPlan::parse("rate=0").is_err());
        assert!(FaultPlan::parse("peers=").is_err());
    }

    #[test]
    fn durations_parse_all_suffixes() {
        assert_eq!(parse_duration("250ns").unwrap(), Duration::from_nanos(250));
        assert_eq!(parse_duration("10us").unwrap(), Duration::from_micros(10));
        assert_eq!(parse_duration("2ms").unwrap(), Duration::from_millis(2));
        assert_eq!(parse_duration("1s").unwrap(), Duration::from_secs(1));
        assert_eq!(parse_duration("0.5").unwrap(), Duration::from_millis(500));
        assert!(parse_duration("-1ms").is_err());
    }

    #[test]
    fn token_bucket_throttles_to_the_configured_rate() {
        // 1 KiB bucket refilled every 20ms = 50 KiB/s. Pushing 3 KiB must
        // take at least the ~2 refills the burst does not cover.
        let mut bucket = TokenBucket::new(1024, Duration::from_millis(20));
        let start = Instant::now();
        for _ in 0..3 {
            bucket.consume(1024);
        }
        let elapsed = start.elapsed();
        assert!(
            elapsed >= Duration::from_millis(30),
            "3 KiB through a 50 KiB/s bucket took only {elapsed:?}"
        );
    }

    #[test]
    fn oversized_request_is_clamped_not_deadlocked() {
        let mut bucket = TokenBucket::new(64, Duration::from_millis(1));
        // 10x the capacity: must complete (clamped to one full bucket).
        bucket.consume(640);
    }

    #[test]
    fn delay_fault_slows_the_link() {
        let mut ts = mesh_transports(2).into_iter();
        let spec = FaultSpec {
            delay: Duration::from_millis(5),
            ..FaultSpec::default()
        };
        let mut ep0 = Endpoint::new(Box::new(FaultTransport::new(
            Box::new(ts.next().unwrap()),
            spec,
            0,
        )));
        let mut ep1 = Endpoint::new(Box::new(ts.next().unwrap()));
        let start = Instant::now();
        ep0.send(1, 0, vec![1, 2, 3]).unwrap();
        assert!(start.elapsed() >= Duration::from_millis(5));
        assert_eq!(ep1.recv(0, 0).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn drop_after_cuts_the_link_both_ways() {
        let mut ts = mesh_transports(2).into_iter();
        let spec = FaultSpec {
            drop_after: Some(2),
            ..FaultSpec::default()
        };
        let mut ep0 = Endpoint::new(Box::new(FaultTransport::new(
            Box::new(ts.next().unwrap()),
            spec,
            0,
        )));
        let mut ep1 = Endpoint::new(Box::new(ts.next().unwrap()));
        ep0.send(1, 0, vec![1]).unwrap();
        ep0.send(1, 1, vec![2]).unwrap();
        let err = ep0.send(1, 2, vec![3]).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::PeerGone);
        assert!(err.is_recoverable());
        assert!(err.to_string().contains("drop-after"), "{err}");
        // The two pre-cut frames still arrive.
        assert_eq!(ep1.recv(0, 0).unwrap(), vec![1]);
        assert_eq!(ep1.recv(0, 1).unwrap(), vec![2]);
        // Receive side of the cut link: inbound traffic from the peer is
        // replaced by a peer-down control frame -> typed error, no hang.
        ep1.send(0, 7, vec![9]).unwrap();
        let err = ep0.recv(1, 7).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::PeerGone);
        assert!(err.to_string().contains("partitioned"), "{err}");
    }

    #[test]
    fn untargeted_peers_are_untouched() {
        let mut ts = mesh_transports(3).into_iter();
        let spec = FaultSpec {
            drop_after: Some(0),
            peers: Some(vec![2]),
            ..FaultSpec::default()
        };
        let mut ep0 = Endpoint::new(Box::new(FaultTransport::new(
            Box::new(ts.next().unwrap()),
            spec,
            0,
        )));
        let mut ep1 = Endpoint::new(Box::new(ts.next().unwrap()));
        let _ep2 = Endpoint::new(Box::new(ts.next().unwrap()));
        // Link to rank 1 is not in peers= — it works.
        ep0.send(1, 0, vec![5]).unwrap();
        assert_eq!(ep1.recv(0, 0).unwrap(), vec![5]);
        // Link to rank 2 is cut from the first send.
        let err = ep0.send(2, 0, vec![5]).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::PeerGone);
    }
}
