//! Network fabric models for the simulator plane.
//!
//! The paper's testbed synchronizes 8× V100 over either PCIe 3.0 ×16 (MPI)
//! or NVLink (NCCL). We model each fabric with the standard α-β cost model
//! (α = per-message latency, β = bus bandwidth in bytes/s) and the textbook
//! collective cost functions (Thakur et al. 2005; Patarasuk & Yuan 2009).
//!
//! Calibration: the β values below are *effective* end-to-end throughputs,
//! not link speeds. The paper's own worked example (§3.2) pins them: FP32
//! communication for ResNet50 (102.4 MB of gradients) between 2 GPUs over
//! PCIe costs ≈66 ms ⇒ ~1.6 GB/s effective (MPI allreduce without GPUDirect
//! staging through host memory), and the FP32 NVLink scaling factor of ~75%
//! at 8 GPUs (Fig. 4) pins NCCL/NVLink at tens of GB/s. See
//! `calibration_matches_paper_worked_example` below and EXPERIMENTS.md.

pub mod cost;
pub mod drift;
pub mod hierarchy;

pub use cost::{CollectiveCost, CostModel};
pub use drift::NetScenario;
pub use hierarchy::{HierCost, RouteDepth, ThreeLevelFabric, TwoLevelFabric};

/// A communication fabric: per-message latency + effective bandwidth +
/// shared-bus contention.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fabric {
    pub name: &'static str,
    /// Per-message latency in seconds (software stack + link).
    pub alpha: f64,
    /// Effective bus bandwidth in bytes/second at 2 workers.
    pub beta: f64,
    /// Shared-medium contention exponent: effective bandwidth for `w`
    /// workers is `beta / (w/2)^contention`. PCIe rings cross one host
    /// complex (MPI staging through host memory), so bandwidth degrades as
    /// workers multiply; NVLink links are point-to-point (0). Calibrated so
    /// the FP32 8-GPU PCIe scaling lands near the paper's Fig. 4 baseline.
    pub contention: f64,
}

impl Fabric {
    /// PCIe 3.0 ×16 with MPI (no GPUDirect): gradients are staged through
    /// host memory and reduced on CPU, which is what Horovod's MPI path did
    /// on the paper's testbed. Effective throughput calibrated to the
    /// paper's §3.2 worked example (66 ms for 102.4 MB, 2 GPUs).
    pub fn pcie() -> Fabric {
        Fabric {
            name: "pcie",
            alpha: 30e-6,
            beta: 1.55e9,
            contention: 0.36,
        }
    }

    /// NVLink with NCCL2: V100 hybrid-cube-mesh. α includes Horovod's
    /// per-operation coordination/launch cost (~25 µs), which is what makes
    /// 161 layer-wise NCCL calls expensive even on NVLink and pins the FP32
    /// ResNet50/CIFAR10 8-GPU scaling at ~75% (paper §5.1). β is the
    /// effective NCCL ring bandwidth (tens of GB/s).
    pub fn nvlink() -> Fabric {
        Fabric {
            name: "nvlink",
            alpha: 25e-6,
            beta: 6.0e10,
            contention: 0.0,
        }
    }

    /// Datacenter TCP (10 GbE class): the inter-node level of a two-level
    /// fabric. α covers the kernel/network stack round-trip; β is the
    /// effective single-stream socket throughput; the shared ToR uplink
    /// congests mildly as more node pairs talk.
    pub fn tcp() -> Fabric {
        Fabric {
            name: "tcp",
            alpha: 50e-6,
            beta: 1.18e9,
            contention: 0.15,
        }
    }

    /// Cross-site / cross-region link ("WAN-ish"): long round trips and a
    /// thin effective pipe — the third level of a
    /// [`ThreeLevelFabric`](hierarchy::ThreeLevelFabric), above TCP.
    pub fn wan() -> Fabric {
        Fabric {
            name: "wan",
            alpha: 1.5e-3,
            beta: 1.25e8,
            contention: 0.1,
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Fabric> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "pcie" => Fabric::pcie(),
            "nvlink" => Fabric::nvlink(),
            "tcp" | "ethernet" | "10gbe" => Fabric::tcp(),
            "wan" => Fabric::wan(),
            other => anyhow::bail!("unknown fabric '{other}' (pcie|nvlink|tcp|wan)"),
        })
    }

    /// Custom fabric for ablations.
    pub fn custom(alpha: f64, beta: f64) -> Fabric {
        Fabric {
            name: "custom",
            alpha,
            beta,
            contention: 0.0,
        }
    }

    /// Effective bandwidth once `world` workers share the medium.
    pub fn beta_eff(&self, world: usize) -> f64 {
        let w = (world as f64 / 2.0).max(1.0);
        self.beta / w.powf(self.contention)
    }

    /// Point-to-point transfer time for `bytes`.
    pub fn p2p(&self, bytes: usize) -> f64 {
        self.alpha + bytes as f64 / self.beta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_monotone_in_bytes() {
        let f = Fabric::pcie();
        assert!(f.p2p(1000) < f.p2p(10_000));
        assert!(f.p2p(0) == f.alpha);
    }

    #[test]
    fn nvlink_faster_than_pcie() {
        let p = Fabric::pcie();
        let n = Fabric::nvlink();
        for bytes in [1usize << 10, 1 << 20, 100 << 20] {
            assert!(n.p2p(bytes) < p.p2p(bytes));
        }
    }

    #[test]
    fn from_name_roundtrip() {
        assert_eq!(Fabric::from_name("pcie").unwrap(), Fabric::pcie());
        assert_eq!(Fabric::from_name("NVLink").unwrap(), Fabric::nvlink());
        assert_eq!(Fabric::from_name("tcp").unwrap(), Fabric::tcp());
        assert_eq!(Fabric::from_name("ethernet").unwrap(), Fabric::tcp());
        assert!(Fabric::from_name("infiniband").is_err());
    }

    #[test]
    fn wan_is_slower_than_every_other_level() {
        let w = Fabric::wan();
        for bytes in [1usize << 10, 1 << 20, 100 << 20] {
            assert!(w.p2p(bytes) > Fabric::tcp().p2p(bytes));
            assert!(w.p2p(bytes) > Fabric::nvlink().p2p(bytes));
        }
        assert_eq!(Fabric::from_name("wan").unwrap(), Fabric::wan());
    }

    #[test]
    fn tcp_is_the_slow_level() {
        // The inter-node fabric must be slower than both intra classes at
        // bulk sizes — that ordering is what the two-level exchange
        // (netsim::hierarchy) exploits.
        let t = Fabric::tcp();
        for bytes in [1usize << 20, 100 << 20] {
            assert!(t.p2p(bytes) > Fabric::nvlink().p2p(bytes));
            assert!(t.p2p(bytes) > Fabric::pcie().p2p(bytes));
        }
    }
}
