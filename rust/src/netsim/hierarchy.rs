//! Two-level fabric models: a fast intra-node link class (NVLink/PCIe)
//! under a slow inter-node one (TCP), and the analytic costs of running
//! either the **flat ring** or the **two-level exchange**
//! (`collectives::hierarchical`) across them.
//!
//! The flat ring's cost on a hierarchical fabric is gated by its slowest
//! link: with contiguous node blocks, `nodes` of the ring's hops cross the
//! inter-node fabric, and since every rank advances in lockstep, all
//! `2·(w−1)` steps pay the slow link's latency and bandwidth. The
//! two-level exchange instead pays the slow level only for a ring over the
//! `L` node leaders — `2·(L−1)` steps on `1/L`-sized chunks — which is why
//! hierarchical collectives keep the paper's scaling-factor story alive
//! off the single-box testbed. `benches/hierarchy.rs` emits these
//! predictions next to the measured inter-node byte counts
//! (`results/BENCH_hierarchy.json`).

use super::Fabric;
use crate::compression::{CodecKind, Collective};

/// A two-level fabric: `nodes` machines, each hosting a contiguous block
/// of ranks wired by `intra`, with the machines connected by `inter`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TwoLevelFabric {
    pub intra: Fabric,
    pub inter: Fabric,
    pub nodes: usize,
}

/// Predicted cost of one collective on a [`TwoLevelFabric`].
#[derive(Debug, Clone, Copy)]
pub struct HierCost {
    /// End-to-end seconds (intra + inter stages, serialized).
    pub seconds: f64,
    /// Seconds attributable to the intra-node level.
    pub intra_secs: f64,
    /// Seconds attributable to the inter-node level.
    pub inter_secs: f64,
    /// Total bytes crossing the inter-node fabric (summed over all links).
    pub inter_bytes: f64,
}

impl TwoLevelFabric {
    pub fn new(intra: Fabric, inter: Fabric, nodes: usize) -> TwoLevelFabric {
        assert!(nodes >= 1);
        TwoLevelFabric { intra, inter, nodes }
    }

    /// The headline multi-node scenario: NVLink inside each box, TCP
    /// between boxes.
    pub fn nvlink_tcp(nodes: usize) -> TwoLevelFabric {
        TwoLevelFabric::new(Fabric::nvlink(), Fabric::tcp(), nodes)
    }

    /// PCIe boxes over TCP (the paper's MPI testbed, scaled out).
    pub fn pcie_tcp(nodes: usize) -> TwoLevelFabric {
        TwoLevelFabric::new(Fabric::pcie(), Fabric::tcp(), nodes)
    }

    /// Largest node size under contiguous near-even placement.
    fn max_node_size(&self, world: usize) -> f64 {
        (world as f64 / self.nodes as f64).ceil()
    }

    /// Flat ring allreduce of `bytes` on this fabric: every one of the
    /// `2·(w−1)` lockstep steps is gated by the slowest link in the ring.
    pub fn flat_allreduce(&self, world: usize, bytes: f64) -> HierCost {
        if world <= 1 {
            return HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
        }
        let w = world as f64;
        let steps = 2.0 * (w - 1.0);
        let chunk = bytes / w;
        let step_secs = if self.nodes > 1 {
            let slow = self.inter.alpha + chunk / self.inter.beta_eff(self.nodes);
            let fast = self.intra.alpha + chunk / self.intra.beta_eff(world);
            slow.max(fast)
        } else {
            self.intra.alpha + chunk / self.intra.beta_eff(world)
        };
        let inter_bytes = if self.nodes > 1 {
            self.nodes as f64 * steps * chunk
        } else {
            0.0
        };
        let seconds = steps * step_secs;
        HierCost {
            seconds,
            intra_secs: if self.nodes > 1 { 0.0 } else { seconds },
            inter_secs: if self.nodes > 1 { seconds } else { 0.0 },
            inter_bytes,
        }
    }

    /// Two-level allreduce of `bytes`: serialized member→leader fan-in,
    /// a ring over the `nodes` leaders, serialized leader→member fan-out.
    pub fn hier_allreduce(&self, world: usize, bytes: f64) -> HierCost {
        if world <= 1 {
            return HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
        }
        let l = self.nodes as f64;
        let m = self.max_node_size(world);
        // Fan-in and fan-out each move (m−1) full buffers over intra links.
        let intra_secs = 2.0 * (m - 1.0) * (self.intra.alpha + bytes / self.intra.beta);
        let (inter_secs, inter_bytes) = if self.nodes > 1 {
            let steps = 2.0 * (l - 1.0);
            let chunk = bytes / l;
            (
                steps * (self.inter.alpha + chunk / self.inter.beta_eff(self.nodes)),
                l * steps * chunk,
            )
        } else {
            (0.0, 0.0)
        };
        HierCost {
            seconds: intra_secs + inter_secs,
            intra_secs,
            inter_secs,
            inter_bytes,
        }
    }

    /// Flat ring allgather where every rank contributes `bytes_per_rank`.
    pub fn flat_allgather(&self, world: usize, bytes_per_rank: f64) -> HierCost {
        if world <= 1 {
            return HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
        }
        let w = world as f64;
        let steps = w - 1.0;
        let step_secs = if self.nodes > 1 {
            let slow = self.inter.alpha + bytes_per_rank / self.inter.beta_eff(self.nodes);
            let fast = self.intra.alpha + bytes_per_rank / self.intra.beta_eff(world);
            slow.max(fast)
        } else {
            self.intra.alpha + bytes_per_rank / self.intra.beta_eff(world)
        };
        let inter_bytes = if self.nodes > 1 {
            self.nodes as f64 * steps * bytes_per_rank
        } else {
            0.0
        };
        let seconds = steps * step_secs;
        HierCost {
            seconds,
            intra_secs: if self.nodes > 1 { 0.0 } else { seconds },
            inter_secs: if self.nodes > 1 { seconds } else { 0.0 },
            inter_bytes,
        }
    }

    /// Two-level allgather: member payloads fan in to the leader, leaders
    /// ring-exchange node frames (`m·s` bytes each), the full table
    /// (`w·s` bytes) fans back out.
    pub fn hier_allgather(&self, world: usize, bytes_per_rank: f64) -> HierCost {
        if world <= 1 {
            return HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
        }
        let l = self.nodes as f64;
        let m = self.max_node_size(world);
        let w = world as f64;
        let fan_in = (m - 1.0) * (self.intra.alpha + bytes_per_rank / self.intra.beta);
        let fan_out = (m - 1.0) * (self.intra.alpha + w * bytes_per_rank / self.intra.beta);
        let (inter_secs, inter_bytes) = if self.nodes > 1 {
            let frame = m * bytes_per_rank;
            let steps = l - 1.0;
            (
                steps * (self.inter.alpha + frame / self.inter.beta_eff(self.nodes)),
                l * steps * frame,
            )
        } else {
            (0.0, 0.0)
        };
        HierCost {
            seconds: fan_in + fan_out + inter_secs,
            intra_secs: fan_in + fan_out,
            inter_secs,
            inter_bytes,
        }
    }

    /// Predicted (flat, two-level) cost of synchronizing an `elems`-element
    /// group compressed with `kind` — the collective follows paper Table 1,
    /// the wire size is the codec's exact one.
    pub fn group_comm(&self, kind: CodecKind, world: usize, elems: usize) -> (HierCost, HierCost) {
        let wire = kind.wire_size(elems) as f64;
        match kind.collective() {
            Collective::AllReduce => (
                self.flat_allreduce(world, wire),
                self.hier_allreduce(world, wire),
            ),
            Collective::AllGather => (
                self.flat_allgather(world, wire),
                self.hier_allgather(world, wire),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> TwoLevelFabric {
        TwoLevelFabric::nvlink_tcp(2)
    }

    #[test]
    fn hierarchical_beats_flat_when_inter_is_slow() {
        let f = fabric();
        let world = 8;
        for bytes in [1e6, 25.6e6, 400e6] {
            let flat = f.flat_allreduce(world, bytes);
            let hier = f.hier_allreduce(world, bytes);
            assert!(
                hier.seconds < flat.seconds,
                "{bytes}B allreduce: hier {} vs flat {}",
                hier.seconds,
                flat.seconds
            );
            let flat = f.flat_allgather(world, bytes / world as f64);
            let hier = f.hier_allgather(world, bytes / world as f64);
            assert!(
                hier.seconds < flat.seconds,
                "{bytes}B allgather: hier {} vs flat {}",
                hier.seconds,
                flat.seconds
            );
        }
    }

    #[test]
    fn hierarchical_moves_fewer_inter_node_bytes() {
        let f = fabric();
        let world = 8;
        let bytes = 100e6;
        // Flat ring: 2 boundary links × 2·(w−1)·S/w each = 3.5·S.
        let flat = f.flat_allreduce(world, bytes);
        assert!((flat.inter_bytes - 3.5 * bytes).abs() / bytes < 1e-9);
        // Leader ring: 2 leaders × 2·(L−1)/L·S each = 2·S.
        let hier = f.hier_allreduce(world, bytes);
        assert!((hier.inter_bytes - 2.0 * bytes).abs() / bytes < 1e-9);
        assert!(hier.inter_bytes < flat.inter_bytes);

        // Allgather: flat crosses each boundary (w−1)·s times; the leader
        // ring moves (L−1) node frames of m·s per leader.
        let s = 1e6;
        let flat = f.flat_allgather(world, s);
        assert!((flat.inter_bytes - 2.0 * 7.0 * s).abs() / s < 1e-9);
        let hier = f.hier_allgather(world, s);
        assert!((hier.inter_bytes - 2.0 * 4.0 * s).abs() / s < 1e-9);
        assert!(hier.inter_bytes < flat.inter_bytes);
    }

    #[test]
    fn single_node_degenerates_to_intra_only() {
        let f = TwoLevelFabric::new(Fabric::nvlink(), Fabric::tcp(), 1);
        let c = f.flat_allreduce(8, 1e6);
        assert_eq!(c.inter_bytes, 0.0);
        assert_eq!(c.inter_secs, 0.0);
        assert!(c.intra_secs > 0.0);
        let h = f.hier_allreduce(8, 1e6);
        assert_eq!(h.inter_bytes, 0.0);
        // Solo world costs nothing.
        assert_eq!(f.flat_allgather(1, 1e6).seconds, 0.0);
        assert_eq!(f.hier_allgather(1, 1e6).seconds, 0.0);
    }

    #[test]
    fn group_comm_picks_the_table_1_collective() {
        let f = fabric();
        let (flat_ar, hier_ar) = f.group_comm(CodecKind::Fp32, 8, 1 << 20);
        let (flat_ag, hier_ag) = f.group_comm(CodecKind::EfSignSgd, 8, 1 << 20);
        // Compressed payloads are ~32x smaller; every cost must reflect it.
        assert!(flat_ag.seconds < flat_ar.seconds / 4.0);
        assert!(hier_ag.seconds < hier_ar.seconds / 4.0);
    }

    #[test]
    fn non_divisible_worlds_use_the_ceiling_node_size() {
        let f = TwoLevelFabric::nvlink_tcp(4);
        // world=6 over 4 nodes: 2+2+1+1 — the fan-in serializes over the
        // largest node (2 ranks ⇒ 1 transfer).
        let c = f.hier_allreduce(6, 1e6);
        assert!(c.intra_secs > 0.0);
        assert!(c.inter_secs > 0.0);
    }
}
