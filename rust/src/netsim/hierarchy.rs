//! Two-level fabric models: a fast intra-node link class (NVLink/PCIe)
//! under a slow inter-node one (TCP), and the analytic costs of running
//! either the **flat ring** or the **two-level exchange**
//! (`collectives::hierarchical`) across them.
//!
//! The flat ring's cost on a hierarchical fabric is gated by its slowest
//! link: with contiguous node blocks, `nodes` of the ring's hops cross the
//! inter-node fabric, and since every rank advances in lockstep, all
//! `2·(w−1)` steps pay the slow link's latency and bandwidth. The
//! two-level exchange instead pays the slow level only for a ring over the
//! `L` node leaders — `2·(L−1)` steps on `1/L`-sized chunks — which is why
//! hierarchical collectives keep the paper's scaling-factor story alive
//! off the single-box testbed. `benches/hierarchy.rs` emits these
//! predictions next to the measured inter-node byte counts
//! (`results/BENCH_hierarchy.json`).

use super::Fabric;
use crate::compression::{CodecKind, Collective};

/// A two-level fabric: `nodes` machines, each hosting a contiguous block
/// of ranks wired by `intra`, with the machines connected by `inter`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TwoLevelFabric {
    pub intra: Fabric,
    pub inter: Fabric,
    pub nodes: usize,
}

/// Predicted cost of one collective on a [`TwoLevelFabric`].
#[derive(Debug, Clone, Copy)]
pub struct HierCost {
    /// End-to-end seconds (intra + inter stages, serialized).
    pub seconds: f64,
    /// Seconds attributable to the intra-node level.
    pub intra_secs: f64,
    /// Seconds attributable to the inter-node level.
    pub inter_secs: f64,
    /// Total bytes crossing the inter-node fabric (summed over all links).
    pub inter_bytes: f64,
}

impl TwoLevelFabric {
    pub fn new(intra: Fabric, inter: Fabric, nodes: usize) -> TwoLevelFabric {
        assert!(nodes >= 1);
        TwoLevelFabric { intra, inter, nodes }
    }

    /// The headline multi-node scenario: NVLink inside each box, TCP
    /// between boxes.
    pub fn nvlink_tcp(nodes: usize) -> TwoLevelFabric {
        TwoLevelFabric::new(Fabric::nvlink(), Fabric::tcp(), nodes)
    }

    /// PCIe boxes over TCP (the paper's MPI testbed, scaled out).
    pub fn pcie_tcp(nodes: usize) -> TwoLevelFabric {
        TwoLevelFabric::new(Fabric::pcie(), Fabric::tcp(), nodes)
    }

    /// Largest node size under contiguous near-even placement.
    fn max_node_size(&self, world: usize) -> f64 {
        (world as f64 / self.nodes as f64).ceil()
    }

    /// Flat ring allreduce of `bytes` on this fabric: every one of the
    /// `2·(w−1)` lockstep steps is gated by the slowest link in the ring.
    pub fn flat_allreduce(&self, world: usize, bytes: f64) -> HierCost {
        if world <= 1 {
            return HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
        }
        let w = world as f64;
        let steps = 2.0 * (w - 1.0);
        let chunk = bytes / w;
        let step_secs = if self.nodes > 1 {
            let slow = self.inter.alpha + chunk / self.inter.beta_eff(self.nodes);
            let fast = self.intra.alpha + chunk / self.intra.beta_eff(world);
            slow.max(fast)
        } else {
            self.intra.alpha + chunk / self.intra.beta_eff(world)
        };
        let inter_bytes = if self.nodes > 1 {
            self.nodes as f64 * steps * chunk
        } else {
            0.0
        };
        let seconds = steps * step_secs;
        HierCost {
            seconds,
            intra_secs: if self.nodes > 1 { 0.0 } else { seconds },
            inter_secs: if self.nodes > 1 { seconds } else { 0.0 },
            inter_bytes,
        }
    }

    /// Two-level allreduce of `bytes`: serialized member→leader fan-in,
    /// a ring over the `nodes` leaders, serialized leader→member fan-out.
    pub fn hier_allreduce(&self, world: usize, bytes: f64) -> HierCost {
        if world <= 1 {
            return HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
        }
        let l = self.nodes as f64;
        let m = self.max_node_size(world);
        // Fan-in and fan-out each move (m−1) full buffers over intra links.
        let intra_secs = 2.0 * (m - 1.0) * (self.intra.alpha + bytes / self.intra.beta);
        let (inter_secs, inter_bytes) = if self.nodes > 1 {
            let steps = 2.0 * (l - 1.0);
            let chunk = bytes / l;
            (
                steps * (self.inter.alpha + chunk / self.inter.beta_eff(self.nodes)),
                l * steps * chunk,
            )
        } else {
            (0.0, 0.0)
        };
        HierCost {
            seconds: intra_secs + inter_secs,
            intra_secs,
            inter_secs,
            inter_bytes,
        }
    }

    /// Flat ring allgather where every rank contributes `bytes_per_rank`.
    pub fn flat_allgather(&self, world: usize, bytes_per_rank: f64) -> HierCost {
        if world <= 1 {
            return HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
        }
        let w = world as f64;
        let steps = w - 1.0;
        let step_secs = if self.nodes > 1 {
            let slow = self.inter.alpha + bytes_per_rank / self.inter.beta_eff(self.nodes);
            let fast = self.intra.alpha + bytes_per_rank / self.intra.beta_eff(world);
            slow.max(fast)
        } else {
            self.intra.alpha + bytes_per_rank / self.intra.beta_eff(world)
        };
        let inter_bytes = if self.nodes > 1 {
            self.nodes as f64 * steps * bytes_per_rank
        } else {
            0.0
        };
        let seconds = steps * step_secs;
        HierCost {
            seconds,
            intra_secs: if self.nodes > 1 { 0.0 } else { seconds },
            inter_secs: if self.nodes > 1 { seconds } else { 0.0 },
            inter_bytes,
        }
    }

    /// Two-level allgather: member payloads fan in to the leader, leaders
    /// ring-exchange node frames (`m·s` bytes each), the full table
    /// (`w·s` bytes) fans back out.
    pub fn hier_allgather(&self, world: usize, bytes_per_rank: f64) -> HierCost {
        if world <= 1 {
            return HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
        }
        let l = self.nodes as f64;
        let m = self.max_node_size(world);
        let w = world as f64;
        let fan_in = (m - 1.0) * (self.intra.alpha + bytes_per_rank / self.intra.beta);
        let fan_out = (m - 1.0) * (self.intra.alpha + w * bytes_per_rank / self.intra.beta);
        let (inter_secs, inter_bytes) = if self.nodes > 1 {
            let frame = m * bytes_per_rank;
            let steps = l - 1.0;
            (
                steps * (self.inter.alpha + frame / self.inter.beta_eff(self.nodes)),
                l * steps * frame,
            )
        } else {
            (0.0, 0.0)
        };
        HierCost {
            seconds: fan_in + fan_out + inter_secs,
            intra_secs: fan_in + fan_out,
            inter_secs,
            inter_bytes,
        }
    }

    /// Predicted (flat, two-level) cost of synchronizing an `elems`-element
    /// group compressed with `kind` — the collective follows paper Table 1,
    /// the wire size is the codec's exact one.
    pub fn group_comm(&self, kind: CodecKind, world: usize, elems: usize) -> (HierCost, HierCost) {
        let wire = kind.wire_size(elems) as f64;
        match kind.collective() {
            Collective::AllReduce => (
                self.flat_allreduce(world, wire),
                self.hier_allreduce(world, wire),
            ),
            Collective::AllGather => (
                self.flat_allgather(world, wire),
                self.hier_allgather(world, wire),
            ),
        }
    }
}

/// How deep a collective recurses over a [`ThreeLevelFabric`]'s hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteDepth {
    /// One flat ring over all ranks — every step gated by the WAN.
    Flat,
    /// Node fan-in, then a ring over **all** node leaders (which still
    /// crosses the WAN on every lap).
    TwoLevel,
    /// Node fan-in, rack fan-in, then a ring over the rack leaders only —
    /// the WAN carries just `2·(R−1)` chunked steps.
    ThreeLevel,
}

/// A three-level fabric: `racks` racks of `nodes_per_rack` nodes, each
/// node a contiguous block of ranks wired by `intra`; nodes within a rack
/// talk over `inter`, racks over `wan` — the NVLink × TCP × WAN-ish stack
/// the N-level topology (`nodes=…;racks=…`) routes over.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThreeLevelFabric {
    pub intra: Fabric,
    pub inter: Fabric,
    pub wan: Fabric,
    pub nodes_per_rack: usize,
    pub racks: usize,
}

impl ThreeLevelFabric {
    pub fn new(
        intra: Fabric,
        inter: Fabric,
        wan: Fabric,
        nodes_per_rack: usize,
        racks: usize,
    ) -> ThreeLevelFabric {
        assert!(nodes_per_rack >= 1 && racks >= 1);
        ThreeLevelFabric {
            intra,
            inter,
            wan,
            nodes_per_rack,
            racks,
        }
    }

    /// The headline geo-distributed scenario: NVLink inside each box, TCP
    /// inside each rack, a WAN-class link between racks.
    pub fn nvlink_tcp_wan(nodes_per_rack: usize, racks: usize) -> ThreeLevelFabric {
        ThreeLevelFabric::new(Fabric::nvlink(), Fabric::tcp(), Fabric::wan(), nodes_per_rack, racks)
    }

    fn num_nodes(&self) -> usize {
        self.nodes_per_rack * self.racks
    }

    /// Ranks per node under contiguous near-even placement.
    fn ranks_per_node(&self, world: usize) -> f64 {
        (world as f64 / self.num_nodes() as f64).ceil()
    }

    /// Allreduce of `bytes` at the given recursion depth. `inter_secs` /
    /// `inter_bytes` account the **WAN** level (the slowest link class).
    pub fn allreduce(&self, world: usize, bytes: f64, depth: RouteDepth) -> HierCost {
        if world <= 1 {
            return HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
        }
        let w = world as f64;
        let m = self.ranks_per_node(world);
        let l = self.num_nodes() as f64;
        let npr = self.nodes_per_rack as f64;
        let r = self.racks as f64;
        let multi_rack = self.racks > 1;
        // A ring that spans racks is gated by the WAN on every lockstep
        // step; a single-rack ring is gated by the rack fabric.
        let ring = |steps: f64, chunk: f64| -> (f64, f64) {
            if multi_rack {
                let secs = steps * (self.wan.alpha + chunk / self.wan.beta_eff(self.racks));
                (secs, r * steps * chunk)
            } else {
                (steps * (self.inter.alpha + chunk / self.inter.beta_eff(world)), 0.0)
            }
        };
        let node_fan = 2.0 * (m - 1.0) * (self.intra.alpha + bytes / self.intra.beta);
        match depth {
            RouteDepth::Flat => {
                let (secs, wan_bytes) = ring(2.0 * (w - 1.0), bytes / w);
                HierCost {
                    seconds: secs,
                    intra_secs: 0.0,
                    inter_secs: if multi_rack { secs } else { 0.0 },
                    inter_bytes: wan_bytes,
                }
            }
            RouteDepth::TwoLevel => {
                let (ring_secs, wan_bytes) = ring(2.0 * (l - 1.0), bytes / l);
                HierCost {
                    seconds: node_fan + ring_secs,
                    intra_secs: node_fan,
                    inter_secs: if multi_rack { ring_secs } else { 0.0 },
                    inter_bytes: wan_bytes,
                }
            }
            RouteDepth::ThreeLevel => {
                let rack_fan =
                    2.0 * (npr - 1.0) * (self.inter.alpha + bytes / self.inter.beta);
                let (wan_secs, wan_bytes) = if multi_rack {
                    let steps = 2.0 * (r - 1.0);
                    let chunk = bytes / r;
                    (
                        steps * (self.wan.alpha + chunk / self.wan.beta_eff(self.racks)),
                        r * steps * chunk,
                    )
                } else {
                    (0.0, 0.0)
                };
                HierCost {
                    seconds: node_fan + rack_fan + wan_secs,
                    intra_secs: node_fan + rack_fan,
                    inter_secs: wan_secs,
                    inter_bytes: wan_bytes,
                }
            }
        }
    }

    /// Allgather where every rank contributes `bytes_per_rank`, at the
    /// given recursion depth. WAN accounting as in
    /// [`ThreeLevelFabric::allreduce`].
    pub fn allgather(&self, world: usize, bytes_per_rank: f64) -> [HierCost; 3] {
        if world <= 1 {
            let z = HierCost { seconds: 0.0, intra_secs: 0.0, inter_secs: 0.0, inter_bytes: 0.0 };
            return [z, z, z];
        }
        let s = bytes_per_rank;
        let w = world as f64;
        let m = self.ranks_per_node(world);
        let l = self.num_nodes() as f64;
        let npr = self.nodes_per_rack as f64;
        let r = self.racks as f64;
        let multi_rack = self.racks > 1;
        let ring = |steps: f64, frame: f64| -> (f64, f64) {
            if multi_rack {
                let secs = steps * (self.wan.alpha + frame / self.wan.beta_eff(self.racks));
                (secs, r * steps * frame)
            } else {
                (steps * (self.inter.alpha + frame / self.inter.beta_eff(world)), 0.0)
            }
        };
        let node_fan = (m - 1.0) * (self.intra.alpha + s / self.intra.beta)
            + (m - 1.0) * (self.intra.alpha + w * s / self.intra.beta);
        // Flat.
        let (secs, wan_bytes) = ring(w - 1.0, s);
        let flat = HierCost {
            seconds: secs,
            intra_secs: 0.0,
            inter_secs: if multi_rack { secs } else { 0.0 },
            inter_bytes: wan_bytes,
        };
        // Two-level: node-frame ring over all node leaders.
        let (ring_secs, wan_bytes) = ring(l - 1.0, m * s);
        let two = HierCost {
            seconds: node_fan + ring_secs,
            intra_secs: node_fan,
            inter_secs: if multi_rack { ring_secs } else { 0.0 },
            inter_bytes: wan_bytes,
        };
        // Three-level: rack fan-in of node frames + full-table fan-out,
        // rack-frame ring over rack leaders only.
        let rack_fan = (npr - 1.0) * (self.inter.alpha + m * s / self.inter.beta)
            + (npr - 1.0) * (self.inter.alpha + w * s / self.inter.beta);
        let (wan_secs, wan_bytes) = if multi_rack {
            let steps = r - 1.0;
            let frame = w / r * s;
            (
                steps * (self.wan.alpha + frame / self.wan.beta_eff(self.racks)),
                r * steps * frame,
            )
        } else {
            (0.0, 0.0)
        };
        let three = HierCost {
            seconds: node_fan + rack_fan + wan_secs,
            intra_secs: node_fan + rack_fan,
            inter_secs: wan_secs,
            inter_bytes: wan_bytes,
        };
        [flat, two, three]
    }

    /// Predicted cost of synchronizing an `elems`-element group compressed
    /// with `kind` at each recursion depth (`[flat, two, three]`).
    pub fn group_comm(&self, kind: CodecKind, world: usize, elems: usize) -> [HierCost; 3] {
        let wire = kind.wire_size(elems) as f64;
        match kind.collective() {
            Collective::AllReduce => [
                self.allreduce(world, wire, RouteDepth::Flat),
                self.allreduce(world, wire, RouteDepth::TwoLevel),
                self.allreduce(world, wire, RouteDepth::ThreeLevel),
            ],
            Collective::AllGather => self.allgather(world, wire),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> TwoLevelFabric {
        TwoLevelFabric::nvlink_tcp(2)
    }

    #[test]
    fn hierarchical_beats_flat_when_inter_is_slow() {
        let f = fabric();
        let world = 8;
        for bytes in [1e6, 25.6e6, 400e6] {
            let flat = f.flat_allreduce(world, bytes);
            let hier = f.hier_allreduce(world, bytes);
            assert!(
                hier.seconds < flat.seconds,
                "{bytes}B allreduce: hier {} vs flat {}",
                hier.seconds,
                flat.seconds
            );
            let flat = f.flat_allgather(world, bytes / world as f64);
            let hier = f.hier_allgather(world, bytes / world as f64);
            assert!(
                hier.seconds < flat.seconds,
                "{bytes}B allgather: hier {} vs flat {}",
                hier.seconds,
                flat.seconds
            );
        }
    }

    #[test]
    fn hierarchical_moves_fewer_inter_node_bytes() {
        let f = fabric();
        let world = 8;
        let bytes = 100e6;
        // Flat ring: 2 boundary links × 2·(w−1)·S/w each = 3.5·S.
        let flat = f.flat_allreduce(world, bytes);
        assert!((flat.inter_bytes - 3.5 * bytes).abs() / bytes < 1e-9);
        // Leader ring: 2 leaders × 2·(L−1)/L·S each = 2·S.
        let hier = f.hier_allreduce(world, bytes);
        assert!((hier.inter_bytes - 2.0 * bytes).abs() / bytes < 1e-9);
        assert!(hier.inter_bytes < flat.inter_bytes);

        // Allgather: flat crosses each boundary (w−1)·s times; the leader
        // ring moves (L−1) node frames of m·s per leader.
        let s = 1e6;
        let flat = f.flat_allgather(world, s);
        assert!((flat.inter_bytes - 2.0 * 7.0 * s).abs() / s < 1e-9);
        let hier = f.hier_allgather(world, s);
        assert!((hier.inter_bytes - 2.0 * 4.0 * s).abs() / s < 1e-9);
        assert!(hier.inter_bytes < flat.inter_bytes);
    }

    #[test]
    fn single_node_degenerates_to_intra_only() {
        let f = TwoLevelFabric::new(Fabric::nvlink(), Fabric::tcp(), 1);
        let c = f.flat_allreduce(8, 1e6);
        assert_eq!(c.inter_bytes, 0.0);
        assert_eq!(c.inter_secs, 0.0);
        assert!(c.intra_secs > 0.0);
        let h = f.hier_allreduce(8, 1e6);
        assert_eq!(h.inter_bytes, 0.0);
        // Solo world costs nothing.
        assert_eq!(f.flat_allgather(1, 1e6).seconds, 0.0);
        assert_eq!(f.hier_allgather(1, 1e6).seconds, 0.0);
    }

    #[test]
    fn group_comm_picks_the_table_1_collective() {
        let f = fabric();
        let (flat_ar, hier_ar) = f.group_comm(CodecKind::Fp32, 8, 1 << 20);
        let (flat_ag, hier_ag) = f.group_comm(CodecKind::EfSignSgd, 8, 1 << 20);
        // Compressed payloads are ~32x smaller; every cost must reflect it.
        assert!(flat_ag.seconds < flat_ar.seconds / 4.0);
        assert!(hier_ag.seconds < hier_ar.seconds / 4.0);
    }

    #[test]
    fn three_level_recursion_pays_off_iff_the_wan_gap_is_real() {
        // 8 ranks, 2 racks × 2 nodes × 2 ranks, NVLink × TCP × WAN.
        let f = ThreeLevelFabric::nvlink_tcp_wan(2, 2);
        let world = 8;
        for bytes in [10e6, 100e6, 400e6] {
            let flat = f.allreduce(world, bytes, RouteDepth::Flat);
            let two = f.allreduce(world, bytes, RouteDepth::TwoLevel);
            let three = f.allreduce(world, bytes, RouteDepth::ThreeLevel);
            assert!(two.seconds < flat.seconds, "{bytes}B: two {two:?} vs flat {flat:?}");
            assert!(three.seconds < two.seconds, "{bytes}B: three {three:?} vs two {two:?}");
            assert!(three.inter_bytes < two.inter_bytes);
            assert!(two.inter_bytes < flat.inter_bytes);
            let [ag_flat, ag_two, ag_three] = f.allgather(world, bytes / world as f64);
            assert!(ag_three.seconds < ag_two.seconds && ag_two.seconds < ag_flat.seconds);
        }
        // Flip the gap: with the "WAN" as fast as the rack fabric, the
        // extra rack stage is pure overhead and two-level wins — the
        // ordering the route search must track.
        let no_gap = ThreeLevelFabric::new(Fabric::nvlink(), Fabric::tcp(), Fabric::tcp(), 2, 2);
        for bytes in [10e6, 100e6] {
            let two = no_gap.allreduce(world, bytes, RouteDepth::TwoLevel);
            let three = no_gap.allreduce(world, bytes, RouteDepth::ThreeLevel);
            assert!(
                two.seconds < three.seconds,
                "{bytes}B without a gap: two {} vs three {}",
                two.seconds,
                three.seconds
            );
        }
    }

    #[test]
    fn three_level_group_comm_single_rack_degenerates() {
        let f = ThreeLevelFabric::new(Fabric::nvlink(), Fabric::tcp(), Fabric::wan(), 2, 1);
        let c = f.allreduce(4, 1e6, RouteDepth::ThreeLevel);
        assert_eq!(c.inter_bytes, 0.0);
        assert_eq!(c.inter_secs, 0.0);
        let [flat, _, _] = f.group_comm(CodecKind::EfSignSgd, 4, 1 << 20);
        assert!(flat.seconds > 0.0);
        assert_eq!(f.allreduce(1, 1e6, RouteDepth::Flat).seconds, 0.0);
    }

    #[test]
    fn non_divisible_worlds_use_the_ceiling_node_size() {
        let f = TwoLevelFabric::nvlink_tcp(4);
        // world=6 over 4 nodes: 2+2+1+1 — the fan-in serializes over the
        // largest node (2 ranks ⇒ 1 transfer).
        let c = f.hier_allreduce(6, 1e6);
        assert!(c.intra_secs > 0.0);
        assert!(c.inter_secs > 0.0);
    }
}
