//! Analytic collective cost functions over a [`Fabric`](super::Fabric).
//!
//! - Ring allreduce (Patarasuk & Yuan 2009):
//!   `2(n−1)·α + 2·(n−1)/n · S/β` for an S-byte dense buffer.
//! - Ring allgather (Thakur et al. 2005):
//!   `(n−1)·α + (n−1)·S/β` where S is the per-rank payload.
//!
//! These are the models NCCL and MPI implementations asymptotically achieve
//! and are the standard analytic substitute for a hardware testbed.

use super::Fabric;
use crate::compression::{CodecKind, Collective};

/// Cost model for one (fabric, world-size) pair.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub fabric: Fabric,
    pub world: usize,
}

/// Breakdown of a collective's predicted time.
#[derive(Debug, Clone, Copy)]
pub struct CollectiveCost {
    pub seconds: f64,
    pub bytes_per_rank: usize,
}

impl CostModel {
    pub fn new(fabric: Fabric, world: usize) -> Self {
        assert!(world >= 1);
        Self { fabric, world }
    }

    /// Dense allreduce of `bytes` (FP32/FP16 payloads).
    pub fn allreduce(&self, bytes: usize) -> CollectiveCost {
        let n = self.world as f64;
        if self.world == 1 {
            return CollectiveCost {
                seconds: 0.0,
                bytes_per_rank: 0,
            };
        }
        let moved = 2.0 * (n - 1.0) / n * bytes as f64;
        CollectiveCost {
            seconds: 2.0 * (n - 1.0) * self.fabric.alpha
                + moved / self.fabric.beta_eff(self.world),
            bytes_per_rank: moved as usize,
        }
    }

    /// Allgather where every rank contributes `bytes_per_rank`.
    pub fn allgather(&self, bytes_per_rank: usize) -> CollectiveCost {
        let n = self.world as f64;
        if self.world == 1 {
            return CollectiveCost {
                seconds: 0.0,
                bytes_per_rank: 0,
            };
        }
        let moved = (n - 1.0) * bytes_per_rank as f64;
        CollectiveCost {
            seconds: (n - 1.0) * self.fabric.alpha
                + moved / self.fabric.beta_eff(self.world),
            bytes_per_rank: moved as usize,
        }
    }

    /// Communication time for synchronizing an `elems`-element group
    /// compressed with `kind` — picks the collective per paper Table 1 and
    /// charges the codec's exact wire size.
    pub fn group_comm(&self, kind: CodecKind, elems: usize) -> CollectiveCost {
        let wire = kind.wire_size(elems);
        match kind.collective() {
            Collective::AllReduce => self.allreduce(wire),
            Collective::AllGather => self.allgather(wire),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_single_worker_free() {
        let m = CostModel::new(Fabric::pcie(), 1);
        assert_eq!(m.allreduce(1 << 20).seconds, 0.0);
    }

    #[test]
    fn allreduce_bandwidth_term_dominates_large() {
        let m = CostModel::new(Fabric::pcie(), 4);
        let big = m.allreduce(100 << 20);
        // 2*(3/4)*100MiB / beta_eff(4)
        let expect = 2.0 * 0.75 * (100 << 20) as f64 / Fabric::pcie().beta_eff(4);
        assert!((big.seconds - expect).abs() / expect < 0.01);
    }

    #[test]
    fn allgather_scales_with_world() {
        let s = 1 << 20;
        let t2 = CostModel::new(Fabric::pcie(), 2).allgather(s).seconds;
        let t8 = CostModel::new(Fabric::pcie(), 8).allgather(s).seconds;
        assert!(t8 > 3.0 * t2, "allgather grows ~(n-1): {t2} vs {t8}");
    }

    #[test]
    fn latency_term_dominates_small() {
        let m = CostModel::new(Fabric::nvlink(), 8);
        let tiny = m.allreduce(64);
        let expect_alpha = 2.0 * 7.0 * Fabric::nvlink().alpha;
        assert!(tiny.seconds >= expect_alpha);
        assert!(tiny.seconds < expect_alpha * 1.1);
    }

    /// Paper §3.2 worked example: ResNet50 has 25.6M parameters (102.4 MB);
    /// FP32 allreduce between 2 GPUs over PCIe costs ≈66 ms.
    #[test]
    fn calibration_matches_paper_worked_example() {
        let m = CostModel::new(Fabric::pcie(), 2);
        let t = m.allreduce(25_600_000 * 4).seconds;
        assert!(
            (t - 0.066).abs() < 0.005,
            "2-GPU PCIe FP32 ResNet50 comm = {:.1} ms, paper says ~66 ms",
            t * 1e3
        );
    }

    /// Sparsified/1-bit schemes cut the §3.2 communication to < 5 ms.
    #[test]
    fn calibration_compressed_comm_under_5ms() {
        let m = CostModel::new(Fabric::pcie(), 2);
        for kind in [
            CodecKind::Dgc { ratio: 0.01 },
            CodecKind::TopK { ratio: 0.01 },
            CodecKind::EfSignSgd,
            CodecKind::SignSgd,
        ] {
            let t = m.group_comm(kind, 25_600_000).seconds;
            assert!(
                t < 0.005,
                "{}: compressed comm {:.2} ms (paper: <5 ms)",
                kind.name(),
                t * 1e3
            );
        }
    }

    #[test]
    fn group_comm_uses_right_collective() {
        let m = CostModel::new(Fabric::pcie(), 4);
        let n = 1 << 20;
        // FP32: allreduce of 4n bytes. SignSGD: allgather of ~n/8 bytes.
        let fp32 = m.group_comm(CodecKind::Fp32, n);
        let sign = m.group_comm(CodecKind::SignSgd, n);
        assert!(sign.seconds < fp32.seconds / 8.0);
    }
}
