//! Time-varying network scenarios: the conditions the *online* scheduler
//! exists for.
//!
//! The static [`Fabric`](super::Fabric) models a healthy steady-state link.
//! Real clusters drift: a tenant saturates the PCIe switch, a flow gets
//! rerouted, TCP incast collapses the effective bandwidth for seconds at a
//! time. A [`NetScenario`] maps a step index to the fabric in effect at
//! that step, which the simulator-plane validation and
//! `benches/online_resched.rs` use to test whether the scheduler driver
//! tracks the change and the warmup-only baseline does not.

use super::Fabric;

/// A deterministic step-indexed fabric trajectory.
#[derive(Debug, Clone, PartialEq)]
pub enum NetScenario {
    /// No drift (control).
    Static(Fabric),
    /// Abrupt, persistent change at `at_step`: `from` before it, `to`
    /// (complete with its own contention exponent) from it onwards — a
    /// routing change, a new bandwidth hog, a failed link.
    Step {
        from: Fabric,
        to: Fabric,
        at_step: usize,
    },
    /// Periodic congestion: every `period` steps, a burst of `burst_len`
    /// steps runs at degraded bandwidth (`beta_factor < 1`).
    Bursts {
        base: Fabric,
        period: usize,
        burst_len: usize,
        beta_factor: f64,
    },
}

impl NetScenario {
    /// Convenience alias: a step from one named fabric to another.
    pub fn fabric_step(from: Fabric, to: Fabric, at_step: usize) -> NetScenario {
        NetScenario::Step { from, to, at_step }
    }

    /// The fabric in effect at `step`.
    pub fn fabric_at(&self, step: usize) -> Fabric {
        match *self {
            NetScenario::Static(f) => f,
            NetScenario::Step { from, to, at_step } => {
                if step < at_step {
                    from
                } else {
                    to
                }
            }
            NetScenario::Bursts {
                base,
                period,
                burst_len,
                beta_factor,
            } => {
                let period = period.max(1);
                if step % period < burst_len.min(period) {
                    congested(base, beta_factor)
                } else {
                    base
                }
            }
        }
    }

    /// The first step at which the scenario differs from its step-0 fabric
    /// (None for `Static`). The oracle/warmup comparison pivots here.
    pub fn first_change(&self) -> Option<usize> {
        match *self {
            NetScenario::Static(_) => None,
            NetScenario::Step { at_step, .. } => Some(at_step),
            NetScenario::Bursts {
                period, burst_len, ..
            } => {
                // Step 0 starts inside a burst; the first change is when it
                // ends (or when the next burst begins, for burst_len 0).
                if burst_len == 0 {
                    None
                } else {
                    Some(burst_len.min(period.max(1)))
                }
            }
        }
    }
}

/// The base fabric at degraded bandwidth (same link, shared with a hog).
fn congested(base: Fabric, beta_factor: f64) -> Fabric {
    Fabric {
        name: base.name,
        alpha: base.alpha,
        beta: base.beta * beta_factor,
        contention: base.contention,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_never_changes() {
        let s = NetScenario::Static(Fabric::pcie());
        assert_eq!(s.fabric_at(0), s.fabric_at(1_000_000));
        assert_eq!(s.first_change(), None);
    }

    #[test]
    fn step_switches_once_and_persists() {
        let s = NetScenario::Step {
            from: Fabric::nvlink(),
            to: Fabric::pcie(),
            at_step: 100,
        };
        assert_eq!(s.fabric_at(99), Fabric::nvlink());
        assert_eq!(s.fabric_at(100), Fabric::pcie());
        assert_eq!(s.fabric_at(100), s.fabric_at(10_000), "drift persists");
        assert_eq!(s.first_change(), Some(100));
    }

    #[test]
    fn fabric_step_lands_exactly_on_target() {
        // The full target fabric, including its contention exponent — a
        // step to PCIe must model PCIe's multi-worker bandwidth collapse,
        // not NVLink's point-to-point scaling at PCIe's 2-worker rate.
        let s = NetScenario::fabric_step(Fabric::nvlink(), Fabric::pcie(), 5);
        assert_eq!(s.fabric_at(4), Fabric::nvlink());
        assert_eq!(s.fabric_at(5), Fabric::pcie());
        assert!(s.fabric_at(5).beta_eff(8) < Fabric::pcie().beta, "contention applies");
    }

    #[test]
    fn bursts_cycle() {
        let s = NetScenario::Bursts {
            base: Fabric::pcie(),
            period: 10,
            burst_len: 3,
            beta_factor: 0.25,
        };
        for step in 0..30 {
            let f = s.fabric_at(step);
            if step % 10 < 3 {
                assert!(f.beta < Fabric::pcie().beta, "step {step} should be congested");
            } else {
                assert_eq!(f, Fabric::pcie(), "step {step} should be clean");
            }
        }
        assert_eq!(s.first_change(), Some(3));
    }
}
