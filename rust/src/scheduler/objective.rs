//! The model-partition objective F(X_y) — paper Eq. (7):
//!
//! ```text
//! F(X_y) = A + Σ h(x_i) + Σ g(x_i) − Σ p(x_i)
//! ```
//!
//! Algorithm 2 only ever *evaluates* F, so the search is written against the
//! [`Objective`] trait. Two implementations:
//!
//! - [`SimObjective`]: the discrete-event timeline (simulator plane) — this
//!   is F including the overlap term, computed exactly.
//! - [`MeasuredObjective`]: any closure returning a measured mean iteration
//!   time (real plane: the trainer runs a few steps under the candidate
//!   partition — the paper's "less than 50 iterations" warm-up search).

use super::partition::Partition;
use crate::simulator::{simulate, SimSetup};

/// Anything that can score a candidate partition (lower is better).
pub trait Objective {
    fn eval(&mut self, p: &Partition) -> f64;
    /// Number of evaluations performed (search-budget accounting).
    fn evals(&self) -> usize;
}

/// Exact Eq.-7 objective on the simulator plane.
pub struct SimObjective<'a> {
    pub setup: SimSetup<'a>,
    evals: usize,
}

impl<'a> SimObjective<'a> {
    pub fn new(setup: SimSetup<'a>) -> Self {
        Self { setup, evals: 0 }
    }
}

impl Objective for SimObjective<'_> {
    fn eval(&mut self, p: &Partition) -> f64 {
        self.evals += 1;
        simulate(&self.setup, p).iter_time
    }

    fn evals(&self) -> usize {
        self.evals
    }
}

/// Measured objective: wraps a closure that executes a few real iterations
/// under the candidate schedule and reports the mean step time.
pub struct MeasuredObjective<F: FnMut(&Partition) -> f64> {
    f: F,
    evals: usize,
}

impl<F: FnMut(&Partition) -> f64> MeasuredObjective<F> {
    pub fn new(f: F) -> Self {
        Self { f, evals: 0 }
    }
}

impl<F: FnMut(&Partition) -> f64> Objective for MeasuredObjective<F> {
    fn eval(&mut self, p: &Partition) -> f64 {
        self.evals += 1;
        (self.f)(p)
    }

    fn evals(&self) -> usize {
        self.evals
    }
}

/// Eq.-7 objective from **fitted** Assumption-5 cost models — the real
/// execution plane's objective: the trainer measures encode/decode/comm
/// times during warm-up, fits `B + γ·x` ([`super::costmodel::FittedCost`]),
/// and Algorithm 2 searches against this analytic model (so the search
/// costs microseconds instead of training steps).
pub struct AnalyticObjective {
    /// Per-tensor backward durations, backprop order.
    pub bwd_dur: Vec<f64>,
    /// Per-tensor element counts, backprop order.
    pub sizes: Vec<usize>,
    /// Forward-pass time (seconds).
    pub fwd_time: f64,
    /// Fitted encode-path cost (incl. EF decode if the codec uses EF).
    pub enc: super::costmodel::FittedCost,
    /// Fitted decode-path cost per received payload.
    pub dec: super::costmodel::FittedCost,
    /// Fitted collective cost for a group of x elements.
    pub comm: super::costmodel::FittedCost,
    /// Payloads decoded per group (world−1 for allgather, 1 for allreduce).
    pub dec_fanin: usize,
    evals: usize,
}

impl AnalyticObjective {
    pub fn new(
        bwd_dur: Vec<f64>,
        sizes: Vec<usize>,
        fwd_time: f64,
        enc: super::costmodel::FittedCost,
        dec: super::costmodel::FittedCost,
        comm: super::costmodel::FittedCost,
        dec_fanin: usize,
    ) -> Self {
        assert_eq!(bwd_dur.len(), sizes.len());
        Self {
            bwd_dur,
            sizes,
            fwd_time,
            enc,
            dec,
            comm,
            dec_fanin: dec_fanin.max(1),
            evals: 0,
        }
    }
}

impl Objective for AnalyticObjective {
    fn eval(&mut self, p: &Partition) -> f64 {
        self.evals += 1;
        // Same two-resource WFBP timeline as simulator::timeline, driven by
        // the fitted costs.
        let y = p.num_groups();
        let mut gpu_t = self.fwd_time;
        let mut comm_free = 0.0f64;
        let mut comm_done = vec![0.0f64; y];
        for j in 0..y {
            let mut elems = 0usize;
            for i in p.group_range(j) {
                gpu_t += self.bwd_dur[i];
                elems += self.sizes[i];
            }
            gpu_t += self.enc.predict(elems);
            let start = gpu_t.max(comm_free);
            comm_free = start + self.comm.predict(elems);
            comm_done[j] = comm_free;
        }
        for j in 0..y {
            let elems: usize = p.group_range(j).map(|i| self.sizes[i]).sum();
            gpu_t = gpu_t.max(comm_done[j]) + self.dec.predict(elems) * self.dec_fanin as f64;
        }
        gpu_t
    }

    fn evals(&self) -> usize {
        self.evals
    }
}

/// Memoizing wrapper — Algorithm 2 revisits cut positions; cache them.
pub struct Memo<'o> {
    inner: &'o mut dyn Objective,
    cache: std::collections::HashMap<Vec<usize>, f64>,
}

impl<'o> Memo<'o> {
    pub fn new(inner: &'o mut dyn Objective) -> Self {
        Self {
            inner,
            cache: std::collections::HashMap::new(),
        }
    }

    pub fn eval(&mut self, p: &Partition) -> f64 {
        let key = p.bounds().to_vec();
        if let Some(&v) = self.cache.get(&key) {
            return v;
        }
        let v = self.inner.eval(p);
        self.cache.insert(key, v);
        v
    }

    pub fn evals(&self) -> usize {
        self.inner.evals()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::CodecKind;
    use crate::netsim::Fabric;
    use crate::profiles::resnet50_cifar10;

    #[test]
    fn sim_objective_counts_evals() {
        let profile = resnet50_cifar10();
        let setup = SimSetup {
            profile: &profile,
            kind: CodecKind::EfSignSgd,
            fabric: Fabric::pcie(),
            world: 4,
        };
        let mut obj = SimObjective::new(setup);
        let p = Partition::naive_even(profile.num_tensors(), 2);
        let f1 = obj.eval(&p);
        let f2 = obj.eval(&p);
        assert_eq!(f1, f2, "deterministic");
        assert_eq!(obj.evals(), 2);
    }

    #[test]
    fn memo_caches() {
        let profile = resnet50_cifar10();
        let setup = SimSetup {
            profile: &profile,
            kind: CodecKind::Dgc { ratio: 0.01 },
            fabric: Fabric::pcie(),
            world: 2,
        };
        let mut obj = SimObjective::new(setup);
        let mut memo = Memo::new(&mut obj);
        let p = Partition::naive_even(profile.num_tensors(), 3);
        let f1 = memo.eval(&p);
        let f2 = memo.eval(&p);
        assert_eq!(f1, f2);
        assert_eq!(memo.evals(), 1, "second eval served from cache");
    }

    #[test]
    fn measured_objective_calls_closure() {
        let mut calls = 0usize;
        {
            let mut obj = MeasuredObjective::new(|p: &Partition| {
                calls += 1;
                p.num_groups() as f64
            });
            let f = obj.eval(&Partition::naive_even(10, 2));
            assert_eq!(f, 2.0);
        }
        assert_eq!(calls, 1);
    }
}
