//! The model-partition objective F(X_y) — paper Eq. (7):
//!
//! ```text
//! F(X_y) = A + Σ h(x_i) + Σ g(x_i) − Σ p(x_i)
//! ```
//!
//! Algorithm 2 only ever *evaluates* F, so the search is written against the
//! [`Objective`] trait. Two implementations:
//!
//! - [`SimObjective`]: the discrete-event timeline (simulator plane) — this
//!   is F including the overlap term, computed exactly.
//! - [`MeasuredObjective`]: any closure returning a measured mean iteration
//!   time (real plane: the trainer runs a few steps under the candidate
//!   partition — the paper's "less than 50 iterations" warm-up search).

use super::costmodel::{CodecCostModel, FittedCost, RouteCostModel};
use super::partition::Partition;
use super::search::RouteChoice;
use crate::compression::{CodecKind, Collective};
use crate::simulator::{simulate, SimSetup};

/// Pricing for the sharded exchange (DESIGN.md "Sharded exchange"): the
/// flat-route reduce-scatter skips the allreduce's allgather phase (× 0.5
/// for allreduce codecs — the hierarchical route runs the full allreduce
/// and saves nothing), and every group additionally pays an allgather of
/// updated **uncompressed f32 parameter shards**, 4·elems·(w−1)/w bytes —
/// half an uncompressed ring allreduce of the group, whatever the gradient
/// codec. With an FP32 base codec on the flat route the two adjustments
/// cancel exactly: sharded ties full-mode wall-clock while holding 1/world
/// of the optimizer state (the textbook RS+AG ≡ allreduce identity the
/// simulator scenario in `simulator/validate.rs` pins down).
#[derive(Debug, Clone, Copy)]
pub struct ShardedCost {
    /// Collective fit in an uncompressed-f32-element basis (what the
    /// parameter-shard allgather ships).
    pub fp32_comm: FittedCost,
    /// The run's base codec — groups without a codec model are priced
    /// under its collective type.
    pub base_codec: CodecKind,
}

impl ShardedCost {
    /// The parameter-shard allgather price for a group of `elems`.
    fn param_allgather(&self, elems: usize) -> f64 {
        0.5 * self.fp32_comm.predict(elems)
    }

    /// Scale a gradient-collective price for the sharded exchange: the
    /// flat-route reduce-scatter is half the allreduce; allgather codecs
    /// and the hierarchical route communicate exactly as full mode.
    fn scale_comm(&self, kind: CodecKind, route: Option<RouteChoice>, comm: f64) -> f64 {
        let flat = route.unwrap_or(RouteChoice::Flat) == RouteChoice::Flat;
        if kind.collective() == Collective::AllReduce && flat {
            0.5 * comm
        } else {
            comm
        }
    }
}

/// Anything that can score a candidate partition (lower is better).
pub trait Objective {
    fn eval(&mut self, p: &Partition) -> f64;
    /// Number of evaluations performed (search-budget accounting).
    fn evals(&self) -> usize;
    /// The per-group routes `eval` implicitly priced `p` under. The
    /// default (empty) means the objective has no route freedom — callers
    /// keep the communicator's global route. [`AnalyticObjective`]
    /// overrides this once a [`RouteCostModel`] is attached.
    fn routes(&self, _p: &Partition) -> Vec<RouteChoice> {
        Vec::new()
    }
    /// The per-group codecs `eval` implicitly priced `p` under. The
    /// default (empty) means the objective has no codec freedom — callers
    /// keep the configured codec everywhere. [`AnalyticObjective`]
    /// overrides this once a [`CodecCostModel`] is attached.
    fn codecs(&self, _p: &Partition) -> Vec<CodecKind> {
        Vec::new()
    }
}

/// Exact Eq.-7 objective on the simulator plane.
pub struct SimObjective<'a> {
    pub setup: SimSetup<'a>,
    evals: usize,
}

impl<'a> SimObjective<'a> {
    pub fn new(setup: SimSetup<'a>) -> Self {
        Self { setup, evals: 0 }
    }
}

impl Objective for SimObjective<'_> {
    fn eval(&mut self, p: &Partition) -> f64 {
        self.evals += 1;
        simulate(&self.setup, p).iter_time
    }

    fn evals(&self) -> usize {
        self.evals
    }
}

/// Measured objective: wraps a closure that executes a few real iterations
/// under the candidate schedule and reports the mean step time.
pub struct MeasuredObjective<F: FnMut(&Partition) -> f64> {
    f: F,
    evals: usize,
}

impl<F: FnMut(&Partition) -> f64> MeasuredObjective<F> {
    pub fn new(f: F) -> Self {
        Self { f, evals: 0 }
    }
}

impl<F: FnMut(&Partition) -> f64> Objective for MeasuredObjective<F> {
    fn eval(&mut self, p: &Partition) -> f64 {
        self.evals += 1;
        (self.f)(p)
    }

    fn evals(&self) -> usize {
        self.evals
    }
}

/// Eq.-7 objective from **fitted** Assumption-5 cost models — the real
/// execution plane's objective: the trainer measures encode/decode/comm
/// times during warm-up, fits `B + γ·x` ([`super::costmodel::FittedCost`]),
/// and Algorithm 2 searches against this analytic model (so the search
/// costs microseconds instead of training steps).
pub struct AnalyticObjective {
    /// Per-tensor backward durations, backprop order.
    pub bwd_dur: Vec<f64>,
    /// Per-tensor element counts, backprop order.
    pub sizes: Vec<usize>,
    /// Forward-pass time (seconds).
    pub fwd_time: f64,
    /// Fitted encode-path cost (incl. EF decode if the codec uses EF).
    pub enc: super::costmodel::FittedCost,
    /// Fitted decode-path cost per received payload.
    pub dec: super::costmodel::FittedCost,
    /// Fitted collective cost for a group of x elements (the global-route
    /// model; superseded per group when `route_costs` is attached).
    pub comm: super::costmodel::FittedCost,
    /// Payloads decoded per group (world−1 for allgather, 1 for allreduce).
    pub dec_fanin: usize,
    /// Per-route comm models: when present, each group is priced under
    /// the cheaper of flat/hierarchical — the `(partition, route)` search
    /// space — and [`AnalyticObjective::routes`] reports the choices.
    route_costs: Option<RouteCostModel>,
    /// Per-codec cost models: when present, each group is priced under the
    /// cheapest `(codec, route)` pair from the pool — the full
    /// `(partition, route, codec)` search space — with the incumbent
    /// switch penalty charged, and [`AnalyticObjective::codecs`] reports
    /// the choices.
    codec_costs: Option<CodecCostModel>,
    /// When present, every group's comm price is adjusted for the sharded
    /// exchange's reduce-scatter + parameter-allgather byte pattern.
    sharded: Option<ShardedCost>,
    evals: usize,
}

/// The priced cost components of one candidate group.
struct GroupPrice {
    enc: f64,
    comm: f64,
    /// Full-group decode (fan-in already included).
    dec: f64,
    /// Codec-switch penalty (outside the timeline; charged additively).
    penalty: f64,
}

impl AnalyticObjective {
    pub fn new(
        bwd_dur: Vec<f64>,
        sizes: Vec<usize>,
        fwd_time: f64,
        enc: super::costmodel::FittedCost,
        dec: super::costmodel::FittedCost,
        comm: super::costmodel::FittedCost,
        dec_fanin: usize,
    ) -> Self {
        assert_eq!(bwd_dur.len(), sizes.len());
        Self {
            bwd_dur,
            sizes,
            fwd_time,
            enc,
            dec,
            comm,
            dec_fanin: dec_fanin.max(1),
            route_costs: None,
            codec_costs: None,
            sharded: None,
            evals: 0,
        }
    }

    /// Attach per-route comm models, turning the search space into
    /// `(partition, per-group route)`.
    pub fn with_route_costs(mut self, route_costs: RouteCostModel) -> Self {
        self.route_costs = Some(route_costs);
        self
    }

    pub fn set_route_costs(&mut self, route_costs: Option<RouteCostModel>) {
        self.route_costs = route_costs;
    }

    pub fn route_costs(&self) -> Option<&RouteCostModel> {
        self.route_costs.as_ref()
    }

    /// Attach per-codec cost models, turning the search space into
    /// `(partition, per-group route, per-group codec)`.
    pub fn with_codec_costs(mut self, codec_costs: CodecCostModel) -> Self {
        self.codec_costs = Some(codec_costs);
        self
    }

    pub fn set_codec_costs(&mut self, codec_costs: Option<CodecCostModel>) {
        self.codec_costs = codec_costs;
    }

    pub fn codec_costs(&self) -> Option<&CodecCostModel> {
        self.codec_costs.as_ref()
    }

    /// Attach the sharded-exchange pricing (see [`ShardedCost`]).
    pub fn with_sharded_exchange(mut self, sharded: ShardedCost) -> Self {
        self.sharded = Some(sharded);
        self
    }

    pub fn set_sharded_exchange(&mut self, sharded: Option<ShardedCost>) {
        self.sharded = sharded;
    }

    pub fn sharded_exchange(&self) -> Option<&ShardedCost> {
        self.sharded.as_ref()
    }

    /// Comm cost of one group under `kind`: forced route, best route (when
    /// a route model is attached, compared under the sharded adjustment so
    /// the route choice and the price agree), or the global-route model.
    fn comm_secs(&self, kind: CodecKind, elems: usize, forced: Option<RouteChoice>) -> f64 {
        let grad = match (&self.route_costs, forced) {
            (Some(rc), Some(route)) => {
                let c = rc.cost(route).predict(elems);
                match &self.sharded {
                    Some(sc) => sc.scale_comm(kind, Some(route), c),
                    None => c,
                }
            }
            (Some(rc), None) => match &self.sharded {
                Some(sc) => [RouteChoice::Flat, RouteChoice::Hierarchical]
                    .into_iter()
                    .map(|r| sc.scale_comm(kind, Some(r), rc.cost(r).predict(elems)))
                    .fold(f64::INFINITY, f64::min),
                None => rc.best(elems).1,
            },
            (None, _) => {
                let c = self.comm.predict(elems);
                match &self.sharded {
                    Some(sc) => sc.scale_comm(kind, forced, c),
                    None => c,
                }
            }
        };
        grad + self.sharded.map(|sc| sc.param_allgather(elems)).unwrap_or(0.0)
    }

    /// Price one group under the objective's own (codec-free) fits.
    fn base_price(&self, elems: usize, route: Option<RouteChoice>) -> GroupPrice {
        let kind = self.sharded.map(|sc| sc.base_codec).unwrap_or(CodecKind::Fp32);
        GroupPrice {
            enc: self.enc.predict(elems),
            comm: self.comm_secs(kind, elems, route),
            dec: self.dec.predict(elems) * self.dec_fanin as f64,
            penalty: 0.0,
        }
    }

    /// Joint per-group `(codec, route)` choice: minimize the group's serial
    /// cost (encode + collective + decode, plus the switch penalty when
    /// the codec differs from the incumbent of any tensor the group spans)
    /// over the candidate pool. Pinning `fcodec`/`froute` restricts the
    /// choice — how the driver prices the *current* schedule. Because the
    /// choice decomposes per group, minimizing inside the objective
    /// searches the product space exactly, like the route axis.
    fn choose(
        &self,
        p: &Partition,
        j: usize,
        elems: usize,
        froute: Option<RouteChoice>,
        fcodec: Option<CodecKind>,
    ) -> (Option<CodecKind>, Option<RouteChoice>, GroupPrice) {
        let Some(cm) = &self.codec_costs else {
            return (None, froute, self.base_price(elems, froute));
        };
        let mut best: Option<(CodecKind, Option<RouteChoice>, GroupPrice, f64)> = None;
        for entry in cm
            .entries
            .iter()
            .filter(|e| fcodec.map(|k| e.kind == k).unwrap_or(true))
        {
            let (route, mut comm) = entry.comm_for(elems, froute);
            if let Some(sc) = &self.sharded {
                comm = sc.scale_comm(entry.kind, route, comm) + sc.param_allgather(elems);
            }
            let penalty = if cm.incumbent.is_empty()
                || p.group_range(j).all(|i| cm.incumbent[i] == entry.kind)
            {
                0.0
            } else {
                cm.switch_cost
            };
            let price = GroupPrice {
                enc: entry.enc.predict(elems),
                comm,
                dec: entry.dec.predict(elems),
                penalty,
            };
            let total = price.enc + price.comm + price.dec + price.penalty;
            if best.as_ref().map(|(_, _, _, bt)| total < *bt).unwrap_or(true) {
                best = Some((entry.kind, route, price, total));
            }
        }
        match best {
            Some((kind, route, price, _)) => (Some(kind), route, price),
            // A pinned codec absent from the pool: price it under the
            // objective's own fits (they were measured under the incumbent).
            None => (fcodec, froute, self.base_price(elems, froute)),
        }
    }

    fn eval_inner(
        &mut self,
        p: &Partition,
        forced_routes: Option<&[RouteChoice]>,
        forced_codecs: Option<&[CodecKind]>,
    ) -> f64 {
        self.evals += 1;
        if let Some(routes) = forced_routes {
            assert_eq!(routes.len(), p.num_groups(), "one route per group");
        }
        if let Some(codecs) = forced_codecs {
            assert_eq!(codecs.len(), p.num_groups(), "one codec per group");
        }
        // Same two-resource WFBP timeline as simulator::timeline, driven by
        // the fitted costs.
        let y = p.num_groups();
        let mut gpu_t = self.fwd_time;
        let mut comm_free = 0.0f64;
        let mut comm_done = vec![0.0f64; y];
        let mut dec_secs = vec![0.0f64; y];
        let mut penalty = 0.0f64;
        for j in 0..y {
            let mut elems = 0usize;
            for i in p.group_range(j) {
                gpu_t += self.bwd_dur[i];
                elems += self.sizes[i];
            }
            let (_, _, price) = self.choose(
                p,
                j,
                elems,
                forced_routes.map(|r| r[j]),
                forced_codecs.map(|c| c[j]),
            );
            gpu_t += price.enc;
            let start = gpu_t.max(comm_free);
            comm_free = start + price.comm;
            comm_done[j] = comm_free;
            dec_secs[j] = price.dec;
            penalty += price.penalty;
        }
        for j in 0..y {
            gpu_t = gpu_t.max(comm_done[j]) + dec_secs[j];
        }
        gpu_t + penalty
    }

    /// Score `p` with every group pinned to the given route — how the
    /// driver prices the *current* `(partition, routes)` schedule so that
    /// route-only improvements register as predicted gain.
    pub fn eval_with_routes(&mut self, p: &Partition, routes: &[RouteChoice]) -> f64 {
        self.eval_with_schedule(p, routes, &[])
    }

    /// Score `p` with every group pinned to the given route *and* codec —
    /// the full current-schedule price when the codec axis is live. Empty
    /// slices leave the corresponding axis free.
    pub fn eval_with_schedule(
        &mut self,
        p: &Partition,
        routes: &[RouteChoice],
        codecs: &[CodecKind],
    ) -> f64 {
        let fr = (!routes.is_empty()).then_some(routes);
        let fc = (!codecs.is_empty()).then_some(codecs);
        self.eval_inner(p, fr, fc)
    }
}

impl Objective for AnalyticObjective {
    fn eval(&mut self, p: &Partition) -> f64 {
        self.eval_inner(p, None, None)
    }

    fn evals(&self) -> usize {
        self.evals
    }

    fn routes(&self, p: &Partition) -> Vec<RouteChoice> {
        if self.route_costs.is_none() {
            return Vec::new();
        }
        (0..p.num_groups())
            .map(|j| {
                let elems: usize = p.group_range(j).map(|i| self.sizes[i]).sum();
                // The joint (codec, route) choice when the codec axis is
                // live; the plain route comparison otherwise.
                match self.choose(p, j, elems, None, None) {
                    (_, Some(route), _) => route,
                    _ => self.route_costs.as_ref().unwrap().best(elems).0,
                }
            })
            .collect()
    }

    fn codecs(&self, p: &Partition) -> Vec<CodecKind> {
        if self
            .codec_costs
            .as_ref()
            .map(|cm| cm.entries.is_empty())
            .unwrap_or(true)
        {
            return Vec::new();
        }
        (0..p.num_groups())
            .filter_map(|j| {
                let elems: usize = p.group_range(j).map(|i| self.sizes[i]).sum();
                self.choose(p, j, elems, None, None).0
            })
            .collect()
    }
}

/// Memoizing wrapper — Algorithm 2 revisits cut positions; cache them.
pub struct Memo<'o> {
    inner: &'o mut dyn Objective,
    cache: std::collections::HashMap<Vec<usize>, f64>,
}

impl<'o> Memo<'o> {
    pub fn new(inner: &'o mut dyn Objective) -> Self {
        Self {
            inner,
            cache: std::collections::HashMap::new(),
        }
    }

    pub fn eval(&mut self, p: &Partition) -> f64 {
        let key = p.bounds().to_vec();
        if let Some(&v) = self.cache.get(&key) {
            return v;
        }
        let v = self.inner.eval(p);
        self.cache.insert(key, v);
        v
    }

    pub fn evals(&self) -> usize {
        self.inner.evals()
    }

    /// The inner objective's route recommendation for `p` (not cached —
    /// it is pure given the fitted models and only queried once per
    /// search).
    pub fn routes(&self, p: &Partition) -> Vec<RouteChoice> {
        self.inner.routes(p)
    }

    /// The inner objective's codec recommendation for `p` (pure, queried
    /// once per search, like [`Memo::routes`]).
    pub fn codecs(&self, p: &Partition) -> Vec<CodecKind> {
        self.inner.codecs(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::CodecKind;
    use crate::netsim::Fabric;
    use crate::profiles::resnet50_cifar10;

    #[test]
    fn sim_objective_counts_evals() {
        let profile = resnet50_cifar10();
        let setup = SimSetup {
            profile: &profile,
            kind: CodecKind::EfSignSgd,
            fabric: Fabric::pcie(),
            world: 4,
        };
        let mut obj = SimObjective::new(setup);
        let p = Partition::naive_even(profile.num_tensors(), 2);
        let f1 = obj.eval(&p);
        let f2 = obj.eval(&p);
        assert_eq!(f1, f2, "deterministic");
        assert_eq!(obj.evals(), 2);
    }

    #[test]
    fn memo_caches() {
        let profile = resnet50_cifar10();
        let setup = SimSetup {
            profile: &profile,
            kind: CodecKind::Dgc { ratio: 0.01 },
            fabric: Fabric::pcie(),
            world: 2,
        };
        let mut obj = SimObjective::new(setup);
        let mut memo = Memo::new(&mut obj);
        let p = Partition::naive_even(profile.num_tensors(), 3);
        let f1 = memo.eval(&p);
        let f2 = memo.eval(&p);
        assert_eq!(f1, f2);
        assert_eq!(memo.evals(), 1, "second eval served from cache");
    }

    #[test]
    fn route_aware_objective_prices_each_group_under_the_cheaper_route() {
        use super::super::costmodel::{FittedCost, RouteCostModel};
        // Flat: cheap latency, steep slope. Hier: big latency, shallow
        // slope. Crossover near 21k elements.
        let flat = FittedCost { b: 1e-5, g: 1e-8, r2: 1.0 };
        let hier = FittedCost { b: 2e-4, g: 1e-9, r2: 1.0 };
        let rc = RouteCostModel { flat, hier };
        let zero = FittedCost { b: 0.0, g: 0.0, r2: 1.0 };
        let sizes = vec![100usize, 1_000_000];
        let mut obj = AnalyticObjective::new(
            vec![1e-3, 1e-3],
            sizes,
            1e-3,
            zero,
            zero,
            flat,
            1,
        )
        .with_route_costs(rc);
        let p = Partition::layer_wise(2);
        let f_auto = obj.eval(&p);
        let routes = obj.routes(&p);
        assert_eq!(routes, vec![RouteChoice::Flat, RouteChoice::Hierarchical]);
        // Forced-uniform routes can never beat the per-group minimum.
        let f_flat = obj.eval_with_routes(&p, &[RouteChoice::Flat, RouteChoice::Flat]);
        let f_hier = obj.eval_with_routes(
            &p,
            &[RouteChoice::Hierarchical, RouteChoice::Hierarchical],
        );
        assert!(f_auto <= f_flat + 1e-15 && f_auto <= f_hier + 1e-15);
        // Pinning the objective's own choices reproduces the auto score.
        assert_eq!(obj.eval_with_routes(&p, &routes), f_auto);
        // Without a route model, no routes are reported.
        obj.set_route_costs(None);
        assert!(obj.routes(&p).is_empty());
    }

    #[test]
    fn codec_aware_objective_picks_the_cheapest_codec_per_group() {
        use super::super::costmodel::{CodecCostEntry, CodecCostModel, FittedCost};
        let zero = FittedCost { b: 0.0, g: 0.0, r2: 1.0 };
        // One fabric plane in bytes (50µs latency, 1ns/byte) converted per
        // codec: FP32 is latency-free but dense; TopK pays a big encode
        // cost but ships 0.8% of the bytes.
        let wire = FittedCost { b: 5e-5, g: 1e-9, r2: 1.0 };
        let topk = CodecKind::TopK { ratio: 0.01 };
        let entries = vec![
            CodecCostEntry {
                kind: CodecKind::Fp32,
                enc: zero,
                dec: zero,
                comm: wire.per_elems_for(CodecKind::Fp32),
                routes: None,
            },
            CodecCostEntry {
                kind: topk,
                enc: FittedCost { b: 2e-4, g: 2e-9, r2: 1.0 },
                dec: FittedCost { b: 1e-5, g: 1e-10, r2: 1.0 },
                comm: wire.per_elems_for(topk),
                routes: None,
            },
        ];
        let sizes = vec![100usize, 4_000_000];
        let mut obj = AnalyticObjective::new(
            vec![1e-3, 1e-3],
            sizes,
            1e-3,
            zero,
            zero,
            wire.per_elems_for(CodecKind::Fp32),
            1,
        )
        .with_codec_costs(CodecCostModel {
            entries,
            switch_cost: 0.0,
            incumbent: Vec::new(),
        });
        let p = Partition::layer_wise(2);
        let f_auto = obj.eval(&p);
        let codecs = obj.codecs(&p);
        // Small latency-bound group: don't compress. Huge bandwidth-bound
        // group: the sparsifier's encode cost pays for itself.
        assert_eq!(codecs, vec![CodecKind::Fp32, topk]);
        // Forced-uniform codecs can never beat the per-group minimum.
        let f_fp32 = obj.eval_with_schedule(&p, &[], &[CodecKind::Fp32, CodecKind::Fp32]);
        let f_topk = obj.eval_with_schedule(&p, &[], &[topk, topk]);
        assert!(f_auto <= f_fp32 + 1e-15 && f_auto <= f_topk + 1e-15);
        assert!(f_auto < f_fp32.min(f_topk), "the mix must strictly win here");
        // Pinning the objective's own choices reproduces the auto score.
        assert_eq!(obj.eval_with_schedule(&p, &[], &codecs), f_auto);
        // Without a codec model, no codecs are reported.
        obj.set_codec_costs(None);
        assert!(obj.codecs(&p).is_empty());
    }

    #[test]
    fn switch_cost_pins_the_incumbent_until_the_gain_clears_it() {
        use super::super::costmodel::{CodecCostEntry, CodecCostModel, FittedCost};
        let zero = FittedCost { b: 0.0, g: 0.0, r2: 1.0 };
        // FP16 is marginally cheaper than the incumbent FP32 on this plane.
        let mk = |g: f64| FittedCost { b: 1e-5, g, r2: 1.0 };
        let entries = vec![
            CodecCostEntry {
                kind: CodecKind::Fp32,
                enc: zero,
                dec: zero,
                comm: mk(4e-9),
                routes: None,
            },
            CodecCostEntry {
                kind: CodecKind::Fp16,
                enc: zero,
                dec: zero,
                comm: mk(2e-9),
                routes: None,
            },
        ];
        let n = 100_000usize;
        let gain = (4e-9 - 2e-9) * n as f64;
        let with_cost = |switch_cost: f64| {
            let mut obj = AnalyticObjective::new(
                vec![1e-3],
                vec![n],
                1e-3,
                zero,
                zero,
                mk(4e-9),
                1,
            )
            .with_codec_costs(CodecCostModel {
                entries: entries.clone(),
                switch_cost,
                incumbent: vec![CodecKind::Fp32],
            });
            obj.codecs(&Partition::full_merge(1))
        };
        // Below the per-step gain the switch goes through; above it the
        // incumbent holds — no thrash on noise-level differences.
        assert_eq!(with_cost(gain * 0.5), vec![CodecKind::Fp16]);
        assert_eq!(with_cost(gain * 2.0), vec![CodecKind::Fp32]);
    }

    #[test]
    fn sharded_pricing_ties_fp32_and_charges_the_param_allgather() {
        use super::super::costmodel::FittedCost;
        let zero = FittedCost { b: 0.0, g: 0.0, r2: 1.0 };
        let comm = FittedCost { b: 1e-5, g: 1e-9, r2: 1.0 };
        let n = 50_000usize;
        let mk = |c: FittedCost| {
            AnalyticObjective::new(vec![1e-3], vec![n], 1e-3, zero, zero, c, 1)
        };
        let p = Partition::full_merge(1);
        // One group ⇒ eval = fwd + bwd + enc + comm + dec, so comm-price
        // changes show up in the score verbatim.
        let full = mk(comm).eval(&p);

        // FP32 base: ½·allreduce (the reduce-scatter) + ½·fp32 allreduce
        // (the parameter-shard allgather) = the full allreduce — exact tie.
        let mut obj = mk(comm).with_sharded_exchange(ShardedCost {
            fp32_comm: comm,
            base_codec: CodecKind::Fp32,
        });
        assert!((obj.eval(&p) - full).abs() < 1e-12, "fp32 sharded must tie full mode");

        // Allgather codec: the gradient collective is unchanged; the param
        // allgather is pure extra.
        let mut obj = mk(comm).with_sharded_exchange(ShardedCost {
            fp32_comm: comm,
            base_codec: CodecKind::EfSignSgd,
        });
        let want = full + 0.5 * comm.predict(n);
        assert!((obj.eval(&p) - want).abs() < 1e-12);

        // FP16 (allreduce on a cheaper wire): ½ codec comm + ½ fp32 comm.
        let half = FittedCost { b: 1e-5, g: 5e-10, r2: 1.0 };
        let fp16_full = mk(half).eval(&p);
        let mut obj = mk(half).with_sharded_exchange(ShardedCost {
            fp32_comm: comm,
            base_codec: CodecKind::Fp16,
        });
        let want = fp16_full - 0.5 * half.predict(n) + 0.5 * comm.predict(n);
        assert!((obj.eval(&p) - want).abs() < 1e-12);

        // The knob detaches cleanly.
        obj.set_sharded_exchange(None);
        assert!(obj.sharded_exchange().is_none());
        assert!((obj.eval(&p) - fp16_full).abs() < 1e-12);
    }

    #[test]
    fn measured_objective_calls_closure() {
        let mut calls = 0usize;
        {
            let mut obj = MeasuredObjective::new(|p: &Partition| {
                calls += 1;
                p.num_groups() as f64
            });
            let f = obj.eval(&Partition::naive_even(10, 2));
            assert_eq!(f, 2.0);
        }
        assert_eq!(calls, 1);
    }
}
