//! Rolling cost estimation for the online scheduler.
//!
//! The warmup-only path fits the Assumption-5 models (`t(x) = B + γ·x`)
//! once, from a handful of probe measurements, and never looks at the
//! system again. This module replaces that with **exponentially weighted
//! least squares**: every exchanged group contributes one `(elems, secs)`
//! sample per cost kind (encode, decode, comm), old samples decay
//! geometrically, and the fit therefore tracks whatever the fabric and the
//! host are doing *right now* — the MG-WFBP observation that merge
//! decisions must follow measured timings, not a one-shot calibration.
//!
//! Identifiability: a slope needs at least two well-separated sizes. A
//! full-merge schedule only ever shows the estimator a single size, so each
//! [`EwmaCost`] carries a prior (the warmup fit, or a default) and degrades
//! gracefully: while the live x-spread is too small to identify γ, it
//! returns the prior *rescaled* by the observed/predicted ratio — a pure
//! bandwidth/latency drift at one size still moves the model in the right
//! direction, which is what lets the search escape a stale full merge.
//! Once the partition has ≥ 2 distinct group sizes, the full weighted fit
//! takes over.

use super::costmodel::{
    CodecCostEntry, CodecCostModel, FittedCost, RouteCostModel, TwoLevelCost,
};
use super::objective::AnalyticObjective;
use crate::collectives::CommRoute;
use crate::compression::CodecKind;
use crate::coordinator::GroupSample;

/// Minimum coefficient of variation of the (weighted) sizes before the
/// regression slope is trusted over the rescaled prior.
const MIN_X_CV: f64 = 0.05;

/// Exponentially weighted linear fit of `t(x) = b + g·x`.
#[derive(Debug, Clone)]
pub struct EwmaCost {
    /// Weight of each new sample in (0, 1]; history is scaled by `1 - ewma`
    /// per observation.
    ewma: f64,
    prior: FittedCost,
    // Decayed moments of the weighted sample cloud.
    w: f64,
    sx: f64,
    sy: f64,
    sxx: f64,
    sxy: f64,
    syy: f64,
    samples: u64,
}

impl EwmaCost {
    pub fn new(ewma: f64, prior: FittedCost) -> Self {
        assert!(ewma > 0.0 && ewma <= 1.0, "ewma weight must be in (0, 1]");
        Self {
            ewma,
            prior,
            w: 0.0,
            sx: 0.0,
            sy: 0.0,
            sxx: 0.0,
            sxy: 0.0,
            syy: 0.0,
            samples: 0,
        }
    }

    /// Record one `(elems, seconds)` observation.
    pub fn observe(&mut self, elems: usize, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        let x = elems as f64;
        let keep = 1.0 - self.ewma;
        self.w = self.w * keep + 1.0;
        self.sx = self.sx * keep + x;
        self.sy = self.sy * keep + secs;
        self.sxx = self.sxx * keep + x * x;
        self.sxy = self.sxy * keep + x * secs;
        self.syy = self.syy * keep + secs * secs;
        self.samples += 1;
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Current best model. Falls back to the rescaled prior while the live
    /// sizes cannot identify a slope.
    pub fn fit(&self) -> FittedCost {
        if self.samples == 0 || self.w <= 0.0 {
            return self.prior;
        }
        let mean_x = self.sx / self.w;
        let var_x = (self.sxx / self.w - mean_x * mean_x).max(0.0);
        let identifiable =
            self.samples >= 2 && mean_x > 0.0 && var_x.sqrt() > MIN_X_CV * mean_x;
        if !identifiable {
            // Rescaled prior: mean observed / mean predicted at the sizes
            // actually seen.
            let predicted = self.prior.b * self.w + self.prior.g * self.sx;
            let ratio = if predicted > 0.0 { self.sy / predicted } else { 1.0 };
            let ratio = ratio.max(0.0);
            return FittedCost {
                b: self.prior.b * ratio,
                g: self.prior.g * ratio,
                r2: 0.0,
            };
        }
        let denom = self.w * self.sxx - self.sx * self.sx;
        let g = (self.w * self.sxy - self.sx * self.sy) / denom;
        let b = (self.sy - g * self.sx) / self.w;
        let var_y = (self.w * self.syy - self.sy * self.sy).max(0.0);
        let cov = self.w * self.sxy - self.sx * self.sy;
        let r2 = if var_y > 0.0 { (cov * cov) / (denom * var_y) } else { 1.0 };
        FittedCost {
            b: b.max(0.0),
            g: g.max(0.0),
            r2: r2.clamp(0.0, 1.0),
        }
    }
}

/// Scalar EWMA (for the measured compute-step time).
#[derive(Debug, Clone)]
pub struct Ewma {
    ewma: f64,
    value: f64,
    samples: u64,
}

impl Ewma {
    pub fn new(ewma: f64) -> Self {
        assert!(ewma > 0.0 && ewma <= 1.0);
        Self {
            ewma,
            value: 0.0,
            samples: 0,
        }
    }

    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        if self.samples == 0 {
            self.value = v;
        } else {
            self.value += self.ewma * (v - self.value);
        }
        self.samples += 1;
    }

    pub fn value(&self) -> Option<f64> {
        (self.samples > 0).then_some(self.value)
    }
}

/// Per-codec rolling encode/decode fits, keyed by [`CodecKind`] (a `Vec`
/// + `PartialEq` scan — the pool is a handful of kinds, and `CodecKind`
/// carries `f64` payloads so it cannot be a hash key).
#[derive(Debug, Clone)]
struct CodecFit {
    kind: CodecKind,
    enc: EwmaCost,
    dec: EwmaCost,
}

/// Rolling cost models: encode path, decode path (full group, fan-in
/// included), and the α+β·**bytes** collective cost — plus the EWMA'd
/// compute-step time. One instance per worker; fed by [`GroupSample`]s
/// from the exchange engine.
///
/// **Comm fits live in wire-byte space.** The collective's cost depends on
/// the bytes it moves, not on the pre-compression element count, so every
/// comm sample files under `x = codec.wire_bytes(elems)`. One fabric plane
/// then prices *every* codec — including codecs that have never run — via
/// [`FittedCost::per_elems_for`]; the public accessors
/// ([`CostEstimator::comm_fit`], [`CostEstimator::two_level_fit`],
/// [`CostEstimator::route_costs`]) convert back to the element basis of
/// the configured `base_codec`, which keeps the objective and every
/// pre-codec-search caller unchanged.
///
/// **Encode/decode fits are keyed by codec.** Compression compute does
/// depend on the scheme, so alongside the route-agnostic aggregates the
/// estimator keeps one `(enc, dec)` fit per observed [`CodecKind`], and
/// [`CostEstimator::seed_codec`] installs microcalibration priors so a
/// codec is priceable before its first group ever runs —
/// [`CostEstimator::codec_cost_model`] assembles the search's codec axis
/// from both.
///
/// On a hierarchical fabric the samples additionally carry the inter-node
/// share of each collective ([`GroupSample::comm_inter_secs`]), and the
/// estimator keeps **per-level** fits alongside the total: `comm_inter`
/// models the leader ring, `comm_intra` the intra-node stages. When
/// per-level samples exist, [`CostEstimator::objective`] feeds the search
/// their combined (summed) model — so Algorithm 2 optimizes against
/// whichever link class actually dominates — and
/// [`CostEstimator::two_level_fit`] exposes the split for diagnostics.
#[derive(Debug, Clone)]
pub struct CostEstimator {
    pub enc: EwmaCost,
    pub dec: EwmaCost,
    /// Total collective cost in wire-byte space (every sample regardless
    /// of route; the fallback model when no per-route split exists).
    pub comm: EwmaCost,
    /// Inter-node stage only, byte space (fed by hierarchical-routed
    /// samples that carry a per-level split).
    pub comm_inter: EwmaCost,
    /// Intra-node stages only, byte space (fed alongside `comm_inter`).
    pub comm_intra: EwmaCost,
    /// Flat-routed samples only, byte space — the measured side of the
    /// flat/hier route comparison once any group actually rides the flat
    /// ring.
    pub comm_flat: EwmaCost,
    step_secs: Ewma,
    /// The codec whose element basis the public comm accessors convert to
    /// (the configured training codec; FP32 by default).
    base_codec: CodecKind,
    /// Per-codec encode/decode fits (observed and/or seeded).
    codec_fits: Vec<CodecFit>,
    /// EWMA weight, kept to mint per-codec fits lazily.
    ewma: f64,
}

/// Neutral priors when no warmup fit is available (loose V100-ish numbers;
/// immediately rescaled by live observations).
fn default_prior() -> FittedCost {
    FittedCost {
        b: 1e-4,
        g: 1e-9,
        r2: 0.0,
    }
}

impl CostEstimator {
    /// `ewma` is the weight of each new group sample (the config's
    /// `resched_ewma`); priors default when `None`. `comm_prior` is in
    /// **wire-byte** space (`t = b + g·bytes`); callers holding an
    /// element-based warmup fit convert it with the base codec's
    /// [`CodecKind::wire_affine`] density first.
    pub fn new(
        ewma: f64,
        enc_prior: Option<FittedCost>,
        dec_prior: Option<FittedCost>,
        comm_prior: Option<FittedCost>,
    ) -> Self {
        // The per-level fits start from the total-comm prior: until real
        // two-level samples arrive they are unused, and once they do the
        // rescaled-prior fallback pulls each level towards its share.
        let level_prior = comm_prior.unwrap_or_else(default_prior);
        Self {
            enc: EwmaCost::new(ewma, enc_prior.unwrap_or_else(default_prior)),
            dec: EwmaCost::new(ewma, dec_prior.unwrap_or_else(default_prior)),
            comm: EwmaCost::new(ewma, comm_prior.unwrap_or_else(default_prior)),
            comm_inter: EwmaCost::new(ewma, level_prior),
            comm_intra: EwmaCost::new(ewma, level_prior),
            comm_flat: EwmaCost::new(ewma, level_prior),
            step_secs: Ewma::new(ewma),
            base_codec: CodecKind::Fp32,
            codec_fits: Vec::new(),
            ewma,
        }
    }

    /// Set the codec whose element basis the public comm accessors report
    /// in (the configured training codec).
    pub fn set_base_codec(&mut self, kind: CodecKind) {
        self.base_codec = kind;
    }

    pub fn base_codec(&self) -> CodecKind {
        self.base_codec
    }

    /// Install microcalibration priors for one codec's encode/decode fits,
    /// so the codec axis can price it before its first group ever runs.
    /// `dec` must carry full-group semantics (allgather fan-in baked in),
    /// matching the measured [`GroupSample::decode_secs`]. A codec that
    /// already has a fit keeps its observations (priors only re-anchor the
    /// no-data fallback).
    pub fn seed_codec(&mut self, kind: CodecKind, enc: FittedCost, dec: FittedCost) {
        if self.codec_fits.iter().any(|c| c.kind == kind) {
            return;
        }
        self.codec_fits.push(CodecFit {
            kind,
            enc: EwmaCost::new(self.ewma, enc),
            dec: EwmaCost::new(self.ewma, dec),
        });
    }

    fn codec_fit_mut(&mut self, kind: CodecKind) -> &mut CodecFit {
        if let Some(i) = self.codec_fits.iter().position(|c| c.kind == kind) {
            return &mut self.codec_fits[i];
        }
        self.codec_fits.push(CodecFit {
            kind,
            enc: EwmaCost::new(self.ewma, default_prior()),
            dec: EwmaCost::new(self.ewma, default_prior()),
        });
        self.codec_fits.last_mut().unwrap()
    }

    /// This codec's encode/decode fits: observed/seeded when available,
    /// the aggregate fits otherwise.
    fn codec_io_fits(&self, kind: CodecKind) -> (FittedCost, FittedCost) {
        match self.codec_fits.iter().find(|c| c.kind == kind) {
            Some(c) => (c.enc.fit(), c.dec.fit()),
            None => (self.enc.fit(), self.dec.fit()),
        }
    }

    /// Record one step's per-group timings plus the measured compute time.
    /// Each sample files under the fits of the route it actually ran:
    /// flat-routed groups feed `comm_flat`, hierarchical-routed groups
    /// with a per-level split feed `comm_inter`/`comm_intra`, and every
    /// sample feeds the route-agnostic total. Comm samples are converted
    /// to wire bytes through the codec that ran the group; encode/decode
    /// samples additionally feed that codec's keyed fit.
    pub fn observe_step(&mut self, samples: &[GroupSample], compute_secs: f64) {
        for s in samples {
            self.enc.observe(s.elems, s.encode_secs);
            self.dec.observe(s.elems, s.decode_secs);
            let cf = self.codec_fit_mut(s.codec);
            cf.enc.observe(s.elems, s.encode_secs);
            cf.dec.observe(s.elems, s.decode_secs);
            let bytes = s.codec.wire_bytes(s.elems);
            self.comm.observe(bytes, s.comm_secs);
            match s.route {
                CommRoute::Flat => self.comm_flat.observe(bytes, s.comm_secs),
                CommRoute::TwoLevel => {
                    if s.comm_inter_secs > 0.0 {
                        self.comm_inter.observe(bytes, s.comm_inter_secs);
                        self.comm_intra
                            .observe(bytes, (s.comm_secs - s.comm_inter_secs).max(0.0));
                    }
                }
            }
        }
        self.step_secs.observe(compute_secs);
    }

    /// The total collective fit, converted to the base codec's element
    /// basis (what the route-free objective consumes).
    pub fn comm_fit(&self) -> FittedCost {
        self.comm.fit().per_elems_for(self.base_codec)
    }

    /// The total collective fit in uncompressed-FP32 element space — the
    /// cost basis for the sharded mode's parameter allgather, which always
    /// moves raw f32 shards regardless of the gradient codec. Uses the
    /// per-level combined fit when the hierarchy has been observed, like
    /// [`CostEstimator::codec_cost_model`].
    pub fn fp32_comm_fit(&self) -> FittedCost {
        let bytes = match self.two_level_fit_bytes() {
            Some(tl) => tl.combined(),
            None => self.comm.fit(),
        };
        bytes.per_elems_for(CodecKind::Fp32)
    }

    /// Per-level communication fits in the base codec's element basis,
    /// once hierarchical samples have been observed (`None` on a flat
    /// fabric).
    pub fn two_level_fit(&self) -> Option<TwoLevelCost> {
        self.two_level_fit_bytes().map(|tl| TwoLevelCost {
            intra: tl.intra.per_elems_for(self.base_codec),
            inter: tl.inter.per_elems_for(self.base_codec),
        })
    }

    /// Per-level fits in raw wire-byte space (the codec-agnostic fabric
    /// plane the codec axis converts per candidate).
    fn two_level_fit_bytes(&self) -> Option<TwoLevelCost> {
        (self.comm_inter.samples() > 0).then(|| TwoLevelCost {
            intra: self.comm_intra.fit(),
            inter: self.comm_inter.fit(),
        })
    }

    /// Per-route comm models in wire-byte space. `None` until hierarchical
    /// samples exist.
    fn route_costs_bytes(&self, world: usize, nodes: usize) -> Option<RouteCostModel> {
        let tl = self.two_level_fit_bytes()?;
        let flat = if self.comm_flat.samples() > 0 {
            self.comm_flat.fit()
        } else {
            tl.flat_equivalent(world, nodes)
        };
        Some(RouteCostModel {
            flat,
            hier: tl.combined(),
        })
    }

    /// Per-route comm models for the `(partition, route)` search, in the
    /// base codec's element basis, once the hierarchy has been observed.
    /// The hierarchical side is the combined per-level fit; the flat side
    /// is the live flat fit when any group has actually ridden the flat
    /// ring, and the ring-geometry conversion
    /// [`TwoLevelCost::flat_equivalent`] before that. `None` until
    /// hierarchical samples exist — there is then nothing to choose
    /// between, and the search keeps the global route.
    pub fn route_costs(&self, world: usize, nodes: usize) -> Option<RouteCostModel> {
        let rb = self.route_costs_bytes(world, nodes)?;
        Some(RouteCostModel {
            flat: rb.flat.per_elems_for(self.base_codec),
            hier: rb.hier.per_elems_for(self.base_codec),
        })
    }

    /// Assemble the codec axis for the schedule search: one
    /// [`CodecCostEntry`] per pool codec, pricing its encode/decode from
    /// the keyed fits (seeded or observed) and its collective cost from
    /// the byte-based fabric plane converted through its wire density —
    /// per route when `routing = Some((world, nodes))` and the hierarchy
    /// has been observed. `incumbent` is the current per-tensor codec
    /// assignment (backprop order); `switch_cost` is the seconds the
    /// objective charges a group for abandoning its incumbent. `None` for
    /// an empty pool.
    pub fn codec_cost_model(
        &self,
        pool: &[CodecKind],
        routing: Option<(usize, usize)>,
        switch_cost: f64,
        incumbent: Vec<CodecKind>,
    ) -> Option<CodecCostModel> {
        if pool.is_empty() {
            return None;
        }
        // The codec-agnostic fabric plane: per-level combined when the
        // hierarchy has been observed (better conditioned), total else.
        let comm_bytes = match self.two_level_fit_bytes() {
            Some(tl) => tl.combined(),
            None => self.comm.fit(),
        };
        let route_bytes = routing.and_then(|(w, l)| self.route_costs_bytes(w, l));
        let entries = pool
            .iter()
            .map(|&kind| {
                let (enc, dec) = self.codec_io_fits(kind);
                CodecCostEntry {
                    kind,
                    enc,
                    dec,
                    comm: comm_bytes.per_elems_for(kind),
                    routes: route_bytes.map(|rb| RouteCostModel {
                        flat: rb.flat.per_elems_for(kind),
                        hier: rb.hier.per_elems_for(kind),
                    }),
                }
            })
            .collect();
        Some(CodecCostModel {
            entries,
            switch_cost,
            incumbent,
        })
    }

    /// EWMA'd compute (fwd+bwd) step seconds.
    pub fn step_secs(&self) -> Option<f64> {
        self.step_secs.value()
    }

    pub fn group_samples_seen(&self) -> u64 {
        self.comm.samples()
    }

    /// Build the Eq.-7 analytic objective from the current fits. `bwd_shares`
    /// are per-tensor backward-FLOPs fractions in backprop order (summing to
    /// ~1); `fwd_frac` splits the measured step time. The measured decode
    /// samples already include the allgather fan-in, so the objective's
    /// `dec_fanin` is 1.
    pub fn objective(
        &self,
        sizes: Vec<usize>,
        bwd_shares: &[f64],
        fwd_frac: f64,
    ) -> Option<AnalyticObjective> {
        let step = self.step_secs.value()?;
        if self.group_samples_seen() == 0 {
            return None;
        }
        assert_eq!(sizes.len(), bwd_shares.len());
        let bwd = step * (1.0 - fwd_frac);
        let bwd_dur: Vec<f64> = bwd_shares.iter().map(|s| bwd * s).collect();
        // On a hierarchical fabric the per-level fits are better
        // conditioned than the single total fit (each level's α and β are
        // identified separately), and their sum is the same affine class.
        let comm = match self.two_level_fit() {
            Some(tl) => tl.combined(),
            None => self.comm_fit(),
        };
        Some(AnalyticObjective::new(
            bwd_dur,
            sizes,
            step * fwd_frac,
            self.enc.fit(),
            self.dec.fit(),
            comm,
            1,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(elems: usize, enc: f64, comm: f64, dec: f64) -> GroupSample {
        GroupSample {
            group: 0,
            elems,
            route: CommRoute::Flat,
            codec: CodecKind::Fp32,
            encode_secs: enc,
            comm_secs: comm,
            comm_exposed_secs: comm,
            comm_inter_secs: 0.0,
            decode_secs: dec,
        }
    }

    #[test]
    fn recovers_exact_linear_model() {
        let (b, g) = (2e-4, 3e-9);
        let mut e = EwmaCost::new(0.2, default_prior());
        for _ in 0..50 {
            for &n in &[1usize << 10, 1 << 14, 1 << 18, 1 << 20] {
                e.observe(n, b + g * n as f64);
            }
        }
        let f = e.fit();
        assert!((f.b - b).abs() / b < 1e-6, "b = {}", f.b);
        assert!((f.g - g).abs() / g < 1e-6, "g = {}", f.g);
        assert!(f.r2 > 0.999);
    }

    #[test]
    fn tracks_drift_away_from_initial_costs() {
        let mut e = EwmaCost::new(0.2, default_prior());
        let sizes = [1usize << 12, 1 << 16, 1 << 20];
        // Regime A, then a 10x bandwidth (slope) drop.
        for _ in 0..50 {
            for &n in &sizes {
                e.observe(n, 1e-4 + 1e-9 * n as f64);
            }
        }
        for _ in 0..200 {
            for &n in &sizes {
                e.observe(n, 1e-4 + 1e-8 * n as f64);
            }
        }
        let f = e.fit();
        assert!((f.g - 1e-8).abs() / 1e-8 < 1e-3, "g = {} after drift", f.g);
        assert!((f.b - 1e-4).abs() / 1e-4 < 1e-2, "b = {} after drift", f.b);
    }

    #[test]
    fn single_size_falls_back_to_rescaled_prior() {
        let prior = FittedCost { b: 1e-4, g: 1e-9, r2: 1.0 };
        let mut e = EwmaCost::new(0.25, prior);
        let n = 1usize << 20;
        // Observed cost is 5x the prior's prediction at this single size:
        // the model must scale up even though the slope is unidentifiable.
        let t = 5.0 * prior.predict(n);
        for _ in 0..100 {
            e.observe(n, t);
        }
        let f = e.fit();
        assert!((f.predict(n) - t).abs() / t < 1e-6, "predict {}", f.predict(n));
        let ratio_b = f.b / prior.b;
        let ratio_g = f.g / prior.g;
        assert!((ratio_b - ratio_g).abs() < 1e-9, "prior shape preserved");
        assert!((ratio_b - 5.0).abs() < 1e-6, "scaled by observed ratio");
    }

    #[test]
    fn estimator_builds_objective_after_observations() {
        let mut est = CostEstimator::new(0.2, None, None, None);
        assert!(est.objective(vec![100, 200], &[0.5, 0.5], 0.3).is_none());
        for _ in 0..10 {
            est.observe_step(
                &[sample(100, 1e-4, 2e-4, 5e-5), sample(200, 1.5e-4, 3e-4, 8e-5)],
                1e-2,
            );
        }
        let mut obj = est.objective(vec![100, 200], &[0.5, 0.5], 0.3).unwrap();
        use crate::scheduler::objective::Objective as _;
        let f = obj.eval(&crate::scheduler::Partition::full_merge(2));
        assert!(f > 1e-2, "objective includes the measured compute time");
        assert!(f.is_finite());
    }

    #[test]
    fn two_level_fits_recover_each_level_and_feed_the_objective() {
        let mut est = CostEstimator::new(0.2, None, None, None);
        assert!(est.two_level_fit().is_none(), "flat samples leave no split");

        // Intra: b=2e-5, g=1e-10. Inter: b=4e-4, g=3e-9 (dominant).
        let (bi, gi) = (2e-5, 1e-10);
        let (bx, gx) = (4e-4, 3e-9);
        for _ in 0..60 {
            for &n in &[1usize << 12, 1 << 16, 1 << 20] {
                let intra = bi + gi * n as f64;
                let inter = bx + gx * n as f64;
                let mut s = sample(n, 1e-5, intra + inter, 1e-5);
                s.route = CommRoute::TwoLevel;
                s.comm_inter_secs = inter;
                est.observe_step(&[s], 1e-2);
            }
        }
        let tl = est.two_level_fit().expect("two-level samples were fed");
        assert!((tl.inter.b - bx).abs() / bx < 1e-3, "inter b = {}", tl.inter.b);
        assert!((tl.inter.g - gx).abs() / gx < 1e-3, "inter g = {}", tl.inter.g);
        assert!((tl.intra.b - bi).abs() / bi < 1e-2, "intra b = {}", tl.intra.b);
        assert!(tl.inter_dominates(1 << 16));
        // The combined model is what the objective consumes; it must match
        // the total fit (the levels sum to the total by construction).
        let total = est.comm_fit();
        let combined = tl.combined();
        let n = 1usize << 18;
        let rel = (combined.predict(n) - total.predict(n)).abs() / total.predict(n);
        assert!(rel < 1e-6, "combined vs total at {n}: rel {rel}");
        assert!(est.objective(vec![100, 200], &[0.5, 0.5], 0.3).is_some());
    }

    #[test]
    fn route_costs_derive_flat_until_flat_samples_arrive() {
        let (world, nodes) = (8usize, 2usize);
        let mut est = CostEstimator::new(0.2, None, None, None);
        assert!(est.route_costs(world, nodes).is_none(), "no hierarchy observed yet");

        // Hierarchical samples only: the flat side must come from the
        // ring-geometry conversion of the inter fit.
        let (bi, gi) = (2e-5, 1e-10);
        let (bx, gx) = (4e-4, 3e-9);
        for _ in 0..60 {
            for &n in &[1usize << 12, 1 << 16, 1 << 20] {
                let inter = bx + gx * n as f64;
                let mut s = sample(n, 1e-5, bi + gi * n as f64 + inter, 1e-5);
                s.route = CommRoute::TwoLevel;
                s.comm_inter_secs = inter;
                est.observe_step(&[s], 1e-2);
            }
        }
        let rc = est.route_costs(world, nodes).expect("hierarchy observed");
        let derived = est.two_level_fit().unwrap().flat_equivalent(world, nodes);
        assert!((rc.flat.b - derived.b).abs() < 1e-12);
        assert!((rc.flat.g - derived.g).abs() < 1e-18);
        assert!((rc.hier.b - (bi + bx)).abs() / (bi + bx) < 1e-2);

        // Once flat-routed samples flow, the measured flat fit replaces
        // the derived one.
        let (fb, fg) = (9e-4, 8e-9);
        for _ in 0..60 {
            for &n in &[1usize << 12, 1 << 16, 1 << 20] {
                est.observe_step(&[sample(n, 1e-5, fb + fg * n as f64, 1e-5)], 1e-2);
            }
        }
        let rc = est.route_costs(world, nodes).unwrap();
        assert!((rc.flat.b - fb).abs() / fb < 1e-2, "flat b = {}", rc.flat.b);
        assert!((rc.flat.g - fg).abs() / fg < 1e-3, "flat g = {}", rc.flat.g);
    }

    #[test]
    fn byte_basis_round_trips_through_the_base_codec() {
        // Samples labeled with the base codec must reproduce the same
        // element-basis fit the pre-codec estimator produced: the wire is
        // 4·elems bytes for FP32, so filing at bytes and converting back
        // is exact.
        let (b, g) = (2e-4, 3e-9);
        let mut est = CostEstimator::new(0.2, None, None, None);
        for _ in 0..50 {
            for &n in &[1usize << 12, 1 << 16, 1 << 20] {
                est.observe_step(&[sample(n, 1e-5, b + g * n as f64, 1e-5)], 1e-2);
            }
        }
        let f = est.comm_fit();
        assert!((f.b - b).abs() / b < 1e-6, "b = {}", f.b);
        assert!((f.g - g).abs() / g < 1e-6, "g = {}", f.g);
    }

    #[test]
    fn codec_model_prices_unobserved_codecs_from_the_fabric_plane() {
        // Feed FP32 traffic only; the codec model must still price a
        // 1-bit codec's comm from the shared byte fit (≈ wire-density
        // ratio cheaper) and use its *seeded* encode/decode fits.
        let (b, g) = (1e-4, 4e-9);
        let mut est = CostEstimator::new(0.2, None, None, None);
        for _ in 0..50 {
            for &n in &[1usize << 12, 1 << 16, 1 << 20] {
                est.observe_step(&[sample(n, 2e-5, b + g * n as f64, 3e-5)], 1e-2);
            }
        }
        let enc_seed = FittedCost { b: 5e-5, g: 2e-9, r2: 1.0 };
        let dec_seed = FittedCost { b: 7e-5, g: 1e-9, r2: 1.0 };
        est.seed_codec(CodecKind::EfSignSgd, enc_seed, dec_seed);

        let cm = est
            .codec_cost_model(
                &[CodecKind::Fp32, CodecKind::EfSignSgd],
                None,
                0.0,
                Vec::new(),
            )
            .expect("non-empty pool");
        assert_eq!(cm.entries.len(), 2);
        let fp32 = cm.entry(CodecKind::Fp32).unwrap();
        let ef = cm.entry(CodecKind::EfSignSgd).unwrap();

        let n = 1usize << 20;
        // FP32's comm entry is the measured plane verbatim.
        assert!((fp32.comm.predict(n) - (b + g * n as f64)).abs() < 1e-9);
        // The sign codec moves 1/32 of the bytes: its slope must shrink by
        // the density ratio (0.125 vs 4 bytes/elem).
        let expect_g = g / 4.0 * 0.125;
        assert!(
            (ef.comm.g - expect_g).abs() / expect_g < 1e-6,
            "ef comm g = {}",
            ef.comm.g
        );
        // Encode/decode come from the seed, not the FP32 aggregates.
        assert!((ef.enc.predict(n) - enc_seed.predict(n)).abs() < 1e-12);
        assert!((ef.dec.predict(n) - dec_seed.predict(n)).abs() < 1e-12);
        assert!(ef.routes.is_none(), "no hierarchy observed, no route split");

        // Empty pool yields no model; seeding twice keeps the first fit.
        assert!(est.codec_cost_model(&[], None, 0.0, Vec::new()).is_none());
        est.seed_codec(CodecKind::EfSignSgd, default_prior(), default_prior());
        let cm2 = est
            .codec_cost_model(&[CodecKind::EfSignSgd], None, 0.0, Vec::new())
            .unwrap();
        let ef2 = cm2.entry(CodecKind::EfSignSgd).unwrap();
        assert!((ef2.enc.predict(n) - enc_seed.predict(n)).abs() < 1e-12);
    }

    #[test]
    fn observed_codec_traffic_overrides_the_seeded_io_fits() {
        // A codec that actually runs gets its enc/dec fits from live
        // samples, and its comm samples land on the shared byte plane.
        let mut est = CostEstimator::new(0.2, None, None, None);
        let (eb, eg) = (3e-5, 5e-10);
        for _ in 0..50 {
            for &n in &[1usize << 12, 1 << 16, 1 << 20] {
                let mut s = sample(n, eb + eg * n as f64, 1e-4 + 1e-9 * n as f64, 1e-5);
                s.codec = CodecKind::EfSignSgd;
                est.observe_step(&[s], 1e-2);
            }
        }
        let cm = est
            .codec_cost_model(&[CodecKind::EfSignSgd], None, 0.0, Vec::new())
            .unwrap();
        let ef = cm.entry(CodecKind::EfSignSgd).unwrap();
        assert!((ef.enc.g - eg).abs() / eg < 1e-3, "enc g = {}", ef.enc.g);
        // The byte plane saw 0.125-byte/elem traffic plus a 4-byte header:
        // converting back to FP32 elems multiplies the slope by 32.
        let f = est.comm_fit();
        assert!((f.g - 1e-9 * 32.0).abs() / (32e-9) < 1e-2, "g = {}", f.g);
    }

    #[test]
    fn rejects_bad_observations() {
        let mut e = EwmaCost::new(0.5, default_prior());
        e.observe(100, f64::NAN);
        e.observe(100, -1.0);
        assert_eq!(e.samples(), 0);
    }
}
