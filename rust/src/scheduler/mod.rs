//! The MergeComp scheduler — the paper's contribution (§4).
//!
//! - [`partition`]: contiguous model partitions (layer-wise, full-merge,
//!   naive-even, and searched).
//! - [`costmodel`]: online fitting of the paper's Assumption-5 linear
//!   overhead models from measurements.
//! - [`objective`]: the Eq. (7) iteration-time objective F(X_y).
//! - [`search`]: Algorithm 2 — the heuristic that finds a near-optimal
//!   partition with binary search over the unimodal F(X_2) (Theorem 3),
//!   extended to y > 2 one cut at a time.

pub mod costmodel;
pub mod objective;
pub mod partition;
pub mod search;

pub use costmodel::FittedCost;
pub use partition::Partition;
pub use search::{mergecomp_search, SearchOutcome, SearchParams};
