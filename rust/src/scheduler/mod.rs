//! The MergeComp scheduler — the paper's contribution (§4), plus the online
//! rescheduling loop that keeps it honest over time.
//!
//! - [`partition`]: contiguous model partitions (layer-wise, full-merge,
//!   naive-even, and searched).
//! - [`costmodel`]: one-shot fitting of the paper's Assumption-5 linear
//!   overhead models from warmup measurements.
//! - [`estimator`]: rolling, exponentially-weighted refits of the same
//!   models from live per-group timings (the measure half of the online
//!   loop).
//! - [`objective`]: the Eq. (7) iteration-time objective F(X_y).
//! - [`search`]: Algorithm 2 — the heuristic that finds a near-optimal
//!   partition with binary search over the unimodal F(X_2) (Theorem 3),
//!   extended to y > 2 one cut at a time; on hierarchical fabrics the
//!   search space is `(partition, per-group route)` and the outcome
//!   carries one [`RouteChoice`] per group.
//! - [`driver`]: the measure → search → repartition loop: periodic
//!   re-search against live fits, hysteresis against thrash, and the
//!   epoch-tagged broadcast that applies switches consistently on every
//!   rank.

pub mod costmodel;
pub mod driver;
pub mod estimator;
pub mod objective;
pub mod partition;
pub mod search;

pub use costmodel::{CodecCostEntry, CodecCostModel, FittedCost, RouteCostModel, TwoLevelCost};
pub use driver::{Decision, Driver, DriverConfig, ScheduleUpdate};
pub use objective::ShardedCost;
pub use estimator::CostEstimator;
pub use partition::Partition;
pub use search::{
    mergecomp_search, CodecMode, RouteChoice, RouteMode, SearchOutcome, SearchParams,
};
