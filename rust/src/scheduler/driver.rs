//! The online rescheduler: the measure → search → repartition loop.
//!
//! The warmup-only trainer resolved its partition exactly once and never
//! revisited it, so any drift in network conditions or step time silently
//! invalidated the schedule. The [`Driver`] closes the loop:
//!
//! 1. **measure** — every step, the exchange engine's per-group timings
//!    ([`GroupSample`]) and the measured compute time feed the rolling
//!    [`CostEstimator`];
//! 2. **search** — every `interval` steps, rank 0 re-runs Algorithm 2
//!    against an [`AnalyticObjective`] built from the *live* fits;
//! 3. **repartition** — if the predicted gain beats the hysteresis
//!    threshold ε, the new partition is adopted under a bumped **epoch**
//!    and broadcast; every rank applies the identical switch via
//!    `ExchangeEngine::repartition`, which remaps error-feedback state
//!    bit-exactly.
//!
//! Hysteresis prevents thrash: tiny predicted gains (noise-level
//! differences between neighbouring cuts) never trigger a switch, so under
//! stationary conditions the schedule is stable, while a real bandwidth or
//! latency shift produces a large predicted gain and a prompt switch.
//!
//! Consistency: partition switches must be applied on the same step on
//! every rank or ranks would issue mismatched collectives. The decision is
//! centralized (rank 0) and distributed through an **epoch-tagged
//! broadcast** at fixed step boundaries (`due`); followers apply a switch
//! iff the received epoch is ahead of theirs, and parse the bounds
//! strictly — a malformed payload is an error, never a silently-dropped
//! bound.
//!
//! **Routes ride the same broadcast.** On a hierarchical fabric the
//! schedule is `(partition, per-group route)`: [`Driver::with_routing`]
//! makes each re-search score candidate groups under both the flat ring
//! and the hierarchical exchange (the estimator's per-level fits), and an
//! adopted switch carries one [`RouteChoice`] per group inside the same
//! `{epoch, bounds, routes}` payload — a route flip lands on the same
//! step on every rank, which keeps collective tag sequences aligned and
//! the flip bit-invisible to gradients (`tests/route_choice.rs`).
//!
//! **Codecs ride it too.** Under `--codec auto` the schedule is the full
//! `(partition, per-group route, per-group codec)` triple:
//! [`Driver::with_codecs`] hands each re-search a pool of candidate
//! [`CodecKind`]s priced off the estimator's shared byte-space fabric
//! plane and per-codec encode/decode fits, with a per-group switch cost
//! charged against abandoning the incumbent codec (a codec change resets
//! or converts error-feedback state, so it must *pay for itself*). An
//! adopted switch carries one codec name per group inside the
//! `{epoch, bounds, routes, codecs}` payload, parsed as strictly as the
//! bounds; the engine applies the flip on the same step everywhere
//! (`tests/codec_choice.rs`).
//!
//! [`AnalyticObjective`]: super::objective::AnalyticObjective

use super::estimator::CostEstimator;
use super::objective::ShardedCost;
use super::partition::Partition;
use super::search::{mergecomp_search, RouteChoice, SearchParams};
use crate::collectives::Comm;
use crate::compression::CodecKind;
use crate::coordinator::GroupSample;
use crate::metrics::MetricsRegistry;
use crate::util::json::Value;

/// Online-rescheduling policy knobs (`config::TrainConfig` plumbs these
/// from `--resched-interval`, `--resched-ewma`, `--resched-eps`).
#[derive(Debug, Clone, Copy)]
pub struct DriverConfig {
    /// Steps between reschedule attempts.
    pub interval: usize,
    /// Weight of each new timing sample in the rolling fits, in (0, 1].
    pub ewma: f64,
    /// Hysteresis ε: switch only if the predicted relative gain over the
    /// current partition exceeds this fraction.
    pub hysteresis: f64,
    /// Algorithm-2 parameters for each re-search.
    pub search: SearchParams,
    /// Don't search before this many group samples have been observed.
    pub min_samples: u64,
}

impl Default for DriverConfig {
    fn default() -> Self {
        Self {
            interval: 25,
            ewma: 0.1,
            hysteresis: 0.05,
            search: SearchParams::default(),
            min_samples: 8,
        }
    }
}

/// Outcome of one rank-0 reschedule attempt.
#[derive(Debug, Clone)]
pub enum Decision {
    /// Keep the current schedule (not enough data, search returned the
    /// same `(partition, routes, codecs)`, or the predicted gain was
    /// below ε).
    Keep,
    /// Adopt `(partition, routes, codecs)`; the objective predicts `f_new`
    /// vs `f_current`. `routes` is empty when per-group routing is off,
    /// `codecs` when the codec search is off.
    Switch {
        partition: Partition,
        routes: Vec<RouteChoice>,
        codecs: Vec<CodecKind>,
        f_current: f64,
        f_new: f64,
    },
}

/// One adopted schedule switch, as returned by [`Driver::sync`]: the
/// caller repartitions its exchange engine and (when non-empty) installs
/// the per-group routes and codecs.
#[derive(Debug, Clone)]
pub struct ScheduleUpdate {
    pub partition: Partition,
    /// One route per group; empty = keep the communicator's global route.
    pub routes: Vec<RouteChoice>,
    /// One codec per group; empty = keep the configured global codec.
    pub codecs: Vec<CodecKind>,
}

/// Per-group route search configuration (only `RouteMode::Auto` reaches
/// the driver; forced modes pin the communicator's global route and never
/// need per-group state).
#[derive(Debug, Clone, Copy)]
struct Routing {
    world: usize,
    nodes: usize,
}

/// Per-group codec search configuration (only `CodecMode::Auto` reaches
/// the driver; fixed mode pins the configured codec and needs no
/// per-group state).
#[derive(Debug, Clone)]
struct CodecAxis {
    /// The configured training codec: the schedule every group starts on
    /// and the fallback when the search reports no codec freedom.
    base: CodecKind,
    /// Candidate kinds each re-search prices per group (always contains
    /// `base` and `Fp32`).
    pool: Vec<CodecKind>,
    /// Seconds the objective charges a group for leaving its incumbent
    /// codec (EF-state conversion/reset amortization).
    switch_cost: f64,
}

/// The online rescheduler for one training run. All ranks construct one
/// (same config); only rank 0's estimator drives decisions, the others
/// follow the epoch broadcast.
pub struct Driver {
    cfg: DriverConfig,
    est: CostEstimator,
    /// Per-tensor element counts, backprop order.
    sizes: Vec<usize>,
    /// Per-tensor backward-FLOPs shares, backprop order (sums to ~1).
    bwd_shares: Vec<f64>,
    fwd_frac: f64,
    partition: Partition,
    /// Per-group routes of the current schedule; empty when per-group
    /// routing is off (the communicator's global route applies).
    routes: Vec<RouteChoice>,
    /// Per-group codecs of the current schedule; empty when the codec
    /// search is off (the configured global codec applies).
    codecs: Vec<CodecKind>,
    routing: Option<Routing>,
    codec_axis: Option<CodecAxis>,
    /// `Some(base codec)` when the run exchanges under `--exchange-mode
    /// sharded`: every re-search prices the reduce-scatter + parameter
    /// allgather byte pattern instead of the full allreduce.
    sharded: Option<CodecKind>,
    epoch: u64,
    /// Number of adopted partition switches.
    pub reschedules: usize,
    /// Objective evaluations spent across all re-searches.
    pub search_evals: usize,
    metrics: MetricsRegistry,
}

impl Driver {
    pub fn new(
        cfg: DriverConfig,
        est: CostEstimator,
        sizes: Vec<usize>,
        bwd_shares: Vec<f64>,
        fwd_frac: f64,
        initial: Partition,
    ) -> Self {
        assert_eq!(sizes.len(), bwd_shares.len());
        assert_eq!(sizes.len(), initial.num_tensors());
        Self {
            cfg,
            est,
            sizes,
            bwd_shares,
            fwd_frac,
            partition: initial,
            routes: Vec::new(),
            codecs: Vec::new(),
            routing: None,
            codec_axis: None,
            sharded: None,
            epoch: 0,
            reschedules: 0,
            search_evals: 0,
            metrics: MetricsRegistry::new(),
        }
    }

    /// Enable per-group route search (`--route auto` on a non-trivial
    /// topology): every re-search scores candidate groups under both the
    /// flat ring and the hierarchical exchange, and switches carry one
    /// [`RouteChoice`] per group. Initial routes are all-hierarchical —
    /// the communicator's default on a non-trivial topology — so the
    /// estimator sees per-level samples from the first step. `nodes` is
    /// the **top ring's** member count (`Topology::top_leaders().len()`,
    /// the stage the measured inter split times — equal to the node count
    /// only on two-level topologies).
    pub fn with_routing(mut self, world: usize, nodes: usize) -> Self {
        self.routes = vec![RouteChoice::Hierarchical; self.partition.num_groups()];
        self.routing = Some(Routing { world, nodes });
        self
    }

    /// Enable per-group codec search (`--codec auto`): every re-search
    /// prices candidate groups under each pool codec and switches carry
    /// one [`CodecKind`] per group. `base` is the configured training
    /// codec — every group starts on it, and it joins the pool along with
    /// uncompressed FP32 (so the search can always decline to compress a
    /// latency-bound group). `switch_cost` (seconds) is charged against
    /// any group that abandons its incumbent codec, amortizing the
    /// error-feedback reset a codec flip may cost.
    pub fn with_codecs(mut self, base: CodecKind, pool: &[CodecKind], switch_cost: f64) -> Self {
        let mut dedup: Vec<CodecKind> = Vec::new();
        for k in [base, CodecKind::Fp32].iter().chain(pool) {
            if !dedup.contains(k) {
                dedup.push(*k);
            }
        }
        self.codecs = vec![base; self.partition.num_groups()];
        self.codec_axis = Some(CodecAxis {
            base,
            pool: dedup,
            switch_cost: switch_cost.max(0.0),
        });
        self
    }

    /// Price re-searches for the sharded exchange (`--exchange-mode
    /// sharded`): AllReduce-codec groups on the flat ring are charged
    /// half their allreduce cost (the reduce-scatter phase alone), and
    /// every group additionally pays the uncompressed-FP32 allgather of
    /// the updated parameter shards. `base` is the configured training
    /// codec (the objective's price floor when the codec search is off).
    pub fn with_sharded_exchange(mut self, base: CodecKind) -> Self {
        self.sharded = Some(base);
        self
    }

    pub fn config(&self) -> &DriverConfig {
        &self.cfg
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Per-group routes of the current schedule (empty = global route).
    pub fn routes(&self) -> &[RouteChoice] {
        &self.routes
    }

    /// Per-group codecs of the current schedule (empty = global codec).
    pub fn codecs(&self) -> &[CodecKind] {
        &self.codecs
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn estimator(&self) -> &CostEstimator {
        &self.est
    }

    /// Reschedule counters / gains ("resched.*" namespace).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Feed one step's measurements (every rank calls this; cheap).
    pub fn observe(&mut self, samples: &[GroupSample], compute_secs: f64) {
        self.est.observe_step(samples, compute_secs);
    }

    /// Is `step` a reschedule boundary? Must be a pure function of the
    /// config and the step so all ranks agree without communicating.
    pub fn due(&self, step: usize) -> bool {
        step > 0 && step % self.cfg.interval.max(1) == 0
    }

    /// Rank-0 decision: re-run Algorithm 2 against the live cost fits and
    /// apply hysteresis. Does not communicate and does not mutate the
    /// current schedule — pair with [`Driver::apply`] (local/simulated) or
    /// [`Driver::sync`] (distributed).
    pub fn decide(&mut self) -> Decision {
        self.metrics.incr("resched.attempts", 1);
        if self.est.group_samples_seen() < self.cfg.min_samples {
            return Decision::Keep;
        }
        let obj = self
            .est
            .objective(self.sizes.clone(), &self.bwd_shares, self.fwd_frac);
        let mut obj = match obj {
            Some(o) => o,
            None => return Decision::Keep,
        };
        // On a hierarchical fabric, record which level the live fits say
        // dominates (diagnostics; the objective already uses the combined
        // per-level model).
        if let Some(tl) = self.est.two_level_fit() {
            self.metrics.gauge("resched.comm_inter_g", tl.inter.g);
            self.metrics.gauge("resched.comm_intra_g", tl.intra.g);
        }
        // Route search: attach the per-route comm models so Algorithm 2
        // minimizes over (partition, per-group route).
        if let Some(r) = self.routing {
            obj.set_route_costs(self.est.route_costs(r.world, r.nodes));
        }
        // Codec search: attach the per-codec cost entries so the search
        // also minimizes over the per-group codec, with the incumbent
        // assignment charged zero switch penalty.
        if let Some(ca) = &self.codec_axis {
            let routing = self.routing.map(|r| (r.world, r.nodes));
            obj.set_codec_costs(self.est.codec_cost_model(
                &ca.pool,
                routing,
                ca.switch_cost,
                self.incumbent_codecs(),
            ));
        }
        // Sharded exchange: reprice every candidate's comm term as
        // reduce-scatter + FP32 parameter allgather.
        if let Some(base) = self.sharded {
            obj.set_sharded_exchange(Some(ShardedCost {
                fp32_comm: self.est.fp32_comm_fit(),
                base_codec: base,
            }));
        }
        use super::objective::Objective as _;
        let f_current = obj.eval_with_schedule(&self.partition, &self.routes, &self.codecs);
        let out = mergecomp_search(&mut obj, self.sizes.len(), self.cfg.search);
        self.search_evals += obj.evals();
        let new_routes = if self.routing.is_some() {
            if out.routes.is_empty() {
                // No route model identified yet: stay on the hierarchy.
                vec![RouteChoice::Hierarchical; out.partition.num_groups()]
            } else {
                out.routes
            }
        } else {
            Vec::new()
        };
        let new_codecs = match &self.codec_axis {
            Some(ca) => {
                if out.codecs.is_empty() {
                    // No codec model attached (e.g. empty pool): stay on
                    // the configured codec everywhere.
                    vec![ca.base; out.partition.num_groups()]
                } else {
                    out.codecs
                }
            }
            None => Vec::new(),
        };
        let gain = (f_current - out.f_min) / f_current.max(f64::MIN_POSITIVE);
        self.metrics.observe("resched.predicted_gain", gain);
        let unchanged = out.partition == self.partition
            && new_routes == self.routes
            && new_codecs == self.codecs;
        if unchanged || gain <= self.cfg.hysteresis {
            return Decision::Keep;
        }
        Decision::Switch {
            partition: out.partition,
            routes: new_routes,
            codecs: new_codecs,
            f_current,
            f_new: out.f_min,
        }
    }

    /// The current per-tensor codec assignment (backprop order): each
    /// tensor inherits its group's codec. This is what the objective's
    /// switch-cost penalty is charged against, so a candidate group
    /// spanning tensors that already run its chosen codec switches for
    /// free even across a repartition.
    fn incumbent_codecs(&self) -> Vec<CodecKind> {
        if self.codecs.is_empty() {
            return Vec::new();
        }
        (0..self.partition.num_groups())
            .flat_map(|j| self.partition.group_range(j).map(move |_| self.codecs[j]))
            .collect()
    }

    /// Adopt a new `(partition, routes, codecs)` locally, bumping the
    /// epoch. Used directly by the single-process simulation loop; the
    /// trainer goes through [`Driver::sync`] so every rank switches on the
    /// same step. An empty `routes` means "no per-group routing"; an empty
    /// `codecs` means "no per-group codec search".
    pub fn apply(
        &mut self,
        partition: Partition,
        routes: Vec<RouteChoice>,
        codecs: Vec<CodecKind>,
    ) {
        assert_eq!(partition.num_tensors(), self.sizes.len());
        if !routes.is_empty() {
            assert_eq!(routes.len(), partition.num_groups(), "one route per group");
        }
        if !codecs.is_empty() {
            assert_eq!(codecs.len(), partition.num_groups(), "one codec per group");
        }
        self.partition = partition;
        self.metrics.gauge(
            "resched.flat_groups",
            routes.iter().filter(|&&r| r == RouteChoice::Flat).count() as f64,
        );
        if let Some(ca) = &self.codec_axis {
            self.metrics.gauge(
                "resched.nonbase_codec_groups",
                codecs.iter().filter(|&&k| k != ca.base).count() as f64,
            );
        }
        self.routes = routes;
        self.codecs = codecs;
        self.epoch += 1;
        self.reschedules += 1;
        self.metrics.incr("resched.switches", 1);
        self.metrics.gauge("resched.epoch", self.epoch as f64);
    }

    /// Restore the adopted schedule from a checkpoint: the
    /// `(partition, routes, codecs)` triple and the epoch it was adopted
    /// under. Unlike [`Driver::apply`] this neither bumps the epoch nor
    /// counts as a reschedule — the switch happened in a previous
    /// incarnation of the run; this driver merely resumes from it.
    /// Counters (`reschedules`, `search_evals`) restart at zero: they
    /// describe this process's work. The estimator's fits also restart
    /// cold and re-warm from live measurements.
    pub fn restore_schedule(
        &mut self,
        partition: Partition,
        routes: Vec<RouteChoice>,
        codecs: Vec<CodecKind>,
        epoch: u64,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            partition.num_tensors() == self.sizes.len(),
            "restore_schedule: partition is over {} tensors, driver has {}",
            partition.num_tensors(),
            self.sizes.len()
        );
        anyhow::ensure!(
            routes.is_empty() || routes.len() == partition.num_groups(),
            "restore_schedule: {} routes for {} groups",
            routes.len(),
            partition.num_groups()
        );
        anyhow::ensure!(
            codecs.is_empty() || codecs.len() == partition.num_groups(),
            "restore_schedule: {} codecs for {} groups",
            codecs.len(),
            partition.num_groups()
        );
        self.partition = partition;
        self.routes = routes;
        self.codecs = codecs;
        self.epoch = epoch;
        self.metrics.gauge("resched.epoch", self.epoch as f64);
        Ok(())
    }

    /// Distribute one reschedule decision: rank 0 folds `decision` into
    /// its schedule state and broadcasts `{epoch, bounds, routes, codecs}`;
    /// followers adopt the broadcast schedule iff its epoch is ahead of
    /// theirs (strictly parsed — any malformed bound, route, or codec
    /// token is an error). Every rank must call this at the same step
    /// (`due`). Returns the new `(partition, routes, codecs)` when this
    /// rank switched (the caller then remaps its exchange engine and
    /// installs the routes and codecs).
    pub fn sync(
        &mut self,
        comm: &mut Comm,
        decision: Decision,
    ) -> anyhow::Result<Option<ScheduleUpdate>> {
        let n = self.sizes.len();
        if comm.rank() == 0 {
            let switched = match decision {
                Decision::Switch {
                    partition,
                    routes,
                    codecs,
                    ..
                } => {
                    self.apply(partition, routes, codecs);
                    true
                }
                Decision::Keep => false,
            };
            let routes_json = Value::Arr(
                self.routes
                    .iter()
                    .map(|r| Value::from(r.name()))
                    .collect(),
            );
            let codecs_json = Value::Arr(
                self.codecs
                    .iter()
                    .map(|k| Value::from(k.name()))
                    .collect(),
            );
            let payload = Value::from_pairs(vec![
                ("epoch", Value::from(self.epoch)),
                ("bounds", self.partition.bounds_to_json()),
                ("routes", routes_json),
                ("codecs", codecs_json),
            ]);
            let mut bytes = payload.to_string_compact().into_bytes();
            comm.broadcast(0, &mut bytes)?;
            Ok(switched.then(|| ScheduleUpdate {
                partition: self.partition.clone(),
                routes: self.routes.clone(),
                codecs: self.codecs.clone(),
            }))
        } else {
            let mut bytes = Vec::new();
            comm.broadcast(0, &mut bytes)?;
            let text = std::str::from_utf8(&bytes)
                .map_err(|e| anyhow::anyhow!("schedule broadcast: invalid utf8: {e}"))?;
            let v = Value::parse(text)
                .map_err(|e| anyhow::anyhow!("schedule broadcast: {e}"))?;
            let epoch = v
                .get("epoch")
                .and_then(Value::as_usize)
                .ok_or_else(|| anyhow::anyhow!("schedule broadcast: missing epoch"))?
                as u64;
            anyhow::ensure!(
                epoch == self.epoch || epoch == self.epoch + 1,
                "schedule broadcast: epoch {epoch} unreachable from local {}",
                self.epoch
            );
            if epoch == self.epoch {
                return Ok(None);
            }
            let bounds = v
                .get("bounds")
                .ok_or_else(|| anyhow::anyhow!("schedule broadcast: missing bounds"))?;
            let partition = Partition::from_json_bounds(n, bounds)?;
            let routes = parse_routes(&v, partition.num_groups())?;
            let codecs = parse_codecs(&v, partition.num_groups())?;
            self.apply(partition.clone(), routes.clone(), codecs.clone());
            Ok(Some(ScheduleUpdate {
                partition,
                routes,
                codecs,
            }))
        }
    }
}

/// Strict parse of the broadcast's `routes` array: every entry must be a
/// known route token, and a non-empty list must have one entry per group —
/// a malformed route is an error, never a silently-defaulted one (the same
/// contract as the partition bounds).
fn parse_routes(v: &Value, groups: usize) -> anyhow::Result<Vec<RouteChoice>> {
    let routes_v = v
        .get("routes")
        .ok_or_else(|| anyhow::anyhow!("schedule broadcast: missing routes"))?;
    let arr = routes_v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("schedule broadcast: routes is not an array"))?;
    let routes = arr
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let token = t
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("schedule broadcast: routes[{i}] not a string"))?;
            RouteChoice::from_name(token)
                .map_err(|e| anyhow::anyhow!("schedule broadcast: routes[{i}]: {e}"))
        })
        .collect::<anyhow::Result<Vec<RouteChoice>>>()?;
    anyhow::ensure!(
        routes.is_empty() || routes.len() == groups,
        "schedule broadcast: {} routes for {groups} groups",
        routes.len()
    );
    Ok(routes)
}

/// Strict parse of the broadcast's `codecs` array, under the same
/// contract as `parse_routes`: every entry must be a known codec name
/// ([`CodecKind::from_name`]) and a non-empty list must have one entry per
/// group. The pool only ever holds default-parameterized kinds, whose
/// `name()` round-trips through `from_name` exactly.
fn parse_codecs(v: &Value, groups: usize) -> anyhow::Result<Vec<CodecKind>> {
    let codecs_v = v
        .get("codecs")
        .ok_or_else(|| anyhow::anyhow!("schedule broadcast: missing codecs"))?;
    let arr = codecs_v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("schedule broadcast: codecs is not an array"))?;
    let codecs = arr
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let token = t
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("schedule broadcast: codecs[{i}] not a string"))?;
            CodecKind::from_name(token)
                .map_err(|e| anyhow::anyhow!("schedule broadcast: codecs[{i}]: {e}"))
        })
        .collect::<anyhow::Result<Vec<CodecKind>>>()?;
    anyhow::ensure!(
        codecs.is_empty() || codecs.len() == groups,
        "schedule broadcast: {} codecs for {groups} groups",
        codecs.len()
    );
    Ok(codecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_comm_group, CommRoute};
    use crate::coordinator::GroupSample;
    use crate::scheduler::costmodel::FittedCost;

    fn sample(elems: usize, enc: f64, comm: f64, dec: f64) -> GroupSample {
        GroupSample {
            group: 0,
            elems,
            route: CommRoute::Flat,
            codec: crate::compression::CodecKind::Fp32,
            encode_secs: enc,
            comm_secs: comm,
            comm_exposed_secs: comm,
            comm_inter_secs: 0.0,
            decode_secs: dec,
        }
    }

    fn driver_with(interval: usize, hysteresis: f64, n: usize) -> Driver {
        let cfg = DriverConfig {
            interval,
            ewma: 0.25,
            hysteresis,
            search: SearchParams { y_max: 3, alpha: 0.0 },
            min_samples: 4,
        };
        let est = CostEstimator::new(cfg.ewma, None, None, None);
        Driver::new(
            cfg,
            est,
            vec![10_000; n],
            vec![1.0 / n as f64; n],
            0.3,
            Partition::full_merge(n),
        )
    }

    /// Synthetic measured plane with comm ≈ compute (the partition-sensitive
    /// sweet spot): under a full merge none of the collective is hidden, so
    /// the search can win ~`bwd` seconds of overlap by splitting.
    fn feed(d: &mut Driver, b: f64, g: f64, steps: usize) {
        for _ in 0..steps {
            // Two distinct sizes so the slope is identifiable.
            let s1 = sample(4_000, 1e-5, b + g * 4_000.0, 1e-5);
            let s2 = sample(36_000, 1e-5, b + g * 36_000.0, 1e-5);
            d.observe(&[s1, s2], 4e-2);
        }
    }

    #[test]
    fn due_is_periodic_and_skips_step_zero() {
        let d = driver_with(10, 0.05, 4);
        assert!(!d.due(0));
        assert!(d.due(10));
        assert!(!d.due(11));
        assert!(d.due(20));
    }

    #[test]
    fn keeps_before_min_samples() {
        let mut d = driver_with(10, 0.05, 4);
        assert!(matches!(d.decide(), Decision::Keep));
    }

    #[test]
    fn hysteresis_blocks_marginal_switches() {
        // ε = ∞ effectively: even a real improvement must be kept.
        let mut d = driver_with(10, 1e9, 8);
        feed(&mut d, 1e-6, 1e-7, 50);
        assert!(matches!(d.decide(), Decision::Keep));
        assert_eq!(d.epoch(), 0);
    }

    #[test]
    fn switches_when_gain_is_large_and_epoch_advances() {
        let mut d = driver_with(10, 0.05, 8);
        // Comm dominated by a steep slope: splitting overlaps comm under
        // backward compute, so some multi-group partition beats full merge.
        feed(&mut d, 1e-6, 5e-7, 60);
        match d.decide() {
            Decision::Switch { partition, routes, codecs, f_current, f_new } => {
                assert!(partition.num_groups() > 1);
                assert!(routes.is_empty(), "no routing enabled");
                assert!(codecs.is_empty(), "no codec search enabled");
                assert!(f_new < f_current);
                d.apply(partition, routes, codecs);
            }
            Decision::Keep => panic!("expected a switch under comm-dominated costs"),
        }
        assert_eq!(d.epoch(), 1);
        assert_eq!(d.reschedules, 1);
        assert_eq!(d.metrics().counter_value("resched.switches"), 1);
        // Stationary conditions after the switch: no thrash.
        feed(&mut d, 1e-6, 5e-7, 60);
        if let Decision::Switch { f_current, f_new, .. } = d.decide() {
            panic!("thrash: re-switched {f_current} -> {f_new} with unchanged costs");
        }
    }

    #[test]
    fn sync_applies_same_epoch_partition_routes_and_codecs_on_all_ranks() {
        use crate::compression::CodecKind::{EfSignSgd, Fp32};
        use crate::scheduler::RouteChoice::{Flat, Hierarchical};
        let results = run_comm_group(3, |c| {
            let mut d = driver_with(10, 0.05, 8)
                .with_routing(3, 2)
                .with_codecs(EfSignSgd, &[], 0.0);
            // Rank 0 decides a switch with mixed routes and codecs;
            // followers pass Keep (ignored).
            let decision = if c.rank() == 0 {
                Decision::Switch {
                    partition: Partition::naive_even(8, 3),
                    routes: vec![Flat, Hierarchical, Flat],
                    codecs: vec![EfSignSgd, Fp32, EfSignSgd],
                    f_current: 1.0,
                    f_new: 0.5,
                }
            } else {
                Decision::Keep
            };
            let switched = d.sync(c, decision).unwrap();
            (
                d.epoch(),
                d.partition().bounds().to_vec(),
                d.routes().to_vec(),
                d.codecs().to_vec(),
                switched.is_some(),
            )
        });
        for (epoch, bounds, routes, codecs, switched) in &results {
            assert_eq!(*epoch, 1);
            assert_eq!(bounds, results[0].1.as_slice());
            assert_eq!(routes, &vec![Flat, Hierarchical, Flat]);
            assert_eq!(codecs, &vec![EfSignSgd, Fp32, EfSignSgd]);
            assert!(*switched);
        }
    }

    #[test]
    fn route_search_flips_groups_to_flat_when_the_hierarchy_stops_paying() {
        // Routing over 8 ranks / 2 nodes. The hierarchical samples carry a
        // huge intra (fan-stage) cost next to a tiny inter ring, so the
        // flat ring implied by the inter fit is far cheaper at every size:
        // the re-search must flip every group's route to Flat.
        let mut d = driver_with(10, 0.05, 8).with_routing(8, 2);
        assert_eq!(d.routes(), &[RouteChoice::Hierarchical]);
        let (bi, gi) = (2e-2, 1e-7);
        let (bx, gx) = (1e-6, 1e-9);
        let mk = |elems: usize| {
            let inter = bx + gx * elems as f64;
            let mut s = sample(elems, 1e-5, bi + gi * elems as f64 + inter, 1e-5);
            s.route = CommRoute::TwoLevel;
            s.comm_inter_secs = inter;
            s
        };
        for _ in 0..60 {
            d.observe(&[mk(4_000), mk(36_000)], 4e-2);
        }
        match d.decide() {
            Decision::Switch { partition, routes, codecs, f_current, f_new } => {
                assert!(f_new < f_current);
                assert_eq!(routes.len(), partition.num_groups());
                assert!(
                    routes.iter().all(|&r| r == RouteChoice::Flat),
                    "expected all-flat routes, got {routes:?}"
                );
                d.apply(partition, routes, codecs);
            }
            Decision::Keep => panic!("expected a route switch away from the hierarchy"),
        }
        assert!(d.routes().iter().all(|&r| r == RouteChoice::Flat));
        // Stationary conditions: no thrash back.
        for _ in 0..60 {
            d.observe(&[mk(4_000), mk(36_000)], 4e-2);
        }
        assert!(matches!(d.decide(), Decision::Keep));
    }

    #[test]
    fn codec_search_moves_comm_bound_groups_off_fp32() {
        use crate::compression::CodecKind;
        let cfg = DriverConfig {
            interval: 10,
            ewma: 0.25,
            hysteresis: 0.05,
            search: SearchParams { y_max: 3, alpha: 0.0 },
            min_samples: 4,
        };
        // Seed a near-free 1-bit codec so the pool is priceable before it
        // ever runs; FP32 traffic dominates the measured comm plane.
        let mut est = CostEstimator::new(cfg.ewma, None, None, None);
        let tiny = FittedCost { b: 1e-6, g: 1e-11, r2: 1.0 };
        est.seed_codec(CodecKind::EfSignSgd, tiny, tiny);
        let n = 8;
        let mut d = Driver::new(
            cfg,
            est,
            vec![10_000; n],
            vec![1.0 / n as f64; n],
            0.3,
            Partition::full_merge(n),
        )
        .with_codecs(CodecKind::Fp32, &[CodecKind::EfSignSgd], 0.0);
        assert_eq!(d.codecs(), &[CodecKind::Fp32], "starts on the base codec");
        feed(&mut d, 1e-6, 5e-7, 60);
        match d.decide() {
            Decision::Switch { partition, routes, codecs, f_current, f_new } => {
                assert!(f_new < f_current);
                assert_eq!(codecs.len(), partition.num_groups(), "one codec per group");
                assert!(
                    codecs.contains(&CodecKind::EfSignSgd),
                    "comm-bound groups should compress, got {codecs:?}"
                );
                d.apply(partition, routes, codecs);
            }
            Decision::Keep => panic!("expected a codec switch under comm-dominated costs"),
        }
        // Stationary conditions with the new incumbent: no thrash.
        feed(&mut d, 1e-6, 5e-7, 60);
        assert!(matches!(d.decide(), Decision::Keep));
    }

    #[test]
    fn parse_codecs_is_strict() {
        use crate::compression::CodecKind::{EfSignSgd, Fp32};
        let ok = Value::parse(r#"{"codecs": ["fp32", "efsignsgd"]}"#).unwrap();
        assert_eq!(parse_codecs(&ok, 2).unwrap(), vec![Fp32, EfSignSgd]);
        let empty = Value::parse(r#"{"codecs": []}"#).unwrap();
        assert!(parse_codecs(&empty, 3).unwrap().is_empty());
        // Wrong count, unknown token, wrong types, missing key: all errors.
        assert!(parse_codecs(&ok, 3).is_err());
        let bad = Value::parse(r#"{"codecs": ["fp32", "zip"]}"#).unwrap();
        assert!(parse_codecs(&bad, 2).is_err());
        let bad = Value::parse(r#"{"codecs": [1, 2]}"#).unwrap();
        assert!(parse_codecs(&bad, 2).is_err());
        let bad = Value::parse(r#"{"codecs": "fp32"}"#).unwrap();
        assert!(parse_codecs(&bad, 1).is_err());
        let bad = Value::parse(r#"{"epoch": 1}"#).unwrap();
        assert!(parse_codecs(&bad, 1).is_err());
    }

    #[test]
    fn parse_routes_is_strict() {
        let ok = Value::parse(r#"{"routes": ["flat", "hier"]}"#).unwrap();
        assert_eq!(
            parse_routes(&ok, 2).unwrap(),
            vec![RouteChoice::Flat, RouteChoice::Hierarchical]
        );
        let empty = Value::parse(r#"{"routes": []}"#).unwrap();
        assert!(parse_routes(&empty, 3).unwrap().is_empty());
        // Wrong count, unknown token, wrong types, missing key: all errors.
        assert!(parse_routes(&ok, 3).is_err());
        let bad = Value::parse(r#"{"routes": ["flat", "warp"]}"#).unwrap();
        assert!(parse_routes(&bad, 2).is_err());
        let bad = Value::parse(r#"{"routes": [1, 2]}"#).unwrap();
        assert!(parse_routes(&bad, 2).is_err());
        let bad = Value::parse(r#"{"routes": "flat"}"#).unwrap();
        assert!(parse_routes(&bad, 1).is_err());
        let bad = Value::parse(r#"{"epoch": 1}"#).unwrap();
        assert!(parse_routes(&bad, 1).is_err());
    }

    #[test]
    fn sync_keep_is_a_no_op_everywhere() {
        let results = run_comm_group(2, |c| {
            let mut d = driver_with(10, 0.05, 8);
            let switched = d.sync(c, Decision::Keep).unwrap();
            (d.epoch(), switched.is_none())
        });
        for (epoch, kept) in results {
            assert_eq!(epoch, 0);
            assert!(kept);
        }
    }

    #[test]
    fn estimator_priors_shape_the_first_fit() {
        let prior = FittedCost { b: 5e-4, g: 2e-9, r2: 1.0 };
        let est = CostEstimator::new(0.2, Some(prior), Some(prior), Some(prior));
        assert_eq!(est.comm.fit().b, prior.b);
        assert_eq!(est.comm.fit().g, prior.g);
    }
}
