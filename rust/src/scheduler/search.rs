//! Algorithm 2 — MergeComp's heuristic model-partition search.
//!
//! Structure follows the paper's §9.3.3 proof of Theorem 3:
//!
//! - `y = 2`: `F(X_2)` as a function of the single cut point first
//!   decreases (growing the first group grows its overlap) and then
//!   increases (the first group's communication no longer finishes before
//!   backprop does) — unimodal, so the optimal cut is found by a
//!   golden-section-style **binary search over cut positions** in
//!   O(log N) evaluations.
//! - `y > 2`: fix the first `y−2` cut points (enumerated), solve the last
//!   one by the same unimodal search → O(N^{y−2}·log N) (Theorem 3).
//! - The outer loop grows `y` from 2 to `Y`, stopping early when the best
//!   `y`-group partition is worse than the `(y−1)`-group one or improves it
//!   by less than `α·F_min(y−1)` — the diminishing-returns rule that makes
//!   `Y = 2` the paper's recommended setting (§5.2).
//!
//! **Route-aware search.** On a hierarchical fabric the search space is
//! `(partition, per-group route)`, not partitions alone: each candidate
//! group is scored under both the flat ring and the hierarchical exchange
//! (the per-level α+β·size fits of
//! [`RouteCostModel`](super::costmodel::RouteCostModel)), the cheaper one
//! wins, and [`SearchOutcome::routes`] records one [`RouteChoice`] per
//! group of the winning partition. Because the route decomposes per group,
//! minimizing over routes inside the objective searches the product space
//! exactly — no extra enumeration. Objectives without route freedom return
//! no routes and callers keep the communicator's global route.
//!
//! **Codec-aware search.** Under `--codec auto` the space grows a third
//! axis: `(partition, per-group route, per-group codec)`. Each candidate
//! group is priced under every codec in the pool (per-codec encode/decode
//! fits plus the byte-based fabric plane converted through each codec's
//! wire density — [`CodecCostModel`](super::costmodel::CodecCostModel)),
//! jointly with the route, and [`SearchOutcome::codecs`] records one
//! [`CodecKind`] per group. FP32 always rides in the pool, so "don't
//! compress" is a first-class outcome for latency-bound groups. Like the
//! route axis, the codec choice decomposes per group, so minimizing inside
//! the objective searches the product space exactly.

use super::objective::{Memo, Objective};
use super::partition::Partition;
use crate::compression::CodecKind;

/// Which collective algorithm one tensor group rides — the scheduler-side
/// counterpart of [`CommRoute`](crate::collectives::CommRoute), chosen per
/// group by Algorithm 2 from the fitted per-level costs.
///
/// ```
/// use mergecomp::scheduler::RouteChoice;
/// let r = RouteChoice::from_name("hier").unwrap();
/// assert_eq!(r, RouteChoice::Hierarchical);
/// assert_eq!(RouteChoice::from_name(r.name()).unwrap(), r);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouteChoice {
    /// Single-level ring over all ranks.
    #[default]
    Flat,
    /// The hierarchical exchange over the attached topology (fan-in up
    /// the leader chain, top-leader ring, fan-out).
    Hierarchical,
}

impl RouteChoice {
    /// Wire token used in the epoch-tagged schedule broadcast.
    pub fn name(&self) -> &'static str {
        match self {
            RouteChoice::Flat => "flat",
            RouteChoice::Hierarchical => "hier",
        }
    }

    /// Strict inverse of [`RouteChoice::name`] (any other token is an
    /// error — a malformed route must never be silently defaulted).
    pub fn from_name(name: &str) -> anyhow::Result<RouteChoice> {
        Ok(match name {
            "flat" => RouteChoice::Flat,
            "hier" => RouteChoice::Hierarchical,
            other => anyhow::bail!("unknown route '{other}' (flat|hier)"),
        })
    }
}

/// Config/CLI-facing route policy (`--route auto|flat|hierarchical`):
/// let the search pick per group, or pin every group to one route.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouteMode {
    /// Algorithm 2 chooses per group from the fitted per-level costs.
    #[default]
    Auto,
    /// Every group rides the flat ring.
    Flat,
    /// Every group rides the hierarchical exchange.
    Hierarchical,
}

impl RouteMode {
    pub fn name(&self) -> &'static str {
        match self {
            RouteMode::Auto => "auto",
            RouteMode::Flat => "flat",
            RouteMode::Hierarchical => "hierarchical",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<RouteMode> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "auto" => RouteMode::Auto,
            "flat" => RouteMode::Flat,
            "hierarchical" | "hier" | "two-level" | "twolevel" => RouteMode::Hierarchical,
            other => anyhow::bail!("unknown route mode '{other}' (auto|flat|hierarchical)"),
        })
    }

    /// The uniform per-group choice a forced mode pins (`None` for
    /// `Auto`).
    pub fn forced(&self) -> Option<RouteChoice> {
        match self {
            RouteMode::Auto => None,
            RouteMode::Flat => Some(RouteChoice::Flat),
            RouteMode::Hierarchical => Some(RouteChoice::Hierarchical),
        }
    }
}

/// Config/CLI-facing codec policy: `--codec auto` lets Algorithm 2 pick a
/// codec per group from the fitted per-codec costs; naming a codec pins
/// every group to it (the pre-codec-search behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CodecMode {
    /// Every group runs the single configured codec.
    #[default]
    Fixed,
    /// Algorithm 2 chooses `(partition, route, codec)` per group; FP32 is
    /// always in the candidate pool so "don't compress" is a first-class
    /// outcome.
    Auto,
}

impl CodecMode {
    pub fn name(&self) -> &'static str {
        match self {
            CodecMode::Fixed => "fixed",
            CodecMode::Auto => "auto",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<CodecMode> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "fixed" => CodecMode::Fixed,
            "auto" => CodecMode::Auto,
            other => anyhow::bail!("unknown codec mode '{other}' (fixed|auto)"),
        })
    }
}

/// Algorithm 2 inputs: Y (max groups) and α (marginal-benefit threshold).
#[derive(Debug, Clone, Copy)]
pub struct SearchParams {
    pub y_max: usize,
    pub alpha: f64,
}

impl Default for SearchParams {
    fn default() -> Self {
        // Paper §5.2: Y = 2 suffices in practice; α small.
        Self {
            y_max: 2,
            alpha: 0.02,
        }
    }
}

/// Search result.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub partition: Partition,
    pub f_min: f64,
    /// One [`RouteChoice`] per group of `partition`, when the objective
    /// has route freedom (a fitted [`RouteCostModel`]); empty otherwise —
    /// callers then keep the communicator's global route.
    ///
    /// [`RouteCostModel`]: super::costmodel::RouteCostModel
    pub routes: Vec<RouteChoice>,
    /// One [`CodecKind`] per group of `partition`, when the objective has
    /// codec freedom (an attached [`CodecCostModel`]); empty otherwise —
    /// callers then keep the configured codec everywhere.
    ///
    /// [`CodecCostModel`]: super::costmodel::CodecCostModel
    pub codecs: Vec<CodecKind>,
    /// Best objective found for each explored y (1-indexed by position 0 = y 1).
    pub per_y: Vec<(usize, f64)>,
    /// Objective evaluations spent (the paper reports < 50 iterations for
    /// Y = 2 on the measured plane).
    pub evals: usize,
}

/// Unimodal minimization of `f(cut)` over `cut ∈ [lo, hi]` (inclusive) by
/// ternary search, with a final exhaustive sweep of the residual bracket —
/// robust to small plateaus from discrete tensor sizes.
fn unimodal_min(
    mut f: impl FnMut(usize) -> f64,
    mut lo: usize,
    mut hi: usize,
) -> (usize, f64) {
    assert!(lo <= hi);
    while hi - lo > 3 {
        let third = (hi - lo) / 3;
        let m1 = lo + third;
        let m2 = hi - third;
        if f(m1) <= f(m2) {
            hi = m2 - 1;
        } else {
            lo = m1 + 1;
        }
    }
    let mut best = (lo, f(lo));
    for c in lo + 1..=hi {
        let v = f(c);
        if v < best.1 {
            best = (c, v);
        }
    }
    best
}

/// Find the best y-group partition with the first `y−2` cuts fixed,
/// searching the final cut in the open interval after `fixed`'s last cut.
fn best_last_cut(
    memo: &mut Memo,
    n: usize,
    fixed: &[usize],
) -> Option<(Partition, f64)> {
    let start = fixed.last().copied().unwrap_or(0) + 1;
    if start > n - 1 {
        return None;
    }
    let eval_cut = |memo: &mut Memo, c: usize| {
        let mut cuts = fixed.to_vec();
        cuts.push(c);
        let p = Partition::from_cuts(n, cuts);
        (p.clone(), memo.eval(&p))
    };
    let (c, f) = unimodal_min(|c| eval_cut(memo, c).1, start, n - 1);
    Some(eval_cut(memo, c)).map(|(p, _)| (p, f))
}

/// Enumerate all fixed-prefix combinations for `y` groups (`y−2` cuts) and
/// binary-search the last cut for each — the §9.3.3 procedure. To keep
/// wall-clock bounded on huge models a stride coarsens the enumeration once
/// the combination count passes `budget` (documented deviation; exact for
/// every paper experiment, which all use Y ≤ 4 and N ≤ 314 with budget
/// defaults far above the need).
fn best_partition_for_y(
    memo: &mut Memo,
    n: usize,
    y: usize,
    budget: usize,
) -> Option<(Partition, f64)> {
    assert!(y >= 2);
    if y > n {
        return None;
    }
    if y == 2 {
        return best_last_cut(memo, n, &[]);
    }
    // Enumerate the first y-2 cuts with optional stride coarsening.
    let prefix_len = y - 2;
    let combos = (n as f64).powi(prefix_len as i32);
    let stride = if combos > budget as f64 {
        ((combos / budget as f64).powf(1.0 / prefix_len as f64)).ceil() as usize
    } else {
        1
    }
    .max(1);

    let mut best: Option<(Partition, f64)> = None;
    let mut prefix = vec![0usize; prefix_len];

    // Odometer over increasing cut positions with the given stride.
    fn rec(
        memo: &mut Memo,
        n: usize,
        prefix: &mut Vec<usize>,
        level: usize,
        start: usize,
        stride: usize,
        y: usize,
        best: &mut Option<(Partition, f64)>,
    ) {
        let remaining = (y - 2) - level;
        if level == y - 2 {
            if let Some((p, f)) = best_last_cut(memo, n, prefix) {
                if best.as_ref().map(|(_, bf)| f < *bf).unwrap_or(true) {
                    *best = Some((p, f));
                }
            }
            return;
        }
        // Leave room for the remaining cuts plus the last searched one.
        let hi = n - 1 - remaining;
        let mut c = start;
        while c <= hi {
            prefix[level] = c;
            rec(memo, n, prefix, level + 1, c + 1, stride, y, best);
            c += stride;
        }
    }
    rec(memo, n, &mut prefix, 0, 1, stride, y, &mut best);
    best
}

/// Algorithm 2. `objective` scores candidate partitions (lower = faster
/// iteration); `n` is the tensor count in backprop order.
pub fn mergecomp_search(
    objective: &mut dyn Objective,
    n: usize,
    params: SearchParams,
) -> SearchOutcome {
    let mut memo = Memo::new(objective);
    let full = Partition::full_merge(n);
    let mut f_min = memo.eval(&full); // F_min(1) = F(X_1)
    let mut best = full;
    let mut per_y = vec![(1usize, f_min)];

    let y_max = params.y_max.clamp(1, n.max(1));
    for y in 2..=y_max {
        let Some((cand, f)) = best_partition_for_y(&mut memo, n, y, 2_000_000) else {
            break;
        };
        per_y.push((y, f));
        if f_min < f {
            // F_min(y-1) < F_min(y): stop, keep y-1 groups.
            break;
        }
        let improved = f_min - f;
        best = cand;
        let prev = f_min;
        f_min = f;
        if improved < params.alpha * prev {
            // Marginal benefit below α: stop with y groups.
            break;
        }
    }

    let routes = memo.routes(&best);
    let codecs = memo.codecs(&best);
    SearchOutcome {
        partition: best,
        f_min,
        routes,
        codecs,
        per_y,
        evals: memo.evals(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::objective::{MeasuredObjective, Objective, SimObjective};
    use super::*;
    use crate::compression::CodecKind;
    use crate::netsim::Fabric;
    use crate::profiles::{resnet101_imagenet, resnet50_cifar10};
    use crate::simulator::SimSetup;

    #[test]
    fn unimodal_min_finds_valley() {
        // f(c) = (c - 37)^2 over [1, 100]
        let (c, v) = unimodal_min(|c| ((c as f64) - 37.0).powi(2), 1, 100);
        assert_eq!(c, 37);
        assert_eq!(v, 0.0);
        // Plateau at the bottom.
        let (c, _) = unimodal_min(|c| ((c as isize - 10).abs().max(2)) as f64, 1, 50);
        assert!((8..=12).contains(&c));
        // Monotone functions: boundary minima.
        let (c, _) = unimodal_min(|c| c as f64, 1, 99);
        assert_eq!(c, 1);
        let (c, _) = unimodal_min(|c| -(c as f64), 1, 99);
        assert_eq!(c, 99);
    }

    fn sim_objective(kind: CodecKind, world: usize) -> (SimObjective<'static>, usize) {
        static PROFILE: std::sync::OnceLock<crate::profiles::ModelProfile> =
            std::sync::OnceLock::new();
        let profile = PROFILE.get_or_init(resnet50_cifar10);
        let setup = SimSetup {
            profile,
            kind,
            fabric: Fabric::pcie(),
            world,
        };
        (SimObjective::new(setup), profile.num_tensors())
    }

    #[test]
    fn y2_search_matches_exhaustive() {
        let (mut obj, n) = sim_objective(CodecKind::Dgc { ratio: 0.01 }, 4);
        // Exhaustive best cut.
        let mut best_f = f64::INFINITY;
        for c in 1..n {
            let f = obj.eval(&Partition::from_cuts(n, vec![c]));
            best_f = best_f.min(f);
        }
        let (mut obj2, _) = sim_objective(CodecKind::Dgc { ratio: 0.01 }, 4);
        let out = mergecomp_search(&mut obj2, n, SearchParams { y_max: 2, alpha: 0.0 });
        assert!(
            out.f_min <= best_f * 1.001,
            "binary search {} vs exhaustive {}",
            out.f_min,
            best_f
        );
        // O(log N) evals, not O(N): the paper's <50-iterations claim.
        assert!(out.evals < 50, "used {} evals", out.evals);
    }

    #[test]
    fn search_reports_routes_when_the_objective_has_route_freedom() {
        use crate::scheduler::costmodel::{FittedCost, RouteCostModel};
        use crate::scheduler::objective::AnalyticObjective;
        let zero = FittedCost { b: 0.0, g: 0.0, r2: 1.0 };
        let flat = FittedCost { b: 1e-5, g: 1e-8, r2: 1.0 };
        let hier = FittedCost { b: 2e-4, g: 1e-9, r2: 1.0 };
        let sizes: Vec<usize> = [vec![100usize; 4], vec![1_000_000usize; 4]].concat();
        let mut obj =
            AnalyticObjective::new(vec![1e-3; 8], sizes, 1e-3, zero, zero, flat, 1)
                .with_route_costs(RouteCostModel { flat, hier });
        let out = mergecomp_search(&mut obj, 8, SearchParams { y_max: 3, alpha: 0.0 });
        assert_eq!(out.routes.len(), out.partition.num_groups());
        // A route-free objective reports no routes.
        let (mut sim, n) = sim_objective(CodecKind::EfSignSgd, 4);
        let out = mergecomp_search(&mut sim, n, SearchParams::default());
        assert!(out.routes.is_empty());
    }

    #[test]
    fn search_reports_codecs_when_the_objective_has_codec_freedom() {
        use crate::scheduler::costmodel::{CodecCostEntry, CodecCostModel, FittedCost};
        use crate::scheduler::objective::AnalyticObjective;
        let zero = FittedCost { b: 0.0, g: 0.0, r2: 1.0 };
        // Byte-priced fabric plane: FP32 is latency-free, TopK trades a
        // real encode cost for 0.8% of the wire bytes.
        let wire = FittedCost { b: 5e-5, g: 1e-9, r2: 1.0 };
        let topk = CodecKind::TopK { ratio: 0.01 };
        let entries = vec![
            CodecCostEntry {
                kind: CodecKind::Fp32,
                enc: zero,
                dec: zero,
                comm: wire.per_elems_for(CodecKind::Fp32),
                routes: None,
            },
            CodecCostEntry {
                kind: topk,
                enc: FittedCost { b: 2e-4, g: 2e-9, r2: 1.0 },
                dec: FittedCost { b: 1e-5, g: 1e-10, r2: 1.0 },
                comm: wire.per_elems_for(topk),
                routes: None,
            },
        ];
        let sizes: Vec<usize> = [vec![100usize; 4], vec![4_000_000usize; 4]].concat();
        let mut obj = AnalyticObjective::new(
            vec![1e-3; 8],
            sizes,
            1e-3,
            zero,
            zero,
            wire.per_elems_for(CodecKind::Fp32),
            1,
        )
        .with_codec_costs(CodecCostModel {
            entries,
            switch_cost: 0.0,
            incumbent: Vec::new(),
        });
        let out = mergecomp_search(&mut obj, 8, SearchParams { y_max: 3, alpha: 0.0 });
        assert_eq!(out.codecs.len(), out.partition.num_groups());
        assert!(
            out.codecs.contains(&topk),
            "the huge tail must compress: {:?}",
            out.codecs
        );
        // A codec-free objective reports no codecs.
        let (mut sim, n) = sim_objective(CodecKind::EfSignSgd, 4);
        let out = mergecomp_search(&mut sim, n, SearchParams::default());
        assert!(out.codecs.is_empty());
    }

    #[test]
    fn codec_mode_names_are_strict() {
        assert!(CodecMode::from_name("turbo").is_err());
        assert_eq!(CodecMode::from_name("auto").unwrap(), CodecMode::Auto);
        assert_eq!(CodecMode::from_name("fixed").unwrap(), CodecMode::Fixed);
        assert_eq!(CodecMode::default(), CodecMode::Fixed);
        for m in [CodecMode::Auto, CodecMode::Fixed] {
            assert_eq!(CodecMode::from_name(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn route_names_are_strict() {
        assert!(RouteChoice::from_name("warp").is_err());
        assert!(RouteMode::from_name("scenic").is_err());
        assert_eq!(RouteMode::from_name("two-level").unwrap(), RouteMode::Hierarchical);
        assert_eq!(RouteMode::Auto.forced(), None);
        assert_eq!(RouteMode::Flat.forced(), Some(RouteChoice::Flat));
        assert_eq!(
            RouteMode::Hierarchical.forced(),
            Some(RouteChoice::Hierarchical)
        );
        for m in [RouteMode::Auto, RouteMode::Flat, RouteMode::Hierarchical] {
            assert_eq!(RouteMode::from_name(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn search_beats_layerwise_and_naive() {
        for kind in [
            CodecKind::Dgc { ratio: 0.01 },
            CodecKind::EfSignSgd,
            CodecKind::Fp16,
        ] {
            let (mut obj, n) = sim_objective(kind, 8);
            let f_layer = obj.eval(&Partition::layer_wise(n));
            let f_naive = obj.eval(&Partition::naive_even(n, 2));
            let (mut obj2, _) = sim_objective(kind, 8);
            let out = mergecomp_search(&mut obj2, n, SearchParams::default());
            assert!(
                out.f_min <= f_naive + 1e-12,
                "{}: search {} > naive {}",
                kind.name(),
                out.f_min,
                f_naive
            );
            assert!(
                out.f_min <= f_layer,
                "{}: search {} > layer-wise {}",
                kind.name(),
                out.f_min,
                f_layer
            );
        }
    }

    #[test]
    fn y3_no_worse_than_y2() {
        let profile = resnet101_imagenet();
        let setup = SimSetup {
            profile: &profile,
            kind: CodecKind::EfSignSgd,
            fabric: Fabric::pcie(),
            world: 8,
        };
        let mut o2 = SimObjective::new(setup);
        let f2 = mergecomp_search(&mut o2, profile.num_tensors(), SearchParams { y_max: 2, alpha: 0.0 }).f_min;
        let mut o3 = SimObjective::new(setup);
        let f3 = mergecomp_search(&mut o3, profile.num_tensors(), SearchParams { y_max: 3, alpha: 0.0 }).f_min;
        assert!(f3 <= f2 + 1e-12, "y=3 search must contain y=2 ({f3} vs {f2})");
    }

    #[test]
    fn alpha_stops_early() {
        let (mut obj, n) = sim_objective(CodecKind::EfSignSgd, 4);
        // Huge alpha: any improvement below 90% stops at y=2.
        let out = mergecomp_search(&mut obj, n, SearchParams { y_max: 4, alpha: 0.9 });
        assert!(out.partition.num_groups() <= 2);
        assert!(out.per_y.len() <= 2 + 1);
    }

    #[test]
    fn degenerate_single_tensor_model() {
        let mut obj = MeasuredObjective::new(|p: &Partition| p.num_groups() as f64);
        let out = mergecomp_search(&mut obj, 1, SearchParams::default());
        assert_eq!(out.partition.num_groups(), 1);
    }

    #[test]
    fn measured_objective_prefers_fewer_groups_when_flat() {
        // Objective = number of groups (monotone): Alg. 2 must return y=1.
        let mut obj = MeasuredObjective::new(|p: &Partition| p.num_groups() as f64);
        let out = mergecomp_search(&mut obj, 50, SearchParams { y_max: 4, alpha: 0.01 });
        assert_eq!(out.partition.num_groups(), 1);
        assert_eq!(out.f_min, 1.0);
    }
}
