//! Online fitting of the paper's Assumption-5 linear cost models.
//!
//! Assumption 5: compression time `h(x) = B_h + γ_h·x` and communication
//! time `g(x) = B_g + γ_g·x`. The real execution plane measures (size, time)
//! samples during warm-up steps and fits them here by least squares; the
//! fit quality (R²) doubles as a runtime check that the assumption actually
//! holds on the current hardware (`ablate_calibration` bench).
//!
//! On a hierarchical fabric the single `g(x)` hides which link class is
//! actually the bottleneck, so [`TwoLevelCost`] keeps one α+β·size fit per
//! level (intra-node, inter-node). The sum of two affine models is affine,
//! so [`TwoLevelCost::combined`] plugs straight into the Eq.-7 objective —
//! the search automatically optimizes against whichever level dominates.

use crate::compression::CodecKind;
use crate::util::stats::linfit;

/// A fitted `t(x) = b + g·x` model with its fit quality.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FittedCost {
    /// Startup/latency term (seconds).
    pub b: f64,
    /// Per-element term (seconds/element).
    pub g: f64,
    /// Coefficient of determination of the fit.
    pub r2: f64,
}

impl FittedCost {
    /// Fit from (elements, seconds) samples. Requires ≥ 2 distinct sizes.
    pub fn fit(samples: &[(usize, f64)]) -> anyhow::Result<FittedCost> {
        anyhow::ensure!(samples.len() >= 2, "need at least two samples");
        let xs: Vec<f64> = samples.iter().map(|(n, _)| *n as f64).collect();
        let ys: Vec<f64> = samples.iter().map(|(_, t)| *t).collect();
        anyhow::ensure!(
            xs.iter().any(|&x| x != xs[0]),
            "need at least two distinct sizes to identify the slope"
        );
        let (b, g, r2) = linfit(&xs, &ys);
        Ok(FittedCost {
            // Negative intercepts/slopes are fit noise; clamp to the
            // physically meaningful region.
            b: b.max(0.0),
            g: g.max(0.0),
            r2,
        })
    }

    pub fn predict(&self, elems: usize) -> f64 {
        self.b + self.g * elems as f64
    }

    /// Reinterpret a **wire-byte**-based fit (`t = b + g·bytes`) as an
    /// element-based fit for `kind`, via its affine wire size
    /// `bytes ≈ header + density·elems` ([`CodecKind::wire_affine`]).
    ///
    /// This is how one fitted fabric plane prices every codec, including
    /// codecs that have never run: the collective's cost depends on the
    /// bytes it moves, and the codec only enters through its wire density.
    pub fn per_elems_for(&self, kind: CodecKind) -> FittedCost {
        let (header, density) = kind.wire_affine();
        FittedCost {
            b: self.b + self.g * header,
            g: self.g * density,
            r2: self.r2,
        }
    }
}

/// Per-level communication cost models for a hierarchical fabric: the fan
/// (intra) stages and the top-leader ring (inter), each fit as its own
/// Assumption-5 affine model. (On an N-level topology "inter" is the
/// topmost ring and "intra" lumps every fan stage below it — the split
/// [`CommBreakdown`](crate::collectives::CommBreakdown) reports.)
///
/// The per-level split is what lets the scheduler reason about *routes*,
/// not just partitions: [`TwoLevelCost::combined`] prices the hierarchical
/// exchange, [`TwoLevelCost::flat_equivalent`] converts the inter-level
/// fit into the flat ring's implied cost, and [`RouteCostModel`] feeds
/// both to Algorithm 2 so each group rides whichever route its size
/// favors.
///
/// ```
/// use mergecomp::scheduler::costmodel::{FittedCost, TwoLevelCost};
/// let tl = TwoLevelCost {
///     intra: FittedCost { b: 1e-5, g: 1e-10, r2: 1.0 },
///     inter: FittedCost { b: 5e-4, g: 2e-9, r2: 1.0 },
/// };
/// // The combined model is the sum of the levels (affine again):
/// let c = tl.combined();
/// assert!((c.predict(1000) - (tl.intra.predict(1000) + tl.inter.predict(1000))).abs() < 1e-12);
/// // Here the inter level dominates at every size:
/// assert!(tl.inter_dominates(1) && tl.inter_dominates(1 << 24));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TwoLevelCost {
    /// Fan stages (member→leader fan-in + leader→member fan-out).
    pub intra: FittedCost,
    /// Top ring among the topmost-level leaders.
    pub inter: FittedCost,
}

impl TwoLevelCost {
    /// Total communication model: the levels run back-to-back, and the sum
    /// of two affine models is affine — directly usable as the objective's
    /// `g(x)`.
    pub fn combined(&self) -> FittedCost {
        FittedCost {
            b: self.intra.b + self.inter.b,
            g: self.intra.g + self.inter.g,
            r2: self.intra.r2.min(self.inter.r2),
        }
    }

    /// Does the inter-node level dominate the predicted cost at this group
    /// size? (What the partition search is implicitly optimizing against.)
    pub fn inter_dominates(&self, elems: usize) -> bool {
        self.inter.predict(elems) >= self.intra.predict(elems)
    }

    /// The **flat ring's** implied cost model on the same fabric, derived
    /// from the inter-level fit alone — how the scheduler prices the route
    /// it is *not* currently running, before any flat samples exist.
    ///
    /// Derivation: the inter fit models the leader ring — `2(L−1)` steps
    /// for an allreduce, each paying the slow link's latency `α` plus a
    /// `1/L`-sized chunk over its bandwidth `β` — so `b = 2(L−1)·α` and
    /// `g = 2(L−1)/L · c` with `c` the per-element wire cost. A flat ring
    /// over all `w` ranks is gated by the same slow link on **every** one
    /// of its `2(w−1)` steps (that is the hierarchy's whole premise), so
    /// its implied model is `b·(w−1)/(L−1)` and `g·(w−1)·L/(w·(L−1))`.
    /// The allgather conversion works out to the same two factors under
    /// near-even node splits (`m = w/L` members per node), so one formula
    /// serves both collectives; uneven splits make it an approximation,
    /// which live flat samples replace as soon as any group actually
    /// rides the flat ring. `nodes` is the size `L` of the ring the inter
    /// fit actually timed — the **top** ring
    /// (`Topology::top_leaders().len()`) on an N-level topology.
    pub fn flat_equivalent(&self, world: usize, nodes: usize) -> FittedCost {
        if world <= 1 || nodes <= 1 || nodes >= world {
            return self.combined();
        }
        let w = world as f64;
        let l = nodes as f64;
        FittedCost {
            b: self.inter.b * (w - 1.0) / (l - 1.0),
            g: self.inter.g * (w - 1.0) * l / (w * (l - 1.0)),
            r2: self.inter.r2,
        }
    }
}

/// Fitted cost of synchronizing a group under each available route — the
/// objective Algorithm 2 minimizes over when the search space is
/// `(partition, per-group route)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteCostModel {
    /// Flat ring over all ranks.
    pub flat: FittedCost,
    /// Hierarchical exchange (both levels, i.e. [`TwoLevelCost::combined`]).
    pub hier: FittedCost,
}

impl RouteCostModel {
    pub fn cost(&self, route: super::search::RouteChoice) -> FittedCost {
        match route {
            super::search::RouteChoice::Flat => self.flat,
            super::search::RouteChoice::Hierarchical => self.hier,
        }
    }

    /// The cheaper route for a group of `elems` elements and its predicted
    /// cost. Ties break to `Flat` deterministically (fewer moving parts).
    pub fn best(&self, elems: usize) -> (super::search::RouteChoice, f64) {
        let f = self.flat.predict(elems);
        let h = self.hier.predict(elems);
        if h < f {
            (super::search::RouteChoice::Hierarchical, h)
        } else {
            (super::search::RouteChoice::Flat, f)
        }
    }
}

/// Fitted costs of synchronizing a group under one candidate codec: the
/// encode path, the decode path (full group, fan-in included — matching
/// the measured [`GroupSample`](crate::coordinator::GroupSample)
/// semantics), and the collective cost converted to this codec's wire
/// density (per route when the fabric is hierarchical).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodecCostEntry {
    pub kind: CodecKind,
    pub enc: FittedCost,
    pub dec: FittedCost,
    /// Collective cost under the global/flat route (element basis for
    /// `kind`; superseded by `routes` when present).
    pub comm: FittedCost,
    /// Per-route collective costs for `kind`, when the hierarchy has been
    /// observed — the joint `(codec, route)` choice prices both axes.
    pub routes: Option<RouteCostModel>,
}

impl CodecCostEntry {
    /// Collective cost of a group of `elems` elements: pinned to `route`
    /// when given and a route model exists, else the cheaper route, else
    /// the global model. Returns the route actually priced (`None` when
    /// the entry has no route freedom).
    pub fn comm_for(
        &self,
        elems: usize,
        route: Option<super::search::RouteChoice>,
    ) -> (Option<super::search::RouteChoice>, f64) {
        match (&self.routes, route) {
            (Some(rm), Some(r)) => (Some(r), rm.cost(r).predict(elems)),
            (Some(rm), None) => {
                let (r, c) = rm.best(elems);
                (Some(r), c)
            }
            (None, r) => (r, self.comm.predict(elems)),
        }
    }
}

/// The codec axis of the schedule search: one [`CodecCostEntry`] per
/// candidate codec (FP32 always included upstream, so "don't compress" is
/// a first-class outcome), the incumbent codec of every tensor, and the
/// switch cost the objective charges a group for abandoning its incumbent
/// — pricing the error-feedback state conversion/reset a codec flip costs
/// so the search doesn't thrash.
#[derive(Debug, Clone, PartialEq)]
pub struct CodecCostModel {
    pub entries: Vec<CodecCostEntry>,
    /// Seconds charged per group whose chosen codec differs from the
    /// incumbent codec of any tensor it spans.
    pub switch_cost: f64,
    /// Incumbent codec per tensor, backprop order (empty = no incumbent,
    /// e.g. the very first search — no switch penalty anywhere).
    pub incumbent: Vec<CodecKind>,
}

impl CodecCostModel {
    pub fn entry(&self, kind: CodecKind) -> Option<&CodecCostEntry> {
        self.entries.iter().find(|e| e.kind == kind)
    }
}

/// Accumulates timing samples for one operation kind and fits on demand.
#[derive(Debug, Clone, Default)]
pub struct CostSampler {
    samples: Vec<(usize, f64)>,
}

impl CostSampler {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, elems: usize, seconds: f64) {
        self.samples.push((elems, seconds));
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn fit(&self) -> anyhow::Result<FittedCost> {
        FittedCost::fit(&self.samples)
    }

    pub fn samples(&self) -> &[(usize, f64)] {
        &self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_linear_model() {
        let b = 1.5e-4;
        let g = 2e-9;
        let samples: Vec<(usize, f64)> = [64usize, 1024, 65536, 1 << 20]
            .iter()
            .map(|&n| (n, b + g * n as f64))
            .collect();
        let fit = FittedCost::fit(&samples).unwrap();
        assert!((fit.b - b).abs() / b < 1e-9);
        assert!((fit.g - g).abs() / g < 1e-9);
        assert!(fit.r2 > 0.999);
    }

    #[test]
    fn noisy_fit_still_close() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (b, g) = (1e-4, 1e-9);
        let mut s = CostSampler::new();
        for _ in 0..200 {
            let n = 1usize << (6 + rng.gen_range(15));
            let noise = 1.0 + 0.1 * (rng.next_f64() - 0.5);
            s.record(n, (b + g * n as f64) * noise);
        }
        let fit = s.fit().unwrap();
        assert!((fit.b - b).abs() / b < 0.3, "b = {}", fit.b);
        assert!((fit.g - g).abs() / g < 0.2, "g = {}", fit.g);
        assert!(fit.r2 > 0.9);
    }

    #[test]
    fn rejects_degenerate_input() {
        assert!(FittedCost::fit(&[(10, 1.0)]).is_err());
        assert!(FittedCost::fit(&[(10, 1.0), (10, 1.1)]).is_err());
    }

    #[test]
    fn two_level_combined_is_the_sum_and_dominance_flips_with_size() {
        // Intra: cheap latency, decent bandwidth. Inter: big latency, slow
        // bandwidth — the multi-node regime.
        let tl = TwoLevelCost {
            intra: FittedCost { b: 1e-5, g: 1e-10, r2: 1.0 },
            inter: FittedCost { b: 5e-4, g: 2e-9, r2: 0.9 },
        };
        let c = tl.combined();
        assert!((c.b - 5.1e-4).abs() < 1e-12);
        assert!((c.g - 2.1e-9).abs() < 1e-18);
        assert_eq!(c.r2, 0.9);
        assert!(tl.inter_dominates(1));
        assert!(tl.inter_dominates(1 << 24));
        // Flip the levels: intra dominates everywhere.
        let tl = TwoLevelCost { intra: tl.inter, inter: tl.intra };
        assert!(!tl.inter_dominates(1 << 20));
    }

    #[test]
    fn flat_equivalent_inverts_the_ring_geometry() {
        use crate::scheduler::RouteChoice;
        // Leader ring over L=2 nodes of w=8 ranks: 2(L−1)=2 steps of
        // chunk x/2. α=50µs per step, c=1ns/elem on the slow link.
        let (alpha, c) = (50e-6, 1e-9);
        let (l, w) = (2.0f64, 8.0f64);
        let inter = FittedCost {
            b: 2.0 * (l - 1.0) * alpha,
            g: 2.0 * (l - 1.0) / l * c,
            r2: 1.0,
        };
        let tl = TwoLevelCost {
            intra: FittedCost { b: 0.0, g: 0.0, r2: 1.0 },
            inter,
        };
        let flat = tl.flat_equivalent(8, 2);
        // Flat ring: 2(w−1) steps of α, chunk x/w over the same link.
        assert!((flat.b - 2.0 * (w - 1.0) * alpha).abs() < 1e-12, "b = {}", flat.b);
        assert!((flat.g - 2.0 * (w - 1.0) / w * c).abs() < 1e-20, "g = {}", flat.g);
        // Degenerate shapes fall back to the combined model.
        assert_eq!(tl.flat_equivalent(1, 1), tl.combined());
        assert_eq!(tl.flat_equivalent(8, 8), tl.combined());

        // A route model over (flat, hier): latency favors flat at small
        // sizes once the hier path pays real fan-stage latency.
        let rc = RouteCostModel {
            flat,
            hier: TwoLevelCost {
                intra: FittedCost { b: 3e-4, g: 1e-11, r2: 1.0 },
                inter,
            }
            .combined(),
        };
        let (small, _) = rc.best(1);
        let (large, _) = rc.best(1 << 24);
        assert_eq!(small, RouteChoice::Flat);
        assert_eq!(large, RouteChoice::Hierarchical);
        assert_eq!(rc.cost(RouteChoice::Flat), rc.flat);
        assert_eq!(rc.cost(RouteChoice::Hierarchical), rc.hier);
    }

    #[test]
    fn per_elems_conversion_matches_exact_wire_sizes() {
        // One fabric plane in bytes: α = 100µs, 1ns/byte.
        let bytes_fit = FittedCost { b: 1e-4, g: 1e-9, r2: 1.0 };
        for kind in CodecKind::paper_set() {
            let f = bytes_fit.per_elems_for(kind);
            for &n in &[1usize << 12, 1 << 16, 1 << 20] {
                let exact = bytes_fit.b + bytes_fit.g * kind.wire_size(n) as f64;
                let rel = (f.predict(n) - exact).abs() / exact;
                assert!(
                    rel < 1e-3,
                    "{} n={n}: affine {} vs exact {exact}",
                    kind.name(),
                    f.predict(n)
                );
            }
        }
        // FP32 is the identity up to the 4-bytes-per-element density.
        let f = bytes_fit.per_elems_for(CodecKind::Fp32);
        assert_eq!(f.b, bytes_fit.b);
        assert_eq!(f.g, 4.0 * bytes_fit.g);
        // A dense codec prices above a 1% sparsifier at bandwidth-bound
        // sizes — the ordering the codec search exploits.
        let dense = bytes_fit.per_elems_for(CodecKind::Fp32);
        let sparse = bytes_fit.per_elems_for(CodecKind::TopK { ratio: 0.01 });
        assert!(dense.predict(1 << 22) > sparse.predict(1 << 22));
    }

    #[test]
    fn codec_entries_price_routes_jointly() {
        use crate::scheduler::RouteChoice;
        let flat = FittedCost { b: 1e-5, g: 1e-8, r2: 1.0 };
        let hier = FittedCost { b: 2e-4, g: 1e-9, r2: 1.0 };
        let zero = FittedCost { b: 0.0, g: 0.0, r2: 1.0 };
        let entry = CodecCostEntry {
            kind: CodecKind::Fp32,
            enc: zero,
            dec: zero,
            comm: flat,
            routes: Some(RouteCostModel { flat, hier }),
        };
        // Small groups ride flat, large ones hier; pinning overrides.
        let (r, c) = entry.comm_for(100, None);
        assert_eq!(r, Some(RouteChoice::Flat));
        assert_eq!(c, flat.predict(100));
        let (r, _) = entry.comm_for(1 << 24, None);
        assert_eq!(r, Some(RouteChoice::Hierarchical));
        let (r, c) = entry.comm_for(1 << 24, Some(RouteChoice::Flat));
        assert_eq!(r, Some(RouteChoice::Flat));
        assert_eq!(c, flat.predict(1 << 24));
        // Without route freedom the global model applies.
        let bare = CodecCostEntry { routes: None, ..entry };
        let (r, c) = bare.comm_for(1 << 24, None);
        assert_eq!(r, None);
        assert_eq!(c, flat.predict(1 << 24));
        // Model lookup by kind (PartialEq covers parameterized kinds).
        let cm = CodecCostModel {
            entries: vec![entry],
            switch_cost: 0.0,
            incumbent: Vec::new(),
        };
        assert!(cm.entry(CodecKind::Fp32).is_some());
        assert!(cm.entry(CodecKind::Fp16).is_none());
    }

    #[test]
    fn clamps_negative_terms() {
        // Decreasing times would fit a negative slope; clamp to 0.
        let fit = FittedCost::fit(&[(100, 2e-3), (10_000, 1e-3)]).unwrap();
        assert_eq!(fit.g, 0.0);
        assert!(fit.b >= 0.0);
    }
}
