//! Model partitions (paper §4.3).
//!
//! A partition splits the model's N gradient tensors — **in back-propagation
//! order** (the order gradients become available, i.e. reverse forward
//! order) — into `y` contiguous groups. Contiguity follows the paper: groups
//! are compressed and communicated as their last tensor's gradient arrives,
//! so a group is an interval of the backprop sequence (Lemma 1 counts
//! exactly the `C(N-1, y-1)` interval partitions).

/// A contiguous partition over `n` backprop-ordered tensors.
///
/// `bounds` has `y+1` entries: group `j` covers tensor indices
/// `bounds[j]..bounds[j+1]` (backprop order), `bounds[0] == 0`,
/// `bounds[y] == n`.
///
/// ```
/// use mergecomp::scheduler::Partition;
/// let p = Partition::from_cuts(5, vec![2]);
/// assert_eq!(p.num_groups(), 2);
/// assert_eq!(p.group_range(1), 2..5);
/// assert_eq!(p.group_elems(&[10, 20, 30, 40, 50]), vec![30, 120]);
/// // Bounds round-trip through the schedule broadcast's JSON wire form,
/// // and malformed payloads are errors, never silently-dropped bounds:
/// let wire = p.bounds_to_json();
/// assert_eq!(Partition::from_json_bounds(5, &wire).unwrap(), p);
/// assert!(Partition::try_from_bounds(5, vec![0, 2, 2, 5]).is_err());
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    bounds: Vec<usize>,
    n: usize,
}

impl Partition {
    pub fn from_bounds(n: usize, bounds: Vec<usize>) -> Partition {
        Partition::try_from_bounds(n, bounds).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Validating constructor for bounds from untrusted sources (schedule
    /// broadcasts, config files): returns an error instead of panicking.
    pub fn try_from_bounds(n: usize, bounds: Vec<usize>) -> anyhow::Result<Partition> {
        anyhow::ensure!(n >= 1, "empty models have no partitions");
        anyhow::ensure!(bounds.len() >= 2, "need at least one group");
        anyhow::ensure!(bounds[0] == 0, "bounds must start at 0, got {}", bounds[0]);
        let last = *bounds.last().unwrap();
        anyhow::ensure!(last == n, "bounds must end at n = {n}, got {last}");
        for w in bounds.windows(2) {
            anyhow::ensure!(
                w[0] < w[1],
                "groups must be non-empty and ordered ({} !< {})",
                w[0],
                w[1]
            );
        }
        Ok(Partition { bounds, n })
    }

    /// Bounds as a JSON array (the wire format of the schedule broadcast).
    pub fn bounds_to_json(&self) -> crate::util::json::Value {
        crate::util::json::Value::Arr(
            self.bounds
                .iter()
                .map(|&b| crate::util::json::Value::from(b))
                .collect(),
        )
    }

    /// Strict inverse of [`Partition::bounds_to_json`]: any missing,
    /// non-array, or non-usize entry is an error — malformed bounds must
    /// never be silently dropped (a dropped entry would merge two groups on
    /// one rank only and corrupt training).
    pub fn from_json_bounds(
        n: usize,
        v: &crate::util::json::Value,
    ) -> anyhow::Result<Partition> {
        let arr = v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("partition bounds: not an array"))?;
        let bounds = arr
            .iter()
            .enumerate()
            .map(|(i, b)| {
                b.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("partition bounds[{i}]: not a usize ({b:?})"))
            })
            .collect::<anyhow::Result<Vec<usize>>>()?;
        Partition::try_from_bounds(n, bounds)
    }

    /// Cut points between groups (excluding 0 and n).
    pub fn from_cuts(n: usize, mut cuts: Vec<usize>) -> Partition {
        cuts.sort_unstable();
        cuts.dedup();
        let mut bounds = Vec::with_capacity(cuts.len() + 2);
        bounds.push(0);
        bounds.extend(cuts.into_iter().filter(|&c| c > 0 && c < n));
        bounds.push(n);
        Partition::from_bounds(n, bounds)
    }

    /// Layer-wise compression: one group per tensor (the status quo the
    /// paper's §3 profiles).
    pub fn layer_wise(n: usize) -> Partition {
        Partition::from_bounds(n, (0..=n).collect())
    }

    /// Single group: compress the whole model at once (the paper's extreme
    /// case: no WFBP overlap at all).
    pub fn full_merge(n: usize) -> Partition {
        Partition::from_bounds(n, vec![0, n])
    }

    /// Naive baseline (paper Table 3): split the *tensor count* evenly into
    /// `y` groups, ignoring tensor sizes.
    pub fn naive_even(n: usize, y: usize) -> Partition {
        let y = y.clamp(1, n);
        let base = n / y;
        let rem = n % y;
        let mut bounds = vec![0];
        let mut off = 0;
        for j in 0..y {
            off += base + usize::from(j < rem);
            bounds.push(off);
        }
        Partition::from_bounds(n, bounds)
    }

    pub fn num_groups(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn num_tensors(&self) -> usize {
        self.n
    }

    /// Group `j` as a range of backprop-ordered tensor indices.
    pub fn group_range(&self, j: usize) -> std::ops::Range<usize> {
        self.bounds[j]..self.bounds[j + 1]
    }

    pub fn bounds(&self) -> &[usize] {
        &self.bounds
    }

    /// Element count per group given per-tensor sizes (backprop order).
    pub fn group_elems(&self, sizes: &[usize]) -> Vec<usize> {
        assert_eq!(sizes.len(), self.n);
        (0..self.num_groups())
            .map(|j| self.group_range(j).map(|i| sizes[i]).sum())
            .collect()
    }

    /// Which group a tensor belongs to.
    pub fn group_of(&self, tensor: usize) -> usize {
        assert!(tensor < self.n);
        // bounds is sorted; binary search the interval.
        match self.bounds.binary_search(&tensor) {
            Ok(j) if j == self.num_groups() => j - 1,
            Ok(j) => j,
            Err(j) => j - 1,
        }
    }
}

impl std::fmt::Display for Partition {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Partition(y={}, bounds={:?})", self.num_groups(), self.bounds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gens};

    #[test]
    fn layer_wise_and_full_merge() {
        let lw = Partition::layer_wise(5);
        assert_eq!(lw.num_groups(), 5);
        for j in 0..5 {
            assert_eq!(lw.group_range(j), j..j + 1);
        }
        let fm = Partition::full_merge(5);
        assert_eq!(fm.num_groups(), 1);
        assert_eq!(fm.group_range(0), 0..5);
    }

    #[test]
    fn naive_even_distributes_remainder() {
        let p = Partition::naive_even(10, 3);
        assert_eq!(p.bounds(), &[0, 4, 7, 10]);
        let p = Partition::naive_even(9, 3);
        assert_eq!(p.bounds(), &[0, 3, 6, 9]);
        let p = Partition::naive_even(3, 7);
        assert_eq!(p.num_groups(), 3, "y clamps to n");
    }

    #[test]
    fn group_elems_sums() {
        let p = Partition::from_cuts(4, vec![2]);
        let sizes = [10usize, 20, 30, 40];
        assert_eq!(p.group_elems(&sizes), vec![30, 70]);
    }

    #[test]
    fn group_of_lookup() {
        let p = Partition::from_bounds(6, vec![0, 2, 5, 6]);
        assert_eq!(p.group_of(0), 0);
        assert_eq!(p.group_of(1), 0);
        assert_eq!(p.group_of(2), 1);
        assert_eq!(p.group_of(4), 1);
        assert_eq!(p.group_of(5), 2);
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn rejects_empty_groups() {
        Partition::from_bounds(4, vec![0, 2, 2, 4]);
    }

    #[test]
    fn try_from_bounds_errors_instead_of_panicking() {
        assert!(Partition::try_from_bounds(4, vec![0, 2, 4]).is_ok());
        assert!(Partition::try_from_bounds(4, vec![0, 2, 2, 4]).is_err());
        assert!(Partition::try_from_bounds(4, vec![1, 4]).is_err());
        assert!(Partition::try_from_bounds(4, vec![0, 3]).is_err());
        assert!(Partition::try_from_bounds(4, vec![0]).is_err());
    }

    #[test]
    fn json_bounds_roundtrip_and_strictness() {
        use crate::util::json::Value;
        let p = Partition::from_bounds(6, vec![0, 2, 5, 6]);
        let v = p.bounds_to_json();
        let p2 = Partition::from_json_bounds(6, &v).unwrap();
        assert_eq!(p, p2);

        // A malformed entry must be an error, never silently dropped: with
        // the old filter_map behavior [0, "x", 6] would collapse to [0, 6]
        // and quietly merge two groups on one rank only.
        let bad = Value::Arr(vec![Value::from(0usize), Value::from("x"), Value::from(6usize)]);
        assert!(Partition::from_json_bounds(6, &bad).is_err());
        let bad = Value::Arr(vec![Value::from(0usize), Value::from(2.5), Value::from(6usize)]);
        assert!(Partition::from_json_bounds(6, &bad).is_err());
        assert!(Partition::from_json_bounds(6, &Value::from("nope")).is_err());
        // Wrong model size is an error too.
        assert!(Partition::from_json_bounds(7, &v).is_err());
    }

    #[test]
    fn from_cuts_filters_degenerate() {
        let p = Partition::from_cuts(5, vec![0, 3, 5, 3]);
        assert_eq!(p.bounds(), &[0, 3, 5]);
    }

    /// Property: every partition covers each tensor exactly once.
    #[test]
    fn prop_partitions_cover_exactly_once() {
        check(
            "partition coverage",
            200,
            gens::pair(gens::usize_in(1..200), gens::usize_in(1..50)),
            |&(n, y)| {
                for p in [
                    Partition::layer_wise(n),
                    Partition::full_merge(n),
                    Partition::naive_even(n, y),
                ] {
                    let mut seen = vec![0usize; n];
                    for j in 0..p.num_groups() {
                        for i in p.group_range(j) {
                            seen[i] += 1;
                        }
                    }
                    if seen.iter().any(|&c| c != 1) {
                        return Err(format!("{p}: coverage {seen:?}"));
                    }
                    // group_of agrees with group_range
                    for j in 0..p.num_groups() {
                        for i in p.group_range(j) {
                            if p.group_of(i) != j {
                                return Err(format!("group_of({i}) != {j} in {p}"));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
