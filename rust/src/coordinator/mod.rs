//! The coordinator: MergeComp's L3 execution engine for the measured plane.
//!
//! This module owns the **pipelined exchange engine** — the component that
//! makes the paper's overlap claim (Fig. 1, Eq. 7) observable in the *real*
//! trainer rather than only in the `simulator/` plane. It splits each
//! worker into two lanes, mirroring the simulator's two-resource model:
//!
//! - the **compute lane** (the worker thread itself) merges each tensor
//!   group, runs the codec's `encode_into` / `decode_into` against reusable
//!   buffers, and scatters averaged gradients back;
//! - the **comm lane** (a dedicated thread borrowed via
//!   [`crate::collectives::lane_scope`]) executes one collective at a time,
//!   in submission order, over the tagged transport.
//!
//! With [`PipelineMode::Pipelined`], group *j*'s collective runs while
//! group *j+1* encodes and group *j−1* decodes — the software-pipelined
//! schedule MG-WFBP-style systems use. [`PipelineMode::Serial`] preserves
//! the strictly sequential encode → collective → decode loop; both modes
//! produce **bit-identical** gradients and error-feedback state (enforced
//! by `tests/pipeline_equivalence.rs`), because the per-group operation
//! order seen by the codecs, the RNG, and the transport's tag sequence is
//! the same in both.
//!
//! [`ExchangeStats`] separates `comm_secs` (total collective occupancy,
//! measured on the comm lane) from `comm_exposed_secs` (time the compute
//! lane actually stalled in `CommHandle::wait`) — the measured counterpart
//! of the simulator's `comm_total` / `comm_exposed` split, and the quantity
//! Eq. 7's Σp(x_i) overlap term hides.

pub mod checkpoint;
pub mod engine;

pub use checkpoint::{AsyncCheckpointer, Checkpoint, PlaneCache, CHECKPOINT_VERSION};
pub use engine::ExchangeEngine;

/// How the exchange engine schedules encode / collective / decode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipelineMode {
    /// Strictly sequential per group (the legacy measured plane; zero
    /// overlap by construction). The conservative default for library
    /// users; the trainer defaults to `Pipelined`.
    #[default]
    Serial,
    /// Dedicated comm lane; encode/decode of neighbouring groups overlap
    /// the in-flight collective.
    Pipelined,
}

impl PipelineMode {
    pub fn from_name(name: &str) -> anyhow::Result<PipelineMode> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "serial" => PipelineMode::Serial,
            "pipelined" | "pipeline" | "overlap" => PipelineMode::Pipelined,
            other => anyhow::bail!("unknown pipeline mode '{other}' (serial|pipelined)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PipelineMode::Serial => "serial",
            PipelineMode::Pipelined => "pipelined",
        }
    }
}

/// How the exchange distributes the reduced gradient — and with it, who
/// holds optimizer state (DESIGN.md "Sharded exchange").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExchangeMode {
    /// Every rank ends the exchange with the full averaged gradient and
    /// holds full optimizer state (the legacy path, and the default).
    #[default]
    Full,
    /// Per scheduled group, each rank finishes the exchange owning only its
    /// shard of the averaged gradient (reduce-scatter for allreduce codecs;
    /// shard-at-the-consumer for allgather codecs), updates only its shard
    /// of the optimizer state, and an allgather of updated parameter shards
    /// restores full parameters everywhere. Per-rank optimizer memory drops
    /// to ≈ 1/world of the full mode's.
    Sharded,
}

impl ExchangeMode {
    pub fn from_name(name: &str) -> anyhow::Result<ExchangeMode> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "full" => ExchangeMode::Full,
            "sharded" | "shard" | "zero" => ExchangeMode::Sharded,
            other => anyhow::bail!("unknown exchange mode '{other}' (full|sharded)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExchangeMode::Full => "full",
            ExchangeMode::Sharded => "sharded",
        }
    }
}

/// One group's measured exchange timings from a single step — the raw
/// observations the online [`CostEstimator`] fits its rolling Assumption-5
/// models from. `comm_secs` is the collective's full occupancy (the α+β·size
/// quantity the cost model predicts); `comm_exposed_secs` is only the part
/// the compute lane actually waited for.
///
/// [`CostEstimator`]: crate::scheduler::estimator::CostEstimator
#[derive(Debug, Clone, Copy, Default)]
pub struct GroupSample {
    /// Group index within the step's partition.
    pub group: usize,
    /// Elements merged into the group.
    pub elems: usize,
    /// Which collective route this group's exchange actually ran — per
    /// group, now that the scheduler can mix flat and hierarchical routes
    /// within one step. The estimator files `comm_secs` under the right
    /// per-route fit with it.
    pub route: crate::collectives::CommRoute,
    /// Which codec the group actually ran — per group, now that the
    /// scheduler can mix codecs within one step. The estimator files
    /// encode/decode timings under the right per-codec fit and converts
    /// `comm_secs` to wire bytes with it.
    pub codec: crate::compression::CodecKind,
    pub encode_secs: f64,
    pub comm_secs: f64,
    pub comm_exposed_secs: f64,
    /// Portion of `comm_secs` spent in the **inter-node** stage of a
    /// hierarchical collective (0 on the flat route, and on non-leader
    /// ranks, whose wall time hides inside the intra fan-out wait). Rank 0
    /// — the rank whose estimator drives the schedule search — is always a
    /// top-level leader, so its samples carry the real inter-level
    /// timings.
    pub comm_inter_secs: f64,
    pub decode_secs: f64,
}

/// Per-step timing/size accounting (feeds the measured cost models, the
/// EXPERIMENTS.md overhead tables, and the simulator-vs-trainer overlap
/// validation).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExchangeStats {
    pub encode_secs: f64,
    /// Total collective occupancy (sum of collective durations, whether or
    /// not they were hidden) — the measured analogue of the simulator's
    /// `comm_total`.
    pub comm_secs: f64,
    pub decode_secs: f64,
    /// Communication time the compute lane actually waited for — the
    /// *exposed* remainder after pipeline overlap. Equals `comm_secs` in
    /// `Serial` mode by definition.
    pub comm_exposed_secs: f64,
    /// Portion of `comm_secs` spent in the inter-node stage of two-level
    /// collectives (0 on the flat route; leader-measured, see
    /// [`GroupSample::comm_inter_secs`]).
    pub comm_inter_secs: f64,
    pub bytes_sent: u64,
    /// Payload bytes sent to peers on **other** nodes of the attached
    /// topology — the traffic that crosses the slow fabric level. 0 under
    /// a flat topology; under a node topology it is the quantity the
    /// two-level exchange exists to shrink (`benches/hierarchy.rs`).
    pub inter_bytes_sent: u64,
    pub groups: usize,
}

impl ExchangeStats {
    /// Total work performed (compute + comm occupancy, ignoring overlap).
    pub fn total_secs(&self) -> f64 {
        self.encode_secs + self.comm_secs + self.decode_secs
    }

    /// Wall-clock contribution of the exchange to the step: compression
    /// compute plus only the comm that could not be hidden.
    pub fn critical_path_secs(&self) -> f64 {
        self.encode_secs + self.comm_exposed_secs + self.decode_secs
    }

    /// Communication hidden behind encode/decode (Σp in Eq. 7, measured).
    pub fn overlap_secs(&self) -> f64 {
        (self.comm_secs - self.comm_exposed_secs).max(0.0)
    }

    /// Fraction of comm hidden; 0 when there was no communication.
    pub fn overlap_frac(&self) -> f64 {
        if self.comm_secs > 0.0 {
            self.overlap_secs() / self.comm_secs
        } else {
            0.0
        }
    }

    /// Accumulate another step's stats (groups/bytes follow the addend).
    pub fn accumulate(&mut self, other: &ExchangeStats) {
        self.encode_secs += other.encode_secs;
        self.comm_secs += other.comm_secs;
        self.decode_secs += other.decode_secs;
        self.comm_exposed_secs += other.comm_exposed_secs;
        self.comm_inter_secs += other.comm_inter_secs;
        self.bytes_sent += other.bytes_sent;
        self.inter_bytes_sent += other.inter_bytes_sent;
        self.groups = other.groups;
    }

    /// Divide all timings by `steps` (for per-step means).
    pub fn scaled(&self, steps: f64) -> ExchangeStats {
        ExchangeStats {
            encode_secs: self.encode_secs / steps,
            comm_secs: self.comm_secs / steps,
            decode_secs: self.decode_secs / steps,
            comm_exposed_secs: self.comm_exposed_secs / steps,
            comm_inter_secs: self.comm_inter_secs / steps,
            bytes_sent: (self.bytes_sent as f64 / steps) as u64,
            inter_bytes_sent: (self.inter_bytes_sent as f64 / steps) as u64,
            groups: self.groups,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_names_roundtrip() {
        for m in [PipelineMode::Serial, PipelineMode::Pipelined] {
            assert_eq!(PipelineMode::from_name(m.name()).unwrap(), m);
        }
        assert!(PipelineMode::from_name("warp-drive").is_err());
        assert_eq!(PipelineMode::default(), PipelineMode::Serial);
    }

    #[test]
    fn exchange_mode_names_roundtrip() {
        for m in [ExchangeMode::Full, ExchangeMode::Sharded] {
            assert_eq!(ExchangeMode::from_name(m.name()).unwrap(), m);
        }
        assert!(ExchangeMode::from_name("mirrored").is_err());
        assert_eq!(ExchangeMode::default(), ExchangeMode::Full);
    }

    #[test]
    fn stats_overlap_accounting() {
        let s = ExchangeStats {
            encode_secs: 1.0,
            comm_secs: 4.0,
            decode_secs: 0.5,
            comm_exposed_secs: 1.0,
            comm_inter_secs: 2.0,
            bytes_sent: 10,
            inter_bytes_sent: 4,
            groups: 2,
        };
        assert!((s.total_secs() - 5.5).abs() < 1e-12);
        assert!((s.critical_path_secs() - 2.5).abs() < 1e-12);
        assert!((s.overlap_secs() - 3.0).abs() < 1e-12);
        assert!((s.overlap_frac() - 0.75).abs() < 1e-12);

        let mut acc = ExchangeStats::default();
        acc.accumulate(&s);
        acc.accumulate(&s);
        assert!((acc.comm_secs - 8.0).abs() < 1e-12);
        assert!((acc.comm_inter_secs - 4.0).abs() < 1e-12);
        assert_eq!(acc.inter_bytes_sent, 8);
        let mean = acc.scaled(2.0);
        assert!((mean.comm_secs - 4.0).abs() < 1e-12);
        assert!((mean.comm_inter_secs - 2.0).abs() < 1e-12);
        assert_eq!(mean.inter_bytes_sent, 4);
        assert_eq!(mean.groups, 2);
    }
}
