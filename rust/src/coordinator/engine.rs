//! The exchange engine: merge → encode → collective → decode → scatter for
//! every tensor group, in either [`PipelineMode`].
//!
//! Equivalence invariant (tested in `tests/pipeline_equivalence.rs` and,
//! across transports, `tests/transport_equivalence.rs`): both modes perform
//! the *same* sequence of codec and collective operations — encodes in
//! group order on the compute lane (so RNG draws and EF updates are
//! identical), collectives in group order on one communicator (so tag
//! sequencing and reduction order are identical), decodes in group order
//! with the same accumulate-then-average arithmetic. Pipelining changes
//! only *when* things run, never *what* runs — gradients and codec state
//! are bit-identical. The same argument applies to the transport backend:
//! the engine sees only `Comm`, so sockets vs channels cannot change a bit.
//!
//! Failure semantics: a peer dying mid-collective fails the exchange with a
//! typed [`Error`] (rank, peer, tag) instead of poisoning the
//! process — the trainer turns it into a step-level error with context.
//!
//! Allocation discipline: merge/decode scratch is double-buffered
//! (`flats`), encode targets cycle through `wire_pool`, and gathered peer
//! payloads are handed back to the transport's receive pool (`retired` →
//! `Endpoint::recycle`) once decoded — so the steady-state hot path
//! performs no heap allocation end to end (asserted across the TCP
//! backend in `tests/transport_equivalence.rs`).

use super::{ExchangeMode, ExchangeStats, GroupSample, PipelineMode};
use crate::collectives::{
    lane_scope, shard_elems, Comm, CommHandle, CommOutcome, CommRoute, Error,
};
use crate::compression::{Codec, CodecKind, Collective};
use crate::scheduler::{Partition, RouteChoice};
use crate::util::rng::Xoshiro256;
use crate::util::stats::Stopwatch;

/// One worker's exchange engine for a (base codec, partition) pair, with
/// optional per-group codec overrides from the scheduler's codec search.
pub struct ExchangeEngine {
    /// The configured base codec: what every group starts on, what a
    /// repartition normalizes back to, and what [`ExchangeEngine::set_codecs`]
    /// `None` reverts to.
    kind: CodecKind,
    partition: Partition,
    /// Per-tensor element counts, backprop order.
    sizes: Vec<usize>,
    /// One stateful codec per group (EF granularity = group, §4.2). Groups
    /// may run different kinds under `--codec auto`; each group's
    /// collective is dispatched off its own codec's kind.
    codecs: Vec<Box<dyn Codec>>,
    group_elems: Vec<usize>,
    /// Per-group collective routes from the scheduler (`None` = every
    /// group rides the communicator's global route). Part of the
    /// symmetric-SPMD contract: every rank must install the same vector
    /// (the driver's epoch broadcast guarantees it).
    routes: Option<Vec<RouteChoice>>,
    /// Double-buffered merge/decode scratch: slot `j % 2` serves group `j`,
    /// so the in-flight group's decode buffer survives while the next
    /// group merges into the other slot.
    flats: [Vec<f32>; 2],
    /// Recycled wire buffers (encode targets / returned payloads).
    wire_pool: Vec<Vec<u8>>,
    /// Peer payloads consumed this exchange, awaiting return to the
    /// transport's receive pool ([`crate::collectives::Endpoint::recycle`]).
    /// Drained at the end of every [`ExchangeEngine::exchange`]; kept on
    /// the engine so `finish_group` can run on the compute lane while
    /// `comm` lives on the comm lane.
    retired: Vec<Vec<u8>>,
    /// Per-group timings of the most recent exchange (one entry per group,
    /// overwritten each step) — the online scheduler's measurement feed.
    group_log: Vec<GroupSample>,
}

impl ExchangeEngine {
    pub fn new(kind: CodecKind, partition: Partition, sizes_backprop: Vec<usize>) -> Self {
        let group_elems = partition.group_elems(&sizes_backprop);
        let codecs = group_elems.iter().map(|&n| kind.build(n)).collect();
        let max_group = group_elems.iter().copied().max().unwrap_or(0);
        ExchangeEngine {
            kind,
            partition,
            sizes: sizes_backprop,
            codecs,
            group_elems,
            routes: None,
            flats: [Vec::with_capacity(max_group), Vec::with_capacity(max_group)],
            wire_pool: Vec::new(),
            retired: Vec::new(),
            group_log: Vec::new(),
        }
    }

    pub fn kind(&self) -> CodecKind {
        self.kind
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Install per-group collective routes (one per group; `None` reverts
    /// every group to the communicator's global route). Routes are
    /// schedule state exactly like the partition: every rank must install
    /// the same vector at the same step.
    pub fn set_routes(&mut self, routes: Option<Vec<RouteChoice>>) -> anyhow::Result<()> {
        if let Some(r) = &routes {
            anyhow::ensure!(
                r.len() == self.partition.num_groups(),
                "set_routes: {} routes for {} groups",
                r.len(),
                self.partition.num_groups()
            );
        }
        self.routes = routes;
        Ok(())
    }

    /// Current per-group routes (`None` = global route).
    pub fn routes(&self) -> Option<&[RouteChoice]> {
        self.routes.as_deref()
    }

    /// The codec kind each group currently runs (all equal to
    /// [`ExchangeEngine::kind`] unless [`ExchangeEngine::set_codecs`]
    /// installed overrides).
    pub fn group_codecs(&self) -> Vec<CodecKind> {
        self.codecs.iter().map(|c| c.kind()).collect()
    }

    /// Install per-group codecs (one per group; `None` reverts every group
    /// to the engine's base codec). Codecs are schedule state exactly like
    /// the partition and routes: every rank must install the same vector
    /// at the same step, or ranks would issue mismatched collectives.
    ///
    /// **Error-feedback policy.** A group that keeps its kind is untouched
    /// (state and all). A group that flips kinds carries its state planes
    /// into the new codec when the plane shapes are compatible — same
    /// nonzero plane count, e.g. one EF residual plane for
    /// `efsignsgd ↔ onebit`, or DGC's two planes across a ratio change —
    /// making the flip bit-invisible to a flip back
    /// (`tests/codec_choice.rs`). Otherwise the new codec starts with
    /// fresh (zero) state: a reset, which is exactly the cost the
    /// scheduler's codec switch penalty amortizes.
    pub fn set_codecs(&mut self, kinds: Option<Vec<CodecKind>>) -> anyhow::Result<()> {
        let target = match kinds {
            Some(ks) => {
                anyhow::ensure!(
                    ks.len() == self.partition.num_groups(),
                    "set_codecs: {} codecs for {} groups",
                    ks.len(),
                    self.partition.num_groups()
                );
                ks
            }
            None => vec![self.kind; self.partition.num_groups()],
        };
        for (j, &k) in target.iter().enumerate() {
            if self.codecs[j].kind() == k {
                continue;
            }
            let mut fresh = k.build(self.group_elems[j]);
            let old = self.codecs[j].state_planes();
            if !old.is_empty() && old.len() == fresh.state_planes().len() {
                fresh.load_state_planes(&old);
            }
            drop(old);
            self.codecs[j] = fresh;
        }
        Ok(())
    }

    /// The [`CommRoute`] each group will actually run under `comm`:
    /// per-group choices (or the global route), clamped to `Flat` on a
    /// trivial topology — mirroring `Comm::set_route` so the recorded
    /// [`GroupSample::route`] always matches the executed collective.
    fn effective_routes(&self, comm: &Comm) -> Vec<CommRoute> {
        let trivial = comm.topology().is_trivial();
        let global = comm.route();
        (0..self.partition.num_groups())
            .map(|j| {
                let r = match &self.routes {
                    Some(rs) => match rs[j] {
                        RouteChoice::Flat => CommRoute::Flat,
                        RouteChoice::Hierarchical => CommRoute::TwoLevel,
                    },
                    None => global,
                };
                if trivial {
                    CommRoute::Flat
                } else {
                    r
                }
            })
            .collect()
    }

    /// Fingerprint of all per-group codec state (EF residuals, momentum).
    pub fn state_digest(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01B3;
        self.codecs
            .iter()
            .fold(crate::compression::STATE_DIGEST_SEED, |h, c| {
                h.wrapping_mul(PRIME) ^ c.state_digest()
            })
    }

    /// Per-group timings of the most recent [`ExchangeEngine::exchange`]
    /// call, in group order — what the online scheduler's cost estimator
    /// consumes. Empty before the first exchange.
    pub fn group_samples(&self) -> &[GroupSample] {
        &self.group_log
    }

    /// The codec state planes flattened to full-model length (backprop
    /// order), one vector per plane. Partition-independent: re-chunking the
    /// groups must leave this bit-identical (see [`ExchangeEngine::repartition`]).
    /// Under mixed per-group codecs the plane count is the maximum over
    /// groups, with a group's missing planes reading as zeros (the state a
    /// fresh codec of the wider kind would hold there).
    pub fn flat_state(&self) -> Vec<Vec<f32>> {
        let total: usize = self.sizes.iter().sum();
        let n_planes = self
            .codecs
            .iter()
            .map(|c| c.state_planes().len())
            .max()
            .unwrap_or(0);
        let mut planes = vec![Vec::with_capacity(total); n_planes];
        for (codec, &n) in self.codecs.iter().zip(&self.group_elems) {
            let cplanes = codec.state_planes();
            for (p, flat) in planes.iter_mut().enumerate() {
                match cplanes.get(p) {
                    Some(plane) => flat.extend_from_slice(plane),
                    None => flat.resize(flat.len() + n, 0.0),
                }
            }
        }
        planes
    }

    /// Inverse of [`ExchangeEngine::flat_state`]: install codec state from
    /// full-model-length planes (backprop order) — the checkpoint-restore
    /// path. Callers must first restore the partition and per-group codecs
    /// the planes were captured under; each group then consumes as many
    /// leading planes as its codec holds (mirroring the zero-fill that
    /// `flat_state` applies to a group's missing planes).
    pub fn load_flat_state(&mut self, planes: &[Vec<f32>]) -> anyhow::Result<()> {
        let total: usize = self.sizes.iter().sum();
        for (p, plane) in planes.iter().enumerate() {
            anyhow::ensure!(
                plane.len() == total,
                "load_flat_state: plane {p} has {} elements, model has {total}",
                plane.len()
            );
        }
        let mut off = 0;
        for (codec, &n) in self.codecs.iter_mut().zip(&self.group_elems) {
            let want = codec.state_planes().len();
            anyhow::ensure!(
                want <= planes.len(),
                "load_flat_state: codec '{}' holds {want} planes but only {} supplied",
                codec.kind().name(),
                planes.len()
            );
            let views: Vec<&[f32]> = planes[..want].iter().map(|p| &p[off..off + n]).collect();
            codec.load_state_planes(&views);
            off += n;
        }
        Ok(())
    }

    /// Switch to a new partition over the same tensors, remapping all codec
    /// state (EF residuals, momentum, DGC velocity) into the new grouping
    /// **bit-exactly**: groups concatenate tensors in backprop order, so the
    /// flattened state is partition-independent and re-chunking it loses
    /// nothing (proven by `tests/online_resched.rs`). Scratch buffers are
    /// retained; wire buffers re-grow on the next exchange.
    pub fn repartition(&mut self, new: Partition) -> anyhow::Result<()> {
        anyhow::ensure!(
            new.num_tensors() == self.sizes.len(),
            "repartition: {} tensors, engine has {}",
            new.num_tensors(),
            self.sizes.len()
        );
        if new == self.partition {
            return Ok(());
        }

        // Mixed per-group codecs cannot be re-chunked meaningfully — their
        // state planes differ in kind across group boundaries that are
        // about to move — so a repartition first normalizes every group
        // back to the base codec under the `set_codecs` state policy
        // (convert where plane shapes match, reset otherwise). The
        // schedule broadcast that carried the new bounds reinstalls the
        // per-group codecs sized for the new grouping right after.
        if self.codecs.iter().any(|c| c.kind() != self.kind) {
            self.set_codecs(None)?;
        }

        let flat_planes = self.flat_state();
        let group_elems = new.group_elems(&self.sizes);
        let mut codecs: Vec<Box<dyn Codec>> =
            group_elems.iter().map(|&n| self.kind.build(n)).collect();
        let mut off = 0;
        for (codec, &n) in codecs.iter_mut().zip(&group_elems) {
            let views: Vec<&[f32]> = flat_planes.iter().map(|p| &p[off..off + n]).collect();
            codec.load_state_planes(&views);
            off += n;
        }

        self.partition = new;
        self.group_elems = group_elems;
        self.codecs = codecs;
        // Routes are per-group, so they cannot survive a re-grouping:
        // revert to the global route until the caller installs a vector
        // sized for the new partition (the trainer does both from the
        // same schedule broadcast).
        self.routes = None;
        self.group_log.clear();
        Ok(())
    }

    /// Per-group element counts of the current partition (backprop order).
    pub fn group_elems(&self) -> &[usize] {
        &self.group_elems
    }

    /// The element range (within each group's flat buffer) that `rank`
    /// owns in [`ExchangeMode::Sharded`] — a pure function of the group
    /// sizes and the world, identical on every rank and every route (see
    /// [`crate::collectives::reduce_scatter`]).
    pub fn owned_group_ranges(&self, world: usize, rank: usize) -> Vec<(usize, usize)> {
        self.group_elems
            .iter()
            .map(|&n| shard_elems(n, world, rank))
            .collect()
    }

    /// Aggregate gradients across the group. `grads` holds per-tensor
    /// buffers in **backprop order**; on success each buffer contains the
    /// mean of the (compressed) gradients over all workers. A dead rank
    /// fails the step with a typed [`Error`] naming the peer and
    /// tag.
    pub fn exchange(
        &mut self,
        comm: &mut Comm,
        grads: &mut [Vec<f32>],
        rng: &mut Xoshiro256,
        mode: PipelineMode,
    ) -> Result<ExchangeStats, Error> {
        self.exchange_mode(comm, grads, rng, mode, ExchangeMode::Full)
    }

    /// [`ExchangeEngine::exchange`] with an explicit [`ExchangeMode`].
    ///
    /// In [`ExchangeMode::Sharded`], allreduce-codec groups run only the
    /// reduce-scatter phase of the ring: on return, a group's scattered
    /// gradients are the true mean **only inside this rank's owned element
    /// range** ([`ExchangeEngine::owned_group_ranges`]); the rest of the
    /// group holds deterministic partial-sum residue that must not be
    /// consumed. Allgather-codec groups are communicated exactly as in
    /// full mode (every rank still decodes every payload — the memory win
    /// for them is optimizer-state sharding at the consumer), so their
    /// gradients stay valid everywhere. Encode order, RNG draws, EF
    /// updates, tag sequencing, and the owned range's arithmetic are all
    /// bit-identical to full mode (`tests/sharded_equivalence.rs`).
    pub fn exchange_mode(
        &mut self,
        comm: &mut Comm,
        grads: &mut [Vec<f32>],
        rng: &mut Xoshiro256,
        mode: PipelineMode,
        xmode: ExchangeMode,
    ) -> Result<ExchangeStats, Error> {
        assert_eq!(grads.len(), self.sizes.len());
        let routed = self.routes.is_some();
        let sharded = xmode == ExchangeMode::Sharded;
        let result = match mode {
            PipelineMode::Serial => self.exchange_serial(comm, grads, rng, sharded),
            PipelineMode::Pipelined => self.exchange_pipelined(comm, grads, rng, sharded),
        };
        // Restore the canonical route even when the exchange failed
        // mid-group: a per-group route must never leak into collectives
        // outside the engine.
        if routed {
            comm.reset_route();
        }
        // Hand every consumed peer payload back to the transport's receive
        // pool (even on failure — the buffers are still reusable), so the
        // steady-state receive path never allocates.
        for buf in self.retired.drain(..) {
            comm.ep.recycle(buf);
        }
        result
    }

    /// Legacy schedule: encode → collective → decode strictly per group on
    /// the worker thread. `comm_exposed_secs == comm_secs` by definition.
    fn exchange_serial(
        &mut self,
        comm: &mut Comm,
        grads: &mut [Vec<f32>],
        rng: &mut Xoshiro256,
        sharded: bool,
    ) -> Result<ExchangeStats, Error> {
        let world = comm.world() as f32;
        let rank = comm.rank();
        let y = self.partition.num_groups();
        let mut stats = ExchangeStats {
            groups: y,
            ..Default::default()
        };
        let bytes_before = comm.bytes_sent();
        let routed = self.routes.is_some();
        let effective = self.effective_routes(comm);

        let ExchangeEngine {
            kind: _,
            partition,
            sizes,
            codecs,
            group_elems,
            routes: _,
            flats,
            wire_pool,
            retired,
            group_log,
        } = self;
        group_log.clear();
        group_log.resize(y, GroupSample::default());

        for j in 0..y {
            let n = group_elems[j];
            // Mixed-codec schedules dispatch each group's collective off
            // its own codec's kind.
            let collective = codecs[j].kind().collective();
            group_log[j].group = j;
            group_log[j].elems = n;
            group_log[j].route = effective[j];
            group_log[j].codec = codecs[j].kind();

            // --- merge -----------------------------------------------------
            let flat = &mut flats[0];
            flat.clear();
            for i in partition.group_range(j) {
                flat.extend_from_slice(&grads[i]);
            }
            debug_assert_eq!(flat.len(), n);

            // --- encode ----------------------------------------------------
            let mut wire = wire_pool.pop().unwrap_or_default();
            let sw = Stopwatch::start();
            codecs[j].encode_into(flat, rng, &mut wire);
            let enc_secs = sw.elapsed().as_secs_f64();
            stats.encode_secs += enc_secs;
            group_log[j].encode_secs = enc_secs;

            // --- communicate (blocking, on this thread) --------------------
            if routed {
                comm.set_route(effective[j]);
            }
            let inter_before = comm.inter_node_bytes();
            let sw = Stopwatch::start();
            let outcome = match collective {
                Collective::AllReduce => {
                    if sharded {
                        comm.reduce_scatter_wire(&mut wire, codecs[j].as_ref())?;
                    } else {
                        comm.allreduce_wire(&mut wire, codecs[j].as_ref())?;
                    }
                    CommOutcome::Reduced(wire)
                }
                Collective::AllGather => CommOutcome::Gathered(comm.allgather(wire)?),
            };
            let comm_secs = sw.elapsed().as_secs_f64();
            stats.comm_secs += comm_secs;
            group_log[j].comm_secs = comm_secs;
            group_log[j].comm_exposed_secs = comm_secs;
            let inter_secs = comm
                .take_last_breakdown()
                .map(|b| b.inter_secs)
                .unwrap_or(0.0);
            stats.comm_inter_secs += inter_secs;
            group_log[j].comm_inter_secs = inter_secs;
            stats.inter_bytes_sent += comm.inter_node_bytes() - inter_before;

            // --- decode + scatter: the SAME helper the pipelined path uses,
            // so the bit-identical guarantee is structural.
            let dec_before = stats.decode_secs;
            finish_group(
                j,
                outcome,
                codecs,
                partition,
                sizes,
                &mut flats[0],
                grads,
                wire_pool,
                retired,
                n,
                world,
                rank,
                &mut stats,
            )?;
            group_log[j].decode_secs = stats.decode_secs - dec_before;
        }

        stats.comm_exposed_secs = stats.comm_secs;
        stats.bytes_sent = comm.bytes_sent() - bytes_before;
        Ok(stats)
    }

    /// Pipelined schedule: the comm lane runs group `j`'s collective while
    /// the compute lane encodes group `j+1` and decodes group `j−1`.
    fn exchange_pipelined(
        &mut self,
        comm: &mut Comm,
        grads: &mut [Vec<f32>],
        rng: &mut Xoshiro256,
        sharded: bool,
    ) -> Result<ExchangeStats, Error> {
        let world = comm.world() as f32;
        let rank = comm.rank();
        let y = self.partition.num_groups();
        let mut stats = ExchangeStats {
            groups: y,
            ..Default::default()
        };
        let bytes_before = comm.bytes_sent();
        let routed = self.routes.is_some();
        let effective = self.effective_routes(comm);

        // Disjoint field borrows so the lane closure can mutate scratch
        // state while `comm` itself lives on the comm-lane thread.
        let ExchangeEngine {
            kind: _,
            partition,
            sizes,
            codecs,
            group_elems,
            routes: _,
            flats,
            wire_pool,
            retired,
            group_log,
        } = self;
        group_log.clear();
        group_log.resize(y, GroupSample::default());

        let effective = &effective;
        let (result, _lane_busy) =
            lane_scope(comm, |lane| -> Result<(), Error> {
                let mut inflight: Option<(usize, CommHandle)> = None;
                for j in 0..y {
                    let n = group_elems[j];
                    // Per-group dispatch: the group's own codec decides
                    // which collective rides the lane.
                    let gkind = codecs[j].kind();
                    group_log[j].group = j;
                    group_log[j].elems = n;
                    group_log[j].route = effective[j];
                    group_log[j].codec = gkind;

                    // --- merge + encode group j (overlaps group j−1's comm)
                    let flat = &mut flats[j % 2];
                    flat.clear();
                    for i in partition.group_range(j) {
                        flat.extend_from_slice(&grads[i]);
                    }
                    debug_assert_eq!(flat.len(), n);

                    let mut wire = wire_pool.pop().unwrap_or_default();
                    let sw = Stopwatch::start();
                    codecs[j].encode_into(flat, rng, &mut wire);
                    let enc_secs = sw.elapsed().as_secs_f64();
                    stats.encode_secs += enc_secs;
                    group_log[j].encode_secs = enc_secs;

                    // --- hand group j to the comm lane ----------------------
                    let route = if routed { Some(effective[j]) } else { None };
                    let handle = match gkind.collective() {
                        Collective::AllReduce if sharded => {
                            lane.start_reduce_scatter_routed(wire, gkind, n, route)
                        }
                        Collective::AllReduce => {
                            lane.start_allreduce_routed(wire, gkind, n, route)
                        }
                        Collective::AllGather => lane.start_allgather_routed(wire, route),
                    };

                    // --- drain group j−1 (its comm overlapped our encode) ---
                    if let Some((pj, ph)) = inflight.replace((j, handle)) {
                        complete_group(
                            pj,
                            ph,
                            codecs,
                            partition,
                            sizes,
                            &mut flats[pj % 2],
                            grads,
                            wire_pool,
                            retired,
                            group_elems[pj],
                            world,
                            rank,
                            &mut stats,
                            group_log,
                        )?;
                    }
                }
                if let Some((pj, ph)) = inflight.take() {
                    complete_group(
                        pj,
                        ph,
                        codecs,
                        partition,
                        sizes,
                        &mut flats[pj % 2],
                        grads,
                        wire_pool,
                        retired,
                        group_elems[pj],
                        world,
                        rank,
                        &mut stats,
                        group_log,
                    )?;
                }
                Ok(())
            });
        result?;

        stats.bytes_sent = comm.bytes_sent() - bytes_before;
        Ok(stats)
    }
}

/// Wait for group `j`'s collective, hand its outcome to [`finish_group`],
/// and write the group's comm/decode timings into `group_log[j]` (as
/// deltas of the running stats). Pipelined path only; the wait is the
/// *exposed* comm.
#[allow(clippy::too_many_arguments)]
fn complete_group(
    j: usize,
    handle: CommHandle,
    codecs: &[Box<dyn Codec>],
    partition: &Partition,
    sizes: &[usize],
    flat: &mut Vec<f32>,
    grads: &mut [Vec<f32>],
    wire_pool: &mut Vec<Vec<u8>>,
    retired: &mut Vec<Vec<u8>>,
    n: usize,
    world: f32,
    rank: usize,
    stats: &mut ExchangeStats,
    group_log: &mut [GroupSample],
) -> Result<(), Error> {
    let before = (
        stats.comm_secs,
        stats.comm_exposed_secs,
        stats.decode_secs,
        stats.comm_inter_secs,
    );
    // Only the time actually spent blocked here is *exposed* comm.
    let sw = Stopwatch::start();
    let done = handle.wait()?;
    stats.comm_exposed_secs += sw.elapsed().as_secs_f64();
    stats.comm_secs += done.secs;
    stats.comm_inter_secs += done.breakdown.map(|b| b.inter_secs).unwrap_or(0.0);
    stats.inter_bytes_sent += done.inter_bytes;
    finish_group(
        j,
        done.outcome,
        codecs,
        partition,
        sizes,
        flat,
        grads,
        wire_pool,
        retired,
        n,
        world,
        rank,
        stats,
    )?;
    group_log[j].comm_secs = stats.comm_secs - before.0;
    group_log[j].comm_exposed_secs = stats.comm_exposed_secs - before.1;
    group_log[j].decode_secs = stats.decode_secs - before.2;
    group_log[j].comm_inter_secs = stats.comm_inter_secs - before.3;
    Ok(())
}

/// Decode + average a completed collective into `flat`, scatter into the
/// per-tensor gradient buffers, and recycle wire buffers: this rank's own
/// encode target returns to `wire_pool`, while peer payloads are parked in
/// `retired` for the transport's receive pool. Shared by the Serial and
/// Pipelined schedules — one copy of the arithmetic keeps the two modes
/// bit-identical by construction.
///
/// The outcome shape must match the group codec's collective: handing an
/// allreduce result to an allgather codec (or vice versa) is a typed
/// [`Error::codec`] naming the group and codec — the failure a
/// mixed-codec schedule bug would otherwise surface as silent garbage.
#[allow(clippy::too_many_arguments)]
fn finish_group(
    j: usize,
    outcome: CommOutcome,
    codecs: &[Box<dyn Codec>],
    partition: &Partition,
    sizes: &[usize],
    flat: &mut Vec<f32>,
    grads: &mut [Vec<f32>],
    wire_pool: &mut Vec<Vec<u8>>,
    retired: &mut Vec<Vec<u8>>,
    n: usize,
    world: f32,
    rank: usize,
    stats: &mut ExchangeStats,
) -> Result<(), Error> {
    let kind = codecs[j].kind();
    match (outcome, kind.collective()) {
        (CommOutcome::Reduced(wire), Collective::AllReduce) => {
            let sw = Stopwatch::start();
            codecs[j].decode_into(&wire, flat);
            for v in flat.iter_mut() {
                *v /= world;
            }
            stats.decode_secs += sw.elapsed().as_secs_f64();
            wire_pool.push(wire);
        }
        (CommOutcome::Gathered(payloads), Collective::AllGather) => {
            let sw = Stopwatch::start();
            flat.clear();
            flat.resize(n, 0.0);
            let w = 1.0 / world;
            for bytes in &payloads {
                codecs[j].decode_add_into(bytes, flat, w);
            }
            stats.decode_secs += sw.elapsed().as_secs_f64();
            for (src, payload) in payloads.into_iter().enumerate() {
                if src == rank {
                    // This rank's own submission: reuse it as a future
                    // encode target.
                    wire_pool.push(payload);
                } else {
                    // A peer's frame from the transport receive path: park
                    // it for `Endpoint::recycle` at the end of the exchange.
                    retired.push(payload);
                }
            }
        }
        (outcome, expected) => {
            let got = match outcome {
                CommOutcome::Reduced(_) => "an allreduce",
                CommOutcome::Gathered(_) => "an allgather",
            };
            return Err(Error::codec(format!(
                "group {j}: codec '{}' expects {expected:?} but received {got} outcome",
                kind.name()
            )));
        }
    }

    let mut off = 0;
    for i in partition.group_range(j) {
        let len = sizes[i];
        grads[i].copy_from_slice(&flat[off..off + len]);
        off += len;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::run_comm_group;

    fn make_grads(rank: usize, sizes: &[usize]) -> Vec<Vec<f32>> {
        sizes
            .iter()
            .enumerate()
            .map(|(t, &n)| {
                (0..n)
                    .map(|i| (rank + 1) as f32 * (t as f32 + 1.0) + i as f32 * 0.001)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn pipelined_fp32_is_exact_mean() {
        let sizes = vec![6usize, 10, 3, 9];
        for y in [1usize, 2, 4] {
            let sizes2 = sizes.clone();
            let results = run_comm_group(3, move |c| {
                let mut eng = ExchangeEngine::new(
                    CodecKind::Fp32,
                    Partition::naive_even(4, y),
                    sizes2.clone(),
                );
                let mut rng = Xoshiro256::seed_from_u64(c.rank() as u64);
                let mut grads = make_grads(c.rank(), &sizes2);
                let stats = eng
                    .exchange(c, &mut grads, &mut rng, PipelineMode::Pipelined)
                    .unwrap();
                assert_eq!(stats.groups, y.min(4));
                (grads, stats.bytes_sent)
            });
            for (grads, bytes) in &results {
                assert!(*bytes > 0);
                for (t, buf) in grads.iter().enumerate() {
                    for (i, v) in buf.iter().enumerate() {
                        let want = 2.0 * (t as f32 + 1.0) + i as f32 * 0.001;
                        assert!((v - want).abs() < 1e-4, "y={y} t={t} i={i}: {v} vs {want}");
                    }
                }
            }
        }
    }

    #[test]
    fn serial_and_pipelined_bit_identical_one_step() {
        // Full 3-step equivalence over all paper codecs lives in
        // tests/pipeline_equivalence.rs; this is the in-module smoke check.
        let sizes = vec![40usize, 25, 70];
        for kind in [CodecKind::EfSignSgd, CodecKind::Fp16] {
            let run = |mode: PipelineMode| {
                let sizes2 = sizes.clone();
                run_comm_group(2, move |c| {
                    let mut eng = ExchangeEngine::new(
                        kind,
                        Partition::naive_even(3, 2),
                        sizes2.clone(),
                    );
                    let mut rng = Xoshiro256::seed_from_u64(7 + c.rank() as u64);
                    let mut grads = make_grads(c.rank(), &sizes2);
                    eng.exchange(c, &mut grads, &mut rng, mode).unwrap();
                    (grads, eng.state_digest())
                })
            };
            let serial = run(PipelineMode::Serial);
            let pipelined = run(PipelineMode::Pipelined);
            assert_eq!(serial, pipelined, "{}: modes diverged", kind.name());
        }
    }

    #[test]
    fn serial_mode_exposes_all_comm() {
        let results = run_comm_group(2, |c| {
            let mut eng =
                ExchangeEngine::new(CodecKind::Fp32, Partition::full_merge(1), vec![2048]);
            let mut rng = Xoshiro256::seed_from_u64(0);
            let mut grads = vec![vec![1.0f32; 2048]];
            eng.exchange(c, &mut grads, &mut rng, PipelineMode::Serial)
                .unwrap()
        });
        for s in results {
            assert_eq!(s.comm_exposed_secs, s.comm_secs);
            assert!((s.overlap_frac() - 0.0).abs() < 1e-12);
        }
    }

    #[test]
    fn group_samples_cover_every_group_and_sum_to_stats() {
        for mode in [PipelineMode::Serial, PipelineMode::Pipelined] {
            let results = run_comm_group(2, move |c| {
                let mut eng = ExchangeEngine::new(
                    CodecKind::EfSignSgd,
                    Partition::naive_even(4, 3),
                    vec![50, 20, 70, 10],
                );
                let mut rng = Xoshiro256::seed_from_u64(9);
                let mut grads = make_grads(c.rank(), &[50, 20, 70, 10]);
                let stats = eng.exchange(c, &mut grads, &mut rng, mode).unwrap();
                (eng.group_samples().to_vec(), stats)
            });
            for (samples, stats) in results {
                assert_eq!(samples.len(), 3);
                let mut elems = 0usize;
                let (mut enc, mut com, mut dec) = (0.0, 0.0, 0.0);
                for (j, s) in samples.iter().enumerate() {
                    assert_eq!(s.group, j);
                    assert!(s.elems > 0);
                    elems += s.elems;
                    enc += s.encode_secs;
                    com += s.comm_secs;
                    dec += s.decode_secs;
                }
                assert_eq!(elems, 150);
                assert!((enc - stats.encode_secs).abs() < 1e-9);
                assert!((com - stats.comm_secs).abs() < 1e-9);
                assert!((dec - stats.decode_secs).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn repartition_preserves_flat_state_and_mean() {
        let sizes = vec![40usize, 25, 70, 15];
        let results = run_comm_group(2, move |c| {
            let mut eng = ExchangeEngine::new(
                CodecKind::EfSignSgd,
                Partition::naive_even(4, 2),
                sizes.clone(),
            );
            let mut rng = Xoshiro256::seed_from_u64(77 + c.rank() as u64);
            let mut grads = make_grads(c.rank(), &sizes);
            eng.exchange(c, &mut grads, &mut rng, PipelineMode::Pipelined)
                .unwrap();

            let before = eng.flat_state();
            eng.repartition(Partition::from_bounds(4, vec![0, 1, 3, 4])).unwrap();
            let after = eng.flat_state();
            assert_eq!(before.len(), after.len());
            for (b, a) in before.iter().zip(&after) {
                let same = b
                    .iter()
                    .zip(a)
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "flat EF state changed across repartition");
            }
            assert_eq!(eng.partition().num_groups(), 3);

            // The engine must still aggregate correctly after the switch.
            let mut grads = make_grads(c.rank(), &sizes);
            eng.exchange(c, &mut grads, &mut rng, PipelineMode::Serial)
                .unwrap();
            grads
        });
        assert_eq!(results[0], results[1], "ranks diverged after repartition");
    }

    #[test]
    fn flat_state_round_trips_through_load() {
        let sizes = vec![40usize, 25, 70];
        let results = run_comm_group(2, move |c| {
            let mut eng = ExchangeEngine::new(
                CodecKind::EfSignSgd,
                Partition::naive_even(3, 2),
                sizes.clone(),
            );
            let mut rng = Xoshiro256::seed_from_u64(31 + c.rank() as u64);
            let mut grads = make_grads(c.rank(), &sizes);
            eng.exchange(c, &mut grads, &mut rng, PipelineMode::Serial)
                .unwrap();
            (eng.flat_state(), eng.state_digest())
        });
        for (planes, digest) in results {
            let mut fresh = ExchangeEngine::new(
                CodecKind::EfSignSgd,
                Partition::naive_even(3, 2),
                vec![40, 25, 70],
            );
            assert_ne!(fresh.state_digest(), digest, "exchange must build EF state");
            fresh.load_flat_state(&planes).unwrap();
            assert_eq!(fresh.state_digest(), digest, "restore must be bit-exact");
            // Shape violations are typed errors, not silent truncation.
            assert!(fresh.load_flat_state(&[vec![0.0; 10]]).is_err());
        }
    }

    #[test]
    fn repartition_rejects_wrong_tensor_count() {
        let mut eng =
            ExchangeEngine::new(CodecKind::Fp32, Partition::layer_wise(3), vec![4, 5, 6]);
        assert!(eng.repartition(Partition::layer_wise(2)).is_err());
    }

    #[test]
    fn two_level_route_is_result_invisible_but_stats_visible() {
        use crate::collectives::Topology;
        let sizes = vec![40usize, 25, 70, 15];
        for mode in [PipelineMode::Serial, PipelineMode::Pipelined] {
            let run = |two_level: bool| {
                let sizes2 = sizes.clone();
                run_comm_group(4, move |c| {
                    if two_level {
                        c.set_topology(Topology::from_sizes(&[2, 2]).unwrap()).unwrap();
                    }
                    let mut eng = ExchangeEngine::new(
                        CodecKind::EfSignSgd,
                        Partition::naive_even(4, 2),
                        sizes2.clone(),
                    );
                    let mut rng = Xoshiro256::seed_from_u64(11 + c.rank() as u64);
                    let mut grads = make_grads(c.rank(), &sizes2);
                    let stats = eng.exchange(c, &mut grads, &mut rng, mode).unwrap();
                    let samples = eng.group_samples().to_vec();
                    (grads, eng.state_digest(), stats, samples)
                })
            };
            let flat = run(false);
            let hier = run(true);
            for (rank, ((fg, fd, fs, _), (hg, hd, hs, samples))) in
                flat.iter().zip(&hier).enumerate()
            {
                // EF-SignSGD rides allgather: the two-level exchange is
                // bit-identical to the flat ring, gradients and EF state.
                assert_eq!(fg, hg, "{}: rank {rank} grads diverged", mode.name());
                assert_eq!(fd, hd, "{}: rank {rank} EF state diverged", mode.name());
                // Flat topology crosses no node boundary; the 2+2 split
                // must record real inter-node traffic and timing.
                assert_eq!(fs.inter_bytes_sent, 0);
                assert_eq!(fs.comm_inter_secs, 0.0);
                if rank % 2 == 0 {
                    // Ranks 0 and 2 lead their nodes: they ring inter-node
                    // and their samples time that stage.
                    assert!(hs.inter_bytes_sent > 0, "leader rank {rank}");
                    assert!(hs.comm_inter_secs > 0.0, "leader rank {rank}");
                    // The per-group split must actually reach the samples
                    // the estimator's two_level_fit consumes.
                    let sample_inter: f64 = samples.iter().map(|s| s.comm_inter_secs).sum();
                    assert!(sample_inter > 0.0, "leader rank {rank} samples lost the split");
                } else {
                    // Members only talk to their leader (intra-node).
                    assert_eq!(hs.inter_bytes_sent, 0, "member rank {rank}");
                }
            }
        }
    }

    #[test]
    fn per_group_routes_are_result_invisible_and_recorded() {
        use crate::collectives::{CommRoute, Topology};
        use crate::scheduler::RouteChoice;
        let sizes = vec![40usize, 25, 70, 15];
        for mode in [PipelineMode::Serial, PipelineMode::Pipelined] {
            let run = |routes: Option<Vec<RouteChoice>>| {
                let sizes2 = sizes.clone();
                run_comm_group(4, move |c| {
                    c.set_topology(Topology::from_sizes(&[2, 2]).unwrap()).unwrap();
                    let mut eng = ExchangeEngine::new(
                        CodecKind::EfSignSgd,
                        Partition::naive_even(4, 2),
                        sizes2.clone(),
                    );
                    eng.set_routes(routes.clone()).unwrap();
                    let mut rng = Xoshiro256::seed_from_u64(21 + c.rank() as u64);
                    let mut grads = make_grads(c.rank(), &sizes2);
                    eng.exchange(c, &mut grads, &mut rng, mode).unwrap();
                    let samples = eng.group_samples().to_vec();
                    // The engine restores the topology-default route after
                    // a routed exchange.
                    (grads, eng.state_digest(), samples, c.route())
                })
            };
            let plain = run(None);
            let mixed = run(Some(vec![RouteChoice::Flat, RouteChoice::Hierarchical]));
            for (rank, ((pg, pd, psamples, _), (mg, md, msamples, after))) in
                plain.iter().zip(&mixed).enumerate()
            {
                // EF-SignSGD rides allgather: both routes are bit-identical
                // to the flat ring, so mixing them per group changes
                // nothing in gradients or EF state.
                assert_eq!(pg, mg, "{}: rank {rank} grads diverged", mode.name());
                assert_eq!(pd, md, "{}: rank {rank} EF state diverged", mode.name());
                // Global route (no engine routes): both groups hierarchical.
                assert!(psamples.iter().all(|s| s.route == CommRoute::TwoLevel));
                // Mixed: the recorded per-group routes match the install.
                assert_eq!(msamples[0].route, CommRoute::Flat, "{}", mode.name());
                assert_eq!(msamples[1].route, CommRoute::TwoLevel, "{}", mode.name());
                assert_eq!(*after, CommRoute::TwoLevel, "route not reset");
            }
        }
    }

    #[test]
    fn set_routes_validates_group_count_and_repartition_clears() {
        use crate::scheduler::RouteChoice;
        let mut eng =
            ExchangeEngine::new(CodecKind::Fp32, Partition::naive_even(3, 2), vec![4, 5, 6]);
        assert!(eng.set_routes(Some(vec![RouteChoice::Flat])).is_err());
        eng.set_routes(Some(vec![RouteChoice::Flat, RouteChoice::Hierarchical]))
            .unwrap();
        assert_eq!(eng.routes().unwrap().len(), 2);
        eng.repartition(Partition::layer_wise(3)).unwrap();
        assert!(eng.routes().is_none(), "repartition must clear per-group routes");
    }

    #[test]
    fn mixed_codec_groups_dispatch_their_own_collectives() {
        // Group 0 rides FP32 allreduce, group 1 a sign-compressed
        // allgather: one exchange, two collectives, and the FP32 group's
        // mean must stay exact while the samples record each group's
        // codec. Both pipeline modes must agree bit-for-bit.
        let sizes = vec![32usize, 48, 16];
        let run = |mode: PipelineMode| {
            let sizes2 = sizes.clone();
            run_comm_group(2, move |c| {
                let mut eng = ExchangeEngine::new(
                    CodecKind::Fp32,
                    Partition::naive_even(3, 2),
                    sizes2.clone(),
                );
                eng.set_codecs(Some(vec![CodecKind::Fp32, CodecKind::EfSignSgd]))
                    .unwrap();
                let mut rng = Xoshiro256::seed_from_u64(13 + c.rank() as u64);
                let mut grads = make_grads(c.rank(), &sizes2);
                eng.exchange(c, &mut grads, &mut rng, mode).unwrap();
                let samples = eng.group_samples().to_vec();
                (grads, eng.state_digest(), samples)
            })
        };
        let serial = run(PipelineMode::Serial);
        let pipelined = run(PipelineMode::Pipelined);
        assert_eq!(serial, pipelined, "mixed-codec modes diverged");
        for (grads, _, samples) in &serial {
            assert_eq!(samples[0].codec, CodecKind::Fp32);
            assert_eq!(samples[1].codec, CodecKind::EfSignSgd);
            // The FP32 group (tensors 0 and 1) is an exact mean.
            for (t, buf) in grads.iter().take(2).enumerate() {
                for (i, v) in buf.iter().enumerate() {
                    let want = 1.5 * (t as f32 + 1.0) + i as f32 * 0.001;
                    assert!((v - want).abs() < 1e-4, "t={t} i={i}: {v} vs {want}");
                }
            }
        }
    }

    #[test]
    fn set_codecs_validates_carries_and_resets_state() {
        let mut eng = ExchangeEngine::new(
            CodecKind::EfSignSgd,
            Partition::naive_even(2, 2),
            vec![24, 40],
        );
        // Wrong count is an error.
        assert!(eng.set_codecs(Some(vec![CodecKind::Fp32])).is_err());

        // Give the EF codecs nonzero residual state.
        let planes: Vec<Vec<f32>> = vec![(0..64).map(|i| i as f32 * 0.25).collect()];
        {
            let views: Vec<&[f32]> = vec![&planes[0][..24]];
            eng.codecs[0].load_state_planes(&views);
            let views: Vec<&[f32]> = vec![&planes[0][24..]];
            eng.codecs[1].load_state_planes(&views);
        }
        let digest = eng.state_digest();

        // efsignsgd → onebit: same single-plane shape, state carries.
        eng.set_codecs(Some(vec![CodecKind::OneBit, CodecKind::EfSignSgd]))
            .unwrap();
        assert_eq!(
            eng.group_codecs(),
            vec![CodecKind::OneBit, CodecKind::EfSignSgd]
        );
        let carried = eng.flat_state();
        assert_eq!(carried.len(), 1);
        assert!(
            carried[0]
                .iter()
                .zip(&planes[0])
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "matched-plane flip must carry EF state"
        );
        // Flip back: bit-identical to the original engine.
        eng.set_codecs(None).unwrap();
        assert_eq!(eng.state_digest(), digest, "round-trip flip changed state");

        // efsignsgd → fp32 (0 planes) → efsignsgd: a reset, state zeroed.
        eng.set_codecs(Some(vec![CodecKind::Fp32, CodecKind::Fp32]))
            .unwrap();
        eng.set_codecs(None).unwrap();
        assert!(
            eng.flat_state()[0].iter().all(|&v| v == 0.0),
            "plane-incompatible flip must reset EF state"
        );
    }

    #[test]
    fn repartition_normalizes_mixed_codecs_to_base() {
        let mut eng = ExchangeEngine::new(
            CodecKind::EfSignSgd,
            Partition::naive_even(3, 2),
            vec![4, 5, 6],
        );
        eng.set_codecs(Some(vec![CodecKind::OneBit, CodecKind::Fp32]))
            .unwrap();
        eng.repartition(Partition::layer_wise(3)).unwrap();
        assert_eq!(eng.group_codecs(), vec![CodecKind::EfSignSgd; 3]);
    }

    #[test]
    fn sharded_exchange_owned_spans_match_full_mode() {
        // Full 3-step / all-codec / both-transport equivalence lives in
        // tests/sharded_equivalence.rs; this is the in-module smoke check:
        // allreduce codecs must agree on the owned span, allgather codecs
        // everywhere.
        let sizes = vec![41usize, 25, 70]; // 136 elems, ragged over 3 ranks
        for kind in [CodecKind::Fp32, CodecKind::Fp16, CodecKind::EfSignSgd] {
            for mode in [PipelineMode::Serial, PipelineMode::Pipelined] {
                let run = |xmode: ExchangeMode| {
                    let sizes2 = sizes.clone();
                    run_comm_group(3, move |c| {
                        let mut eng = ExchangeEngine::new(
                            kind,
                            Partition::naive_even(3, 2),
                            sizes2.clone(),
                        );
                        let mut rng = Xoshiro256::seed_from_u64(5 + c.rank() as u64);
                        let mut grads = make_grads(c.rank(), &sizes2);
                        eng.exchange_mode(c, &mut grads, &mut rng, mode, xmode)
                            .unwrap();
                        let owned = eng.owned_group_ranges(c.world(), c.rank());
                        (grads, eng.state_digest(), owned)
                    })
                };
                let full = run(ExchangeMode::Full);
                let sharded = run(ExchangeMode::Sharded);
                for (rank, ((fg, fd, owned), (sg, sd, _))) in
                    full.iter().zip(&sharded).enumerate()
                {
                    assert_eq!(fd, sd, "{} {}: EF state diverged", kind.name(), mode.name());
                    if kind.collective() == Collective::AllGather {
                        assert_eq!(fg, sg, "{} rank {rank}: allgather codecs agree everywhere", kind.name());
                        continue;
                    }
                    // Allreduce codecs: compare only the owned spans, via
                    // the group-flat → tensor-offset mapping.
                    let p = Partition::naive_even(3, 2);
                    for (j, &(lo, hi)) in owned.iter().enumerate() {
                        let mut off = 0;
                        for i in p.group_range(j) {
                            let len = sizes[i];
                            for e in 0..len {
                                let flat_idx = off + e;
                                if flat_idx >= lo && flat_idx < hi {
                                    assert_eq!(
                                        fg[i][e].to_bits(),
                                        sg[i][e].to_bits(),
                                        "{} {} rank {rank} group {j} tensor {i} elem {e}",
                                        kind.name(),
                                        mode.name()
                                    );
                                }
                            }
                            off += len;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn wire_pool_recycles_buffers() {
        // After a first exchange primes the pool, later exchanges should
        // not grow it beyond the pipeline depth.
        let results = run_comm_group(2, |c| {
            let mut eng = ExchangeEngine::new(
                CodecKind::EfSignSgd,
                Partition::naive_even(4, 4),
                vec![64, 64, 64, 64],
            );
            let mut rng = Xoshiro256::seed_from_u64(3);
            for _ in 0..3 {
                let mut grads = make_grads(c.rank(), &[64, 64, 64, 64]);
                eng.exchange(c, &mut grads, &mut rng, PipelineMode::Pipelined)
                    .unwrap();
            }
            eng.wire_pool.len()
        });
        for pool in results {
            // One recycled buffer per completed group is the ceiling.
            assert!(pool <= 4, "pool grew to {pool}");
        }
    }
}
