//! Versioned on-disk snapshots of the full exchange state — the restore
//! half of elastic training.
//!
//! A [`Checkpoint`] captures everything a rank needs to resume a run
//! **bit-exactly**: the adopted schedule (partition bounds, per-group
//! routes and codecs, the schedule epoch it was broadcast under), every
//! codec's error-feedback state flattened to model-length planes
//! (`flat_state` form), the parameters, and the optimizer's momentum
//! buffers. Floats are serialized as their IEEE-754 bit patterns (`u32`,
//! which a JSON f64 represents exactly), so a save → load round trip
//! changes nothing — not even NaN payloads or signed zeros. The recorded
//! `param_digest` is re-derived on load and any mismatch is a hard error:
//! a truncated or hand-edited snapshot must never silently resume.
//!
//! Writes go through a temp file + atomic rename, so a rank killed
//! mid-write (the exact scenario checkpoints exist for) leaves the previous
//! snapshot intact. The trainer writes one on `--checkpoint-interval`
//! boundaries and again on a recoverable peer failure, before shrinking the
//! world (the "emergency" snapshot a re-joining rank restores from).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::ExchangeMode;
use crate::compression::CodecKind;
use crate::scheduler::{Partition, RouteChoice};
use crate::training::params_digest;
use crate::util::json::Value;

/// Bump when the on-disk layout changes incompatibly; `load` refuses
/// snapshots from any newer (or unknown) version rather than guessing.
/// Version 2 added `exchange_mode` (and, under the sharded mode, records
/// velocity as full-length planes zeroed outside the owning rank's shard);
/// version-1 snapshots still load, as implicitly `exchange_mode = full`.
pub const CHECKPOINT_VERSION: u64 = 2;

/// One rank's complete resumable state at a step boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Completed optimizer steps; a resumed run continues at this index.
    pub step: usize,
    /// World size the snapshot was taken under (a degraded-world snapshot
    /// records the shrunk size).
    pub world: usize,
    /// Rank that wrote the snapshot.
    pub rank: usize,
    /// Run seed — cross-checked on restore so a snapshot cannot resume a
    /// differently-seeded run undetected.
    pub seed: u64,
    /// The run's base codec (`--codec`).
    pub base_codec: CodecKind,
    /// Adopted partition bounds over the backprop-ordered tensors.
    pub bounds: Vec<usize>,
    /// Per-group collective routes (empty = communicator's global route).
    pub routes: Vec<RouteChoice>,
    /// Per-group codecs (empty = base codec everywhere).
    pub codecs: Vec<CodecKind>,
    /// Schedule epoch the adopted schedule was broadcast under.
    pub schedule_epoch: u64,
    /// Exchange mode the run was using (`full` | `sharded`). Shard
    /// ownership under `sharded` is fully derivable from `world`, `bounds`,
    /// and the `shard_elems` contract, so no explicit shard map is stored;
    /// `velocity` planes carry zeros outside this rank's owned spans.
    /// Version-1 snapshots load as `Full`.
    pub exchange_mode: ExchangeMode,
    /// Per-tensor parameters, forward order.
    pub params: Vec<Vec<f32>>,
    /// Per-tensor optimizer momentum, forward order.
    pub velocity: Vec<Vec<f32>>,
    /// Codec state planes flattened to full model length
    /// ([`crate::coordinator::ExchangeEngine::flat_state`] form).
    pub codec_state: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// Conventional snapshot path for `rank` under `dir`.
    pub fn rank_path(dir: &Path, rank: usize) -> PathBuf {
        dir.join(format!("ckpt-rank{rank}.json"))
    }

    /// The partition the snapshot's schedule state describes, validated
    /// against the recorded tensor count.
    pub fn partition(&self) -> anyhow::Result<Partition> {
        Partition::try_from_bounds(self.params.len(), self.bounds.clone())
    }

    /// Digest of the snapshotted parameters (the integrity field `load`
    /// re-derives, and the value a resumed run's `param_digest` must match
    /// at the same step).
    pub fn param_digest(&self) -> u64 {
        params_digest(&self.params)
    }

    pub fn to_json(&self) -> Value {
        Value::from_pairs(vec![
            ("version", Value::from(CHECKPOINT_VERSION)),
            ("step", Value::from(self.step)),
            ("world", Value::from(self.world)),
            ("rank", Value::from(self.rank)),
            ("seed", Value::from(self.seed)),
            ("codec", Value::from(self.base_codec.name())),
            ("bounds", Value::Arr(self.bounds.iter().map(|&b| Value::from(b)).collect())),
            (
                "routes",
                Value::Arr(self.routes.iter().map(|r| Value::from(r.name())).collect()),
            ),
            (
                "codecs",
                Value::Arr(self.codecs.iter().map(|c| Value::from(c.name())).collect()),
            ),
            ("schedule_epoch", Value::from(self.schedule_epoch)),
            ("exchange_mode", Value::from(self.exchange_mode.name())),
            ("param_digest", Value::from(format!("{:016x}", self.param_digest()))),
            ("params", planes_to_json(&self.params)),
            ("velocity", planes_to_json(&self.velocity)),
            ("codec_state", planes_to_json(&self.codec_state)),
        ])
    }

    /// Strict inverse of [`Checkpoint::to_json`]: unknown version, missing
    /// or mistyped fields, malformed bounds, shape mismatches, and a
    /// param-digest mismatch are all errors — never a best-effort resume.
    pub fn from_json(v: &Value) -> anyhow::Result<Checkpoint> {
        let version = field_u64(v, "version")?;
        anyhow::ensure!(
            version == 1 || version == CHECKPOINT_VERSION,
            "checkpoint version {version} (this build reads 1..={CHECKPOINT_VERSION})"
        );
        // exchange_mode arrived in version 2; a v1 snapshot could only have
        // been written by the full exchange.
        let exchange_mode = if version >= 2 {
            ExchangeMode::from_name(field_str(v, "exchange_mode")?)?
        } else {
            ExchangeMode::Full
        };
        let params = planes_from_json(field(v, "params")?, "params")?;
        let recorded = field_str(v, "param_digest")?;
        let want = u64::from_str_radix(recorded, 16)
            .map_err(|e| anyhow::anyhow!("checkpoint param_digest '{recorded}': {e}"))?;
        let got = params_digest(&params);
        anyhow::ensure!(
            got == want,
            "checkpoint integrity: params digest {got:016x} != recorded {want:016x}"
        );
        let velocity = planes_from_json(field(v, "velocity")?, "velocity")?;
        anyhow::ensure!(
            velocity.len() == params.len(),
            "checkpoint: {} velocity tensors for {} param tensors",
            velocity.len(),
            params.len()
        );
        for (t, (p, vel)) in params.iter().zip(&velocity).enumerate() {
            anyhow::ensure!(
                p.len() == vel.len(),
                "checkpoint: tensor {t} has {} params but {} velocity elements",
                p.len(),
                vel.len()
            );
        }
        let bounds = field(v, "bounds")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("checkpoint bounds: not an array"))?
            .iter()
            .enumerate()
            .map(|(i, b)| {
                b.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("checkpoint bounds[{i}]: not a usize ({b:?})"))
            })
            .collect::<anyhow::Result<Vec<usize>>>()?;
        let partition = Partition::try_from_bounds(params.len(), bounds.clone())?;
        let routes = field(v, "routes")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("checkpoint routes: not an array"))?
            .iter()
            .map(|r| {
                r.as_str()
                    .ok_or_else(|| anyhow::anyhow!("checkpoint route {r:?}: not a string"))
                    .and_then(RouteChoice::from_name)
            })
            .collect::<anyhow::Result<Vec<RouteChoice>>>()?;
        anyhow::ensure!(
            routes.is_empty() || routes.len() == partition.num_groups(),
            "checkpoint: {} routes for {} groups",
            routes.len(),
            partition.num_groups()
        );
        let codecs = field(v, "codecs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("checkpoint codecs: not an array"))?
            .iter()
            .map(|c| {
                c.as_str()
                    .ok_or_else(|| anyhow::anyhow!("checkpoint codec {c:?}: not a string"))
                    .and_then(CodecKind::from_name)
            })
            .collect::<anyhow::Result<Vec<CodecKind>>>()?;
        anyhow::ensure!(
            codecs.is_empty() || codecs.len() == partition.num_groups(),
            "checkpoint: {} codecs for {} groups",
            codecs.len(),
            partition.num_groups()
        );
        Ok(Checkpoint {
            step: field_u64(v, "step")? as usize,
            world: field_u64(v, "world")? as usize,
            rank: field_u64(v, "rank")? as usize,
            seed: field_u64(v, "seed")?,
            base_codec: CodecKind::from_name(field_str(v, "codec")?)?,
            bounds,
            routes,
            codecs,
            schedule_epoch: field_u64(v, "schedule_epoch")?,
            exchange_mode,
            params,
            velocity,
            codec_state: planes_from_json(field(v, "codec_state")?, "codec_state")?,
        })
    }

    /// Refuse to resume under a different exchange mode than the snapshot
    /// was written in: the two modes lay optimizer state out differently
    /// (full per-tensor momentum vs zero-padded shard planes), so a silent
    /// cross-mode resume would corrupt the optimizer trajectory. The
    /// trainer calls this before adopting a restored snapshot.
    pub fn ensure_exchange_mode(&self, configured: ExchangeMode) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.exchange_mode == configured,
            "checkpoint was written under '--exchange-mode {}' but this run is configured \
             with '--exchange-mode {}'; re-run with '--exchange-mode {}' to resume it \
             (or start fresh without --resume)",
            self.exchange_mode.name(),
            configured.name(),
            self.exchange_mode.name()
        );
        Ok(())
    }

    /// The serialized form as bytes — what the hot-join protocol streams
    /// over [`crate::collectives::snapshot`] and [`Checkpoint::from_bytes`]
    /// reverses.
    pub fn to_bytes(&self) -> Vec<u8> {
        self.to_json().to_string_compact().into_bytes()
    }

    /// Strict inverse of [`Checkpoint::to_bytes`] (same validation as
    /// [`Checkpoint::from_json`], including the param-digest check).
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Checkpoint> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("checkpoint stream: non-utf8 payload: {e}"))?;
        let v = Value::parse(text).map_err(|e| anyhow::anyhow!("checkpoint stream: {e}"))?;
        Checkpoint::from_json(&v)
    }

    /// Like [`Checkpoint::to_bytes`], but reusing `cache`d per-plane JSON
    /// fragments for planes whose digest is unchanged since the previous
    /// call — the dirty-plane tracking behind incremental interval
    /// checkpoints (EF planes for groups that didn't exchange, frozen
    /// tensors, zero shards all serialize for free). The output parses to
    /// the same [`Checkpoint`] as the uncached form; only the JSON key
    /// order differs.
    pub fn to_bytes_cached(&self, cache: &mut PlaneCache) -> Vec<u8> {
        let scalars = Value::from_pairs(vec![
            ("version", Value::from(CHECKPOINT_VERSION)),
            ("step", Value::from(self.step)),
            ("world", Value::from(self.world)),
            ("rank", Value::from(self.rank)),
            ("seed", Value::from(self.seed)),
            ("codec", Value::from(self.base_codec.name())),
            ("bounds", Value::Arr(self.bounds.iter().map(|&b| Value::from(b)).collect())),
            (
                "routes",
                Value::Arr(self.routes.iter().map(|r| Value::from(r.name())).collect()),
            ),
            (
                "codecs",
                Value::Arr(self.codecs.iter().map(|c| Value::from(c.name())).collect()),
            ),
            ("schedule_epoch", Value::from(self.schedule_epoch)),
            ("exchange_mode", Value::from(self.exchange_mode.name())),
            ("param_digest", Value::from(format!("{:016x}", self.param_digest()))),
        ]);
        let mut text = scalars.to_string_compact();
        debug_assert!(text.ends_with('}'));
        text.pop();
        text.push_str(",\"params\":");
        cache.render_section(PlaneSection::Params, &self.params, &mut text);
        text.push_str(",\"velocity\":");
        cache.render_section(PlaneSection::Velocity, &self.velocity, &mut text);
        text.push_str(",\"codec_state\":");
        cache.render_section(PlaneSection::CodecState, &self.codec_state, &mut text);
        text.push('}');
        text.into_bytes()
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over
    /// `path`. A rank killed mid-write leaves the previous snapshot intact.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        write_atomic(path, &self.to_bytes())
    }

    /// [`Checkpoint::save`] with the incremental serializer: planes
    /// unchanged since `cache` last saw this snapshot path are not
    /// re-serialized. Same tmp + atomic-rename durability.
    pub fn save_with_cache(&self, path: &Path, cache: &mut PlaneCache) -> anyhow::Result<()> {
        write_atomic(path, &self.to_bytes_cached(cache))
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("checkpoint read {}: {e}", path.display()))?;
        let v = Value::parse(&text)
            .map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))?;
        Checkpoint::from_json(&v)
            .map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))
    }
}

fn write_atomic(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| anyhow::anyhow!("checkpoint mkdir {}: {e}", dir.display()))?;
        }
    }
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, bytes)
        .map_err(|e| anyhow::anyhow!("checkpoint write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("checkpoint rename to {}: {e}", path.display()))?;
    Ok(())
}

#[derive(Clone, Copy)]
enum PlaneSection {
    Params,
    Velocity,
    CodecState,
}

/// Per-plane serialization cache for one snapshot path: each entry pairs a
/// plane's content digest with its rendered JSON fragment, so interval
/// checkpoints only pay serialization cost for planes that actually
/// changed since the previous write. Held by the [`AsyncCheckpointer`]'s
/// writer thread, one per path.
#[derive(Debug, Default)]
pub struct PlaneCache {
    params: Vec<(u64, String)>,
    velocity: Vec<(u64, String)>,
    codec_state: Vec<(u64, String)>,
    reused: u64,
    rendered: u64,
}

impl PlaneCache {
    pub fn new() -> PlaneCache {
        PlaneCache::default()
    }

    /// Planes served from cache across all renders (dirty-plane tracking
    /// observability, asserted by the tests).
    pub fn reused(&self) -> u64 {
        self.reused
    }

    /// Planes that had to be (re-)serialized across all renders.
    pub fn rendered(&self) -> u64 {
        self.rendered
    }

    fn render_section(&mut self, section: PlaneSection, planes: &[Vec<f32>], out: &mut String) {
        // Split the counter borrows from the entry borrow by hand: each
        // section owns a distinct Vec but shares the two counters.
        let (entries, reused, rendered) = match section {
            PlaneSection::Params => (&mut self.params, &mut self.reused, &mut self.rendered),
            PlaneSection::Velocity => (&mut self.velocity, &mut self.reused, &mut self.rendered),
            PlaneSection::CodecState => {
                (&mut self.codec_state, &mut self.reused, &mut self.rendered)
            }
        };
        entries.truncate(planes.len());
        out.push('[');
        for (i, plane) in planes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let digest = params_digest(std::slice::from_ref(plane));
            if entries.get(i).is_some_and(|(d, _)| *d == digest) {
                *reused += 1;
                out.push_str(&entries[i].1);
                continue;
            }
            *rendered += 1;
            let frag = Value::Arr(
                plane.iter().map(|&x| Value::from(x.to_bits() as u64)).collect(),
            )
            .to_string_compact();
            out.push_str(&frag);
            if i < entries.len() {
                entries[i] = (digest, frag);
            } else {
                entries.push((digest, frag));
            }
        }
        out.push(']');
    }
}

enum Job {
    Write(PathBuf, Checkpoint),
    Flush(mpsc::Sender<()>),
}

struct AsyncShared {
    /// Wall-clock seconds the writer thread spent serializing + writing —
    /// time the training step no longer pays (`ckpt_async_write_secs`).
    write_secs: Mutex<f64>,
    writes: AtomicU64,
    /// First write failure, surfaced by the next `submit`/`flush`.
    last_error: Mutex<Option<String>>,
    /// Artificial per-write stall (test hook: makes "the write is slow but
    /// the step doesn't block" deterministically observable).
    write_delay: Duration,
}

/// Background interval-checkpoint writer: `submit` clones nothing and does
/// no IO on the caller's thread — the snapshot (already cloned off the hot
/// path by the caller) crosses a channel to a writer thread that
/// serializes incrementally (one [`PlaneCache`] per path) and writes with
/// tmp + atomic-rename. Write failures are latched and surfaced by the
/// next `submit` or `flush` rather than lost. Dropping the handle joins
/// the thread after it drains the queue.
pub struct AsyncCheckpointer {
    tx: Option<mpsc::Sender<Job>>,
    shared: Arc<AsyncShared>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Default for AsyncCheckpointer {
    fn default() -> Self {
        AsyncCheckpointer::new()
    }
}

impl AsyncCheckpointer {
    pub fn new() -> AsyncCheckpointer {
        AsyncCheckpointer::with_write_delay(Duration::ZERO)
    }

    /// Test constructor: every write additionally sleeps `write_delay`
    /// first, making the async-vs-blocking distinction deterministic.
    pub fn with_write_delay(write_delay: Duration) -> AsyncCheckpointer {
        let (tx, rx) = mpsc::channel::<Job>();
        let shared = Arc::new(AsyncShared {
            write_secs: Mutex::new(0.0),
            writes: AtomicU64::new(0),
            last_error: Mutex::new(None),
            write_delay,
        });
        let worker = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("ckpt-writer".to_string())
            .spawn(move || {
                let mut caches: HashMap<PathBuf, PlaneCache> = HashMap::new();
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Write(path, ckpt) => {
                            let start = Instant::now();
                            if !worker.write_delay.is_zero() {
                                std::thread::sleep(worker.write_delay);
                            }
                            let cache = caches.entry(path.clone()).or_default();
                            match ckpt.save_with_cache(&path, cache) {
                                Ok(()) => {
                                    worker.writes.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(e) => {
                                    let mut slot = worker.last_error.lock().unwrap();
                                    if slot.is_none() {
                                        *slot = Some(e.to_string());
                                    }
                                }
                            }
                            *worker.write_secs.lock().unwrap() +=
                                start.elapsed().as_secs_f64();
                        }
                        Job::Flush(ack) => {
                            // FIFO channel: every Write submitted before the
                            // flush has already been processed.
                            let _ = ack.send(());
                        }
                    }
                }
            })
            .expect("spawning checkpoint writer thread");
        AsyncCheckpointer { tx: Some(tx), shared, handle: Some(handle) }
    }

    /// Queue one snapshot write. Off the hot path: the only cost here is
    /// the channel send. Surfaces a failure from any *earlier* write.
    pub fn submit(&self, path: PathBuf, ckpt: Checkpoint) -> anyhow::Result<()> {
        if let Some(e) = self.shared.last_error.lock().unwrap().clone() {
            anyhow::bail!("async checkpoint write failed: {e}");
        }
        self.tx
            .as_ref()
            .expect("submit after drop")
            .send(Job::Write(path, ckpt))
            .map_err(|_| anyhow::anyhow!("checkpoint writer thread exited"))
    }

    /// Block until every previously submitted write has been completed (or
    /// failed), then surface any latched failure. Called at end of run and
    /// before a planned `abort()` so no snapshot is torn.
    pub fn flush(&self) -> anyhow::Result<()> {
        let (ack_tx, ack_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("flush after drop")
            .send(Job::Flush(ack_tx))
            .map_err(|_| anyhow::anyhow!("checkpoint writer thread exited"))?;
        ack_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("checkpoint writer thread exited mid-flush"))?;
        if let Some(e) = self.shared.last_error.lock().unwrap().clone() {
            anyhow::bail!("async checkpoint write failed: {e}");
        }
        Ok(())
    }

    /// Seconds the writer thread has spent on completed writes — the
    /// `ckpt_async_write_secs` RunResult field (time hidden from steps).
    pub fn write_secs(&self) -> f64 {
        *self.shared.write_secs.lock().unwrap()
    }

    /// Completed (successful) snapshot writes.
    pub fn writes(&self) -> u64 {
        self.shared.writes.load(Ordering::Relaxed)
    }
}

impl Drop for AsyncCheckpointer {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn field<'a>(v: &'a Value, key: &str) -> anyhow::Result<&'a Value> {
    v.get(key).ok_or_else(|| anyhow::anyhow!("checkpoint: missing field '{key}'"))
}

fn field_u64(v: &Value, key: &str) -> anyhow::Result<u64> {
    field(v, key)?
        .as_usize()
        .map(|n| n as u64)
        .ok_or_else(|| anyhow::anyhow!("checkpoint field '{key}': not an unsigned integer"))
}

fn field_str<'a>(v: &'a Value, key: &str) -> anyhow::Result<&'a str> {
    field(v, key)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("checkpoint field '{key}': not a string"))
}

/// Per-tensor f32 planes as nested arrays of `u32` bit patterns — every
/// pattern is exactly representable as a JSON f64, so the encoding is
/// lossless for all f32 values including NaNs and signed zeros.
fn planes_to_json(planes: &[Vec<f32>]) -> Value {
    Value::Arr(
        planes
            .iter()
            .map(|p| Value::Arr(p.iter().map(|&x| Value::from(x.to_bits() as u64)).collect()))
            .collect(),
    )
}

fn planes_from_json(v: &Value, what: &str) -> anyhow::Result<Vec<Vec<f32>>> {
    let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("checkpoint {what}: not an array"))?;
    arr.iter()
        .enumerate()
        .map(|(t, plane)| {
            let inner = plane
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("checkpoint {what}[{t}]: not an array"))?;
            inner
                .iter()
                .enumerate()
                .map(|(i, b)| {
                    let bits = b.as_usize().ok_or_else(|| {
                        anyhow::anyhow!("checkpoint {what}[{t}][{i}]: not a bit pattern ({b:?})")
                    })?;
                    anyhow::ensure!(
                        bits <= u32::MAX as usize,
                        "checkpoint {what}[{t}][{i}]: {bits} exceeds a u32 bit pattern"
                    );
                    Ok(f32::from_bits(bits as u32))
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            step: 17,
            world: 4,
            rank: 1,
            seed: 42,
            base_codec: CodecKind::EfSignSgd,
            bounds: vec![0, 2, 3],
            routes: vec![RouteChoice::Flat, RouteChoice::Hierarchical],
            codecs: vec![CodecKind::EfSignSgd, CodecKind::Fp32],
            schedule_epoch: 3,
            exchange_mode: ExchangeMode::Full,
            // Awkward values on purpose: subnormal, -0.0, f32::MAX, and
            // irrationals that don't round-trip through decimal printing.
            params: vec![vec![0.1, -0.0, f32::MIN_POSITIVE / 8.0], vec![1.0 / 3.0]],
            velocity: vec![vec![f32::MAX, -2.5e-7, 0.0], vec![-1.0 / 7.0]],
            codec_state: vec![vec![3.14159, -0.001, 7.0, 1e-30]],
        }
    }

    fn bits(planes: &[Vec<f32>]) -> Vec<Vec<u32>> {
        planes.iter().map(|p| p.iter().map(|x| x.to_bits()).collect()).collect()
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let c = sample();
        let text = c.to_json().to_string_compact();
        let back = Checkpoint::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back, c);
        assert_eq!(bits(&back.params), bits(&c.params));
        assert_eq!(bits(&back.velocity), bits(&c.velocity));
        assert_eq!(bits(&back.codec_state), bits(&c.codec_state));
        assert_eq!(back.partition().unwrap(), c.partition().unwrap());
    }

    #[test]
    fn nan_payloads_survive() {
        let mut c = sample();
        c.params[0][0] = f32::from_bits(0x7fc0_1234); // NaN with a payload
        let text = c.to_json().to_string_compact();
        let back = Checkpoint::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.params[0][0].to_bits(), 0x7fc0_1234);
    }

    #[test]
    fn save_load_round_trips_on_disk() {
        let dir = std::env::temp_dir()
            .join(format!("mergecomp-ckpt-test-{}", std::process::id()));
        let path = Checkpoint::rank_path(&dir, 1);
        let c = sample();
        c.save(&path).unwrap();
        // Saving again overwrites atomically (the rename path).
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tampered_params_fail_the_digest_check() {
        let c = sample();
        let mut v = c.to_json();
        // Flip one parameter bit pattern in the serialized form.
        if let Value::Obj(m) = &mut v {
            if let Some(Value::Arr(planes)) = m.get_mut("params") {
                if let Value::Arr(p0) = &mut planes[0] {
                    p0[0] = Value::from(0x3f80_0000u64); // 1.0f32
                }
            }
        }
        let err = Checkpoint::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("integrity"), "{err}");
    }

    #[test]
    fn exchange_mode_round_trips_and_v1_loads_as_full() {
        let mut c = sample();
        c.exchange_mode = ExchangeMode::Sharded;
        let back =
            Checkpoint::from_json(&Value::parse(&c.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.exchange_mode, ExchangeMode::Sharded);

        // A version-1 snapshot (no exchange_mode field) is implicitly Full.
        let mut v = sample().to_json();
        v.set("version", Value::from(1u64));
        if let Value::Obj(m) = &mut v {
            m.remove("exchange_mode");
        }
        let back = Checkpoint::from_json(&v).unwrap();
        assert_eq!(back.exchange_mode, ExchangeMode::Full);

        // Version 2 requires the field.
        let mut v = sample().to_json();
        if let Value::Obj(m) = &mut v {
            m.remove("exchange_mode");
        }
        assert!(Checkpoint::from_json(&v).is_err());
    }

    #[test]
    fn mode_mismatch_is_actionable() {
        let c = sample();
        c.ensure_exchange_mode(ExchangeMode::Full).unwrap();
        let err = c.ensure_exchange_mode(ExchangeMode::Sharded).unwrap_err().to_string();
        assert!(err.contains("--exchange-mode full"), "{err}");
        assert!(err.contains("--exchange-mode sharded"), "{err}");

        let mut s = sample();
        s.exchange_mode = ExchangeMode::Sharded;
        s.ensure_exchange_mode(ExchangeMode::Sharded).unwrap();
        assert!(s.ensure_exchange_mode(ExchangeMode::Full).is_err());
    }

    #[test]
    fn cached_serialization_parses_identically_and_tracks_dirty_planes() {
        let mut c = sample();
        let mut cache = PlaneCache::new();
        // First render: every plane is a miss.
        let back = Checkpoint::from_bytes(&c.to_bytes_cached(&mut cache)).unwrap();
        assert_eq!(back, c);
        assert_eq!(back, Checkpoint::from_bytes(&c.to_bytes()).unwrap());
        let total = (c.params.len() + c.velocity.len() + c.codec_state.len()) as u64;
        assert_eq!((cache.rendered(), cache.reused()), (total, 0));
        // Unchanged snapshot: everything comes from cache.
        let back = Checkpoint::from_bytes(&c.to_bytes_cached(&mut cache)).unwrap();
        assert_eq!(back, c);
        assert_eq!((cache.rendered(), cache.reused()), (total, total));
        // Dirty one plane: exactly one re-serialization.
        c.params[1][0] = 9.25;
        c.step += 1;
        let back = Checkpoint::from_bytes(&c.to_bytes_cached(&mut cache)).unwrap();
        assert_eq!(back, c);
        assert_eq!((cache.rendered(), cache.reused()), (total + 1, 2 * total - 1));
    }

    #[test]
    fn async_checkpointer_writes_in_background_and_flushes() {
        let dir = std::env::temp_dir()
            .join(format!("mergecomp-async-ckpt-{}", std::process::id()));
        let path = Checkpoint::rank_path(&dir, 1);
        let ckptr = AsyncCheckpointer::new();
        let mut c = sample();
        ckptr.submit(path.clone(), c.clone()).unwrap();
        ckptr.flush().unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c);
        assert_eq!(ckptr.writes(), 1);
        // A second interval snapshot overwrites the first (same path, so
        // the writer's PlaneCache serves the unchanged planes).
        c.step += 1;
        c.params[0][0] += 1.0;
        ckptr.submit(path.clone(), c.clone()).unwrap();
        ckptr.flush().unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c);
        assert_eq!(ckptr.writes(), 2);
        assert!(ckptr.write_secs() >= 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_checkpointer_surfaces_write_errors_on_flush() {
        let dir = std::env::temp_dir()
            .join(format!("mergecomp-async-ckpt-err-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Parent "directory" is a regular file: create_dir_all must fail.
        let blocker = dir.join("not-a-dir");
        std::fs::write(&blocker, b"x").unwrap();
        let ckptr = AsyncCheckpointer::new();
        ckptr.submit(blocker.join("ckpt.json"), sample()).unwrap();
        let err = ckptr.flush().unwrap_err().to_string();
        assert!(err.contains("checkpoint"), "{err}");
        // The latched failure also poisons the next submit.
        let err = ckptr.submit(blocker.join("ckpt.json"), sample()).unwrap_err().to_string();
        assert!(err.contains("checkpoint"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn submitting_is_cheap_even_when_the_write_is_slow() {
        // The step-timing claim behind async interval checkpoints: a write
        // that takes 150 ms must not stall the submitting (training)
        // thread. The artificial delay makes the distinction deterministic
        // even on a slow CI box.
        let dir = std::env::temp_dir()
            .join(format!("mergecomp-async-ckpt-slow-{}", std::process::id()));
        let path = Checkpoint::rank_path(&dir, 0);
        let ckptr = AsyncCheckpointer::with_write_delay(Duration::from_millis(150));
        let start = Instant::now();
        ckptr.submit(path.clone(), sample()).unwrap();
        let exposed = start.elapsed();
        assert!(
            exposed < Duration::from_millis(50),
            "submit exposed {exposed:?} of a 150 ms write to the step"
        );
        ckptr.flush().unwrap();
        assert!(ckptr.write_secs() >= 0.15, "hidden write time: {}", ckptr.write_secs());
        assert_eq!(Checkpoint::load(&path).unwrap(), sample());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_version_and_malformed_fields_are_errors() {
        let c = sample();
        let mut v = c.to_json();
        v.set("version", Value::from(CHECKPOINT_VERSION + 1));
        assert!(Checkpoint::from_json(&v).is_err());

        let mut v = c.to_json();
        v.set("bounds", Value::parse("[0, 2, 2, 3]").unwrap());
        assert!(Checkpoint::from_json(&v).is_err(), "degenerate bounds");

        let mut v = c.to_json();
        v.set("routes", Value::parse(r#"["flat"]"#).unwrap());
        assert!(Checkpoint::from_json(&v).is_err(), "route/group count mismatch");

        let mut v = c.to_json();
        if let Value::Obj(m) = &mut v {
            m.remove("param_digest");
        }
        assert!(Checkpoint::from_json(&v).is_err(), "missing digest");

        // Truncated file: parse error surfaces, not a panic.
        let text = c.to_json().to_string_compact();
        assert!(Value::parse(&text[..text.len() / 2]).is_err());
    }
}
