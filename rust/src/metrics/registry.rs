//! In-process metrics registry: named counters, gauges, and histograms with
//! a scoped-timer convenience. Thread-safe via a single mutex — metrics are
//! recorded outside the innermost hot loops (per tensor-group / per step,
//! not per element), so contention is negligible.

use crate::util::json::Value;
use crate::util::stats::percentile_sorted;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Fixed-boundary histogram with recorded raw samples (bounded reservoir)
/// so percentiles stay exact for the sample counts we see (≤ ~1e6).
#[derive(Debug, Clone)]
pub struct Histogram {
    samples: Vec<f64>,
    max_samples: usize,
    pub count: u64,
    pub sum: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            samples: Vec::new(),
            max_samples: 1 << 20,
            count: 0,
            sum: 0.0,
        }
    }
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        if self.samples.len() < self.max_samples {
            self.samples.push(v);
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&sorted, p)
    }

    pub fn summary(&self) -> Value {
        Value::from_pairs(vec![
            ("count", Value::from(self.count)),
            ("mean", Value::from(self.mean())),
            ("p50", Value::from(self.percentile(50.0))),
            ("p99", Value::from(self.percentile(99.0))),
            ("min", Value::from(self.percentile(0.0))),
            ("max", Value::from(self.percentile(100.0))),
        ])
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

/// Clonable handle to a shared registry.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<Inner>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn gauge(&self, name: &str, v: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), v);
    }

    pub fn observe(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().record(v);
    }

    /// Scoped wall-clock timer: records seconds into histogram `name` on drop.
    pub fn timer(&self, name: &str) -> TimerGuard {
        TimerGuard {
            registry: self.clone(),
            name: name.to_string(),
            start: Instant::now(),
        }
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn histogram_mean(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .map(|h| h.mean())
            .unwrap_or(f64::NAN)
    }

    pub fn histogram_sum(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .map(|h| h.sum)
            .unwrap_or(0.0)
    }

    pub fn histogram_count(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .get(name)
            .map(|h| h.count)
            .unwrap_or(0)
    }

    /// Full snapshot as JSON — dumped at the end of every run/bench.
    pub fn snapshot(&self) -> Value {
        let g = self.inner.lock().unwrap();
        let counters = Value::Obj(
            g.counters
                .iter()
                .map(|(k, v)| (k.clone(), Value::from(*v)))
                .collect(),
        );
        let gauges = Value::Obj(
            g.gauges
                .iter()
                .map(|(k, v)| (k.clone(), Value::from(*v)))
                .collect(),
        );
        let hists = Value::Obj(
            g.histograms
                .iter()
                .map(|(k, h)| (k.clone(), h.summary()))
                .collect(),
        );
        Value::from_pairs(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
        ])
    }

    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.counters.clear();
        g.gauges.clear();
        g.histograms.clear();
    }
}

pub struct TimerGuard {
    registry: MetricsRegistry,
    name: String,
    start: Instant,
}

impl Drop for TimerGuard {
    fn drop(&mut self) {
        self.registry
            .observe(&self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = MetricsRegistry::new();
        m.incr("steps", 1);
        m.incr("steps", 2);
        m.gauge("loss", 1.5);
        assert_eq!(m.counter_value("steps"), 3);
        assert_eq!(m.counter_value("missing"), 0);
        assert_eq!(m.gauge_value("loss"), Some(1.5));
        assert_eq!(m.gauge_value("missing"), None);
        let snap = m.snapshot();
        assert_eq!(snap.get("gauges").unwrap().f64_or("loss", 0.0), 1.5);
    }

    #[test]
    fn histogram_percentiles() {
        let m = MetricsRegistry::new();
        for i in 1..=100 {
            m.observe("lat", i as f64);
        }
        assert!((m.histogram_mean("lat") - 50.5).abs() < 1e-9);
        assert_eq!(m.histogram_count("lat"), 100);
        let snap = m.snapshot();
        let h = snap.get("histograms").unwrap().get("lat").unwrap();
        assert!((h.f64_or("p50", 0.0) - 50.5).abs() < 1.0);
        assert!(h.f64_or("p99", 0.0) >= 99.0);
    }

    #[test]
    fn timer_records() {
        let m = MetricsRegistry::new();
        {
            let _t = m.timer("op");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(m.histogram_count("op"), 1);
        assert!(m.histogram_mean("op") >= 0.002);
    }

    #[test]
    fn shared_across_clones_and_threads() {
        let m = MetricsRegistry::new();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m2 = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    m2.incr("x", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter_value("x"), 400);
    }

    #[test]
    fn reset_clears() {
        let m = MetricsRegistry::new();
        m.incr("a", 1);
        m.observe("b", 1.0);
        m.reset();
        assert_eq!(m.counter_value("a"), 0);
        assert_eq!(m.histogram_count("b"), 0);
    }
}
