//! Metrics: counters, timers, histograms, and CSV/JSON sinks.
//!
//! The trainer, the collectives and the bench harness all report through
//! this module so every experiment in EXPERIMENTS.md is regenerated from the
//! same measurement code path.

mod registry;
mod sink;

pub use registry::{Histogram, MetricsRegistry, TimerGuard};
pub use sink::{write_json, CsvWriter, JsonlWriter};
