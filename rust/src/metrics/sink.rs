//! File sinks for experiment outputs: CSV (bench tables, loss curves) and
//! JSONL (per-step structured records). Both create parent directories and
//! flush on drop so partial runs still leave usable artifacts.

use crate::util::json::Value;
use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

/// CSV writer with a fixed header row.
pub struct CsvWriter {
    out: BufWriter<File>,
    pub path: PathBuf,
    columns: usize,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(&path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self {
            out,
            path,
            columns: header.len(),
        })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            fields.len() == self.columns,
            "csv row has {} fields, header has {}",
            fields.len(),
            self.columns
        );
        let escaped: Vec<String> = fields
            .iter()
            .map(|f| {
                if f.contains(',') || f.contains('"') || f.contains('\n') {
                    format!("\"{}\"", f.replace('"', "\"\""))
                } else {
                    f.clone()
                }
            })
            .collect();
        writeln!(self.out, "{}", escaped.join(","))?;
        Ok(())
    }

    /// Convenience: mixed display row.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) -> anyhow::Result<()> {
        let v: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&v)
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

impl Drop for CsvWriter {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

/// Write one pretty-printed JSON document to `path`, creating parent
/// directories (bench summaries like `results/BENCH_pipeline.json`).
pub fn write_json(path: impl AsRef<Path>, v: &Value) -> anyhow::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    fs::write(path, v.to_string_pretty() + "\n")?;
    Ok(())
}

/// JSON-lines writer.
pub struct JsonlWriter {
    out: BufWriter<File>,
    pub path: PathBuf,
}

impl JsonlWriter {
    pub fn create(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        Ok(Self {
            out: BufWriter::new(File::create(&path)?),
            path,
        })
    }

    pub fn write(&mut self, v: &Value) -> anyhow::Result<()> {
        writeln!(self.out, "{}", v.to_string_compact())?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

impl Drop for JsonlWriter {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("mergecomp-test-{}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip() {
        let p = tmpdir().join("t.csv");
        {
            let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x,y".into()]).unwrap();
            w.rowd(&[&2, &"plain"]).unwrap();
        }
        let text = fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "1,\"x,y\"");
        assert_eq!(lines[2], "2,plain");
    }

    #[test]
    fn csv_rejects_bad_arity() {
        let p = tmpdir().join("t2.csv");
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        assert!(w.row(&["only-one".into()]).is_err());
    }

    #[test]
    fn jsonl_roundtrip() {
        let p = tmpdir().join("t.jsonl");
        {
            let mut w = JsonlWriter::create(&p).unwrap();
            w.write(&Value::from_pairs(vec![("step", Value::from(1usize))]))
                .unwrap();
            w.write(&Value::from_pairs(vec![("step", Value::from(2usize))]))
                .unwrap();
        }
        let text = fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = Value::parse(lines[1]).unwrap();
        assert_eq!(v.usize_or("step", 0), 2);
    }
}
