//! Data pipeline for the real training plane: a synthetic character-level
//! corpus with learnable structure, a byte tokenizer, and per-worker
//! sharded batching.
//!
//! The paper trains on CIFAR10/ImageNet/COCO; none are available offline,
//! so the end-to-end experiments (Figs. 7–8, Table 4) substitute a language
//! modeling task whose loss curve exposes exactly the same phenomenon —
//! whether compression + scheduling preserves optimization progress
//! (DESIGN.md §2 documents the substitution).

mod corpus;

pub use corpus::{Batcher, SyntheticCorpus, VOCAB};
