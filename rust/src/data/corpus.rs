//! Synthetic char-level corpus generator + sharded batcher.
//!
//! Text is produced by a seeded template grammar (subject-verb-object
//! sentences over a small vocabulary plus arithmetic facts), giving the LM
//! real n-gram structure to learn: loss drops fast from ln(V) and keeps
//! improving — the property Figs. 7–8 need to compare convergence speed.

use crate::util::rng::Xoshiro256;

/// Token space: printable ASCII 32..=126 mapped to 0..=94, plus newline=95.
/// Matches the vocab=96 of the e2e model config.
pub const VOCAB: usize = 96;

pub fn encode_char(c: u8) -> i32 {
    match c {
        b'\n' => 95,
        32..=126 => (c - 32) as i32,
        _ => 0, // space for anything exotic
    }
}

pub fn decode_token(t: i32) -> char {
    match t {
        95 => '\n',
        0..=94 => (t as u8 + 32) as char,
        _ => '?',
    }
}

const SUBJECTS: &[&str] = &[
    "the cat", "the dog", "a bird", "the queen", "my friend", "the robot",
    "a child", "the gradient", "the worker", "the model",
];
const VERBS: &[&str] = &[
    "sees", "likes", "chases", "finds", "compresses", "sends", "updates",
    "merges", "ignores", "trains",
];
const OBJECTS: &[&str] = &[
    "the ball", "a tree", "the tensor", "the river", "a song", "the moon",
    "the network", "a letter", "the garden", "the schedule",
];

/// A generated corpus of encoded tokens.
pub struct SyntheticCorpus {
    pub tokens: Vec<i32>,
}

impl SyntheticCorpus {
    /// Generate ~`target_len` tokens of template text.
    pub fn generate(seed: u64, target_len: usize) -> SyntheticCorpus {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut text = String::with_capacity(target_len + 64);
        while text.len() < target_len {
            match rng.gen_range(4) {
                // SVO sentence.
                0 | 1 => {
                    let s = SUBJECTS[rng.gen_range(SUBJECTS.len())];
                    let v = VERBS[rng.gen_range(VERBS.len())];
                    let o = OBJECTS[rng.gen_range(OBJECTS.len())];
                    text.push_str(&format!("{s} {v} {o}.\n"));
                }
                // Arithmetic fact (forces digit structure).
                2 => {
                    let a = rng.gen_range(10);
                    let b = rng.gen_range(10);
                    text.push_str(&format!("{a} plus {b} is {}.\n", a + b));
                }
                // Counting pattern (long-range repetition).
                _ => {
                    let start = rng.gen_range(20);
                    text.push_str(&format!(
                        "count {} {} {} {}.\n",
                        start,
                        start + 1,
                        start + 2,
                        start + 3
                    ));
                }
            }
        }
        let tokens = text.bytes().map(encode_char).collect();
        SyntheticCorpus { tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Per-worker batcher over a disjoint shard of the corpus. Yields
/// next-token-prediction pairs `(x, y)` with `y[t] = x[t+1]`, flattened as
/// `(batch * seq)` i32 vectors (the layout the PJRT literals use).
pub struct Batcher {
    shard: Vec<i32>,
    batch: usize,
    seq: usize,
    rng: Xoshiro256,
}

impl Batcher {
    /// Shard `corpus` across `world` workers, taking rank `rank`'s slice.
    pub fn new(
        corpus: &SyntheticCorpus,
        rank: usize,
        world: usize,
        batch: usize,
        seq: usize,
        seed: u64,
    ) -> Batcher {
        assert!(rank < world);
        let n = corpus.len();
        let per = n / world;
        assert!(
            per > seq + 1,
            "shard too small: {per} tokens for seq {seq}"
        );
        let shard = corpus.tokens[rank * per..(rank + 1) * per].to_vec();
        Batcher {
            shard,
            batch,
            seq,
            rng: Xoshiro256::seed_from_u64(seed ^ (rank as u64) << 32),
        }
    }

    /// Next (x, y) batch, each of length `batch * seq`.
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start = self.rng.gen_range(self.shard.len() - self.seq - 1);
            x.extend_from_slice(&self.shard[start..start + self.seq]);
            y.extend_from_slice(&self.shard[start + 1..start + self.seq + 1]);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let c = SyntheticCorpus::generate(1, 10_000);
        assert!(c.len() >= 10_000);
        assert!(c.tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn encode_decode_roundtrip() {
        for c in b' '..=b'~' {
            assert_eq!(decode_token(encode_char(c)) as u8, c);
        }
        assert_eq!(decode_token(encode_char(b'\n')), '\n');
    }

    #[test]
    fn deterministic_generation() {
        let a = SyntheticCorpus::generate(7, 5000);
        let b = SyntheticCorpus::generate(7, 5000);
        assert_eq!(a.tokens, b.tokens);
        let c = SyntheticCorpus::generate(8, 5000);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn corpus_has_ngram_structure() {
        // "the " must be frequent — the LM has something to learn.
        let c = SyntheticCorpus::generate(3, 50_000);
        let text: String = c.tokens.iter().map(|&t| decode_token(t)).collect();
        let count = text.matches("the ").count();
        assert!(count > 100, "only {count} occurrences of 'the '");
    }

    #[test]
    fn batcher_shapes_and_shift() {
        let c = SyntheticCorpus::generate(1, 100_000);
        let mut b = Batcher::new(&c, 0, 2, 4, 32, 9);
        let (x, y) = b.next_batch();
        assert_eq!(x.len(), 4 * 32);
        assert_eq!(y.len(), 4 * 32);
        // y is x shifted by one within each row.
        for row in 0..4 {
            for t in 0..31 {
                assert_eq!(y[row * 32 + t], x[row * 32 + t + 1]);
            }
        }
    }

    #[test]
    fn shards_are_disjoint() {
        let c = SyntheticCorpus::generate(1, 10_000);
        let b0 = Batcher::new(&c, 0, 2, 1, 16, 1);
        let b1 = Batcher::new(&c, 1, 2, 1, 16, 1);
        assert_eq!(b0.shard.len(), b1.shard.len());
        // Shards come from different halves (compare to the corpus halves).
        assert_eq!(b0.shard[..], c.tokens[..c.len() / 2]);
        assert_eq!(b1.shard[..], c.tokens[c.len() / 2..2 * (c.len() / 2)]);
    }

    #[test]
    #[should_panic(expected = "shard too small")]
    fn tiny_corpus_rejected() {
        let c = SyntheticCorpus::generate(1, 64);
        Batcher::new(&c, 0, 8, 1, 128, 1);
    }
}
