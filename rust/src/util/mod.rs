//! Foundation substrates built in-repo because the offline image carries no
//! `rand`, `serde`, `clap` or `proptest`: deterministic RNG, JSON, CLI
//! parsing, summary statistics/timing and a shrinking property-test harness.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Human-readable byte formatting used across logs and bench reports.
pub fn fmt_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(2.0), "2.000 s");
        assert!(fmt_secs(0.5e-3).contains("µs"));
        assert!(fmt_secs(5e-3).contains("ms"));
        assert!(fmt_secs(5e-8).contains("ns"));
    }
}
