//! Minimal property-based testing harness.
//!
//! The real `proptest` crate is not available in this offline image, so the
//! repository ships its own small harness with the same core loop: generate
//! random cases from a seedable RNG, run the property, and on failure
//! *shrink* the input towards a minimal counterexample before reporting.
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use mergecomp::util::proptest::{check, gens};
//! check("sum is commutative", 256, gens::vec_f32(0..1024, 1.0), |v| {
//!     let a: f32 = v.iter().sum();
//!     let b: f32 = v.iter().rev().sum();
//!     if (a - b).abs() <= 1e-3 * (1.0 + a.abs()) { Ok(()) } else { Err(format!("{a} != {b}")) }
//! });
//! ```

use super::rng::Xoshiro256;

/// A generator produces a random value and knows how to shrink it.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Xoshiro256) -> Self::Value;
    /// Candidate smaller inputs, most aggressive first. Default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `cases` random cases of the property; panic with the (shrunk) minimal
/// counterexample on failure. Seed is derived from the name so adding a test
/// does not perturb the cases of existing tests.
pub fn check<G: Gen>(
    name: &str,
    cases: usize,
    gen: G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let seed = fnv1a(name.as_bytes()) ^ env_seed();
    let mut rng = Xoshiro256::seed_from_u64(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            let (min_v, min_msg, steps) = shrink_loop(&gen, v, msg, &prop);
            panic!(
                "property '{name}' failed (case {case}/{cases}, seed {seed:#x}, \
                 shrunk {steps} steps):\n  input: {min_v:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut v: G::Value,
    mut msg: String,
    prop: &impl Fn(&G::Value) -> Result<(), String>,
) -> (G::Value, String, usize) {
    let mut steps = 0;
    'outer: loop {
        if steps > 2000 {
            break;
        }
        for cand in gen.shrink(&v) {
            if let Err(m) = prop(&cand) {
                v = cand;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (v, msg, steps)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// `MERGECOMP_PT_SEED` perturbs all property tests (fuzz-in-CI hook).
fn env_seed() -> u64 {
    std::env::var("MERGECOMP_PT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// Stock generators.
pub mod gens {
    use super::Gen;
    use crate::util::rng::Xoshiro256;
    use std::ops::Range;

    /// Uniform usize in a range; shrinks towards the lower bound.
    pub struct UsizeIn(pub Range<usize>);

    impl Gen for UsizeIn {
        type Value = usize;
        fn generate(&self, rng: &mut Xoshiro256) -> usize {
            self.0.start + rng.gen_range(self.0.end - self.0.start)
        }
        fn shrink(&self, v: &usize) -> Vec<usize> {
            let mut out = Vec::new();
            if *v > self.0.start {
                out.push(self.0.start);
                out.push(self.0.start + (v - self.0.start) / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        }
    }

    pub fn usize_in(r: Range<usize>) -> UsizeIn {
        UsizeIn(r)
    }

    /// Vec<f32> of random length with ~N(0, std) entries, occasionally spiked
    /// with zeros, denormals and large magnitudes to stress codecs.
    pub struct VecF32 {
        pub len: Range<usize>,
        pub std: f32,
    }

    impl Gen for VecF32 {
        type Value = Vec<f32>;
        fn generate(&self, rng: &mut Xoshiro256) -> Vec<f32> {
            let n = self.len.start + rng.gen_range((self.len.end - self.len.start).max(1));
            let mut v = vec![0f32; n];
            for x in v.iter_mut() {
                let roll = rng.gen_range(20);
                *x = match roll {
                    0 => 0.0,
                    1 => 1e-30,                       // denormal-ish
                    2 => (rng.next_f32() - 0.5) * 1e6, // large
                    _ => rng.normal_ms(0.0, self.std as f64) as f32,
                };
            }
            v
        }
        fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
            let mut out = Vec::new();
            let n = v.len();
            if n > self.len.start {
                // Halve, drop front half, drop back half, drop one element.
                out.push(v[..(n / 2).max(self.len.start)].to_vec());
                out.push(v[n / 2..].to_vec());
                out.push(v[..n - 1].to_vec());
            }
            // Zero out elements (values shrink to 0).
            if v.iter().any(|&x| x != 0.0) {
                out.push(v.iter().map(|_| 0.0).collect());
                let mut half = v.clone();
                for x in half.iter_mut() {
                    *x /= 2.0;
                }
                out.push(half);
            }
            out.retain(|c| c.len() >= self.len.start && c != v);
            out
        }
    }

    pub fn vec_f32(len: Range<usize>, std: f32) -> VecF32 {
        VecF32 { len, std }
    }

    /// Pair combinator.
    pub struct Pair<A, B>(pub A, pub B);

    impl<A: Gen, B: Gen> Gen for Pair<A, B> {
        type Value = (A::Value, B::Value);
        fn generate(&self, rng: &mut Xoshiro256) -> Self::Value {
            (self.0.generate(rng), self.1.generate(rng))
        }
        fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
            let mut out: Vec<Self::Value> = self
                .0
                .shrink(a)
                .into_iter()
                .map(|a2| (a2, b.clone()))
                .collect();
            out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
            out
        }
    }

    pub fn pair<A: Gen, B: Gen>(a: A, b: B) -> Pair<A, B> {
        Pair(a, b)
    }

    /// Vec<f64> of positive sizes — tensor-size sequences for partitions.
    pub struct TensorSizes {
        pub n: Range<usize>,
        pub max_size: usize,
    }

    impl Gen for TensorSizes {
        type Value = Vec<usize>;
        fn generate(&self, rng: &mut Xoshiro256) -> Vec<usize> {
            let n = self.n.start + rng.gen_range((self.n.end - self.n.start).max(1));
            (0..n)
                .map(|_| {
                    // Log-uniform sizes: DNN tensors span decades.
                    let log_max = (self.max_size.max(2) as f64).ln();
                    let s = (rng.next_f64() * log_max).exp() as usize;
                    s.max(1)
                })
                .collect()
        }
        fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
            let mut out = Vec::new();
            if v.len() > self.n.start {
                out.push(v[..v.len() - 1].to_vec());
                out.push(v[..(v.len() / 2).max(self.n.start)].to_vec());
            }
            if v.iter().any(|&s| s > 1) {
                out.push(v.iter().map(|&s| (s / 2).max(1)).collect());
                out.push(v.iter().map(|_| 1).collect());
            }
            out.retain(|c| c.len() >= self.n.start && c != v);
            out
        }
    }

    pub fn tensor_sizes(n: Range<usize>, max_size: usize) -> TensorSizes {
        TensorSizes { n, max_size }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("always passes", 50, gens::usize_in(0..10), |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics() {
        check("always fails", 10, gens::usize_in(0..10), |_| {
            Err("nope".into())
        });
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property: all values < 5. Counterexample should shrink to exactly 5.
        let result = std::panic::catch_unwind(|| {
            check("lt five", 200, gens::usize_in(0..1000), |&v| {
                if v < 5 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 5"))
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("input: 5"), "shrunk message: {msg}");
    }

    #[test]
    fn vec_shrinker_respects_min_len() {
        let g = gens::vec_f32(2..8, 1.0);
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(1);
        for _ in 0..50 {
            let v = g.generate(&mut rng);
            assert!(v.len() >= 2 && v.len() < 8);
            for s in g.shrink(&v) {
                assert!(s.len() >= 2);
            }
        }
    }

    #[test]
    fn tensor_sizes_positive() {
        let g = gens::tensor_sizes(1..50, 1 << 20);
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(2);
        for _ in 0..50 {
            let v = g.generate(&mut rng);
            assert!(!v.is_empty());
            assert!(v.iter().all(|&s| s >= 1));
        }
    }
}
