//! Summary statistics and timing helpers shared by metrics, the simulator
//! calibration code and the bench harness.

use std::time::{Duration, Instant};

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile on a *sorted* slice with linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sort a copy and take a percentile.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Ordinary least squares fit y = a + b*x. Returns (a, b, r2).
/// Used to fit the paper's Assumption 5 linear cost models
/// h(x) = B_h + γ_h x and g(x) = B_g + γ_g x from measurements.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points to fit a line");
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 {
        return (my, 0.0, 0.0);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    let _ = n;
    (a, b, r2)
}

/// Simple stopwatch around `Instant`.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 10.0);
        assert_eq!(r.n, 5);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn linfit_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linfit_degenerate_x() {
        let (a, b, _) = linfit(&[2.0, 2.0], &[5.0, 7.0]);
        assert_eq!(b, 0.0);
        assert_eq!(a, 6.0);
    }

    #[test]
    fn running_empty_is_nan() {
        assert!(Running::new().mean().is_nan());
    }
}
