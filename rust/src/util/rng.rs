//! Deterministic, seedable pseudo-random number generation.
//!
//! The offline build image does not ship the `rand` crate, so the library
//! carries its own small PRNG stack: [`SplitMix64`] for seeding and
//! [`Xoshiro256`] (xoshiro256**) as the workhorse generator. Both are the
//! reference algorithms by Blackman & Vigna and are more than adequate for
//! gradient synthesis, Rand-k sparsification, QSGD stochastic rounding, and
//! the property-test harness. Everything in the repository that needs
//! randomness takes an explicit `&mut Xoshiro256` so experiments are
//! bit-reproducible from a single seed.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm for k << n,
    /// partial shuffle otherwise). Result order is unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.gen_range(n - i);
                all.swap(i, j);
            }
            all.truncate(k);
            all
        } else {
            // Floyd: guarantees distinctness in O(k) expected inserts.
            let mut chosen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.gen_range(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            out
        }
    }

    /// Fill a slice with i.i.d. normal(0, std) f32 values — synthetic gradients.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_ms(0.0, std as f64) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vectors() {
        // Reference output for seed 1234567 (computed from the published
        // algorithm; stable across runs/platforms).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_both_paths() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for (n, k) in [(100, 5), (100, 80), (1, 1), (10, 10), (1000, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
