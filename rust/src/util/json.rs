//! Minimal JSON parser and writer.
//!
//! `serde`/`serde_json` are not available in the offline build image, so the
//! config system and all experiment/metrics outputs go through this module.
//! It implements the full JSON grammar (RFC 8259) with a recursive-descent
//! parser, plus an ergonomic [`Value`] accessor API and a pretty printer.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic output
/// ordering (experiment artifacts diff cleanly run-to-run).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ----- constructors ---------------------------------------------------
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ----- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed field lookup with defaults — the config system's workhorse.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Insert into an object value (panics on non-objects: programmer error).
    pub fn set(&mut self, key: &str, v: Value) -> &mut Self {
        match self {
            Value::Obj(m) => {
                m.insert(key.to_string(), v);
            }
            _ => panic!("Value::set on non-object"),
        }
        self
    }

    // ----- parse ----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ----- emit -----------------------------------------------------------
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Value::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * level {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; emit null like serde_json does.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(a)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid unicode escape"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf8"))?;
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("3.5").unwrap(), Value::Num(3.5));
        assert_eq!(Value::parse("-2e3").unwrap(), Value::Num(-2000.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("").is_err());
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("\"unterminated").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{\"a\":}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // Raw UTF-8 passthrough
        let v = Value::parse("\"héllo 😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 😀");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"nested":{"x":-1}}"#;
        let v = Value::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Value::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Value::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn escape_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.to_string_compact();
        assert_eq!(Value::parse(&s).unwrap(), v);
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let v = Value::parse(r#"{"n": 4, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.usize_or("n", 0), 4);
        assert_eq!(v.usize_or("missing", 7), 7);
        assert_eq!(v.str_or("s", "d"), "x");
        assert_eq!(v.f64_or("f", 0.0), 1.5);
        assert_eq!(v.as_usize(), None);
        assert_eq!(Value::Num(1.5).as_usize(), None, "non-integer");
        assert_eq!(Value::Num(-1.0).as_usize(), None, "negative");
    }

    #[test]
    fn builder_api() {
        let mut v = Value::obj();
        v.set("k", Value::from(3usize)).set("s", Value::from("v"));
        assert_eq!(v.usize_or("k", 0), 3);
        assert_eq!(v.to_string_compact(), r#"{"k":3,"s":"v"}"#);
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Value::Num(f64::NAN).to_string_compact(), "null");
    }
}
