//! Tiny CLI argument parser (the offline image has no `clap`).
//!
//! Grammar: `binary <subcommand> [positional ...] [--key value | --flag]`.
//! `--key=value` is also accepted. Unknown flags are collected and reported
//! by the caller so each subcommand can own its flag set.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    // Boolean flag.
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }

    /// Parsed numeric flag, `None` when absent or unparseable.
    pub fn usize(&self, key: &str) -> Option<usize> {
        self.str(key).and_then(|s| s.parse().ok())
    }

    /// Parsed numeric flag, `None` when absent or unparseable.
    pub fn f64(&self, key: &str) -> Option<f64> {
        self.str(key).and_then(|s| s.parse().ok())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.usize(key).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.f64(key).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.str(key), Some("true" | "1" | "yes"))
    }

    /// Comma-separated list flag, e.g. `--gpus 2,4,8`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.str(key) {
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated string list flag, e.g. `--codecs dgc,topk`.
    pub fn str_list(&self, key: &str) -> Option<Vec<String>> {
        self.str(key).map(|s| {
            s.split(',')
                .map(|p| p.trim().to_string())
                .filter(|p| !p.is_empty())
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["train", "conf.json", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.positional, vec!["conf.json", "extra"]);
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["sim", "--workers", "8", "--codec=dgc", "--verbose"]);
        assert_eq!(a.usize_or("workers", 1), 8);
        assert_eq!(a.str("codec"), Some("dgc"));
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert_eq!(a.str("a"), Some("true"));
        assert_eq!(a.str("b"), Some("v"));
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--gpus", "2,4,8", "--codecs", "dgc, topk"]);
        assert_eq!(a.usize_list_or("gpus", &[1]), vec![2, 4, 8]);
        assert_eq!(
            a.str_list("codecs").unwrap(),
            vec!["dgc".to_string(), "topk".to_string()]
        );
        assert_eq!(a.usize_list_or("missing", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn numeric_defaults() {
        let a = parse(&["x", "--lr", "0.1"]);
        assert_eq!(a.f64_or("lr", 0.0), 0.1);
        assert_eq!(a.f64_or("nope", 2.5), 2.5);
        assert_eq!(a.u64_or("seed", 42), 42);
    }

    #[test]
    fn optional_numeric_accessors() {
        let a = parse(&["x", "--eps", "0.05", "--interval", "25", "--bad", "zzz"]);
        assert_eq!(a.f64("eps"), Some(0.05));
        assert_eq!(a.usize("interval"), Some(25));
        assert_eq!(a.f64("missing"), None);
        assert_eq!(a.usize("bad"), None);
    }
}
