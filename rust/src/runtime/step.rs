//! A loaded, compiled train-step executable.

use super::meta::StepMeta;
use crate::util::stats::Stopwatch;
use std::path::Path;

/// One worker's handle to the AOT train step: a thread-local PJRT CPU
/// client + the compiled executable + the tensor-order contract.
pub struct TrainStep {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub meta: StepMeta,
    /// Wall-clock of the last `run` call (seconds) — feeds the measured
    /// cost models.
    pub last_exec_secs: f64,
}

impl TrainStep {
    /// Compile `hlo_path` (HLO text) on a fresh CPU client.
    pub fn load(hlo_path: impl AsRef<Path>, meta: StepMeta) -> anyhow::Result<TrainStep> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .as_ref()
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", hlo_path.as_ref().display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", hlo_path.as_ref().display()))?;
        Ok(TrainStep {
            client,
            exe,
            meta,
            last_exec_secs: 0.0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one train step.
    ///
    /// `params`: per-tensor f32 buffers in forward (param_spec) order.
    /// `x`, `y`: flattened `(batch*seq)` i32 token buffers.
    ///
    /// Returns `(loss, grads)` with grads in forward order.
    pub fn run(
        &mut self,
        params: &[Vec<f32>],
        x: &[i32],
        y: &[i32],
    ) -> anyhow::Result<(f32, Vec<Vec<f32>>)> {
        let m = &self.meta;
        anyhow::ensure!(
            params.len() == m.tensors.len(),
            "expected {} param tensors, got {}",
            m.tensors.len(),
            params.len()
        );
        anyhow::ensure!(x.len() == m.batch * m.seq_len, "x length");
        anyhow::ensure!(y.len() == m.batch * m.seq_len, "y length");

        let mut inputs = Vec::with_capacity(params.len() + 2);
        for (t, p) in m.tensors.iter().zip(params) {
            anyhow::ensure!(
                p.len() == t.elems,
                "tensor {}: {} elems, expected {}",
                t.name,
                p.len(),
                t.elems
            );
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(p).reshape(&dims).map_err(to_anyhow)?;
            inputs.push(lit);
        }
        let tok_dims = [m.batch as i64, m.seq_len as i64];
        inputs.push(xla::Literal::vec1(x).reshape(&tok_dims).map_err(to_anyhow)?);
        inputs.push(xla::Literal::vec1(y).reshape(&tok_dims).map_err(to_anyhow)?);

        let sw = Stopwatch::start();
        let result = self.exe.execute::<xla::Literal>(&inputs).map_err(to_anyhow)?;
        let out = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        self.last_exec_secs = sw.elapsed().as_secs_f64();

        // aot.py lowers with return_tuple=True: (loss, grad_0, ..., grad_T).
        let parts = out.to_tuple().map_err(to_anyhow)?;
        anyhow::ensure!(
            parts.len() == 1 + m.tensors.len(),
            "expected 1+{} outputs, got {}",
            m.tensors.len(),
            parts.len()
        );
        let mut it = parts.into_iter();
        let loss = it
            .next()
            .unwrap()
            .get_first_element::<f32>()
            .map_err(to_anyhow)?;
        let mut grads = Vec::with_capacity(m.tensors.len());
        for (t, lit) in m.tensors.iter().zip(it) {
            let v = lit.to_vec::<f32>().map_err(to_anyhow)?;
            anyhow::ensure!(
                v.len() == t.elems,
                "grad {}: {} elems, expected {}",
                t.name,
                v.len(),
                t.elems
            );
            grads.push(v);
        }
        Ok((loss, grads))
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}
