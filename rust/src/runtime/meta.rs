//! Artifact metadata: the tensor-order contract between the L2 model
//! (python/compile/model.py `param_spec`) and the rust trainer, serialized
//! by aot.py into `artifacts/meta.json`.

use crate::util::json::Value;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub elems: usize,
}

#[derive(Debug, Clone)]
pub struct StepMeta {
    pub tensors: Vec<TensorMeta>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
}

impl StepMeta {
    /// Load one config ("e2e", "pallas", "big") from meta.json.
    pub fn load(path: impl AsRef<Path>, which: &str) -> anyhow::Result<StepMeta> {
        let v = crate::config::load_json(path)?;
        let cfg = v
            .get(which)
            .ok_or_else(|| anyhow::anyhow!("meta.json has no '{which}' config"))?;
        Self::from_json(cfg)
    }

    pub fn from_json(cfg: &Value) -> anyhow::Result<StepMeta> {
        let tensors = cfg
            .get("tensors")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("meta: missing tensors array"))?
            .iter()
            .map(|t| {
                let name = t.str_or("name", "").to_string();
                let shape: Vec<usize> = t
                    .get("shape")
                    .and_then(Value::as_arr)
                    .map(|a| a.iter().filter_map(Value::as_usize).collect())
                    .unwrap_or_default();
                anyhow::ensure!(!name.is_empty(), "meta: tensor without a name");
                let elems = shape.iter().product::<usize>().max(1);
                Ok(TensorMeta { name, shape, elems })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(!tensors.is_empty(), "meta: empty tensor list");
        Ok(StepMeta {
            tensors,
            batch: cfg.usize_or("batch", 1),
            seq_len: cfg.usize_or("seq_len", 128),
            vocab: cfg.usize_or("vocab", 96),
            n_layers: cfg.usize_or("n_layers", 0),
            d_model: cfg.usize_or("d_model", 0),
            d_ff: cfg.usize_or("d_ff", 0),
        })
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.elems).sum()
    }

    /// Tensor sizes in backprop order (reverse of forward/param order) —
    /// what the partition scheduler consumes.
    pub fn sizes_backprop_order(&self) -> Vec<usize> {
        self.tensors.iter().rev().map(|t| t.elems).collect()
    }

    /// The matching simulator-plane profile (same tensor order), used to
    /// seed the schedule search before measured costs exist.
    pub fn to_profile(&self) -> crate::profiles::ModelProfile {
        crate::profiles::transformer_lm(
            self.n_layers,
            self.d_model,
            self.d_ff,
            self.vocab,
            self.seq_len,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Value {
        Value::parse(
            r#"{
              "n_layers": 1, "d_model": 8, "d_ff": 16, "vocab": 10,
              "seq_len": 4, "batch": 2,
              "tensors": [
                {"name": "embed.weight", "shape": [10, 8], "elems": 80},
                {"name": "head.weight", "shape": [8, 10], "elems": 80}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_meta() {
        let m = StepMeta::from_json(&sample_json()).unwrap();
        assert_eq!(m.tensors.len(), 2);
        assert_eq!(m.tensors[0].name, "embed.weight");
        assert_eq!(m.tensors[0].elems, 80);
        assert_eq!(m.total_params(), 160);
        assert_eq!(m.batch, 2);
        assert_eq!(m.sizes_backprop_order(), vec![80, 80]);
    }

    #[test]
    fn rejects_empty() {
        let v = Value::parse(r#"{"tensors": []}"#).unwrap();
        assert!(StepMeta::from_json(&v).is_err());
    }

    #[test]
    fn profile_matches_when_built_artifacts_exist() {
        let path = std::path::Path::new("artifacts/meta.json");
        if !path.exists() {
            return; // artifacts not built in this checkout
        }
        let m = StepMeta::load(path, "e2e").unwrap();
        let p = m.to_profile();
        assert_eq!(p.num_tensors(), m.tensors.len());
        assert_eq!(p.total_params(), m.total_params());
        // Same order, tensor for tensor.
        for (a, b) in p.tensors.iter().zip(&m.tensors) {
            assert_eq!(a.elems, b.elems, "{} vs {}", a.name, b.name);
        }
    }
}
