//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the rust hot path (pattern from /opt/xla-example/load_hlo).
//!
//! Python never runs at training time: `make artifacts` lowered the L2
//! train step once; this module compiles that text on the CPU PJRT client
//! and exposes a typed `TrainStep::run`.
//!
//! Thread model: the `xla` crate's client types are not `Send`, so each
//! worker thread owns its own `PjRtClient` + compiled executable (identical
//! HLO ⇒ identical semantics; compilation is per-thread one-off cost).

mod meta;
mod step;

pub use meta::{StepMeta, TensorMeta};
pub use step::TrainStep;
