//! Simulator-vs-trainer validation: compare the overlap the timeline
//! simulator *predicts* with the overlap the pipelined exchange engine
//! *measures*.
//!
//! The simulator's two-resource model splits communication into
//! `comm_total` and `comm_exposed` (the part not hidden under GPU-stream
//! work). Since the measured plane got its comm lane, [`ExchangeStats`]
//! reports the same split for real — so the paper's Eq. 7 overlap term is
//! now checkable against reality instead of being a modelling assumption.
//! `benches/pipeline_overlap.rs` emits both sides into
//! `results/BENCH_pipeline.json`.

use super::SimBreakdown;
use crate::coordinator::ExchangeStats;

/// One (simulated, measured) overlap comparison.
#[derive(Debug, Clone)]
pub struct OverlapValidation {
    /// Fraction of comm the simulator predicts is hidden.
    pub sim_overlap_frac: f64,
    /// Fraction of comm the trainer actually hid.
    pub measured_overlap_frac: f64,
    /// Simulated exposed comm per iteration (seconds).
    pub sim_comm_exposed: f64,
    /// Measured exposed comm per iteration (seconds).
    pub measured_comm_exposed: f64,
    /// `measured_overlap_frac - sim_overlap_frac`; negative means the real
    /// pipeline hides less than the model promises.
    pub gap: f64,
}

/// Compare a simulated iteration against measured per-step exchange stats
/// (use per-step means for multi-step runs).
pub fn compare_overlap(sim: &SimBreakdown, measured: &ExchangeStats) -> OverlapValidation {
    let sim_frac = if sim.comm_total > 0.0 {
        (sim.comm_total - sim.comm_exposed) / sim.comm_total
    } else {
        0.0
    };
    let meas_frac = measured.overlap_frac();
    OverlapValidation {
        sim_overlap_frac: sim_frac,
        measured_overlap_frac: meas_frac,
        sim_comm_exposed: sim.comm_exposed,
        measured_comm_exposed: measured.comm_exposed_secs,
        gap: meas_frac - sim_frac,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breakdown(comm_total: f64, comm_exposed: f64) -> SimBreakdown {
        SimBreakdown {
            iter_time: 1.0,
            compute: 0.5,
            encode_path: 0.1,
            decode_path: 0.1,
            comm_total,
            comm_exposed,
            group_events: vec![],
        }
    }

    #[test]
    fn fractions_and_gap() {
        let sim = breakdown(2.0, 0.5); // 75% hidden in the model
        let measured = ExchangeStats {
            comm_secs: 2.0,
            comm_exposed_secs: 1.0, // 50% hidden for real
            ..Default::default()
        };
        let v = compare_overlap(&sim, &measured);
        assert!((v.sim_overlap_frac - 0.75).abs() < 1e-12);
        assert!((v.measured_overlap_frac - 0.5).abs() < 1e-12);
        assert!((v.gap + 0.25).abs() < 1e-12);
    }

    #[test]
    fn zero_comm_is_zero_overlap() {
        let v = compare_overlap(&breakdown(0.0, 0.0), &ExchangeStats::default());
        assert_eq!(v.sim_overlap_frac, 0.0);
        assert_eq!(v.measured_overlap_frac, 0.0);
    }
}
