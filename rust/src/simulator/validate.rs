//! Simulator-vs-trainer validation: compare the overlap the timeline
//! simulator *predicts* with the overlap the pipelined exchange engine
//! *measures* — and validate the **online rescheduler** end to end against
//! an oracle under time-varying network conditions.
//!
//! The simulator's two-resource model splits communication into
//! `comm_total` and `comm_exposed` (the part not hidden under GPU-stream
//! work). Since the measured plane got its comm lane, [`ExchangeStats`]
//! reports the same split for real — so the paper's Eq. 7 overlap term is
//! now checkable against reality instead of being a modelling assumption.
//! `benches/pipeline_overlap.rs` emits both sides into
//! `results/BENCH_pipeline.json`.
//!
//! The online half ([`run_online_loop`]): a [`NetScenario`] drives an
//! exactly-affine synthetic measured plane ([`linear_plane`]); every step
//! the scheduler [`Driver`] is fed the per-group timings that plane
//! produces, and at each reschedule boundary it may re-search and switch.
//! Because the generator is exactly linear, the rolling EWMA fit converges
//! to the true post-drift coefficients, so a correct driver must reach the
//! *same* partition an oracle search over the true costs finds — while the
//! warmup-only baseline keeps the stale pre-drift partition. The per-step
//! iteration-time curves for online / warmup-only / oracle feed
//! `benches/online_resched.rs` (→ `results/BENCH_online.json`).

use super::SimBreakdown;
use crate::compression::{CodecKind, Collective};
use crate::coordinator::{ExchangeStats, GroupSample};
use crate::netsim::{Fabric, HierCost, NetScenario, RouteDepth, ThreeLevelFabric, TwoLevelFabric};
use crate::profiles::ModelProfile;
use crate::scheduler::costmodel::{CodecCostEntry, CodecCostModel, FittedCost, TwoLevelCost};
use crate::scheduler::objective::{AnalyticObjective, Objective as _};
use crate::scheduler::{
    mergecomp_search, CostEstimator, Decision, Driver, DriverConfig, Partition, SearchParams,
    ShardedCost,
};
use crate::simulator::OverheadModel;

/// One (simulated, measured) overlap comparison.
#[derive(Debug, Clone)]
pub struct OverlapValidation {
    /// Fraction of comm the simulator predicts is hidden.
    pub sim_overlap_frac: f64,
    /// Fraction of comm the trainer actually hid.
    pub measured_overlap_frac: f64,
    /// Simulated exposed comm per iteration (seconds).
    pub sim_comm_exposed: f64,
    /// Measured exposed comm per iteration (seconds).
    pub measured_comm_exposed: f64,
    /// `measured_overlap_frac - sim_overlap_frac`; negative means the real
    /// pipeline hides less than the model promises.
    pub gap: f64,
}

/// Compare a simulated iteration against measured per-step exchange stats
/// (use per-step means for multi-step runs).
pub fn compare_overlap(sim: &SimBreakdown, measured: &ExchangeStats) -> OverlapValidation {
    let sim_frac = if sim.comm_total > 0.0 {
        (sim.comm_total - sim.comm_exposed) / sim.comm_total
    } else {
        0.0
    };
    let meas_frac = measured.overlap_frac();
    OverlapValidation {
        sim_overlap_frac: sim_frac,
        measured_overlap_frac: meas_frac,
        sim_comm_exposed: sim.comm_exposed,
        measured_comm_exposed: measured.comm_exposed_secs,
        gap: meas_frac - sim_frac,
    }
}

// ---------------------------------------------------------------------------
// Online-scheduler validation plane
// ---------------------------------------------------------------------------

/// Exactly-affine per-codec cost triple on one fabric: what a drift-free
/// measurement of the system would fit. Decode covers the full group
/// including the allgather fan-in, so objectives built from it use
/// `dec_fanin = 1`.
#[derive(Debug, Clone, Copy)]
pub struct LinearPlane {
    pub enc: FittedCost,
    pub dec: FittedCost,
    pub comm: FittedCost,
}

/// Affine wire-size model `bytes(n) ≈ h + d·n` per codec — delegates to
/// the single source of truth, [`CodecKind::wire_affine`] (the exact
/// `wire_size` staircase without its sub-word rounding, so the synthetic
/// plane is exactly linear and the EWMA fit can recover it bit-for-bit).
fn affine_wire(kind: CodecKind) -> (f64, f64) {
    kind.wire_affine()
}

/// The true Assumption-5 coefficients for `kind` on `fabric` with `world`
/// workers: encode path (incl. EF decode) and full-group decode from the
/// calibrated [`OverheadModel`], collective cost from the textbook ring
/// formulas over the affine wire size.
pub fn linear_plane(kind: CodecKind, fabric: &Fabric, world: usize) -> LinearPlane {
    let m = OverheadModel::for_codec(kind);
    let ef = kind.uses_error_feedback();
    let enc = FittedCost {
        b: m.encode.b + if ef { m.decode.b } else { 0.0 },
        g: m.encode.g + if ef { m.decode.g } else { 0.0 },
        r2: 1.0,
    };
    let fanin = match kind.collective() {
        Collective::AllReduce => 1,
        Collective::AllGather => world.saturating_sub(1).max(1),
    };
    let dec = FittedCost {
        b: m.decode.b * fanin as f64,
        g: m.decode.g * fanin as f64,
        r2: 1.0,
    };
    let (h, d) = affine_wire(kind);
    let w = world as f64;
    let comm = if world <= 1 {
        FittedCost { b: 0.0, g: 0.0, r2: 1.0 }
    } else {
        let beta_eff = fabric.beta_eff(world);
        match kind.collective() {
            Collective::AllReduce => {
                let fac = 2.0 * (w - 1.0) / w;
                FittedCost {
                    b: 2.0 * (w - 1.0) * fabric.alpha + fac * h / beta_eff,
                    g: fac * d / beta_eff,
                    r2: 1.0,
                }
            }
            Collective::AllGather => FittedCost {
                b: (w - 1.0) * fabric.alpha + (w - 1.0) * h / beta_eff,
                g: (w - 1.0) * d / beta_eff,
                r2: 1.0,
            },
        }
    };
    LinearPlane { enc, dec, comm }
}

/// Affine comm model for `kind` on a two-level fabric under either route
/// (flat ring vs the two-level exchange), extracted from the
/// `netsim::hierarchy` cost functions. Exactly affine in elements as long
/// as the same level gates every flat-ring step — true whenever the inter
/// level is slower than intra, which is the whole point of the hierarchy.
/// Together with [`linear_plane`]'s enc/dec fits this builds the synthetic
/// measured plane for hierarchical-fabric scheduling experiments.
pub fn two_level_comm_fit(
    kind: CodecKind,
    two: &TwoLevelFabric,
    world: usize,
    hierarchical: bool,
) -> FittedCost {
    let (h, d) = affine_wire(kind);
    let secs = |elems: f64| {
        let wire = h + d * elems;
        match (kind.collective(), hierarchical) {
            (Collective::AllReduce, false) => two.flat_allreduce(world, wire).seconds,
            (Collective::AllReduce, true) => two.hier_allreduce(world, wire).seconds,
            (Collective::AllGather, false) => two.flat_allgather(world, wire).seconds,
            (Collective::AllGather, true) => two.hier_allgather(world, wire).seconds,
        }
    };
    let n1 = (1usize << 20) as f64;
    let s0 = secs(0.0);
    let s1 = secs(n1);
    FittedCost { b: s0, g: (s1 - s0) / n1, r2: 1.0 }
}

/// The synthetic ground truth for route-choice experiments on a two-level
/// fabric: the flat route's affine comm model plus the hierarchical
/// route's **per-level split** (`TwoLevelCost { intra, inter }` — exactly
/// the decomposition the estimator fits from `CommBreakdown` samples, so
/// a simulated measurement loop can feed the driver per-level timings and
/// compare its route choices against this oracle).
pub fn two_level_route_fits(
    kind: CodecKind,
    two: &TwoLevelFabric,
    world: usize,
) -> (FittedCost, TwoLevelCost) {
    let (h, d) = affine_wire(kind);
    let hier = |elems: f64| -> HierCost {
        let wire = h + d * elems;
        match kind.collective() {
            Collective::AllReduce => two.hier_allreduce(world, wire),
            Collective::AllGather => two.hier_allgather(world, wire),
        }
    };
    let n1 = (1usize << 20) as f64;
    let (c0, c1) = (hier(0.0), hier(n1));
    let fit = |a: f64, b: f64| FittedCost { b: a, g: (b - a) / n1, r2: 1.0 };
    (
        two_level_comm_fit(kind, two, world, false),
        TwoLevelCost {
            intra: fit(c0.intra_secs, c1.intra_secs),
            inter: fit(c0.inter_secs, c1.inter_secs),
        },
    )
}

/// Route-choice ground truth for an **allgather** codec on an explicitly
/// shaped two-level fabric (`node_sizes`, e.g. `[4, 2]` — the real split,
/// not the balanced approximation): affine `(flat, hier per-level split)`
/// models in *elements*.
///
/// Pricing follows the measured plane rather than the lockstep worst-link
/// model:
///
/// - **flat ring** (non-lockstep pipeline, which is what the tagged
///   transport actually runs): pipeline fill pays one latency per hop of
///   the ring — `(w−L)` intra hops plus `L` boundary hops — and steady
///   state moves the `w−1` payloads through the slowest link class.
/// - **hierarchical**: the leader *serializes* its fan — `(m−1)` receives
///   of `s` plus `(m−1)` sends of the full `w·s` table over the intra
///   fabric, with `m` the **largest** node — while the leader ring moves
///   `L−1` node frames of `m·s` over the inter fabric.
///
/// This is the regime where the route choice is real: the flat ring wins
/// small groups whenever `α_inter < (2m−2−w+L)·α_intra` (fewer serialized
/// hops), while the hierarchical exchange wins large groups as soon as
/// the inter bandwidth gap dominates — i.e. "inter-node cost dominates
/// for large groups only".
pub fn shaped_route_fits(
    kind: CodecKind,
    intra: &Fabric,
    inter: &Fabric,
    node_sizes: &[usize],
) -> (FittedCost, TwoLevelCost) {
    assert_eq!(
        kind.collective(),
        Collective::AllGather,
        "shaped_route_fits prices the allgather collectives"
    );
    let (h, d) = affine_wire(kind);
    let w = node_sizes.iter().sum::<usize>() as f64;
    let l = node_sizes.len() as f64;
    let m = node_sizes.iter().copied().max().unwrap_or(1) as f64;
    let slow_beta = inter.beta.min(intra.beta);
    let fit = |b: f64, g_per_byte: f64| FittedCost {
        b: b + g_per_byte * h,
        g: g_per_byte * d,
        r2: 1.0,
    };
    let flat = fit(
        (w - l) * intra.alpha + l * inter.alpha,
        (w - 1.0) / slow_beta,
    );
    let hier_intra = fit(
        2.0 * (m - 1.0) * intra.alpha,
        (m - 1.0) * (1.0 + w) / intra.beta,
    );
    let hier_inter = fit((l - 1.0) * inter.alpha, (l - 1.0) * m / inter.beta);
    (
        flat,
        TwoLevelCost {
            intra: hier_intra,
            inter: hier_inter,
        },
    )
}

/// Affine comm model for `kind` on a three-level fabric at the given
/// recursion depth — the three-route analogue of [`two_level_comm_fit`].
pub fn three_level_comm_fit(
    kind: CodecKind,
    three: &ThreeLevelFabric,
    world: usize,
    depth: RouteDepth,
) -> FittedCost {
    let (h, d) = affine_wire(kind);
    let secs = |elems: f64| {
        let wire = h + d * elems;
        let costs = match kind.collective() {
            Collective::AllReduce => [
                three.allreduce(world, wire, RouteDepth::Flat),
                three.allreduce(world, wire, RouteDepth::TwoLevel),
                three.allreduce(world, wire, RouteDepth::ThreeLevel),
            ],
            Collective::AllGather => three.allgather(world, wire),
        };
        match depth {
            RouteDepth::Flat => costs[0].seconds,
            RouteDepth::TwoLevel => costs[1].seconds,
            RouteDepth::ThreeLevel => costs[2].seconds,
        }
    };
    let n1 = (1usize << 20) as f64;
    let s0 = secs(0.0);
    let s1 = secs(n1);
    FittedCost { b: s0, g: (s1 - s0) / n1, r2: 1.0 }
}

/// One point of the sharded-vs-full exchange tradeoff on a flat fabric:
/// the same searched partition priced under both `--exchange-mode`s, plus
/// the per-rank optimizer-state footprint of each.
#[derive(Debug, Clone, Copy)]
pub struct ShardedTradeoff {
    /// Eq.-7 iteration seconds pricing the full allreduce exchange.
    pub full_secs: f64,
    /// The same partition priced as reduce-scatter + FP32 parameter
    /// allgather (what `--exchange-mode sharded` runs).
    pub sharded_secs: f64,
    /// Replicated per-rank momentum bytes under the full exchange.
    pub full_opt_bytes: u64,
    /// The largest rank's momentum shard under the sharded exchange.
    pub sharded_opt_bytes: u64,
}

/// The analytic ground truth for the sharded exchange's headline claim:
/// on a flat fabric with an uncompressed (FP32) stream, the textbook ring
/// allreduce IS a reduce-scatter followed by an allgather — so splitting
/// the update across ranks costs **zero** extra wall-clock while the
/// per-rank optimizer state shrinks by ~`world`. (Compressed codecs trade
/// some of that tie away: the parameter allgather stays uncompressed —
/// `objective.rs` unit-tests price that side.)
pub fn sharded_exchange_tradeoff(
    profile: &ModelProfile,
    fabric: &Fabric,
    world: usize,
    search: SearchParams,
) -> ShardedTradeoff {
    use crate::collectives::shard_elems;
    let plane = linear_plane(CodecKind::Fp32, fabric, world);
    let mut full = plane_objective(profile, &plane);
    let partition = mergecomp_search(&mut full, profile.num_tensors(), search).partition;
    let full_secs = full.eval(&partition);

    let mut sharded = plane_objective(profile, &plane);
    sharded.set_sharded_exchange(Some(ShardedCost {
        fp32_comm: plane.comm,
        base_codec: CodecKind::Fp32,
    }));
    let sharded_secs = sharded.eval(&partition);

    let sizes = profile.sizes_backprop_order();
    let total: usize = sizes.iter().sum();
    let group_elems: Vec<usize> = (0..partition.num_groups())
        .map(|j| partition.group_range(j).map(|i| sizes[i]).sum())
        .collect();
    let sharded_opt_bytes = (0..world)
        .map(|r| {
            group_elems
                .iter()
                .map(|&n| {
                    let (lo, hi) = shard_elems(n, world, r);
                    4 * (hi - lo) as u64
                })
                .sum::<u64>()
        })
        .max()
        .unwrap_or(0);
    ShardedTradeoff {
        full_secs,
        sharded_secs,
        full_opt_bytes: 4 * total as u64,
        sharded_opt_bytes,
    }
}

/// Eq.-7 objective for `profile` under the true costs of `plane`.
pub fn plane_objective(profile: &ModelProfile, plane: &LinearPlane) -> AnalyticObjective {
    let bwd = profile.iter_compute_s * (1.0 - profile.fwd_frac);
    let bwd_dur: Vec<f64> = profile
        .bwd_flop_shares()
        .into_iter()
        .map(|s| bwd * s)
        .collect();
    AnalyticObjective::new(
        bwd_dur,
        profile.sizes_backprop_order(),
        profile.iter_compute_s * profile.fwd_frac,
        plane.enc,
        plane.dec,
        plane.comm,
        1,
    )
}

// ---------------------------------------------------------------------------
// Codec-axis validation plane
// ---------------------------------------------------------------------------

/// A provably heterogeneous codec regime for the `(partition, codec)`
/// search: exactly-affine per-codec cost triples over a two-tensor model
/// where **no single codec is optimal everywhere**, so a mixed schedule
/// must strictly beat every forced one.
///
/// Construction (backprop order):
/// - tensor 0 is a comm-bound bulk (10^8 elems, grads ready almost
///   immediately) — FP32 moves 4 B/elem and pays seconds of wire time,
///   while the bitmap codec moves 1/32 of that: compression wins by a
///   wide margin despite its fixed encode cost;
/// - tensor 1 is a tiny tail (10^3 elems) whose backward compute is long —
///   its exchange sits fully exposed at the end of the step, and every
///   compressed codec's fixed encode cost dwarfs the few bytes FP32 would
///   have to move: not compressing wins.
///
/// The pool also carries a mid-rate sparse codec that is second-best on
/// both groups — a decoy that a correct joint search must reject on both.
/// Margins are engineered ≥5% under both overlapped and fully-serial
/// timeline semantics.
pub struct CodecRegime {
    /// Tensor element counts, backprop order.
    pub sizes: Vec<usize>,
    /// Per-tensor backward durations, backprop order (seconds).
    pub bwd_dur: Vec<f64>,
    /// The full candidate pool's cost model (no incumbent, no switch cost).
    pub model: CodecCostModel,
}

/// Build the regime. The [`CodecKind`]s are labels for the pool entries;
/// their costs here are synthetic affine planes, not the calibrated
/// [`OverheadModel`] — that keeps the winner provable by arithmetic.
pub fn heterogeneous_codec_regime() -> CodecRegime {
    let zero = FittedCost { b: 0.0, g: 0.0, r2: 1.0 };
    let entry = |kind: CodecKind, enc: FittedCost, comm: FittedCost| CodecCostEntry {
        kind,
        enc,
        dec: zero,
        comm,
        routes: None,
    };
    let model = CodecCostModel {
        entries: vec![
            // FP32: free encode, 4 B/elem on the wire.
            entry(CodecKind::Fp32, zero, FittedCost { b: 1e-3, g: 4e-8, r2: 1.0 }),
            // Bitmap EF codec: expensive fixed encode, 1/32 of the bytes.
            entry(
                CodecKind::EfSignSgd,
                FittedCost { b: 0.5, g: 1e-10, r2: 1.0 },
                FittedCost { b: 1e-3, g: 1.25e-9, r2: 1.0 },
            ),
            // Sparse decoy: mid encode cost, mid wire rate — second place
            // on both the bulk and the tail.
            entry(
                CodecKind::TopK { ratio: 0.01 },
                FittedCost { b: 0.2, g: 4e-9, r2: 1.0 },
                FittedCost { b: 1e-3, g: 3.2e-9, r2: 1.0 },
            ),
        ],
        switch_cost: 0.0,
        incumbent: Vec::new(),
    };
    CodecRegime {
        sizes: vec![100_000_000, 1_000],
        bwd_dur: vec![0.02, 3.0],
        model,
    }
}

impl CodecRegime {
    /// A fresh Eq.-7 objective over the regime's model shape with `model`
    /// attached as the codec axis (`None`: price everything as FP32).
    pub fn objective(&self, model: Option<CodecCostModel>) -> AnalyticObjective {
        let zero = FittedCost { b: 0.0, g: 0.0, r2: 1.0 };
        let fp32_comm = self
            .model
            .entry(CodecKind::Fp32)
            .map(|e| e.comm)
            .unwrap_or(zero);
        let mut obj = AnalyticObjective::new(
            self.bwd_dur.clone(),
            self.sizes.clone(),
            0.0,
            zero,
            zero,
            fp32_comm,
            1,
        );
        obj.set_codec_costs(model);
        obj
    }

    /// The model restricted to a single codec — what a forced
    /// `--codec <kind>` run prices every group with.
    pub fn forced(&self, kind: CodecKind) -> CodecCostModel {
        CodecCostModel {
            entries: self
                .model
                .entries
                .iter()
                .filter(|e| e.kind == kind)
                .cloned()
                .collect(),
            switch_cost: self.model.switch_cost,
            incumbent: Vec::new(),
        }
    }

    /// Every codec in the pool, entry order.
    pub fn pool(&self) -> Vec<CodecKind> {
        self.model.entries.iter().map(|e| e.kind).collect()
    }
}

/// One step of the online-vs-baselines comparison.
#[derive(Debug, Clone)]
pub struct OnlineStepPoint {
    pub step: usize,
    /// Iteration time of the driver's current partition under the true
    /// current costs.
    pub online_secs: f64,
    /// Same for the frozen warmup-only partition.
    pub warmup_secs: f64,
    /// Same for an oracle that re-searches whenever the fabric changes.
    pub oracle_secs: f64,
    pub online_groups: usize,
    pub epoch: u64,
}

/// Outcome of [`run_online_loop`].
#[derive(Debug)]
pub struct OnlineLoopReport {
    pub points: Vec<OnlineStepPoint>,
    /// The pre-drift search result every policy starts from.
    pub warmup_partition: Partition,
    /// The oracle's partition under the final fabric.
    pub oracle_final: Partition,
    /// The driver's partition at the end of the run.
    pub online_final: Partition,
    pub reschedules: usize,
    pub search_evals: usize,
    /// First step from which the online curve stays within `tol` of the
    /// oracle for the remainder of the run (None: never).
    pub converged_at: Option<usize>,
}

impl OnlineLoopReport {
    /// Mean of the last `window` steps of each curve:
    /// `(online, warmup, oracle)`.
    pub fn steady_state(&self, window: usize) -> (f64, f64, f64) {
        if self.points.is_empty() {
            return (f64::NAN, f64::NAN, f64::NAN);
        }
        let k = window.clamp(1, self.points.len());
        let tail = &self.points[self.points.len() - k..];
        let n = tail.len() as f64;
        (
            tail.iter().map(|p| p.online_secs).sum::<f64>() / n,
            tail.iter().map(|p| p.warmup_secs).sum::<f64>() / n,
            tail.iter().map(|p| p.oracle_secs).sum::<f64>() / n,
        )
    }
}

/// Drive the scheduler [`Driver`] through `steps` simulated steps of
/// `scenario` and compare it against the frozen warmup-only schedule and a
/// re-searching oracle. The measured plane is synthesized from
/// [`linear_plane`], i.e. drift-free and exactly affine, so convergence
/// failures are scheduler bugs, not noise.
pub fn run_online_loop(
    profile: &ModelProfile,
    kind: CodecKind,
    scenario: &NetScenario,
    world: usize,
    cfg: DriverConfig,
    steps: usize,
) -> OnlineLoopReport {
    let n = profile.num_tensors();
    let sizes = profile.sizes_backprop_order();
    let bwd_shares = profile.bwd_flop_shares();

    // Warmup: the one-shot search every policy starts from.
    let plane0 = linear_plane(kind, &scenario.fabric_at(0), world);
    let mut warm_obj = plane_objective(profile, &plane0);
    let warmup_partition = mergecomp_search(&mut warm_obj, n, cfg.search).partition;

    let est = CostEstimator::new(cfg.ewma, Some(plane0.enc), Some(plane0.dec), Some(plane0.comm));
    let mut driver = Driver::new(
        cfg,
        est,
        sizes.clone(),
        bwd_shares,
        profile.fwd_frac,
        warmup_partition.clone(),
    );

    let mut points = Vec::with_capacity(steps);
    let mut oracle_fabric = scenario.fabric_at(0);
    let mut oracle_partition = warmup_partition.clone();

    for step in 0..steps {
        let fabric = scenario.fabric_at(step);
        let plane = linear_plane(kind, &fabric, world);

        // Oracle re-searches whenever the fabric changes.
        if fabric != oracle_fabric {
            oracle_fabric = fabric;
            let mut obj = plane_objective(profile, &plane);
            oracle_partition = mergecomp_search(&mut obj, n, cfg.search).partition;
        }

        // Synthesize this step's measured per-group timings.
        let samples: Vec<GroupSample> = (0..driver.partition().num_groups())
            .map(|j| {
                let elems: usize = driver
                    .partition()
                    .group_range(j)
                    .map(|i| sizes[i])
                    .sum();
                GroupSample {
                    group: j,
                    elems,
                    route: crate::collectives::CommRoute::Flat,
                    codec: crate::compression::CodecKind::Fp32,
                    encode_secs: plane.enc.predict(elems),
                    comm_secs: plane.comm.predict(elems),
                    comm_exposed_secs: 0.0,
                    comm_inter_secs: 0.0,
                    decode_secs: plane.dec.predict(elems),
                }
            })
            .collect();
        driver.observe(&samples, profile.iter_compute_s);

        if driver.due(step) {
            if let Decision::Switch {
                partition,
                routes,
                codecs,
                ..
            } = driver.decide()
            {
                driver.apply(partition, routes, codecs);
            }
        }

        let mut truth = plane_objective(profile, &plane);
        points.push(OnlineStepPoint {
            step,
            online_secs: truth.eval(driver.partition()),
            warmup_secs: truth.eval(&warmup_partition),
            oracle_secs: truth.eval(&oracle_partition),
            online_groups: driver.partition().num_groups(),
            epoch: driver.epoch(),
        });
    }

    let tol = 5e-3;
    let converged_at = match points
        .iter()
        .rposition(|p| p.online_secs > p.oracle_secs * (1.0 + tol))
    {
        Some(last_bad) if last_bad + 1 >= points.len() => None,
        Some(last_bad) => Some(points[last_bad + 1].step),
        None => Some(0),
    };

    OnlineLoopReport {
        points,
        warmup_partition,
        oracle_final: oracle_partition,
        online_final: driver.partition().clone(),
        reschedules: driver.reschedules,
        search_evals: driver.search_evals,
        converged_at,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breakdown(comm_total: f64, comm_exposed: f64) -> SimBreakdown {
        SimBreakdown {
            iter_time: 1.0,
            compute: 0.5,
            encode_path: 0.1,
            decode_path: 0.1,
            comm_total,
            comm_exposed,
            group_events: vec![],
        }
    }

    #[test]
    fn fractions_and_gap() {
        let sim = breakdown(2.0, 0.5); // 75% hidden in the model
        let measured = ExchangeStats {
            comm_secs: 2.0,
            comm_exposed_secs: 1.0, // 50% hidden for real
            ..Default::default()
        };
        let v = compare_overlap(&sim, &measured);
        assert!((v.sim_overlap_frac - 0.75).abs() < 1e-12);
        assert!((v.measured_overlap_frac - 0.5).abs() < 1e-12);
        assert!((v.gap + 0.25).abs() < 1e-12);
    }

    #[test]
    fn zero_comm_is_zero_overlap() {
        let v = compare_overlap(&breakdown(0.0, 0.0), &ExchangeStats::default());
        assert_eq!(v.sim_overlap_frac, 0.0);
        assert_eq!(v.measured_overlap_frac, 0.0);
    }

    use crate::profiles::transformer::transformer_100m;
    use crate::scheduler::SearchParams;

    fn drift_cfg(interval: usize) -> DriverConfig {
        DriverConfig {
            interval,
            ewma: 0.25,
            hysteresis: 0.05,
            search: SearchParams { y_max: 3, alpha: 0.02 },
            min_samples: 4,
        }
    }

    /// The headline scenario (numerically sized so the stale schedule is
    /// >5% off post-drift): EFSignSGD on 8 workers, NVLink collapsing to
    /// PCIe-class bandwidth mid-run.
    fn headline_scenario(at_step: usize) -> NetScenario {
        NetScenario::fabric_step(Fabric::nvlink(), Fabric::pcie(), at_step)
    }

    #[test]
    fn online_loop_stays_put_without_drift() {
        let profile = transformer_100m();
        let scenario = NetScenario::Static(Fabric::pcie());
        let report = run_online_loop(
            &profile,
            CodecKind::EfSignSgd,
            &scenario,
            8,
            drift_cfg(5),
            40,
        );
        assert_eq!(report.reschedules, 0, "no drift must mean no switches");
        assert_eq!(report.online_final, report.warmup_partition);
        assert_eq!(report.converged_at, Some(0));
    }

    #[test]
    fn online_loop_converges_to_post_drift_oracle() {
        let profile = transformer_100m();
        let drift_at = 20;
        let interval = 10;
        let scenario = headline_scenario(drift_at);
        let report = run_online_loop(
            &profile,
            CodecKind::EfSignSgd,
            &scenario,
            8,
            drift_cfg(interval),
            120,
        );

        // The drift must actually change the optimum, and the driver must
        // adopt it.
        assert_ne!(
            report.warmup_partition, report.oracle_final,
            "scenario must move the optimal partition"
        );
        assert!(report.reschedules >= 1, "driver never repartitioned");
        assert!(report.search_evals > 0);

        // Convergence within K = 3 reschedule intervals of the drift.
        let deadline = drift_at + 3 * interval;
        match report.converged_at {
            Some(at) => assert!(
                at <= deadline,
                "converged at step {at}, deadline {deadline}"
            ),
            None => panic!("online schedule never converged to the oracle"),
        }

        // Steady state: online matches the oracle; the stale warmup-only
        // schedule pays > 5% (the acceptance margin the bench asserts too).
        let (online, warmup, oracle) = report.steady_state(20);
        assert!(
            online <= oracle * 1.01,
            "online {online} vs oracle {oracle}"
        );
        assert!(
            warmup > oracle * 1.05,
            "warmup-only {warmup} should be >5% over oracle {oracle}"
        );
    }

    #[test]
    fn hysteresis_suppresses_burst_thrash() {
        // Short congestion bursts that revert before the next reschedule
        // boundary: the estimator sees a mixture, and the hysteresis keeps
        // the schedule from flapping every interval.
        let profile = transformer_100m();
        let scenario = NetScenario::Bursts {
            base: Fabric::nvlink(),
            period: 10,
            burst_len: 2,
            beta_factor: 0.5,
        };
        let report = run_online_loop(
            &profile,
            CodecKind::EfSignSgd,
            &scenario,
            8,
            drift_cfg(10),
            100,
        );
        assert!(
            report.reschedules <= 2,
            "bursty noise caused {} switches",
            report.reschedules
        );
    }

    #[test]
    fn two_level_fit_rewards_the_hierarchical_route_and_moves_the_search() {
        let two = TwoLevelFabric::nvlink_tcp(2);
        let world = 8;
        for kind in [CodecKind::Fp32, CodecKind::EfSignSgd, CodecKind::Dgc { ratio: 0.01 }] {
            let flat = two_level_comm_fit(kind, &two, world, false);
            let hier = two_level_comm_fit(kind, &two, world, true);
            for n in [1usize << 14, 1 << 20, 1 << 24] {
                assert!(
                    hier.predict(n) < flat.predict(n),
                    "{} at {n}: hier {} vs flat {}",
                    kind.name(),
                    hier.predict(n),
                    flat.predict(n)
                );
            }
        }
        // The Eq.-7 search against each comm model: the two-level route's
        // optimum must beat the flat ring's on the same fabric.
        let profile = transformer_100m();
        let base = linear_plane(CodecKind::EfSignSgd, &Fabric::tcp(), world);
        let search = SearchParams { y_max: 3, alpha: 0.02 };
        let mut f_min = Vec::new();
        for hierarchical in [false, true] {
            let plane = LinearPlane {
                comm: two_level_comm_fit(CodecKind::EfSignSgd, &two, world, hierarchical),
                ..base
            };
            let mut obj = plane_objective(&profile, &plane);
            f_min.push(mergecomp_search(&mut obj, profile.num_tensors(), search).f_min);
        }
        assert!(
            f_min[1] < f_min[0],
            "two-level optimum {} should beat flat {}",
            f_min[1],
            f_min[0]
        );
    }

    #[test]
    fn shaped_route_fits_cross_over_with_group_size() {
        use crate::scheduler::costmodel::RouteCostModel;
        use crate::scheduler::RouteChoice;
        // world=6 split 4+2, NVLink intra, a low-latency thin inter pipe:
        // inter cost dominates large groups only, so the flat ring wins
        // small groups (fewer serialized hops) and the hierarchical
        // exchange wins large ones.
        let inter = Fabric::custom(30e-6, 1.2e9);
        let (flat, split) =
            shaped_route_fits(CodecKind::EfSignSgd, &Fabric::nvlink(), &inter, &[4, 2]);
        let rc = RouteCostModel { flat, hier: split.combined() };
        assert_eq!(rc.best(10_000).0, RouteChoice::Flat);
        assert_eq!(rc.best(4_000_000).0, RouteChoice::Hierarchical);
        assert!(!split.inter_dominates(10_000), "latency regime: intra fan dominates");
        assert!(split.inter_dominates(4_000_000), "bandwidth regime: inter dominates");
    }

    #[test]
    fn route_fits_split_sums_to_the_total_hier_cost() {
        let two = TwoLevelFabric::nvlink_tcp(2);
        for kind in [CodecKind::Fp32, CodecKind::EfSignSgd] {
            let (flat, split) = two_level_route_fits(kind, &two, 8);
            let total = two_level_comm_fit(kind, &two, 8, true);
            for n in [0usize, 1 << 14, 1 << 22] {
                let sum = split.intra.predict(n) + split.inter.predict(n);
                let rel = (sum - total.predict(n)).abs() / total.predict(n).max(1e-12);
                assert!(rel < 1e-9, "{} at {n}: split sum off by {rel}", kind.name());
            }
            // And the flat side matches the existing flat fit.
            let flat2 = two_level_comm_fit(kind, &two, 8, false);
            assert_eq!(flat, flat2);
        }
    }

    #[test]
    fn three_level_fabric_moves_the_searched_optimum_when_the_gap_flips() {
        let profile = transformer_100m();
        let world = 8;
        let search = SearchParams { y_max: 3, alpha: 0.02 };
        let base = linear_plane(CodecKind::EfSignSgd, &Fabric::tcp(), world);
        let f_for = |fabric: &ThreeLevelFabric, depth: RouteDepth| {
            let plane = LinearPlane {
                comm: three_level_comm_fit(CodecKind::EfSignSgd, fabric, world, depth),
                ..base
            };
            let mut obj = plane_objective(&profile, &plane);
            mergecomp_search(&mut obj, profile.num_tensors(), search).f_min
        };
        // Real WAN gap: each extra recursion level moves the optimum down.
        let wan = ThreeLevelFabric::nvlink_tcp_wan(2, 2);
        let (flat, two, three) = (
            f_for(&wan, RouteDepth::Flat),
            f_for(&wan, RouteDepth::TwoLevel),
            f_for(&wan, RouteDepth::ThreeLevel),
        );
        assert!(three < two, "three-level optimum {three} should beat two-level {two}");
        assert!(two < flat, "two-level optimum {two} should beat flat {flat}");
        // Gap flipped (the "WAN" is just rack fabric): the rack stage is
        // pure overhead and the searched optimum moves back to two-level.
        let no_gap =
            ThreeLevelFabric::new(Fabric::nvlink(), Fabric::tcp(), Fabric::tcp(), 2, 2);
        let two = f_for(&no_gap, RouteDepth::TwoLevel);
        let three = f_for(&no_gap, RouteDepth::ThreeLevel);
        assert!(
            two < three,
            "without a WAN gap two-level {two} should beat three-level {three}"
        );
    }

    #[test]
    fn heterogeneous_regime_rewards_a_mixed_codec_schedule() {
        use crate::compression::CodecKind::{EfSignSgd, Fp32};
        let regime = heterogeneous_codec_regime();
        let search = SearchParams { y_max: 2, alpha: 0.01 };
        let n = regime.sizes.len();

        let mut obj = regime.objective(Some(regime.model.clone()));
        let auto = mergecomp_search(&mut obj, n, search);
        // The joint search must split the model and mix: the bitmap codec
        // on the comm-bound bulk, FP32 on the exposed tail.
        assert_eq!(auto.partition.num_groups(), 2, "bulk and tail must split");
        assert_eq!(auto.codecs, vec![EfSignSgd, Fp32]);

        // ... and the mixed optimum strictly beats every forced codec —
        // by construction no single pool member is best on both groups.
        for kind in regime.pool() {
            let mut obj = regime.objective(Some(regime.forced(kind)));
            let forced = mergecomp_search(&mut obj, n, search);
            assert!(
                auto.f_min < forced.f_min * 0.95,
                "{}: forced {} vs mixed {}",
                kind.name(),
                forced.f_min,
                auto.f_min
            );
        }
    }

    #[test]
    fn sharded_exchange_saves_memory_without_losing_wall_clock() {
        let profile = transformer_100m();
        let world = 4;
        let t = sharded_exchange_tradeoff(
            &profile,
            &Fabric::pcie(),
            world,
            SearchParams { y_max: 3, alpha: 0.02 },
        );
        // FP32 on the flat ring: reduce-scatter + parameter allgather is
        // exactly the two phases of the ring allreduce — a wall-clock tie.
        let rel = (t.sharded_secs - t.full_secs).abs() / t.full_secs.max(1e-12);
        assert!(
            rel < 1e-12,
            "sharded {} vs full {} (rel {rel})",
            t.sharded_secs,
            t.full_secs
        );
        // ... while the per-rank optimizer state drops by ~world (the
        // largest shard carries at most one alignment chunk of slack).
        assert!(t.sharded_opt_bytes < t.full_opt_bytes, "no memory win");
        assert!(
            (t.sharded_opt_bytes as f64) < t.full_opt_bytes as f64 / (world as f64 - 1.0),
            "shard {} too large vs full {} / {}",
            t.sharded_opt_bytes,
            t.full_opt_bytes,
            world
        );
    }

    #[test]
    fn linear_plane_matches_fabric_scaling() {
        let fast = linear_plane(CodecKind::EfSignSgd, &Fabric::nvlink(), 8);
        let slow = linear_plane(CodecKind::EfSignSgd, &Fabric::pcie(), 8);
        assert!(slow.comm.g > 10.0 * fast.comm.g, "bandwidth term must scale");
        // Encode/decode are host-side: fabric-independent.
        assert_eq!(fast.enc.b, slow.enc.b);
        assert_eq!(fast.dec.g, slow.dec.g);
        // Single worker communicates nothing.
        let solo = linear_plane(CodecKind::Fp32, &Fabric::pcie(), 1);
        assert_eq!(solo.comm.b, 0.0);
        assert_eq!(solo.comm.g, 0.0);
    }
}
